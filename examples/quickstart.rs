//! Quickstart: the smallest end-to-end Tuna run.
//!
//! Builds a tiny performance database (offline component), runs the Btree
//! workload under TPP with the Tuna tuner attached (online component,
//! native query backend), and reports fast-memory saving vs performance
//! loss against the fast-memory-only baseline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{build_database, BuildParams};
use tuna::report::pct;

fn main() -> tuna::Result<()> {
    // 1. Offline: a small database (400 configs × 20 fast-memory sizes).
    let params = BuildParams {
        n_configs: 400,
        fractions: (0..20).map(|i| 1.0 - 0.04 * i as f32).collect(),
        ..BuildParams::default()
    };
    println!("building performance database ({} configs)...", params.n_configs);
    let db = Arc::new(build_database(&params));

    // 2. Online: Btree under TPP + Tuna, τ = 5%, period 2.5 s.
    let spec = RunSpec::new("Btree").with_intervals(200);
    let tuna_cfg = TunaConfig::default();
    println!("running {} for {} intervals...", spec.workload, spec.intervals);
    let baseline = coordinator::run_fm_only(&spec)?;
    let run = coordinator::run_tuna_native(&spec, db, &tuna_cfg)?;
    let loss = coordinator::overall_loss(&run.result, &baseline);

    println!();
    println!("Tuna on {}:", spec.workload);
    println!("  tuning decisions   : {}", run.decisions.len());
    println!("  mean FM saving     : {}", pct(run.mean_saving()));
    println!("  max  FM saving     : {}", pct(run.max_saving()));
    println!("  overall perf loss  : {} (target {})", pct(loss), pct(tuna_cfg.loss_target));
    println!("  promotions         : {}", run.result.total_promoted());
    println!("  demotions          : {}", run.result.total_demoted());
    assert!(run.mean_saving() > 0.0, "expected some fast-memory saving");
    println!("\nquickstart OK");
    Ok(())
}
