//! Capacity planning across all five Table 1 workloads — the headline
//! claim: Tuna + TPP saves 8.5% of fast memory on average (up to 16% for
//! Btree) at a 5% performance-loss target, vs the 5% Pond reports.
//!
//! Runs through the batched sweep executor: all five Tuna-managed
//! workload runs execute across threads, each compared against its own
//! memoized fast-memory-only baseline (5 baselines, computed once each).
//!
//! ```sh
//! cargo run --release --example capacity_planning
//! ```

use std::path::Path;
use std::sync::Arc;

use tuna::artifact::cells::SweepTable;
use tuna::artifact::ArtifactStore;
use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{run_sweep_with_cache, BaselineCache, SweepPolicy, SweepSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::report::{pct, Table};
use tuna::util::human_ns;
use tuna::workloads::{ALL_NAMES, TABLE1};

fn main() -> tuna::Result<()> {
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);
    let tuna_cfg = TunaConfig::default();

    let spec = SweepSpec::new(ALL_NAMES)
        .with_policies([SweepPolicy::Tuna])
        .with_intervals(300)
        .with_tuna(db, tuna_cfg);
    // The five fast-memory-only baselines persist in the artifact store:
    // rerunning this example re-simulates zero of them.
    let store = ArtifactStore::open(Path::new("artifacts/store"))?;
    let cache = BaselineCache::persistent(&store.baselines_dir())?;
    let res = run_sweep_with_cache(&spec, &cache)?;

    let mut t = Table::new(
        "Capacity planning: Tuna + TPP at τ = 5% (vs Pond's 5% saving)",
        &["Workload", "paper RSS", "mean FM saving", "max FM saving", "overall loss"],
    );
    let mut savings = Vec::new();
    for cell in &res.cells {
        let stats = cell.tuna.as_ref().expect("tuna cell stats");
        let rss = TABLE1
            .iter()
            .find(|w| w.name.eq_ignore_ascii_case(&cell.spec.workload))
            .unwrap()
            .paper_rss_gb;
        t.row(vec![
            cell.spec.workload.clone(),
            format!("{rss:.1} G"),
            pct(cell.saving),
            pct(1.0 - stats.min_fraction),
            pct(cell.loss),
        ]);
        savings.push(cell.saving);
    }
    t.print();
    let avg = savings.iter().sum::<f64>() / savings.len() as f64;
    println!("\naverage FM saving: {}  (paper: 8.5%)", pct(avg));
    println!(
        "sweep: {} workloads in {} (baselines: {} computed, {} cache hits, {} loaded from disk)",
        res.len(),
        human_ns(res.wall_ns as u64),
        res.baselines_computed,
        res.baseline_hits,
        res.baseline_disk_hits
    );
    let cells_path = store.sweep_path("capacity_planning");
    SweepTable::from_sweep(&res).save(&cells_path)?;
    println!("cells persisted to {}", cells_path.display());
    Ok(())
}
