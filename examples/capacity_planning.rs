//! Capacity planning across all five Table 1 workloads — the headline
//! claim: Tuna + TPP saves 8.5% of fast memory on average (up to 16% for
//! Btree) at a 5% performance-loss target, vs the 5% Pond reports.
//!
//! ```sh
//! cargo run --release --example capacity_planning
//! ```

use std::path::Path;
use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::report::{pct, Table};
use tuna::workloads::{ALL_NAMES, TABLE1};

fn main() -> tuna::Result<()> {
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);
    let tuna_cfg = TunaConfig::default();

    let mut t = Table::new(
        "Capacity planning: Tuna + TPP at τ = 5% (vs Pond's 5% saving)",
        &["Workload", "paper RSS", "mean FM saving", "max FM saving", "overall loss"],
    );
    let mut savings = Vec::new();
    for name in ALL_NAMES {
        let spec = RunSpec::new(name).with_intervals(300);
        let baseline = coordinator::run_fm_only(&spec)?;
        let run = coordinator::run_tuna_native(&spec, db.clone(), &tuna_cfg)?;
        let loss = coordinator::overall_loss(&run.result, &baseline);
        let rss = TABLE1.iter().find(|w| w.name == name).unwrap().paper_rss_gb;
        t.row(vec![
            name.to_string(),
            format!("{rss:.1} G"),
            pct(run.mean_saving()),
            pct(run.max_saving()),
            pct(loss),
        ]);
        savings.push(run.mean_saving());
        eprintln!("{name}: done");
    }
    t.print();
    let avg = savings.iter().sum::<f64>() / savings.len() as f64;
    println!("\naverage FM saving: {}  (paper: 8.5%)", pct(avg));
    Ok(())
}
