//! End-to-end driver (the EXPERIMENTS.md validation run): the full system
//! on a real small workload.
//!
//! All three layers compose here:
//!   L1/L2 — the AOT JAX+Pallas perf-DB query executable (HLO text from
//!           `make artifacts`) loaded and run via PJRT;
//!   L3    — the rust coordinator: BFS over a real synthetic power-law
//!           graph in the tiered-memory simulator under TPP, with the
//!           Tuna tuner reprogramming the reclaim watermarks every 2.5 s.
//!
//! Reports the paper's headline metric for BFS: fast-memory saving at a
//! 5% performance-loss target (paper: ~10.5% saving at 4.4% loss in the
//! motivation study; ~2% overall loss in §6.2).
//!
//! ```sh
//! make artifacts && cargo run --release --example tune_bfs
//! ```

use std::path::Path;
use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::perfdb::native::{NativeNn, NnQuery};
use tuna::report::{ascii_series, pct};
use tuna::runtime::XlaNn;

fn main() -> tuna::Result<()> {
    // Performance database: load the cached artifact or build it.
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);

    // Query backend: the AOT XLA executable if artifacts exist, else the
    // native oracle (with a warning — the point of this example is the
    // full three-layer stack).
    let artifacts = Path::new("artifacts");
    // `+ Send` because the query backend now sits behind the tuner
    // service, which may host it on a background aggregation thread.
    let (query, backend): (Box<dyn NnQuery + Send>, &str) =
        match XlaNn::from_manifest(artifacts, &db) {
            Ok(x) => (Box::new(x), "xla (AOT pallas kernel via PJRT)"),
            Err(e) => {
                eprintln!("WARNING: XLA backend unavailable ({e:#}); run `make artifacts`.");
                (Box::new(NativeNn::new(&db)), "native (fallback)")
            }
        };
    println!("query backend: {backend}");

    // The workload: BFS at paper scale (12.4 paper-GB RSS), 500 intervals
    // ≈ 50 paper-seconds, tuning every 2.5 s with τ = 5%.
    let spec = RunSpec::new("BFS").with_intervals(500);
    let tuna_cfg = TunaConfig::default();

    println!("baseline: BFS with all of RSS in fast memory...");
    let baseline = coordinator::run_fm_only(&spec)?;
    println!("tuned: BFS under TPP + Tuna...");
    let run = coordinator::run_tuna(&spec, db, query, &tuna_cfg)?;
    let loss = coordinator::overall_loss(&run.result, &baseline);

    // FM-fraction trace (Fig. 4-style series).
    let rss = run.result.trace[0].fast_used.max(1); // alloc epoch fills RSS
    let fm = coordinator::fm_fraction_series(&run.result, rss);
    let xs: Vec<f64> = (0..fm.len()).map(|i| i as f64 * 0.1).collect();
    println!("\n{}", ascii_series("fast-memory fraction over time (paper-s)", &xs, &fm, 8));

    println!("== headline (BFS, τ = 5%) ==");
    println!("  decisions          : {}", run.decisions.len());
    println!("  mean FM saving     : {}  (paper motivation: ~10.5%)", pct(run.mean_saving()));
    println!("  max  FM saving     : {}", pct(run.max_saving()));
    println!("  overall perf loss  : {}  (paper §6.2: 2%)", pct(loss));
    println!(
        "  promotions/failures: {}/{}",
        run.result.total_promoted(),
        run.result.total_promote_failed()
    );
    if !run.decisions.is_empty() {
        println!(
            "  query path/decision: {}",
            tuna::util::human_ns((run.decide_ns / run.decisions.len() as u128) as u64)
        );
    }

    assert!(run.mean_saving() > 0.03, "BFS should save >3% fast memory");
    assert!(loss < 0.10, "loss {loss} should be near the 5% target");
    println!("\ntune_bfs OK");
    Ok(())
}
