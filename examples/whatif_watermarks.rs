//! What-if exploration of §4's watermark mechanism without the tuner:
//! apply a manual fast-memory schedule (shrink → hold → shrink further →
//! restore) to BFS and watch kswapd demotions, promotion failures and
//! per-period loss respond.
//!
//! This is the example to read to understand *how* Tuna's only actuator
//! (the min/low/high reclaim watermarks) changes system behaviour.
//!
//! ```sh
//! cargo run --release --example whatif_watermarks
//! ```

use tuna::coordinator::{self, RunSpec};
use tuna::report::{ascii_series, pct, Table};
use tuna::sim::Engine;
use tuna::tpp::{Tpp, Watermarks};
use tuna::sim::{IntervalModel, MachineModel};
use tuna::workloads;

fn main() -> tuna::Result<()> {
    let spec = RunSpec::new("BFS").with_intervals(400);
    let baseline = coordinator::run_fm_only(&spec)?;

    // Manual schedule: fraction of RSS usable in fast memory.
    let schedule = [
        (0u32, 1.00f64),
        (50, 0.92),
        (150, 0.84),
        (250, 0.70), // aggressive — expect loss + failures
        (330, 0.95), // restore
    ];

    let mut w = workloads::by_name(&spec.workload, spec.seed, spec.intervals).unwrap();
    let rss = w.rss_pages() as u64;
    let cap = Engine::fm_capacity(w.rss_pages(), 1.0);
    let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
    let engine = Engine::new(IntervalModel::new(MachineModel::default()));
    let run = engine.run(w.as_mut(), &mut tpp, cap, |t| {
        schedule
            .iter()
            .find(|&&(at, _)| at == t.interval)
            .map(|&(_, frac)| {
                Watermarks::for_target_fm(cap, (rss as f64 * frac).ceil() as u64)
            })
    });

    let period = 25u32;
    let loss = coordinator::period_loss_series(&run, &baseline, period);
    let xs: Vec<f64> = (0..loss.len()).map(|i| (i as f64 + 1.0) * 2.5).collect();
    println!("{}", ascii_series("per-period loss (vs fast-only)", &xs, &loss, 8));

    let fm = coordinator::fm_fraction_series(&run, rss);
    let xf: Vec<f64> = (0..fm.len()).map(|i| i as f64 * 0.1).collect();
    println!("{}", ascii_series("usable FM fraction", &xf, &fm, 6));

    let mut t = Table::new(
        "watermark schedule response",
        &["phase start (s)", "FM fraction", "kswapd demotions", "promo failures", "period loss"],
    );
    for (i, &(at, frac)) in schedule.iter().enumerate() {
        let end = schedule.get(i + 1).map(|&(e, _)| e).unwrap_or(spec.intervals);
        let seg: Vec<_> = run
            .trace
            .iter()
            .filter(|tr| tr.interval > at && tr.interval <= end)
            .collect();
        let dem: u64 = seg.iter().map(|tr| tr.demoted_kswapd).sum();
        let fail: u64 = seg.iter().map(|tr| tr.promote_failed).sum();
        let t_run: f64 = seg.iter().map(|tr| tr.wall_ns).sum();
        let t_base: f64 = baseline
            .trace
            .iter()
            .filter(|tr| tr.interval > at && tr.interval <= end)
            .map(|tr| tr.wall_ns)
            .sum();
        t.row(vec![
            format!("{:.1}", at as f64 * 0.1),
            pct(frac),
            dem.to_string(),
            fail.to_string(),
            pct((t_run - t_base) / t_base),
        ]);
    }
    t.print();
    println!("\nwhatif_watermarks OK");
    Ok(())
}
