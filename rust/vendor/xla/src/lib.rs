//! Offline stub of the PJRT/XLA binding.
//!
//! The real binding links against a PJRT CPU plugin and executes the AOT
//! HLO artifact produced by `python/compile/aot.py`. This build image has
//! no PJRT runtime, so every entry point type-checks against the same API
//! surface but reports the runtime as unavailable at the first call
//! ([`PjRtClient::cpu`]). Callers treat that as "artifacts missing" and
//! fall back to the native brute-force query path, which is exactly the
//! behaviour the benches and tests gate on.

use std::fmt;

/// Error raised by every stubbed entry point.
#[derive(Debug)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Error {
            message: format!(
                "{what}: PJRT/XLA runtime unavailable in this offline build \
                 (native query backend is the supported fallback)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub of the PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
    }
}
