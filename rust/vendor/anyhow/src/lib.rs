//! Offline vendored subset of the `anyhow` API.
//!
//! The build image has no access to the crates.io registry, so this crate
//! provides the exact slice of `anyhow` the workspace uses: the [`Error`]
//! type (a context chain), the [`Result`] alias, the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option`. Semantics match upstream where it matters:
//! `{}` displays the outermost message, `{:#}` the whole chain separated
//! by `": "`, and any `std::error::Error + Send + Sync + 'static` converts
//! via `?`.

use std::fmt;

/// A string-backed error with a context chain (outermost message first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn io_errors_convert_and_keep_context() {
        let r: Result<String> =
            std::fs::read_to_string("/definitely/not/a/file").with_context(|| "reading input");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading input");
        assert!(format!("{e:#}").starts_with("reading input: "));
    }

    #[test]
    fn ensure_and_option_context() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
    }
}
