//! Integration tests: cross-module flows (coordinator over runtime +
//! perfdb + tuner), property tests on system invariants, and failure
//! injection on the artifact-loading path.

use std::path::Path;
use std::sync::Arc;

use tuna::artifact::cells::{diff, SweepTable};
use tuna::artifact::shard::{
    LazyShardedNn, LazyShardedPerfDb, ResidencyLimit, ShardedPerfDb,
};
use tuna::artifact::ArtifactStore;
use tuna::config::experiment::TunaConfig;
use tuna::coordinator::sweep::{
    run_sweep, run_sweep_with_cache, BaselineCache, SweepPolicy, SweepSpec,
};
use tuna::coordinator::{self, RunSpec};
use tuna::obs::{EventKind, Journal, Recorder, DEFAULT_RING_CAPACITY};
use tuna::outcome::{RetuneConfig, RetuneMode};
use tuna::perfdb::builder::{build_database, sample_config, BuildParams};
use tuna::perfdb::native::{dist2, NativeNn, NnQuery};
use tuna::perfdb::{normalize, store, PerfDb};
use tuna::runtime::XlaNn;
use tuna::service::{
    serve_stream, IngestOutput, Ingestor, NetServer, NetServerConfig, TunerService,
};
use tuna::sim::{Engine, IntervalModel, MachineModel, MigrationModel, RunResult};
use tuna::tpp::{Tpp, Watermarks};
use tuna::trace::{format as trace_format, gen as trace_gen};
use tuna::util::proptest::{check, check_u64_range};
use tuna::util::rng::Rng;
use tuna::workloads::{self, ALL_NAMES};

fn tiny_db() -> PerfDb {
    build_database(&BuildParams {
        n_configs: 24,
        fractions: vec![1.0, 0.9, 0.8, 0.7, 0.6],
        intervals: 4,
        warmup: 2,
        seed: 3,
        machine: MachineModel::default(),
        threads: 4,
    })
}

// ---------------------------------------------------------------------------
// end-to-end flows
// ---------------------------------------------------------------------------

#[test]
fn full_stack_tuna_run_on_every_workload() {
    let db = Arc::new(tiny_db());
    for name in ALL_NAMES {
        let spec = RunSpec::new(name).with_intervals(80);
        let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
        let run = coordinator::run_tuna_native(&spec, db.clone(), &cfg).unwrap();
        assert!(!run.decisions.is_empty(), "{name}: no decisions");
        assert!(run.mean_fraction > 0.2 && run.mean_fraction <= 1.0);
        // the watermark trace is consistent with the decisions
        let last_fm = run.result.trace.last().unwrap().usable_fm;
        assert!(last_fm > 0);
    }
}

#[test]
fn xla_backend_end_to_end_if_artifacts_present() {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let db = Arc::new(tiny_db());
    let spec = RunSpec::new("Btree").with_intervals(60);
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let query = Box::new(XlaNn::from_manifest(Path::new("artifacts"), &db).unwrap());
    let run = coordinator::run_tuna(&spec, db, query, &cfg).unwrap();
    assert_eq!(run.backend, "xla");
    assert!(!run.decisions.is_empty());
}

#[test]
fn baseline_ordering_tpp_beats_first_touch_beats_nothing() {
    // 240 intervals: long enough that steady state dominates the
    // migration warm-up transient (matches the Fig. 1 bench setup)
    let spec = RunSpec::new("BFS").with_intervals(240).with_fraction(0.8);
    let base = coordinator::run_fm_only(&spec).unwrap();
    let tpp = coordinator::run_tpp(&spec).unwrap();
    let ft = coordinator::run_first_touch(&spec).unwrap();
    let l_tpp = coordinator::overall_loss(&tpp, &base);
    let l_ft = coordinator::overall_loss(&ft, &base);
    assert!(l_tpp < l_ft, "TPP {l_tpp} must beat first-touch {l_ft}");
    assert!(l_tpp > -0.02, "TPP can't beat the fast-only baseline");
}

// ---------------------------------------------------------------------------
// sweep executor
// ---------------------------------------------------------------------------

#[test]
fn sweep_parallel_is_bit_identical_to_serial() {
    let grid = |threads: usize| {
        let spec = SweepSpec::new(["BFS", "Btree"])
            .with_fractions([0.9, 0.7])
            .with_policies([SweepPolicy::Tpp, SweepPolicy::FirstTouch])
            .with_intervals(30)
            .with_threads(threads);
        run_sweep(&spec).unwrap()
    };
    let serial = grid(1);
    let parallel = grid(4);
    assert_eq!(serial.len(), 8);
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.spec.workload, b.spec.workload, "cell order must be grid order");
        assert_eq!(a.spec.policy, b.spec.policy);
        assert_eq!(
            a.result.total_ns.to_bits(),
            b.result.total_ns.to_bits(),
            "{} {:?} @ {}: thread count changed the simulation",
            a.spec.workload,
            a.spec.policy,
            a.spec.fm_fraction
        );
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.result.total_migrations(), b.result.total_migrations());
    }
}

#[test]
fn sweep_memoizes_baselines_and_runs_tuna_cells() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let spec = SweepSpec::new(["Btree"])
        .with_fractions([0.9, 0.8])
        .with_policies([SweepPolicy::Tpp, SweepPolicy::Tuna])
        .with_intervals(60)
        .with_tuna(db, cfg);
    let res = run_sweep(&spec).unwrap();
    // 2 fractions × Tpp + 1 Tuna cell (the fraction axis collapses for
    // Tuna, which always starts at 100% and shrinks).
    assert_eq!(res.len(), 3);
    assert_eq!(res.baselines_computed, 1, "all cells share one baseline");
    assert_eq!(res.baseline_hits, 3);
    let tuna_cell = res.cell("Btree", SweepPolicy::Tuna, 1.0).unwrap();
    let stats = tuna_cell.tuna.as_ref().expect("tuna cells carry stats");
    assert!(stats.decisions > 0);
    assert!(stats.mean_fraction > 0.2 && stats.mean_fraction <= 1.0);
    assert!((tuna_cell.saving - (1.0 - stats.mean_fraction)).abs() < 1e-12);
    assert!(res.cells.iter().all(|c| c.loss.is_finite()));
}

// ---------------------------------------------------------------------------
// tuner-as-a-service determinism
// ---------------------------------------------------------------------------

fn assert_decisions_bit_identical(
    a: &[tuna::tuner::Decision],
    b: &[tuna::tuner::Decision],
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}: decision count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.interval, y.interval, "{ctx}: interval");
        assert_eq!(x.record, y.record, "{ctx}: record");
        assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "{ctx}: dist");
        assert_eq!(x.fraction.to_bits(), y.fraction.to_bits(), "{ctx}: fraction");
        assert_eq!(x.new_fm, y.new_fm, "{ctx}: new_fm");
        assert_eq!(
            x.predicted_loss.to_bits(),
            y.predicted_loss.to_bits(),
            "{ctx}: predicted_loss"
        );
    }
}

/// Acceptance: the service's channel path must produce bit-identical
/// decisions (and therefore bit-identical runs — watermark feedback
/// shapes every subsequent interval) to the classic in-loop tuner, for
/// every Table-1 workload, in both inline and background-thread modes.
#[test]
fn service_decisions_bit_identical_to_inloop_for_every_workload() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    for name in ALL_NAMES {
        let spec = RunSpec::new(name).with_intervals(50);
        let inloop = coordinator::run_tuna_inloop(
            &spec,
            db.clone(),
            Box::new(NativeNn::new(&db)),
            &cfg,
        )
        .unwrap();
        assert!(!inloop.decisions.is_empty(), "{name}: reference run must decide");
        // inline service (what run_tuna now is)
        let inline_run = coordinator::run_tuna_native(&spec, db.clone(), &cfg).unwrap();
        // channel service: samples cross a bounded channel to the
        // aggregation thread; decisions come back through the mailbox
        let channel_run = {
            let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
            coordinator::run_tuna_service(&spec, &service, &cfg).unwrap()
        };
        for (mode, run) in [("inline", &inline_run), ("channel", &channel_run)] {
            let ctx = format!("{name}/{mode}");
            assert_decisions_bit_identical(&inloop.decisions, &run.decisions, &ctx);
            assert_eq!(
                inloop.result.total_ns.to_bits(),
                run.result.total_ns.to_bits(),
                "{ctx}: tuned run trace must be bit-identical"
            );
            assert_eq!(inloop.vmstat, run.vmstat, "{ctx}: vmstat");
            assert_eq!(
                inloop.mean_fraction.to_bits(),
                run.mean_fraction.to_bits(),
                "{ctx}: mean fraction"
            );
        }
    }
}

/// Acceptance: all Tuna cells of a sweep share one channel service, and
/// the results are bit-identical for any thread count — and to the
/// in-loop reference path.
#[test]
fn sweep_tuna_cells_share_service_and_stay_deterministic() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let sweep_at = |threads: usize| {
        let spec = SweepSpec::new(["BFS", "Btree"])
            .with_policies([SweepPolicy::Tuna])
            .with_seeds([1, 2])
            .with_intervals(40)
            .with_threads(threads)
            .with_tuna(db.clone(), cfg.clone());
        run_sweep(&spec).unwrap()
    };
    let serial = sweep_at(1);
    let parallel = sweep_at(4);
    assert_eq!(serial.len(), 4, "2 workloads x 2 seeds, fraction axis collapsed");
    assert_eq!(serial.len(), parallel.len());
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        let ctx = format!("{} seed {}", a.spec.workload, a.spec.seed);
        assert_eq!(
            a.result.total_ns.to_bits(),
            b.result.total_ns.to_bits(),
            "{ctx}: thread count changed a Tuna cell"
        );
        let (sa, sb) = (a.tuna.as_ref().unwrap(), b.tuna.as_ref().unwrap());
        assert_eq!(sa.decisions, sb.decisions, "{ctx}");
        assert_eq!(sa.mean_fraction.to_bits(), sb.mean_fraction.to_bits(), "{ctx}");
        assert_eq!(sa.min_fraction.to_bits(), sb.min_fraction.to_bits(), "{ctx}");

        // and every cell matches the pre-service in-loop path exactly
        let rs = RunSpec::new(&a.spec.workload)
            .with_intervals(40)
            .with_seed(a.spec.seed);
        let reference =
            coordinator::run_tuna_inloop(&rs, db.clone(), Box::new(NativeNn::new(&db)), &cfg)
                .unwrap();
        assert_eq!(
            a.result.total_ns.to_bits(),
            reference.result.total_ns.to_bits(),
            "{ctx}: sweep cell diverged from the in-loop reference"
        );
        assert_eq!(sa.decisions, reference.decisions.len(), "{ctx}");
        assert_eq!(
            sa.mean_fraction.to_bits(),
            reference.mean_fraction.to_bits(),
            "{ctx}"
        );
    }
}

/// Acceptance: `tuna serve` replaying a recorded sample stream produces
/// the same decisions as the run that recorded it.
#[test]
fn serve_replay_reproduces_recorded_decisions() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let spec = RunSpec::new("Btree").with_intervals(60);

    // live run, tapping the stream exactly as `tuna tune --record` does
    let mut stream = String::new();
    let live = {
        let service = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        coordinator::run_tuna_service_tapped(&spec, &service, &cfg, |ev| {
            stream.push_str(&ev.to_line());
            stream.push('\n');
        })
        .unwrap()
    };
    assert!(!live.decisions.is_empty());

    // replay through a fresh channel service, as `tuna serve` does
    let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
    let mut ingestor = Ingestor::new(&service, cfg.clone());
    let mut decisions = Vec::new();
    let mut report = None;
    let stats = ingestor
        .ingest(stream.as_bytes(), |out| match out {
            IngestOutput::Decision { interval, usable_fm, .. } => {
                decisions.push((interval, usable_fm));
            }
            IngestOutput::Closed(r) => report = Some(r),
        })
        .unwrap();
    assert_eq!(stats.sessions_opened, 1);
    assert_eq!(stats.sessions_closed, 1);
    assert_eq!(stats.samples, 60);
    assert_eq!(stats.decisions as usize, live.decisions.len());

    let report = report.expect("close line must produce the session report");
    assert_eq!(report.samples, 60);
    assert_decisions_bit_identical(&live.decisions, &report.decisions, "serve replay");
    assert_eq!(report.vmstat, live.vmstat, "replayed vmstat counters");
    // each replayed decision reprogrammed the same usable fast memory at
    // the same interval the live run did
    assert_eq!(decisions.len(), live.decisions.len());
    for (d, (interval, usable_fm)) in live.decisions.iter().zip(&decisions) {
        assert_eq!(d.interval, *interval);
        assert_eq!(d.new_fm, *usable_fm);
    }
}

// ---------------------------------------------------------------------------
// fleet-scale serving: sharded aggregation workers + network ingestion
// ---------------------------------------------------------------------------

/// Acceptance (ISSUE 10): the sharded service is bit-identical to
/// [`TunerService::inline`] across the full matrix — worker counts
/// {1, 3, 8} × migration models {exclusive, non-exclusive} × retune
/// {off, observe} — in decisions, engine traces, vmstat and session
/// reports. Session names (`workload@seed`) hash-route across workers,
/// so the 4-session set genuinely spans the shards at 3 and 8.
#[test]
fn sharded_service_matrix_is_bit_identical_to_inline() {
    let db = Arc::new(tiny_db());
    let sessions: Vec<RunSpec> = ["BFS", "kv-drift"]
        .iter()
        .flat_map(|w| [1u64, 2].map(|seed| RunSpec::new(*w).with_intervals(40).with_seed(seed)))
        .collect();
    for migration in [MigrationModel::Exclusive, MigrationModel::non_exclusive_default()] {
        for mode in [RetuneMode::Off, RetuneMode::Observe] {
            let cfg = TunaConfig {
                period_s: 1.0,
                retune: RetuneConfig { mode, ..RetuneConfig::default() },
                ..TunaConfig::default()
            };
            // reference: every session on its own inline service
            let reference: Vec<_> = sessions
                .iter()
                .map(|s| {
                    let spec = s.clone().with_migration(migration);
                    let service =
                        TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
                    coordinator::run_tuna_service(&spec, &service, &cfg).unwrap()
                })
                .collect();
            assert!(
                reference.iter().all(|r| !r.decisions.is_empty()),
                "reference sessions must decide"
            );
            for workers in [1usize, 3, 8] {
                let service = TunerService::spawn_sharded(
                    db.clone(),
                    |_| Box::new(NativeNn::new(&db)),
                    workers,
                );
                assert_eq!(service.workers(), workers);
                for (s, want) in sessions.iter().zip(&reference) {
                    let spec = s.clone().with_migration(migration);
                    let got = coordinator::run_tuna_service(&spec, &service, &cfg).unwrap();
                    let ctx = format!(
                        "{}@{} {migration:?}/{mode:?} workers={workers}",
                        spec.workload, spec.seed
                    );
                    assert_decisions_bit_identical(&want.decisions, &got.decisions, &ctx);
                    assert_eq!(
                        run_digest(&want.result),
                        run_digest(&got.result),
                        "{ctx}: engine trace"
                    );
                    assert_eq!(want.vmstat, got.vmstat, "{ctx}: vmstat");
                    assert_eq!(
                        want.mean_fraction.to_bits(),
                        got.mean_fraction.to_bits(),
                        "{ctx}: mean fraction"
                    );
                    assert_eq!(
                        want.min_fraction.to_bits(),
                        got.min_fraction.to_bits(),
                        "{ctx}: min fraction"
                    );
                    assert_eq!(want.outcomes.len(), got.outcomes.len(), "{ctx}: outcomes");
                    assert_eq!(want.retunes, got.retunes, "{ctx}: retunes");
                }
            }
        }
    }
}

/// Acceptance (ISSUE 10): a recorded telemetry stream served over TCP
/// (`tuna serve --listen`, 4 aggregation workers) yields byte-identical
/// decision lines to single-worker file replay (`tuna serve FILE`).
#[test]
fn net_serve_round_trip_matches_file_replay_on_recorded_streams() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };

    // record two live sessions exactly as `tuna tune --record` does
    let mut stream = String::new();
    for (name, seed) in [("Btree", 7u64), ("BFS", 9)] {
        let spec = RunSpec::new(name).with_intervals(50).with_seed(seed);
        let service = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let live = coordinator::run_tuna_service_tapped(&spec, &service, &cfg, |ev| {
            stream.push_str(&ev.to_line());
            stream.push('\n');
        })
        .unwrap();
        assert!(!live.decisions.is_empty());
    }

    // reference: single-worker file-mode replay, rendered with the same
    // `IngestOutput::render_lines` the network server writes back
    let mut file_mode = String::new();
    {
        let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        let mut ingestor = Ingestor::new(&service, cfg.clone());
        let mut sink = |out: IngestOutput| file_mode.push_str(&out.render_lines());
        ingestor.ingest(stream.as_bytes(), &mut sink).unwrap();
        ingestor.finish_all(&mut sink).unwrap();
    }
    assert!(file_mode.contains("decision ") && file_mode.contains("closed "));

    // network: the same stream through one TCP connection against a
    // 4-worker sharded service
    let service =
        TunerService::spawn_sharded(db.clone(), |_| Box::new(NativeNn::new(&db)), 4);
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetServerConfig { cfg: cfg.clone(), max_conns: 1, ..NetServerConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let mut replies = String::new();
    std::thread::scope(|scope| {
        let server = &server;
        let service = &service;
        let handle = scope.spawn(move || server.serve(service).unwrap());
        let report = serve_stream(&addr, stream.as_bytes(), |line| {
            replies.push_str(line);
            replies.push('\n');
        })
        .unwrap();
        assert!(report.sent_lines > 0 && report.reply_lines > 0);
        let stats = handle.join().unwrap();
        assert_eq!(stats.connections, 1);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.samples, 100);
    });
    assert_eq!(
        replies, file_mode,
        "TCP round trip must be byte-identical to file-mode replay"
    );
}

// ---------------------------------------------------------------------------
// bounded-resident lazy perf DB behind the tuner service
// ---------------------------------------------------------------------------

/// Acceptance: a shared channel-mode service backed by a *lazy* sharded
/// DB capped at ONE resident segment, hammered by concurrent sessions,
/// must reach decisions (and whole engine traces) bit-identical to the
/// flat in-memory backend — while the residency accounting proves the
/// cap was honored and every segment's CRC ran exactly once.
#[test]
fn lazy_capped_service_matches_flat_decisions_under_concurrent_sessions() {
    let db = Arc::new(tiny_db());
    let dir = std::env::temp_dir().join(format!("tuna_it_lazy_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    ShardedPerfDb::from_flat(&db, 4).save(&dir).unwrap();
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let specs: Vec<RunSpec> = ["BFS", "Btree"]
        .iter()
        .flat_map(|w| {
            [1u64, 2].map(|seed| RunSpec::new(w).with_intervals(40).with_seed(seed))
        })
        .collect();

    // flat reference, one session at a time
    let reference: Vec<_> = specs
        .iter()
        .map(|spec| coordinator::run_tuna_native(spec, db.clone(), &cfg).unwrap())
        .collect();
    assert!(reference.iter().all(|r| !r.decisions.is_empty()));

    // lazy: every session shares one channel service and one segment
    // cache capped at a single resident segment
    let lazy = Arc::new(LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap());
    let service = TunerService::spawn(lazy.clone(), Box::new(LazyShardedNn::new(lazy.clone(), 1)));
    let concurrent: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = specs
            .iter()
            .map(|spec| {
                let service = &service;
                let cfg = &cfg;
                s.spawn(move || coordinator::run_tuna_service(spec, service, cfg).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (a, b) in reference.iter().zip(&concurrent) {
        assert_decisions_bit_identical(&a.decisions, &b.decisions, "lazy service");
        assert_eq!(
            a.result.total_ns.to_bits(),
            b.result.total_ns.to_bits(),
            "lazy-backed run trace must be bit-identical to flat"
        );
        assert_eq!(a.mean_fraction.to_bits(), b.mean_fraction.to_bits());
        assert_eq!(a.vmstat, b.vmstat);
    }
    let s = lazy.stats();
    assert_eq!(
        s.peak_resident_segments,
        1,
        "queries run on the single aggregation thread; the cap must hold: {s:?}"
    );
    assert_eq!(s.crc_verifies, 4, "one CRC per segment across all sessions");
    assert!(s.evictions > 0, "cap 1 over 4 segments must churn: {s:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: sweeps route Tuna cells through the lazy backend
/// unchanged — `TunaDb::Lazy` cells are bit-identical to `TunaDb::Flat`
/// cells for any thread count.
#[test]
fn sweep_tuna_cells_over_lazy_db_match_flat_cells() {
    use tuna::coordinator::TunaDb;
    let db = Arc::new(tiny_db());
    let dir = std::env::temp_dir().join(format!("tuna_it_lazysweep_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    ShardedPerfDb::from_flat(&db, 3).save(&dir).unwrap();
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let grid = |tuna_db: TunaDb, threads: usize| {
        let spec = SweepSpec::new(["Btree", "BFS"])
            .with_policies([SweepPolicy::Tuna])
            .with_intervals(30)
            .with_threads(threads)
            .with_tuna_db(tuna_db, cfg.clone());
        run_sweep(&spec).unwrap()
    };
    let flat = grid(TunaDb::Flat(db.clone()), 2);
    let lazy_db = Arc::new(LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap());
    let lazy = grid(TunaDb::Lazy(lazy_db.clone()), 4);
    assert_eq!(flat.len(), lazy.len());
    for (a, b) in flat.cells.iter().zip(&lazy.cells) {
        let ctx = format!("{} seed {}", a.spec.workload, a.spec.seed);
        assert_eq!(
            a.result.total_ns.to_bits(),
            b.result.total_ns.to_bits(),
            "{ctx}: lazy sweep cell diverged"
        );
        let (sa, sb) = (a.tuna.as_ref().unwrap(), b.tuna.as_ref().unwrap());
        assert_eq!(sa.decisions, sb.decisions, "{ctx}");
        assert_eq!(sa.mean_fraction.to_bits(), sb.mean_fraction.to_bits(), "{ctx}");
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{ctx}");
    }
    assert_eq!(lazy_db.stats().peak_resident_segments, 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// A corrupt segment surfaces at first query touch — sessions on the
/// shared service skip decisions (with a diagnostic) instead of
/// panicking, deadlocking or poisoning each other.
#[test]
fn corrupt_lazy_segment_skips_decisions_without_poisoning_sessions() {
    let db = Arc::new(tiny_db());
    let dir = std::env::temp_dir().join(format!("tuna_it_lazycrc_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    ShardedPerfDb::from_flat(&db, 3).save(&dir).unwrap();
    // flip a payload byte in the first non-empty segment
    let seg = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .map(|n| {
                    let n = n.to_string_lossy();
                    n.starts_with("seg-") && n.ends_with(".bin")
                })
                .unwrap_or(false)
        })
        .find(|p| std::fs::metadata(p).unwrap().len() > 8)
        .unwrap();
    let mut bytes = std::fs::read(&seg).unwrap();
    let mid = 8 + (bytes.len() - 8) / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&seg, &bytes).unwrap();

    // open succeeds (CRC is deferred); sessions run to completion with
    // zero decisions rather than erroring out or hanging
    let obs = Recorder::enabled(256);
    let mut lazy = LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap();
    lazy.set_obs(obs.clone());
    let lazy = Arc::new(lazy);
    let service = TunerService::spawn_with_obs(
        lazy.clone(),
        Box::new(LazyShardedNn::new(lazy.clone(), 1)),
        obs.clone(),
    );
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    for seed in [1u64, 2] {
        let spec = RunSpec::new("Btree").with_intervals(30).with_seed(seed);
        let run = coordinator::run_tuna_service(&spec, &service, &cfg).unwrap();
        assert!(run.decisions.is_empty(), "seed {seed}: decisions over a corrupt database");
        assert_eq!(run.result.trace.len(), 30, "the run itself must complete");
    }
    // every skipped decision is observable, not just an stderr line: the
    // tuner warned once per skip and the journal carries the site
    let snap = obs.snapshot();
    assert!(
        snap.counter("obs_warn_total") > 0,
        "corruption must surface in obs_warn_total: {:?}",
        snap.counters
    );
    assert!(
        obs.journal()
            .events
            .iter()
            .any(|e| matches!(&e.kind, EventKind::Warn { site, .. } if site == "tuner.decide")),
        "the skip diagnostic must be journaled as a structured warn event"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// artifact store
// ---------------------------------------------------------------------------

#[test]
fn persisted_sweep_reloads_byte_identical_to_in_memory_result() {
    let spec = SweepSpec::new(["BFS", "Btree"])
        .with_fractions([0.9, 0.7])
        .with_policies([SweepPolicy::Tpp, SweepPolicy::FirstTouch])
        .with_intervals(30);
    let res = run_sweep(&spec).unwrap();
    let in_memory = SweepTable::from_sweep(&res);

    let root = std::env::temp_dir().join(format!("tuna_it_cells_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let store = ArtifactStore::open(&root).unwrap();
    let path = store.sweep_path("it");
    in_memory.save(&path).unwrap();

    // "fresh process": nothing shared with the writer but the file
    let reloaded = SweepTable::load(&path).unwrap();
    assert_eq!(
        reloaded.to_bytes(),
        in_memory.to_bytes(),
        "reloaded sweep table must be byte-identical to the in-memory result"
    );
    // and a self-diff is clean
    let d = diff(&in_memory, &reloaded, 1e-12);
    assert_eq!(d.matched, res.len());
    assert!(d.regressions.is_empty() && d.improvements.is_empty());
    assert!(d.only_in_a.is_empty() && d.only_in_b.is_empty());
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn sharded_perfdb_answers_exactly_like_flat_on_built_records() {
    let db = tiny_db();
    let sharded = ShardedPerfDb::from_flat(&db, 4);
    let mut native = NativeNn::new(&db);
    let mut rng = Rng::new(21);
    for _ in 0..24 {
        let q = normalize(&sample_config(&mut rng).as_array());
        let (fi, fd) = native.nearest(&q).unwrap();
        let (si, sd) = sharded.nearest(&q, 3).unwrap();
        assert_eq!((si, sd.to_bits()), (fi, fd.to_bits()));
        let frac = rng.range_f64(0.5, 1.0);
        assert_eq!(
            db.time_at(fi, frac).to_bits(),
            sharded.time_at(fi, frac).to_bits(),
            "time_at must be bit-identical on shard {fi} at {frac}"
        );
    }
    assert_eq!(store::to_bytes(&sharded.to_flat()), store::to_bytes(&db));
}

#[test]
fn repeated_sweep_against_one_store_resimulates_zero_baselines() {
    let root = std::env::temp_dir().join(format!("tuna_it_store_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let store = ArtifactStore::open(&root).unwrap();
    let spec = SweepSpec::new(["Btree"])
        .with_fractions([0.9, 0.8])
        .with_seeds([1, 2])
        .with_intervals(20);

    let first = BaselineCache::persistent(&store.baselines_dir()).unwrap();
    let res1 = run_sweep_with_cache(&spec, &first).unwrap();
    assert_eq!(res1.baselines_computed, 2, "two seeds, two baselines");
    assert_eq!(res1.baseline_disk_hits, 0);

    // fresh cache over the same store = fresh process: everything loads
    let second = BaselineCache::persistent(&store.baselines_dir()).unwrap();
    let res2 = run_sweep_with_cache(&spec, &second).unwrap();
    assert_eq!(res2.baselines_computed, 0, "no baseline re-simulation on rerun");
    assert_eq!(res2.baseline_disk_hits, 2, "both baselines served from disk");
    assert_eq!(res2.len(), res1.len());
    for (a, b) in res1.cells.iter().zip(&res2.cells) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "disk baselines must not change losses");
        assert_eq!(a.result.total_ns.to_bits(), b.result.total_ns.to_bits());
    }
    // the tables they persist are byte-identical too
    assert_eq!(
        SweepTable::from_sweep(&res1).to_bytes(),
        SweepTable::from_sweep(&res2).to_bytes()
    );
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn golden_tunadb1_fixture_still_parses() {
    // On-disk format compatibility: this fixture was written by the
    // TUNADB1 codec at the time the format was frozen. If it stops
    // parsing — or any value drifts — the format changed and saved
    // artifacts in the field would corrupt. Extend with a new magic
    // instead of mutating this one.
    let path = Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/golden_tunadb1.bin"
    ));
    let data = std::fs::read(path).expect("golden fixture present");
    let db = store::from_bytes(&data).expect("TUNADB1 format drifted: golden fixture unreadable");
    assert_eq!(db.fractions, vec![1.0, 0.8, 0.6]);
    assert_eq!(db.records.len(), 2);
    let r0 = &db.records[0];
    assert_eq!(r0.raw, [1000.0, 200.0, 50.0, 40.0, 2.0, 8000.0, 2.0, 16.0]);
    assert_eq!(r0.times_ns, vec![100.0, 120.0, 150.0]);
    let r1 = &db.records[1];
    assert_eq!(r1.raw, [20000.0, 5000.0, 300.0, 250.0, 0.5, 16000.0, 4.0, 24.0]);
    assert_eq!(r1.times_ns, vec![200.0, 230.0, 290.0]);
    // stored normalized vectors agree with today's normalize()
    for r in &db.records {
        let want = normalize(&r.raw);
        for d in 0..8 {
            assert!(
                (want[d] - r.vec[d]).abs() < 1e-4,
                "normalized dim {d}: fixture {} vs {}",
                r.vec[d],
                want[d]
            );
        }
    }
    // byte-for-byte stability: re-serializing the parsed database must
    // reproduce the checked-in file exactly
    assert_eq!(store::to_bytes(&db), data, "TUNADB1 serializer drifted from golden bytes");
}

#[test]
fn parallel_build_matches_serial_bytes() {
    let mk = |threads: usize| {
        build_database(&BuildParams {
            n_configs: 8,
            fractions: vec![1.0, 0.8, 0.6],
            intervals: 3,
            warmup: 1,
            seed: 77,
            machine: MachineModel::default(),
            threads,
        })
    };
    let serial = store::to_bytes(&mk(1));
    let parallel = store::to_bytes(&mk(8));
    assert_eq!(serial, parallel, "builder output must not depend on thread count");
}

// ---------------------------------------------------------------------------
// property tests (hand-rolled harness; proptest is unavailable offline)
// ---------------------------------------------------------------------------

#[test]
fn prop_fm_capacity_fixed_point_converges() {
    // Engine::fm_capacity solves `usable(cap) == target` by fixed-point
    // iteration; the property is that the usable size under default
    // watermarks always reaches the target without overshooting it by
    // more than a few pages, for any rss/fraction pair.
    check(
        23,
        256,
        |rng: &mut Rng| (16 + rng.below(50_000) as usize, rng.range_f64(0.05, 1.0)),
        |&(rss, fraction)| {
            let mut c = vec![];
            if rss > 16 {
                c.push((16, fraction));
                c.push((16 + (rss - 16) / 2, fraction));
            }
            c
        },
        |&(rss, fraction)| {
            let cap = Engine::fm_capacity(rss, fraction);
            let usable = Watermarks::default_for_capacity(cap).usable(cap);
            let target = (rss as f64 * fraction).ceil() as u64;
            if usable < target {
                return Err(format!(
                    "rss={rss} frac={fraction}: usable {usable} < target {target} (cap {cap})"
                ));
            }
            if usable > target + 8 {
                return Err(format!(
                    "rss={rss} frac={fraction}: usable {usable} overshoots target {target} (cap {cap})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tier_accounting_invariant_under_random_runs() {
    // run random workload/fraction/seed combinations; the engine asserts
    // page-table/occupancy consistency internally (debug) and the trace
    // must be self-consistent: fast_used + fast_free == capacity.
    check(
        42,
        12,
        |rng: &mut Rng| {
            let name = ALL_NAMES[rng.index(ALL_NAMES.len())];
            (name, rng.range_f64(0.3, 1.0), rng.next_u64())
        },
        |_| vec![],
        |&(name, fraction, seed)| {
            let spec = RunSpec::new(name)
                .with_intervals(30)
                .with_fraction(fraction)
                .with_seed(seed);
            let run = coordinator::run_tpp(&spec).map_err(|e| e.to_string())?;
            for t in &run.trace {
                if t.fast_used + t.fast_free != run.fast_capacity {
                    return Err(format!(
                        "interval {}: used {} + free {} != cap {}",
                        t.interval, t.fast_used, t.fast_free, run.fast_capacity
                    ));
                }
                if !t.wall_ns.is_finite() || t.wall_ns <= 0.0 {
                    return Err(format!("interval {}: wall {}", t.interval, t.wall_ns));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_watermark_construction_always_valid() {
    check_u64_range(7, 100, 1_000_000, |capacity| {
        let mut rng = Rng::new(capacity);
        for _ in 0..16 {
            let target = rng.below(capacity + 200);
            let wm = Watermarks::for_target_fm(capacity, target);
            wm.check(capacity).map_err(|e| format!("cap {capacity} target {target}: {e}"))?;
            if wm.usable(capacity) > capacity {
                return Err("usable exceeds capacity".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_perfdb_store_roundtrip_random() {
    check(
        9,
        24,
        |rng: &mut Rng| {
            let n = 1 + rng.index(20);
            let sizes = 2 + rng.index(6);
            (n, sizes, rng.next_u64())
        },
        |_| vec![],
        |&(n, sizes, seed)| {
            let mut rng = Rng::new(seed);
            let mut fractions: Vec<f32> = vec![1.0];
            for i in 1..sizes {
                fractions.push(1.0 - i as f32 * 0.07);
            }
            let records = (0..n)
                .map(|_| {
                    let cfg = sample_config(&mut rng);
                    let raw = cfg.as_array();
                    tuna::perfdb::Record {
                        raw,
                        vec: normalize(&raw),
                        times_ns: (0..sizes).map(|i| 100.0 + i as f32 * rng.f32()).collect(),
                    }
                })
                .collect();
            let db = PerfDb { fractions, records };
            let back = store::from_bytes(&store::to_bytes(&db)).map_err(|e| e.to_string())?;
            if back.records.len() != db.records.len() {
                return Err("record count changed".into());
            }
            for (a, b) in db.records.iter().zip(&back.records) {
                if a.raw != b.raw || a.times_ns != b.times_ns {
                    return Err("record corrupted in roundtrip".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_native_nn_is_true_argmin() {
    let db = tiny_db();
    check(
        11,
        64,
        |rng: &mut Rng| normalize(&sample_config(rng).as_array()),
        |_| vec![],
        |q| {
            let mut nn = NativeNn::new(&db);
            let (idx, d) = nn.nearest(q).map_err(|e| e.to_string())?;
            for (i, r) in db.records.iter().enumerate() {
                let di = dist2(q, &r.vec);
                if di + 1e-7 < d {
                    return Err(format!("record {i} at {di} beats chosen {idx} at {d}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_microbench_equations_roundtrip() {
    check(
        13,
        128,
        |rng: &mut Rng| {
            (
                rng.below(100_000),
                rng.below(40_000),
                rng.below(500),
                rng.below(500),
                2 + rng.below(7) as u32,
            )
        },
        |_| vec![],
        |&(pf, ps, de, pr, hot_thr)| {
            let sets = tuna::microbench::page_sets(pf, ps, de, pr, hot_thr);
            let (f, s) = sets.accesses_per_interval(hot_thr);
            let h = hot_thr as u64;
            let adj_f = pf.saturating_sub(de);
            let adj_s = ps.saturating_sub(pr * h);
            if f > pf || (adj_f > 0 && adj_f - (adj_f % h) + de != f) {
                return Err(format!("fast roundtrip: {f} vs {pf}"));
            }
            if adj_s > 0 && s > ps {
                return Err(format!("slow roundtrip: {s} vs {ps}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_interval_model_monotonicity() {
    // the time model must be monotone in its load inputs: more slow
    // random accesses, more migrations, or fewer threads never speed an
    // interval up.
    use tuna::sim::interval::IntervalInputs;
    let model = IntervalModel::new(MachineModel::default());
    check(
        17,
        128,
        |rng: &mut Rng| IntervalInputs {
            rand_fast: rng.below(2_000_000),
            rand_slow: rng.below(500_000),
            seq_fast: rng.below(2_000_000),
            seq_slow: rng.below(500_000),
            max_page_fast: rng.below(64) as u32,
            max_page_slow: rng.below(64) as u32,
            flops: rng.below(1_000_000_000),
            iops: rng.below(1_000_000_000),
            threads: 1 + rng.below(24) as u32,
            ..Default::default()
        },
        |_| vec![],
        |x| {
            let base = model.evaluate(x).wall_ns;
            if !base.is_finite() || base < 0.0 {
                return Err(format!("non-finite wall {base}"));
            }
            let mut more_slow = *x;
            more_slow.rand_slow += 100_000;
            if model.evaluate(&more_slow).wall_ns + 1e-9 < base {
                return Err("more slow random accesses sped things up".into());
            }
            let mut more_mig = *x;
            more_mig.migrations.promoted += 1_000;
            more_mig.migrations.demoted_kswapd += 1_000;
            if model.evaluate(&more_mig).wall_ns + 1e-9 < base {
                return Err("more migrations sped things up".into());
            }
            let mut fewer_threads = *x;
            fewer_threads.threads = 1;
            if model.evaluate(&fewer_threads).wall_ns + 1e-9 < base {
                return Err("fewer threads sped things up".into());
            }
            // streamed slow traffic must never cost more than the same
            // volume of random slow traffic
            let mut as_random = *x;
            as_random.rand_slow += x.seq_slow;
            as_random.seq_slow = 0;
            if model.evaluate(&as_random).wall_ns + 1e-6 < model.evaluate(x).wall_ns {
                return Err("streaming costed more than random".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

#[test]
fn corrupt_perfdb_file_is_rejected_not_crashing() {
    let dir = std::env::temp_dir().join("tuna_fail_inject");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.bin");
    std::fs::write(&path, b"TUNADB1\0garbage-that-is-not-a-database").unwrap();
    assert!(store::load(&path).is_err());
    // short file
    std::fs::write(&path, b"TU").unwrap();
    assert!(store::load(&path).is_err());
    // truncated valid prefix
    let db = tiny_db();
    let bytes = store::to_bytes(&db);
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(store::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifacts_fail_loudly_with_context() {
    let err = XlaNn::from_manifest(Path::new("/nonexistent/dir"), &tiny_db());
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("manifest") || msg.contains("nonexistent"), "{msg}");
}

#[test]
fn microbench_survives_degenerate_configs() {
    use tuna::microbench::{Microbench, MicrobenchConfig};
    use tuna::workloads::Workload;
    for cfg in [
        MicrobenchConfig {
            pacc_f: 0.0,
            pacc_s: 0.0,
            pm_de: 0.0,
            pm_pr: 0.0,
            ai: 0.0,
            rss_pages: 0.0,
            hot_thr: 1.0,
            num_threads: 1.0,
        },
        MicrobenchConfig {
            pacc_f: 1e9,
            pacc_s: 1e9,
            pm_de: 1e6,
            pm_pr: 1e6,
            ai: 100.0,
            rss_pages: 10.0,
            hot_thr: 2.0,
            num_threads: 64.0,
        },
    ] {
        let mut mb = Microbench::new(cfg, 3);
        let cap = Engine::fm_capacity(mb.rss_pages(), 0.9);
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let engine = Engine::new(IntervalModel::new(MachineModel::default()));
        let res = engine.run(&mut mb, &mut tpp, cap, |_| None);
        assert_eq!(res.trace.len(), 3);
        assert!(res.total_ns.is_finite());
    }
}

#[test]
fn shipped_config_files_parse() {
    for name in [
        "configs/sssp_tune.toml",
        "configs/bfs_sweep.toml",
        "configs/kv_sweep.toml",
        "configs/nomad_sweep.toml",
    ] {
        let cfg = tuna::config::ExperimentConfig::from_file(Path::new(name))
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(cfg.intervals > 0);
        assert!(workloads::by_name(&cfg.workload, 1, 1).is_ok(), "{name}: workload");
    }
}

#[test]
fn memtis_dynamic_threshold_feeds_the_query_dimension() {
    // Run Btree under MEMTIS at pressure; the policy's hot_thr must move
    // away from its initial value at least once (it is a DB query input).
    let mut w = workloads::by_name("Btree", 5, 40).unwrap();
    let cap = Engine::fm_capacity(w.rss_pages(), 0.8);
    let mut m = tuna::tpp::Memtis::new(Watermarks::default_for_capacity(cap));
    use tuna::tpp::PagePolicy;
    let engine = Engine::new(IntervalModel::new(MachineModel::default()));
    let mut thresholds = Vec::new();
    // run manually to sample hot_thr over time
    let _ = engine.run(w.as_mut(), &mut m, cap, |_| {
        thresholds.push(0u32); // placeholder; hot_thr read after run
        None
    });
    thresholds.push(m.hot_thr());
    assert!(m.hot_thr() >= 1);
}

#[test]
fn workload_registry_is_complete_and_consistent() {
    for info in workloads::TABLE1 {
        let w = workloads::by_name(info.name, 1, 2).unwrap();
        let want = (info.paper_rss_gb * workloads::PAGES_PER_PAPER_GB) as usize;
        assert!(
            w.rss_pages() >= want && w.rss_pages() < want + 256,
            "{}: rss {} vs Table 1 {want}",
            info.name,
            w.rss_pages()
        );
    }
    // ... and the KV trace family is part of the same registry
    for name in trace_gen::FAMILY {
        assert!(workloads::is_known(name), "{name} missing from registry");
        let w = workloads::by_name(name, 1, 2).unwrap();
        assert!(w.rss_pages() > 1_000, "{name} rss");
    }
}

// ---------------------------------------------------------------------------
// KV trace subsystem: determinism, replay equivalence, sweep integration
// ---------------------------------------------------------------------------

fn small_kv_spec(name: &str) -> trace_gen::KvGenSpec {
    let mut s = trace_gen::spec_by_name(name).unwrap();
    s.n_keys = 6_000;
    s.ops_per_interval = 4_000;
    s
}

#[test]
fn kv_trace_files_are_byte_identical_per_seed_and_rerecord_stable() {
    let dir = std::env::temp_dir().join(format!("tuna_trcit_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let spec = small_kv_spec("kv-zipfian");
    let (a, b, c) = (dir.join("a.trc"), dir.join("b.trc"), dir.join("c.trc"));

    // same generator spec + seed → byte-identical artifact
    trace_format::save(&a, &trace_gen::generate(&spec, 7, 20)).unwrap();
    trace_format::save(&b, &trace_gen::generate(&spec, 7, 20)).unwrap();
    let bytes_a = std::fs::read(&a).unwrap();
    assert_eq!(bytes_a, std::fs::read(&b).unwrap());
    trace_format::save(&c, &trace_gen::generate(&spec, 8, 20)).unwrap();
    assert_ne!(bytes_a, std::fs::read(&c).unwrap(), "seed must matter");

    // record → load → re-record round-trips byte-for-byte
    let loaded = trace_format::load(&a).unwrap();
    trace_format::save(&c, &loaded).unwrap();
    assert_eq!(bytes_a, std::fs::read(&c).unwrap());

    // traces are store artifacts: `store ls` sees them with a summary
    let store = ArtifactStore::open(&dir.join("store")).unwrap();
    trace_format::save(&store.trace_path("zipf"), &loaded).unwrap();
    let ls = store.ls().unwrap();
    assert!(
        ls.iter().any(|i| i.kind == "trace"
            && i.name == "zipf"
            && i.detail.contains("kv-zipfian")),
        "trace artifact not listed: {ls:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_baselines_are_keyed_by_content_not_path() {
    let path = std::env::temp_dir()
        .join(format!("tuna_trc_key_{}.trc", std::process::id()));
    let spec = small_kv_spec("kv-zipfian");
    trace_format::save(&path, &trace_gen::generate(&spec, 1, 5)).unwrap();
    let rs = RunSpec::new(&format!("trace:{}", path.display())).with_intervals(6);
    let cache = BaselineCache::new();
    let a = cache.get_or_compute(&rs).unwrap();
    let _ = cache.get_or_compute(&rs).unwrap();
    assert_eq!((cache.misses(), cache.hits()), (1, 1), "same content must hit");

    // re-record different ops at the same path → the key changes and the
    // baseline is recomputed (a stale baseline here would silently skew
    // every loss number of a sweep over the re-recorded trace)
    trace_format::save(&path, &trace_gen::generate(&spec, 2, 5)).unwrap();
    let b = cache.get_or_compute(&rs).unwrap();
    assert_eq!(cache.misses(), 2, "content change must invalidate the key");
    assert!(
        a.trace
            .iter()
            .zip(&b.trace)
            .any(|(x, y)| x.iops != y.iops || x.wall_ns.to_bits() != y.wall_ns.to_bits()),
        "re-recorded trace must produce a different baseline run"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn kv_trace_replay_reproduces_live_tuner_decisions() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };

    // live: the registry's default kv-zipfian generator
    let live_spec = RunSpec::new("kv-zipfian").with_intervals(40).with_seed(11);
    let live = coordinator::run_tuna_native(&live_spec, db.clone(), &cfg).unwrap();
    assert!(!live.decisions.is_empty());

    // recorded: the same spec + seed, 39 op frames (+ allocation epoch)
    let path = std::env::temp_dir()
        .join(format!("tuna_trcit_replay_{}.trc", std::process::id()));
    let gspec = trace_gen::spec_by_name("kv-zipfian").unwrap();
    trace_format::save(&path, &trace_gen::generate(&gspec, 11, 39)).unwrap();
    let replay_spec =
        RunSpec::new(&format!("trace:{}", path.display())).with_intervals(40);
    let replay = coordinator::run_tuna_native(&replay_spec, db, &cfg).unwrap();
    std::fs::remove_file(&path).ok();

    // decisions bit-identical to the live run
    assert_eq!(live.decisions.len(), replay.decisions.len());
    for (x, y) in live.decisions.iter().zip(&replay.decisions) {
        assert_eq!(x.interval, y.interval);
        assert_eq!(x.record, y.record);
        assert_eq!(x.new_fm, y.new_fm);
        assert_eq!(x.fraction.to_bits(), y.fraction.to_bits());
        assert_eq!(x.predicted_loss.to_bits(), y.predicted_loss.to_bits());
    }
    assert_eq!(live.mean_fraction.to_bits(), replay.mean_fraction.to_bits());
    // ... and so is the whole engine trace
    assert_eq!(live.result.trace.len(), replay.result.trace.len());
    for (x, y) in live.result.trace.iter().zip(&replay.result.trace) {
        assert_eq!(x.wall_ns.to_bits(), y.wall_ns.to_bits());
        assert_eq!(x.promoted, y.promoted);
        assert_eq!(x.demoted_kswapd, y.demoted_kswapd);
        assert_eq!(x.usable_fm, y.usable_fm);
    }
}

// ---------------------------------------------------------------------------
// non-exclusive (transactional) migration modeling
// ---------------------------------------------------------------------------

/// Serialize the complete observable result of a run — every interval,
/// every counter, every f64 by exact bit pattern — so a fixture of it
/// pins the simulation bit-for-bit.
fn run_digest(run: &RunResult) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    writeln!(
        s,
        "workload {} policy {} fast_capacity {} total_ns {:016x}",
        run.workload,
        run.policy,
        run.fast_capacity,
        run.total_ns.to_bits()
    )
    .unwrap();
    for t in &run.trace {
        write!(
            s,
            "i {} clock {:016x} wall {:016x} acc {}/{} sacc {}/{} flops {} iops {} \
             prom {}/{} dem {}/{} shadow {}/{} txn {}/{} fm {}/{}/{}",
            t.interval,
            t.clock_ns.to_bits(),
            t.wall_ns.to_bits(),
            t.acc_fast,
            t.acc_slow,
            t.sacc_fast,
            t.sacc_slow,
            t.flops,
            t.iops,
            t.promoted,
            t.promote_failed,
            t.demoted_kswapd,
            t.demoted_direct,
            t.shadow_hits,
            t.shadow_free_demotions,
            t.txn_aborts,
            t.txn_retried_copies,
            t.fast_used,
            t.fast_free,
            t.usable_fm
        )
        .unwrap();
        // The admission segment appears only on gated intervals, so every
        // pre-admission golden fixture (all-zero verdicts) keeps its exact
        // bytes — the digest itself proves "admission off" is a no-op.
        let adm = t.admission_accepted
            + t.admission_rejected_budget
            + t.admission_rejected_payoff
            + t.admission_rejected_cooldown;
        if adm > 0 {
            write!(
                s,
                " adm {}/{}/{}/{}",
                t.admission_accepted,
                t.admission_rejected_budget,
                t.admission_rejected_payoff,
                t.admission_rejected_cooldown
            )
            .unwrap();
        }
        s.push('\n');
    }
    s
}

/// Self-golden fixtures: recorded on first run (the files are committed),
/// asserted byte-identical forever after. The exclusive Table-1 run pins
/// the pre-migration-axis engine behaviour; the kv-drift tpp-nomad run
/// pins the transactional semantics as first shipped. Delete a fixture
/// file to re-record after an *intentional* simulation change.
#[test]
fn golden_run_results_stay_bit_identical() {
    let excl = coordinator::run_tpp(
        &RunSpec::new("BFS").with_intervals(60).with_fraction(0.8).with_seed(7),
    )
    .unwrap();
    let nomad = coordinator::run_tpp_nomad(
        &RunSpec::new("kv-drift").with_intervals(60).with_fraction(0.6).with_seed(7),
    )
    .unwrap();
    let nomad_txn = nomad.total_shadow_hits()
        + nomad.total_shadow_free_demotions()
        + nomad.total_txn_aborts()
        + nomad.total_txn_retried_copies();
    assert!(nomad_txn > 0, "the golden nomad run must exercise the transactional model");
    let gated = coordinator::run_tpp_gated(
        &RunSpec::new("kv-drift").with_intervals(60).with_fraction(0.6).with_seed(7),
    )
    .unwrap();
    assert!(
        gated.total_admission_verdicts() > 0,
        "the golden gated run must exercise the admission gate"
    );

    for (name, run) in [
        ("golden_run_bfs_tpp.txt", &excl),
        ("golden_run_kvdrift_nomad.txt", &nomad),
        ("golden_run_kvdrift_gated.txt", &gated),
    ] {
        let digest = run_digest(run);
        let path = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures"))
            .join(name);
        if !path.exists() {
            std::fs::write(&path, &digest).unwrap();
            eprintln!("recorded golden fixture {}", path.display());
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert!(
            digest == want,
            "{name}: simulation output drifted from the golden fixture \
             (delete the file to re-record after an intentional change)"
        );
    }
}

/// Acceptance: replaying a recorded op stream under the non-exclusive
/// model reproduces the live tuner run exactly — decisions, engine trace
/// and the shadow/txn vmstat counters.
#[test]
fn nonexclusive_trace_replay_reproduces_live_tuner_decisions() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let nx = MigrationModel::non_exclusive_default();

    let live_spec =
        RunSpec::new("kv-drift").with_intervals(40).with_seed(11).with_migration(nx);
    let live = coordinator::run_tuna_native(&live_spec, db.clone(), &cfg).unwrap();
    assert!(!live.decisions.is_empty());
    let c = live.result.total_migration_counters();
    assert!(
        c.shadow_hits + c.shadow_free_demotions + c.txn_aborts + c.txn_retried_copies > 0,
        "the live tuned run must actually exercise the transactional model"
    );

    let path = std::env::temp_dir()
        .join(format!("tuna_trcit_nx_{}.trc", std::process::id()));
    let gspec = trace_gen::spec_by_name("kv-drift").unwrap();
    trace_format::save(&path, &trace_gen::generate(&gspec, 11, 39)).unwrap();
    let replay_spec = RunSpec::new(&format!("trace:{}", path.display()))
        .with_intervals(40)
        .with_migration(nx);
    let replay = coordinator::run_tuna_native(&replay_spec, db, &cfg).unwrap();
    std::fs::remove_file(&path).ok();

    assert_decisions_bit_identical(&live.decisions, &replay.decisions, "non-exclusive replay");
    assert_eq!(
        live.result.total_ns.to_bits(),
        replay.result.total_ns.to_bits(),
        "non-exclusive replay run trace must be bit-identical to live"
    );
    assert_eq!(live.vmstat, replay.vmstat, "replayed shadow/txn vmstat counters");
}

/// Acceptance: adding the migration axis to a sweep leaves every
/// exclusive cell byte-identical (same persisted rows, clean diff) while
/// the non-exclusive cells measurably move the measured loss.
#[test]
fn migration_axis_sweep_keeps_exclusive_cells_and_shifts_losses() {
    let grid = |migrations: Vec<MigrationModel>| {
        run_sweep(
            &SweepSpec::new(["kv-drift"])
                .with_fractions([0.8, 0.6])
                .with_intervals(40)
                .with_threads(2)
                .with_migrations(migrations),
        )
        .unwrap()
    };
    let excl = grid(vec![MigrationModel::Exclusive]);
    let mixed = grid(vec![
        MigrationModel::Exclusive,
        MigrationModel::non_exclusive_default(),
    ]);
    assert_eq!(mixed.len(), 2 * excl.len());

    // the exclusive half of the mixed table is byte-identical to the
    // exclusive-only sweep's table (`tuna store diff --strict` clean)
    let ta = SweepTable::from_sweep(&excl);
    let tm = SweepTable::from_sweep(&mixed);
    let tb = SweepTable {
        rows: tm.rows.iter().filter(|r| r.migration.is_exclusive()).cloned().collect(),
    };
    assert_eq!(
        ta.to_bytes(),
        tb.to_bytes(),
        "the migration axis must not perturb exclusive cells"
    );
    let d = diff(&ta, &tm, 1e-12);
    assert_eq!(d.matched, excl.len());
    assert!(d.regressions.is_empty() && d.improvements.is_empty());
    assert!(d.only_in_a.is_empty());
    assert_eq!(d.only_in_b.len(), excl.len(), "non-exclusive cells are new keys");

    // under pressure the transactional model changes the measured loss
    // and reports transactional activity
    let nx: Vec<_> =
        mixed.cells.iter().filter(|c| !c.spec.migration.is_exclusive()).collect();
    assert_eq!(nx.len(), excl.len());
    assert!(
        nx.iter().any(|c| {
            let e = mixed
                .cells
                .iter()
                .find(|x| {
                    x.spec.migration.is_exclusive()
                        && x.spec.fm_fraction.to_bits() == c.spec.fm_fraction.to_bits()
                })
                .unwrap();
            e.loss.to_bits() != c.loss.to_bits()
        }),
        "non-exclusive migration must move at least one measured loss"
    );
    let txn: u64 = nx
        .iter()
        .map(|c| {
            c.result.total_shadow_hits()
                + c.result.total_shadow_free_demotions()
                + c.result.total_txn_aborts()
                + c.result.total_txn_retried_copies()
        })
        .sum();
    assert!(txn > 0, "non-exclusive cells must report transactional activity");
}

// ---------------------------------------------------------------------------
// migration admission control
// ---------------------------------------------------------------------------

/// Acceptance: adding `tpp-gated` to a sweep leaves every ungated cell
/// byte-identical (same persisted rows, `tuna store diff --strict`
/// clean), while the gated cells reject ping-pong candidates on the
/// drifting hot set and beat plain TPP's loss at one or more of the
/// swept fractions — the subsystem's headline artifact.
#[test]
fn admission_sweep_keeps_ungated_cells_and_beats_tpp_on_drift() {
    let grid = |policies: Vec<SweepPolicy>| {
        run_sweep(
            &SweepSpec::new(["kv-drift"])
                .with_fractions([0.8, 0.6])
                .with_intervals(80)
                .with_threads(2)
                .with_policies(policies),
        )
        .unwrap()
    };
    let plain = grid(vec![SweepPolicy::Tpp]);
    let mixed = grid(vec![SweepPolicy::Tpp, SweepPolicy::TppGated]);
    assert_eq!(mixed.len(), 2 * plain.len());

    // the ungated half of the mixed table is byte-identical to the
    // tpp-only sweep's table (`tuna store diff --strict` clean)
    let ta = SweepTable::from_sweep(&plain);
    let tm = SweepTable::from_sweep(&mixed);
    let tb = SweepTable {
        rows: tm.rows.iter().filter(|r| !r.admission.enabled).cloned().collect(),
    };
    assert_eq!(
        ta.to_bytes(),
        tb.to_bytes(),
        "the admission subsystem must not perturb ungated cells"
    );
    let d = diff(&ta, &tm, 1e-12);
    assert_eq!(d.matched, plain.len());
    assert!(d.regressions.is_empty() && d.improvements.is_empty());
    assert!(d.only_in_a.is_empty());
    assert_eq!(d.only_in_b.len(), plain.len(), "gated cells are new keys");

    // gated cells: the drifting hot set re-heats freshly demoted pages,
    // so the cool-down filter must actually fire
    let gated: Vec<_> =
        mixed.cells.iter().filter(|c| c.spec.policy == SweepPolicy::TppGated).collect();
    assert_eq!(gated.len(), plain.len());
    for g in &gated {
        assert!(
            g.result.total_admission_verdicts() > 0,
            "gated cell must record verdicts: {:?}",
            g.spec
        );
    }
    let cooldown: u64 =
        gated.iter().map(|c| c.result.total_admission_rejected_cooldown()).sum();
    assert!(
        cooldown > 0,
        "kv-drift under tpp-gated must reject recently-demoted (ping-pong) candidates"
    );

    // headline: payoff-gated promotion beats ungated TPP at >= 1 fraction
    let better = gated.iter().any(|g| {
        let u = mixed
            .cells
            .iter()
            .find(|x| {
                x.spec.policy == SweepPolicy::Tpp
                    && x.spec.fm_fraction.to_bits() == g.spec.fm_fraction.to_bits()
            })
            .unwrap();
        g.loss < u.loss
    });
    assert!(
        better,
        "tpp-gated must show lower loss than plain tpp at >= 1 swept kv-drift fraction: {:?}",
        mixed
            .cells
            .iter()
            .map(|c| (c.spec.policy.name(), c.spec.fm_fraction, c.loss))
            .collect::<Vec<_>>()
    );
}

/// The admission counters must tell one consistent story end-to-end:
/// the per-interval journal events sum to the metric counters, which
/// equal the engine trace's own totals.
#[test]
fn journaled_admission_verdicts_sum_to_the_metric_counters() {
    let obs = Recorder::enabled(DEFAULT_RING_CAPACITY);
    let spec = RunSpec::new("kv-drift")
        .with_intervals(40)
        .with_fraction(0.6)
        .with_seed(7)
        .with_obs(obs.clone());
    let run = coordinator::run_tpp_gated(&spec).unwrap();
    assert!(run.total_admission_verdicts() > 0);

    let mut sums = [0u64; 4];
    for e in &obs.journal().events {
        if let EventKind::Interval {
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
            ..
        } = e.kind
        {
            sums[0] += admission_accepted;
            sums[1] += admission_rejected_budget;
            sums[2] += admission_rejected_payoff;
            sums[3] += admission_rejected_cooldown;
        }
    }
    let snap = obs.snapshot();
    for (name, journaled, total) in [
        ("mem_admission_accepted_total", sums[0], run.total_admission_accepted()),
        (
            "mem_admission_rejected_budget_total",
            sums[1],
            run.total_admission_rejected_budget(),
        ),
        (
            "mem_admission_rejected_payoff_total",
            sums[2],
            run.total_admission_rejected_payoff(),
        ),
        (
            "mem_admission_rejected_cooldown_total",
            sums[3],
            run.total_admission_rejected_cooldown(),
        ),
    ] {
        assert_eq!(snap.counter(name), total, "{name} must equal the trace total");
        assert_eq!(journaled, total, "journaled {name} events must sum to the trace total");
    }
}

#[test]
fn kv_workloads_flow_through_sweeps_unchanged() {
    let db = Arc::new(tiny_db());
    let spec = SweepSpec::new(["kv-zipfian", "kv-drift"])
        .with_fractions([0.9, 0.7])
        .with_policies([SweepPolicy::Tpp, SweepPolicy::Tuna])
        .with_intervals(30)
        .with_threads(2)
        .with_tuna(db, TunaConfig { period_s: 1.0, ..TunaConfig::default() });
    let res = run_sweep(&spec).unwrap();
    // 2 workloads × (2 Tpp fractions + 1 collapsed Tuna cell)
    assert_eq!(res.len(), 2 * 3);
    assert_eq!(res.baselines_computed, 2, "one baseline per KV workload");
    for c in &res.cells {
        assert!(c.loss.is_finite(), "{:?}", c.spec);
        assert!(c.result.total_ns > 0.0);
    }
    for c in res.cells.iter().filter(|c| c.spec.policy == SweepPolicy::Tuna) {
        let stats = c.tuna.as_ref().expect("tuna cell stats");
        assert!(stats.decisions > 0, "no decisions for {:?}", c.spec);
    }
    // shrinking fast memory must cost something on the skewed KV family
    let l90 = res.cell("kv-zipfian", SweepPolicy::Tpp, 0.9).unwrap().loss;
    let l70 = res.cell("kv-zipfian", SweepPolicy::Tpp, 0.7).unwrap().loss;
    assert!(l70 >= l90 - 0.01, "l70={l70} l90={l90}");
}

// ---------------------------------------------------------------------------
// observability: bit-identity, journal durability, ring accounting
// ---------------------------------------------------------------------------

/// Acceptance (PR 7 hard invariant): enabling observability at ANY ring
/// size changes nothing observable about a run. Decisions, the complete
/// engine trace (via `run_digest`, every f64 by bit pattern) and the
/// vmstat counters must be bit-identical to the obs-off run — for a
/// Table-1 workload and a kv-* workload, under both migration models.
#[test]
fn obs_on_runs_are_bit_identical_to_obs_off() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    for (name, migration) in [
        ("BFS", MigrationModel::Exclusive),
        ("BFS", MigrationModel::non_exclusive_default()),
        ("kv-drift", MigrationModel::Exclusive),
        ("kv-drift", MigrationModel::non_exclusive_default()),
    ] {
        let spec = |obs: Recorder| {
            RunSpec::new(name)
                .with_intervals(40)
                .with_seed(11)
                .with_migration(migration)
                .with_obs(obs)
        };
        let off =
            coordinator::run_tuna_native(&spec(Recorder::disabled()), db.clone(), &cfg).unwrap();
        assert!(!off.decisions.is_empty(), "{name}: reference run must decide");
        for ring in [4usize, DEFAULT_RING_CAPACITY] {
            let obs = Recorder::enabled(ring);
            let on = coordinator::run_tuna_native(&spec(obs.clone()), db.clone(), &cfg).unwrap();
            let ctx = format!("{name}/{migration:?}/ring {ring}");
            assert_decisions_bit_identical(&off.decisions, &on.decisions, &ctx);
            assert_eq!(
                run_digest(&off.result),
                run_digest(&on.result),
                "{ctx}: engine trace must be bit-identical with obs on"
            );
            assert_eq!(off.vmstat, on.vmstat, "{ctx}: vmstat");
            // ... while the recorder actually saw the run
            let snap = obs.snapshot();
            assert_eq!(
                snap.counter("engine_intervals_total"),
                on.result.trace.len() as u64,
                "{ctx}: every interval must be counted"
            );
            assert_eq!(
                snap.counter("tuner_decisions_total"),
                on.decisions.len() as u64,
                "{ctx}: every decision must be counted"
            );
        }
    }
}

/// Observability must not perturb sweeps either: the persisted table of
/// an instrumented sweep is byte-identical to the uninstrumented one,
/// and every cell shows up as a counted, journaled sweep-cell event.
#[test]
fn obs_sweep_table_bytes_identical_on_and_off() {
    let grid = |obs: Recorder| {
        let spec = SweepSpec::new(["BFS", "kv-drift"])
            .with_fractions([0.8, 0.6])
            .with_policies([SweepPolicy::Tpp])
            .with_intervals(20)
            .with_threads(2)
            .with_obs(obs);
        run_sweep(&spec).unwrap()
    };
    let off = grid(Recorder::disabled());
    let obs = Recorder::enabled(1024);
    let on = grid(obs.clone());
    assert_eq!(
        SweepTable::from_sweep(&off).to_bytes(),
        SweepTable::from_sweep(&on).to_bytes(),
        "observability must not perturb sweep results"
    );
    let snap = obs.snapshot();
    assert_eq!(snap.counter("sweep_cells_total"), on.len() as u64);
    let journaled = obs
        .journal()
        .events
        .iter()
        .filter(|e| e.kind.name() == "sweep-cell")
        .count();
    assert_eq!(journaled, on.len(), "one journal event per sweep cell");
}

/// The `TUNAOBS1` journal artifact is durable and canonical: encode →
/// decode → re-encode is byte-identical (so re-dumps are byte-stable),
/// the file round-trips through the store, and corruption is detected.
#[test]
fn obs_journal_roundtrip_is_byte_stable() {
    let db = Arc::new(tiny_db());
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    let obs = Recorder::enabled(DEFAULT_RING_CAPACITY);
    let spec = RunSpec::new("Btree").with_intervals(40).with_obs(obs.clone());
    let run = coordinator::run_tuna_native(&spec, db, &cfg).unwrap();
    assert!(!run.decisions.is_empty());
    obs.warn("it.roundtrip", "synthetic warning for codec coverage");

    let journal = obs.journal();
    for phase in ["engine", "tuner", "warn"] {
        assert!(
            journal.events.iter().any(|e| e.kind.phase() == phase),
            "a tuned run must journal {phase} events"
        );
    }

    let bytes = journal.encode();
    let back = Journal::decode(&bytes).unwrap();
    assert_eq!(back, journal, "decode must reproduce the journal exactly");
    assert_eq!(back.encode(), bytes, "re-encode must be byte-identical");

    // ... and through the filesystem, as `--obs-journal` writes it
    let dir = std::env::temp_dir().join(format!("tuna_it_obs_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("j.bin");
    journal.save(&path).unwrap();
    assert_eq!(
        std::fs::read(&path).unwrap(),
        bytes,
        "the file IS the canonical encoding"
    );
    let loaded = Journal::load(&path).unwrap();
    assert_eq!(loaded, journal);

    // flip one payload byte: the trailing CRC must reject the file
    let mut bad = bytes.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0xFF;
    let bad_path = dir.join("bad.bin");
    std::fs::write(&bad_path, &bad).unwrap();
    assert!(Journal::load(&bad_path).is_err(), "corrupt journal must be rejected");
    std::fs::remove_dir_all(&dir).ok();
}

/// A tiny ring keeps the newest events and accounts for every drop —
/// in the journal's `dropped` field, in `obs_journal_dropped_total`,
/// and across the `TUNAOBS1` round-trip.
#[test]
fn obs_ring_overflow_keeps_newest_and_counts_drops() {
    let obs = Recorder::enabled(4);
    for segment in 0..10u32 {
        obs.record(EventKind::SegmentEvict { segment });
    }
    let j = obs.journal();
    assert_eq!(j.events.len(), 4, "ring capacity bounds the journal");
    assert_eq!(j.dropped, 6);
    assert_eq!(j.metrics.counter("obs_journal_dropped_total"), 6);
    let kept: Vec<u32> = j
        .events
        .iter()
        .map(|e| match e.kind {
            EventKind::SegmentEvict { segment } => segment,
            _ => unreachable!("only evict events were recorded"),
        })
        .collect();
    assert_eq!(kept, [6, 7, 8, 9], "the oldest events are dropped first");
    let back = Journal::decode(&j.encode()).unwrap();
    assert_eq!(back.dropped, 6, "the drop count survives the round-trip");
}

// ---------------------------------------------------------------------------
// decision-outcome accountability: observe ≡ off, retune-on acceptance,
// what-if agreement, journal-tag compatibility
// ---------------------------------------------------------------------------

/// Acceptance (ISSUE 9 hard invariant): `--retune observe` records
/// predicted-vs-realized outcomes but never acts — decisions, the
/// complete engine trace (via `run_digest`, every f64 by bit pattern)
/// and the vmstat counters are bit-identical to `--retune off`, for a
/// Table-1 workload and a kv-* workload under both migration models.
#[test]
fn retune_observe_runs_are_bit_identical_to_off() {
    let db = Arc::new(tiny_db());
    let cfg_with = |mode: RetuneMode| TunaConfig {
        period_s: 1.0,
        retune: RetuneConfig { mode, ..RetuneConfig::default() },
        ..TunaConfig::default()
    };
    for (name, migration) in [
        ("BFS", MigrationModel::Exclusive),
        ("BFS", MigrationModel::non_exclusive_default()),
        ("kv-drift", MigrationModel::Exclusive),
        ("kv-drift", MigrationModel::non_exclusive_default()),
    ] {
        let spec = RunSpec::new(name)
            .with_intervals(40)
            .with_seed(11)
            .with_migration(migration);
        let off =
            coordinator::run_tuna_native(&spec, db.clone(), &cfg_with(RetuneMode::Off)).unwrap();
        let observed =
            coordinator::run_tuna_native(&spec, db.clone(), &cfg_with(RetuneMode::Observe))
                .unwrap();
        let ctx = format!("{name}/{migration:?}");
        assert!(!off.decisions.is_empty(), "{ctx}: reference run must decide");
        assert_decisions_bit_identical(&off.decisions, &observed.decisions, &ctx);
        assert_eq!(
            run_digest(&off.result),
            run_digest(&observed.result),
            "{ctx}: engine trace must be bit-identical under observe"
        );
        assert_eq!(off.vmstat, observed.vmstat, "{ctx}: vmstat");
        // off is fully inert; observe actually joined outcomes without
        // ever acting on them
        assert!(off.outcomes.is_empty(), "{ctx}: off must not track outcomes");
        assert_eq!(off.retunes, 0, "{ctx}: off must not retune");
        assert!(!observed.outcomes.is_empty(), "{ctx}: observe must join outcomes");
        assert_eq!(observed.retunes, 0, "{ctx}: observe must never act");
    }
}

/// The sweep half of the same invariant: the persisted cell table of an
/// observe-mode sweep (Tuna cells included) is byte-identical to the
/// off-mode one.
#[test]
fn retune_observe_sweep_table_bytes_identical_to_off() {
    let db = Arc::new(tiny_db());
    let grid = |mode: RetuneMode| {
        let cfg = TunaConfig {
            period_s: 1.0,
            retune: RetuneConfig { mode, ..RetuneConfig::default() },
            ..TunaConfig::default()
        };
        let spec = SweepSpec::new(["BFS", "kv-drift"])
            .with_fractions([0.8, 0.6])
            .with_policies([SweepPolicy::Tpp, SweepPolicy::Tuna])
            .with_intervals(30)
            .with_threads(2)
            .with_tuna(db.clone(), cfg);
        run_sweep(&spec).unwrap()
    };
    let off = grid(RetuneMode::Off);
    let observed = grid(RetuneMode::Observe);
    assert_eq!(
        SweepTable::from_sweep(&off).to_bytes(),
        SweepTable::from_sweep(&observed).to_bytes(),
        "observe mode must not perturb persisted sweep tables"
    );
}

/// Acceptance (ISSUE 9): `--retune on` over kv-drift — whose phase
/// change guarantees a realized-vs-predicted gap — must (a) actually
/// act (the hair trigger forces re-tunes), (b) stay damped by the
/// cool-down hysteresis (no retune on ≥ half of all decision periods),
/// and (c) realize a loss no worse than the static-decision run at
/// ≥ 1 swept loss target (zero-retune targets are bit-identical runs,
/// so equality also satisfies this).
#[test]
fn retune_on_kvdrift_improves_somewhere_and_hysteresis_damps() {
    let db = Arc::new(tiny_db());
    let spec = RunSpec::new("kv-drift").with_intervals(60).with_seed(7);
    let baseline = coordinator::run_fm_only(&spec).unwrap();
    let run_mode = |mode: RetuneMode, target: f64| {
        let cfg = TunaConfig {
            period_s: 1.0,
            loss_target: target,
            retune: RetuneConfig {
                mode,
                ewma_alpha: 1.0,
                trigger: 1e-6,
                early_intervals: 2,
                cooldown_periods: 2,
            },
            ..TunaConfig::default()
        };
        coordinator::run_tuna_native(&spec, db.clone(), &cfg).unwrap()
    };
    let mut not_worse = 0usize;
    let mut acted = false;
    for target in [0.02, 0.05, 0.1] {
        let off = run_mode(RetuneMode::Off, target);
        let on = run_mode(RetuneMode::On, target);
        assert!(off.decisions.len() >= 2, "target {target}: static run must decide repeatedly");
        assert!(
            (on.retunes as usize) * 2 < on.decisions.len().max(1),
            "target {target}: {} retunes over {} decisions — hysteresis failed to damp",
            on.retunes,
            on.decisions.len()
        );
        if on.retunes > 0 {
            acted = true;
        }
        let l_off = coordinator::overall_loss(&off.result, &baseline);
        let l_on = coordinator::overall_loss(&on.result, &baseline);
        if l_on <= l_off + 1e-12 {
            not_worse += 1;
        }
    }
    assert!(acted, "the hair trigger must force at least one re-tune somewhere");
    assert!(
        not_worse >= 1,
        "adaptive re-tuning must be no worse than static at >= 1 swept target"
    );
}

/// Acceptance (ISSUE 9): `tuna whatif` (measured mode) answers with the
/// offline sweep's loss for the same (workload, fraction) cell,
/// bit-for-bit — both are `overall_loss(run_tpp, run_fm_only)` over
/// identical specs.
#[test]
fn whatif_measured_agrees_bit_for_bit_with_sweep_cells() {
    let spec = SweepSpec::new(["kv-drift"])
        .with_fractions([0.8, 0.6])
        .with_policies([SweepPolicy::Tpp])
        .with_intervals(30);
    let res = run_sweep(&spec).unwrap();
    for fraction in [0.8, 0.6] {
        let cell = res.cell("kv-drift", SweepPolicy::Tpp, fraction).unwrap();
        let rs = RunSpec::new("kv-drift").with_intervals(30).with_fraction(fraction);
        let what = coordinator::whatif_measured(&rs).unwrap();
        assert_eq!(
            what.to_bits(),
            cell.loss.to_bits(),
            "whatif at {fraction} disagrees with the sweep cell"
        );
    }
}

/// Satellite (ISSUE 9): pre-PR9 `TUNAOBS1` journals — V1/V2 interval
/// tags, decision/ingest/segment/sweep-cell/warn tags, no
/// `Outcome`/`Drift` — must keep decoding byte-stably after the new
/// tags land. The fixture journal is hand-built with pinned timestamps
/// (its bytes are fully deterministic), recorded on first run and
/// asserted byte-identical — encode AND decode → re-encode — forever
/// after. Delete the file to re-record after an *intentional* format
/// change.
#[test]
fn golden_pre_pr9_obs_journal_still_decodes_byte_stably() {
    use tuna::obs::{Event, HistSnapshot, MetricsSnapshot};
    let mut metrics = MetricsSnapshot::default();
    metrics.counters.insert("engine_intervals_total".into(), 40);
    metrics.counters.insert("tuner_decisions_total".into(), 4);
    metrics.gauges.insert("perfdb_resident_segments".into(), 2.0);
    metrics.hists.insert(
        "tuner_decision_fraction".into(),
        HistSnapshot {
            bounds: vec![0.25, 0.5, 0.75, 1.0],
            counts: vec![0, 1, 2, 1, 0],
            sum: 2.9,
            count: 4,
        },
    );
    let kinds = vec![
        EventKind::Warn { site: "it.golden".into(), message: "pre-pr9 fixture".into() },
        // all-zero admission verdicts → the legacy V1 interval tag
        EventKind::Interval {
            workload: "BFS".into(),
            policy: "tpp".into(),
            interval: 3,
            wall_ns: 1.5e6,
            fast_used: 1000,
            promoted: 12,
            demoted: 3,
            txn_aborts: 1,
            shadow_free_demotions: 2,
            admission_accepted: 0,
            admission_rejected_budget: 0,
            admission_rejected_payoff: 0,
            admission_rejected_cooldown: 0,
        },
        // nonzero verdicts → the V2 interval tag
        EventKind::Interval {
            workload: "kv-drift".into(),
            policy: "tpp-gated".into(),
            interval: 4,
            wall_ns: 2.5e6,
            fast_used: 512,
            promoted: 9,
            demoted: 4,
            txn_aborts: 0,
            shadow_free_demotions: 0,
            admission_accepted: 9,
            admission_rejected_budget: 3,
            admission_rejected_payoff: 11,
            admission_rejected_cooldown: 5,
        },
        EventKind::Decision {
            interval: 5,
            record: 17,
            dist: 0.25,
            fraction: 0.8,
            new_fm: 4096,
            predicted_loss: 0.031,
            wm_low: 64,
            wm_high: 96,
        },
        EventKind::IngestBatch {
            lines: 10,
            samples: 8,
            decisions: 1,
            sessions_opened: 1,
            sessions_closed: 1,
        },
        EventKind::SegmentLoad { segment: 3, records: 256, crc_checked: true, wall_ns: 42_000 },
        EventKind::SegmentEvict { segment: 3 },
        EventKind::SweepCell {
            workload: "kv-drift".into(),
            policy: "tpp-nomad".into(),
            fraction: 0.6,
            seed: 7,
            wall_ns: 9_000_000,
        },
    ];
    let journal = Journal {
        dropped: 2,
        metrics,
        events: kinds
            .into_iter()
            .enumerate()
            .map(|(i, kind)| Event { t_ns: 1_000 * (i as u64 + 1), kind })
            .collect(),
    };
    let bytes = journal.encode();

    let path = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures"))
        .join("golden_obs_pre_pr9.bin");
    if !path.exists() {
        std::fs::write(&path, &bytes).unwrap();
        eprintln!("recorded golden fixture {}", path.display());
    }
    let want = std::fs::read(&path).unwrap();
    assert!(
        bytes == want,
        "pre-PR9 journal encoding drifted from the golden fixture \
         (delete the file to re-record after an intentional format change)"
    );
    let decoded = Journal::decode(&want).unwrap();
    assert_eq!(decoded, journal, "decode must reproduce the pre-PR9 journal exactly");
    assert_eq!(decoded.encode(), want, "decode -> re-encode must be byte-identical");
}

/// Observe-mode runs journal one `Outcome` event per joined record, and
/// the realized/error histograms and retune counter agree with the
/// run's own records; `tuna obs outcomes` renders the session.
#[test]
fn journaled_outcomes_match_run_records_and_render() {
    let db = Arc::new(tiny_db());
    let obs = Recorder::enabled(DEFAULT_RING_CAPACITY);
    let cfg = TunaConfig {
        period_s: 1.0,
        retune: RetuneConfig { mode: RetuneMode::Observe, ..RetuneConfig::default() },
        ..TunaConfig::default()
    };
    let spec = RunSpec::new("kv-drift")
        .with_intervals(40)
        .with_seed(11)
        .with_obs(obs.clone());
    let run = coordinator::run_tuna_native(&spec, db, &cfg).unwrap();
    assert!(!run.outcomes.is_empty(), "observe must join outcomes");

    let j = obs.journal();
    let journaled: Vec<(u32, f64, f64)> = j
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::Outcome { decision_interval, predicted, realized, .. } => {
                Some((*decision_interval, *predicted, *realized))
            }
            _ => None,
        })
        .collect();
    assert_eq!(journaled.len(), run.outcomes.len(), "one journal event per outcome");
    for (ev, rec) in journaled.iter().zip(&run.outcomes) {
        assert_eq!(ev.0, rec.decision_interval);
        assert_eq!(ev.1.to_bits(), rec.predicted.to_bits());
        assert_eq!(ev.2.to_bits(), rec.realized.to_bits());
    }
    let n = run.outcomes.len() as u64;
    assert_eq!(j.metrics.hists.get("tuner_realized_loss").map(|h| h.count), Some(n));
    assert_eq!(j.metrics.hists.get("tuner_prediction_error").map(|h| h.count), Some(n));
    assert_eq!(j.metrics.counter("tuner_retunes_total"), run.retunes);

    let rendered = tuna::obs::render::render_outcomes(&j);
    assert!(
        rendered.contains("kv-drift@11"),
        "outcomes render must name the session:\n{rendered}"
    );
}
