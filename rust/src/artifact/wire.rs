//! Little-endian wire helpers shared by the artifact codecs.
//!
//! Every on-disk artifact (sharded perf-DB segments, sweep cell tables,
//! baseline caches) is a flat little-endian byte stream; these helpers
//! keep the writers symmetric with a bounds-checked [`Reader`] so a
//! truncated or corrupted file fails parsing instead of panicking.

use anyhow::{bail, Result};

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u128(out: &mut Vec<u8>, v: u128) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// UTF-8 string with a u32 length prefix.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked cursor over a byte slice.
pub struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Reader { data, pos: 0 }
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            bail!(
                "artifact truncated: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.data.len() - self.pos
            );
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn u128(&mut self) -> Result<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// UTF-8 string with a u32 length prefix (bounded at 1 MiB — no real
    /// name or fingerprint is that long, so a corrupt length fails fast).
    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 1 << 20 {
            bail!("implausible string length {n} in artifact");
        }
        let bytes = self.take(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("non-UTF-8 string in artifact: {e}"))?
            .to_string())
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    /// Error unless the whole input was consumed.
    pub fn done(&self) -> Result<()> {
        if self.pos != self.data.len() {
            bail!("artifact has {} trailing bytes", self.data.len() - self.pos);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut out = Vec::new();
        put_u8(&mut out, 7);
        put_u32(&mut out, 0xDEAD_BEEF);
        put_u64(&mut out, u64::MAX - 1);
        put_u128(&mut out, 1 << 100);
        put_f32(&mut out, -1.5);
        put_f64(&mut out, std::f64::consts::PI);
        put_str(&mut out, "hello wire");
        let mut r = Reader::new(&out);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.u128().unwrap(), 1 << 100);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str().unwrap(), "hello wire");
        r.done().unwrap();
    }

    #[test]
    fn truncation_and_trailing_bytes_are_errors() {
        let mut out = Vec::new();
        put_u64(&mut out, 1);
        let mut r = Reader::new(&out[..4]);
        assert!(r.u64().is_err());
        let mut r2 = Reader::new(&out);
        assert_eq!(r2.u32().unwrap(), 1);
        assert!(r2.done().is_err());
        assert_eq!(r2.remaining(), 4);
    }

    #[test]
    fn bogus_string_length_is_rejected() {
        let mut out = Vec::new();
        put_u32(&mut out, u32::MAX);
        assert!(Reader::new(&out).str().is_err());
    }
}
