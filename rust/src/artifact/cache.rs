//! Cross-process baseline cache: fast-memory-only baseline runs keyed by
//! [`BaselineKey`] hash, persisted in the artifact store so a repeated
//! bench or sweep invocation loads memoized baselines from disk instead
//! of re-simulating them.
//!
//! Each artifact (`baselines/<key-hash>.bl`, magic `TUNABAS1`) embeds the
//! *full* key alongside the serialized [`RunResult`], so a hash collision
//! is detected on load (the stored key is compared field-by-field) and
//! degrades to a recompute, never a wrong baseline. The payload carries
//! every trace field bit-exactly (f64/f32 via their IEEE bits), so a
//! baseline loaded from disk is indistinguishable from one simulated in
//! this process.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::wire::{self, Reader};
use super::{fnv1a64, fnv1a64_update, write_atomic};
use crate::coordinator::sweep::BaselineKey;
use crate::perfdb::store::crc32;
use crate::sim::interval::Bound;
use crate::sim::{IntervalOutcome, RunResult, RunTrace};

const MAGIC: &[u8; 8] = b"TUNABAS1";

/// Fingerprint of the simulation code that produced a baseline. Stored in
/// every artifact and checked on load: an artifact written by different
/// simulator code is recomputed, not silently reused — the machine-model
/// string in [`BaselineKey`] captures *parameters*, so code changes need
/// their own signal. The fingerprint is **content-derived** (a hash of
/// the simulator/policy/workload sources compiled into this binary), not
/// a manually-bumped version: any edit to those sources invalidates
/// stored baselines mechanically. False invalidation (comment-only
/// edits) merely costs one recompute.
pub fn sim_fingerprint() -> &'static str {
    use std::sync::OnceLock;
    static FP: OnceLock<String> = OnceLock::new();
    FP.get_or_init(|| {
        // Everything a fast-memory-only baseline run executes: the run
        // harness, the engine + time model, the TPP policy family, the
        // workloads, and the RNG their access streams come from.
        const SOURCES: &[&str] = &[
            include_str!("../coordinator/mod.rs"),
            include_str!("../sim/engine.rs"),
            include_str!("../sim/interval.rs"),
            include_str!("../sim/machine.rs"),
            include_str!("../sim/mem.rs"),
            include_str!("../tpp/mod.rs"),
            include_str!("../tpp/firsttouch.rs"),
            include_str!("../tpp/memtis.rs"),
            include_str!("../tpp/watermarks.rs"),
            include_str!("../util/rng.rs"),
            include_str!("../workloads/mod.rs"),
            include_str!("../workloads/bfs.rs"),
            include_str!("../workloads/btree.rs"),
            include_str!("../workloads/graph.rs"),
            include_str!("../workloads/kv.rs"),
            include_str!("../workloads/pagerank.rs"),
            include_str!("../workloads/sssp.rs"),
            include_str!("../workloads/xsbench.rs"),
            // the KV families' op streams and page mapping (the trace
            // *format* is deliberately absent: a stored op stream means
            // the same accesses regardless of codec changes)
            include_str!("../trace/gen.rs"),
            include_str!("../trace/replay.rs"),
            include_str!("../trace/mod.rs"),
        ];
        let mut h = fnv1a64(b"");
        for src in SOURCES {
            h = fnv1a64_update(h, src.as_bytes());
        }
        format!("tuna-{}-{h:016x}", env!("CARGO_PKG_VERSION"))
    })
}

/// Intern a string into a `&'static str`. [`RunResult`] stores its
/// workload/policy names as `&'static str` (they are compile-time
/// constants on the simulation path); deserialization reuses one leaked
/// copy per distinct name, so memory stays bounded by the name universe.
fn intern(s: &str) -> &'static str {
    use std::sync::{Mutex, OnceLock};
    static POOL: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    let pool = POOL.get_or_init(|| Mutex::new(Vec::new()));
    let mut guard = pool.lock().unwrap();
    if let Some(&hit) = guard.iter().find(|&&x| x == s) {
        return hit;
    }
    let leaked: &'static str = Box::leak(s.to_owned().into_boxed_str());
    guard.push(leaked);
    leaked
}

fn bound_code(b: Bound) -> u8 {
    match b {
        Bound::Compute => 0,
        Bound::Latency => 1,
        Bound::FastBw => 2,
        Bound::SlowBw => 3,
    }
}

fn bound_from_code(c: u8) -> Result<Bound> {
    Ok(match c {
        0 => Bound::Compute,
        1 => Bound::Latency,
        2 => Bound::FastBw,
        3 => Bound::SlowBw,
        other => bail!("bad roofline-bound code {other} in baseline artifact"),
    })
}

fn put_trace(out: &mut Vec<u8>, t: &RunTrace) {
    wire::put_u32(out, t.interval);
    wire::put_f64(out, t.clock_ns);
    wire::put_f64(out, t.wall_ns);
    wire::put_u64(out, t.acc_fast);
    wire::put_u64(out, t.acc_slow);
    wire::put_u64(out, t.sacc_fast);
    wire::put_u64(out, t.sacc_slow);
    wire::put_u64(out, t.flops);
    wire::put_u64(out, t.iops);
    wire::put_u64(out, t.promoted);
    wire::put_u64(out, t.promote_failed);
    wire::put_u64(out, t.demoted_kswapd);
    wire::put_u64(out, t.demoted_direct);
    wire::put_u64(out, t.shadow_hits);
    wire::put_u64(out, t.shadow_free_demotions);
    wire::put_u64(out, t.txn_aborts);
    wire::put_u64(out, t.txn_retried_copies);
    wire::put_u64(out, t.admission_accepted);
    wire::put_u64(out, t.admission_rejected_budget);
    wire::put_u64(out, t.admission_rejected_payoff);
    wire::put_u64(out, t.admission_rejected_cooldown);
    wire::put_u64(out, t.fast_used);
    wire::put_u64(out, t.fast_free);
    wire::put_u64(out, t.usable_fm);
    wire::put_f64(out, t.outcome.wall_ns);
    wire::put_f64(out, t.outcome.t_comp_ns);
    wire::put_f64(out, t.outcome.t_lat_ns);
    wire::put_f64(out, t.outcome.t_bw_fast_ns);
    wire::put_f64(out, t.outcome.t_bw_slow_ns);
    wire::put_f64(out, t.outcome.t_block_ns);
    wire::put_u8(out, bound_code(t.outcome.bound));
}

fn take_trace(r: &mut Reader<'_>) -> Result<RunTrace> {
    Ok(RunTrace {
        interval: r.u32()?,
        clock_ns: r.f64()?,
        wall_ns: r.f64()?,
        acc_fast: r.u64()?,
        acc_slow: r.u64()?,
        sacc_fast: r.u64()?,
        sacc_slow: r.u64()?,
        flops: r.u64()?,
        iops: r.u64()?,
        promoted: r.u64()?,
        promote_failed: r.u64()?,
        demoted_kswapd: r.u64()?,
        demoted_direct: r.u64()?,
        shadow_hits: r.u64()?,
        shadow_free_demotions: r.u64()?,
        txn_aborts: r.u64()?,
        txn_retried_copies: r.u64()?,
        admission_accepted: r.u64()?,
        admission_rejected_budget: r.u64()?,
        admission_rejected_payoff: r.u64()?,
        admission_rejected_cooldown: r.u64()?,
        fast_used: r.u64()?,
        fast_free: r.u64()?,
        usable_fm: r.u64()?,
        outcome: IntervalOutcome {
            wall_ns: r.f64()?,
            t_comp_ns: r.f64()?,
            t_lat_ns: r.f64()?,
            t_bw_fast_ns: r.f64()?,
            t_bw_slow_ns: r.f64()?,
            t_block_ns: r.f64()?,
            bound: bound_from_code(r.u8()?)?,
        },
    })
}

/// Serialize a (key, baseline run) pair into one artifact file image.
pub fn baseline_to_bytes(key: &BaselineKey, result: &RunResult) -> Vec<u8> {
    let mut body = Vec::with_capacity(128 + result.trace.len() * 160);
    wire::put_str(&mut body, sim_fingerprint());
    wire::put_str(&mut body, &key.workload);
    wire::put_u64(&mut body, key.seed);
    wire::put_u32(&mut body, key.intervals);
    wire::put_u32(&mut body, key.hot_thr);
    wire::put_str(&mut body, &key.machine);
    wire::put_str(&mut body, result.workload);
    wire::put_str(&mut body, result.policy);
    wire::put_u64(&mut body, result.fast_capacity);
    wire::put_f64(&mut body, result.total_ns);
    wire::put_u32(&mut body, result.trace.len() as u32);
    for t in &result.trace {
        put_trace(&mut body, t);
    }
    let mut out = Vec::with_capacity(8 + body.len() + 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc32(&body).to_le_bytes());
    out
}

/// Parse a baseline artifact (validates magic, CRC and structure).
pub fn baseline_from_bytes(data: &[u8]) -> Result<(BaselineKey, RunResult)> {
    if data.len() < 8 + 4 || &data[..8] != MAGIC {
        bail!("bad baseline-artifact magic");
    }
    let body = &data[8..data.len() - 4];
    let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let computed = crc32(body);
    if stored != computed {
        bail!("baseline artifact CRC mismatch: stored {stored:#x}, computed {computed:#x}");
    }
    let mut r = Reader::new(body);
    let fingerprint = r.str()?;
    if fingerprint != sim_fingerprint() {
        bail!(
            "baseline artifact written by `{fingerprint}`, this build is `{}` \
             (simulator code changed; stored times are stale)",
            sim_fingerprint()
        );
    }
    let key = BaselineKey {
        workload: r.str()?,
        seed: r.u64()?,
        intervals: r.u32()?,
        hot_thr: r.u32()?,
        machine: r.str()?,
    };
    let workload_name = r.str()?;
    let policy_name = r.str()?;
    // interned names leak one copy each by design; bound them so a
    // crafted artifact can't grow the pool with megabyte "names"
    if workload_name.len() > 256 || policy_name.len() > 256 {
        bail!("implausible name length in baseline artifact");
    }
    let workload = intern(&workload_name);
    let policy = intern(&policy_name);
    let fast_capacity = r.u64()?;
    let total_ns = r.f64()?;
    let n_trace = r.u32()? as usize;
    if n_trace > 10_000_000 {
        bail!("implausible trace length {n_trace} in baseline artifact");
    }
    let mut trace = Vec::with_capacity(n_trace);
    for _ in 0..n_trace {
        trace.push(take_trace(&mut r)?);
    }
    r.done()?;
    Ok((key, RunResult { workload, policy, fast_capacity, total_ns, trace }))
}

/// One-line summary of a baseline artifact for listings, reading only the
/// header (first 4 KiB) — never the trace payload or its CRC, so
/// `tuna store ls` stays proportional to artifact *count*, not bytes.
pub fn peek_summary(path: &Path) -> Result<String> {
    use std::io::Read;
    let mut buf = Vec::with_capacity(4096);
    std::fs::File::open(path)
        .with_context(|| format!("opening baseline artifact {}", path.display()))?
        .take(4096)
        .read_to_end(&mut buf)?;
    if buf.len() < 8 || &buf[..8] != MAGIC {
        bail!("bad baseline-artifact magic");
    }
    let mut r = Reader::new(&buf[8..]);
    let fingerprint = r.str()?;
    let workload = r.str()?;
    let seed = r.u64()?;
    let _intervals = r.u32()?;
    let _hot_thr = r.u32()?;
    let _machine = r.str()?;
    let _run_workload = r.str()?;
    let _run_policy = r.str()?;
    let _fast_capacity = r.u64()?;
    let _total_ns = r.f64()?;
    let n_trace = r.u32()?;
    let stale = if fingerprint == sim_fingerprint() { "" } else { ", stale version" };
    Ok(format!("{workload} seed {seed} ({n_trace} intervals{stale})"))
}

fn key_hash(key: &BaselineKey) -> u64 {
    let mut bytes = Vec::with_capacity(64);
    wire::put_str(&mut bytes, &key.workload);
    wire::put_u64(&mut bytes, key.seed);
    wire::put_u32(&mut bytes, key.intervals);
    wire::put_u32(&mut bytes, key.hot_thr);
    wire::put_str(&mut bytes, &key.machine);
    fnv1a64(&bytes)
}

/// The disk tier behind [`crate::coordinator::sweep::BaselineCache`]:
/// one CRC'd artifact per baseline key under `dir`.
#[derive(Clone, Debug)]
pub struct DiskBaselineCache {
    dir: PathBuf,
}

impl DiskBaselineCache {
    pub fn open(dir: &Path) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating baseline cache dir {}", dir.display()))?;
        Ok(DiskBaselineCache { dir: dir.to_path_buf() })
    }

    pub fn path_for(&self, key: &BaselineKey) -> PathBuf {
        self.dir.join(format!("{:016x}.bl", key_hash(key)))
    }

    /// Load the baseline for `key`, or `None` when absent, unreadable or
    /// keyed differently (hash collision) — all of which degrade to a
    /// recompute, with a warning for the corrupt cases.
    pub fn load(&self, key: &BaselineKey) -> Option<RunResult> {
        self.load_with_obs(key, &crate::obs::Recorder::default())
    }

    /// As [`Self::load`], reporting corrupt-artifact diagnostics through
    /// the recorder (the stderr line is emitted either way; an enabled
    /// recorder additionally counts `obs_warn_total` and journals the
    /// site).
    pub fn load_with_obs(
        &self,
        key: &BaselineKey,
        obs: &crate::obs::Recorder,
    ) -> Option<RunResult> {
        let path = self.path_for(key);
        let data = match std::fs::read(&path) {
            Ok(d) => d,
            // absent = ordinary cache miss; anything else (EACCES etc.)
            // deserves a diagnostic or the persistence feature fails mute
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(e) => {
                obs.warn(
                    "baseline.load",
                    &format!("baseline artifact {} unreadable ({e}); recomputing", path.display()),
                );
                return None;
            }
        };
        match baseline_from_bytes(&data) {
            Ok((stored_key, result)) if stored_key == *key => Some(result),
            Ok(_) => {
                obs.warn(
                    "baseline.load",
                    &format!(
                        "baseline artifact {} holds a different key (hash collision?); recomputing",
                        path.display()
                    ),
                );
                None
            }
            Err(e) => {
                obs.warn(
                    "baseline.load",
                    &format!("baseline artifact {} unreadable ({e:#}); recomputing", path.display()),
                );
                None
            }
        }
    }

    /// Persist the baseline for `key` (atomic write; concurrent writers
    /// of the same key race benignly — runs are deterministic, so both
    /// write identical bytes).
    pub fn store(&self, key: &BaselineKey, result: &RunResult) -> Result<()> {
        write_atomic(&self.path_for(key), &baseline_to_bytes(key, result))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (BaselineKey, RunResult) {
        let key = BaselineKey {
            workload: "bfs".to_string(),
            seed: 42,
            intervals: 2,
            hot_thr: 2,
            machine: "MachineModel { .. }".to_string(),
        };
        let trace = |i: u32| RunTrace {
            interval: i,
            clock_ns: 1e9 * i as f64,
            wall_ns: 5e8 + i as f64,
            acc_fast: 1000 + i as u64,
            acc_slow: 10,
            sacc_fast: 900,
            sacc_slow: 9,
            flops: 1_000_000,
            iops: 2_000_000,
            promoted: 5,
            promote_failed: 1,
            demoted_kswapd: 3,
            demoted_direct: 2,
            shadow_hits: 7 + i as u64,
            shadow_free_demotions: 4,
            txn_aborts: 2,
            txn_retried_copies: 1,
            admission_accepted: 6 + i as u64,
            admission_rejected_budget: 3,
            admission_rejected_payoff: 8,
            admission_rejected_cooldown: 2,
            fast_used: 800,
            fast_free: 200,
            usable_fm: 950,
            outcome: IntervalOutcome {
                wall_ns: 5e8,
                t_comp_ns: 1e8,
                t_lat_ns: 2e8,
                t_bw_fast_ns: 5e8,
                t_bw_slow_ns: 1e7,
                t_block_ns: 0.0,
                bound: Bound::FastBw,
            },
        };
        let result = RunResult {
            workload: "BFS",
            policy: "tpp",
            fast_capacity: 1000,
            total_ns: 1e9,
            trace: vec![trace(1), trace(2)],
        };
        (key, result)
    }

    fn assert_traces_equal(a: &RunResult, b: &RunResult) {
        assert_eq!(a.workload, b.workload);
        assert_eq!(a.policy, b.policy);
        assert_eq!(a.fast_capacity, b.fast_capacity);
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits());
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.interval, y.interval);
            assert_eq!(x.wall_ns.to_bits(), y.wall_ns.to_bits());
            assert_eq!(x.acc_fast, y.acc_fast);
            assert_eq!(x.promoted, y.promoted);
            assert_eq!(x.shadow_hits, y.shadow_hits);
            assert_eq!(x.shadow_free_demotions, y.shadow_free_demotions);
            assert_eq!(x.txn_aborts, y.txn_aborts);
            assert_eq!(x.txn_retried_copies, y.txn_retried_copies);
            assert_eq!(x.admission_accepted, y.admission_accepted);
            assert_eq!(x.admission_rejected_budget, y.admission_rejected_budget);
            assert_eq!(x.admission_rejected_payoff, y.admission_rejected_payoff);
            assert_eq!(x.admission_rejected_cooldown, y.admission_rejected_cooldown);
            assert_eq!(x.usable_fm, y.usable_fm);
            assert_eq!(x.outcome.bound, y.outcome.bound);
            assert_eq!(x.outcome.wall_ns.to_bits(), y.outcome.wall_ns.to_bits());
        }
    }

    #[test]
    fn baseline_roundtrip_is_bit_exact() {
        let (key, result) = sample();
        let bytes = baseline_to_bytes(&key, &result);
        let (k2, r2) = baseline_from_bytes(&bytes).unwrap();
        assert_eq!(k2, key);
        assert_traces_equal(&result, &r2);
    }

    #[test]
    fn corruption_and_truncation_rejected() {
        let (key, result) = sample();
        let bytes = baseline_to_bytes(&key, &result);
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        assert!(baseline_from_bytes(&bad).is_err());
        assert!(baseline_from_bytes(&bytes[..bytes.len() - 5]).is_err());
        assert!(baseline_from_bytes(b"TUNABAS1xx").is_err());
    }

    #[test]
    fn disk_cache_stores_and_guards_key_identity() {
        let dir = std::env::temp_dir().join(format!("tuna_blcache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = DiskBaselineCache::open(&dir).unwrap();
        let (key, result) = sample();
        assert!(cache.load(&key).is_none());
        cache.store(&key, &result).unwrap();
        let loaded = cache.load(&key).unwrap();
        assert_traces_equal(&result, &loaded);
        // a different key misses even if we plant a colliding file
        let mut other = key.clone();
        other.seed = 43;
        assert!(cache.load(&other).is_none());
        std::fs::write(cache.path_for(&other), baseline_to_bytes(&key, &result)).unwrap();
        assert!(cache.load(&other).is_none(), "wrong embedded key must not be served");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_fingerprint_is_rejected() {
        let (key, result) = sample();
        let good = baseline_to_bytes(&key, &result);
        // splice a different fingerprint over the stored one and re-CRC
        let orig_body = &good[8..good.len() - 4];
        let fp_len = 4 + u32::from_le_bytes(orig_body[..4].try_into().unwrap()) as usize;
        let mut body = Vec::new();
        wire::put_str(&mut body, "tuna-0.0.0-other-engine");
        body.extend_from_slice(&orig_body[fp_len..]);
        let mut out = MAGIC.to_vec();
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        let err = baseline_from_bytes(&out).unwrap_err();
        assert!(format!("{err:#}").contains("tuna-0.0.0-other-engine"), "{err:#}");
    }

    #[test]
    fn peek_summary_reads_header_only() {
        let dir = std::env::temp_dir().join(format!("tuna_blpeek_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = DiskBaselineCache::open(&dir).unwrap();
        let (key, result) = sample();
        cache.store(&key, &result).unwrap();
        let s = peek_summary(&cache.path_for(&key)).unwrap();
        assert!(s.contains("bfs") && s.contains("seed 42") && s.contains("2 intervals"), "{s}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn intern_returns_one_copy_per_name() {
        let a = intern("tpp");
        let b = intern("tpp");
        assert!(std::ptr::eq(a, b));
        assert_eq!(intern("first-touch"), "first-touch");
    }
}
