//! Sharded performance database: the flat [`PerfDb`] record vector split
//! into N on-disk segment files (hash of configuration vector → shard)
//! under a manifest carrying per-segment CRCs.
//!
//! Queries fan out across shards on the shared worker pool
//! ([`crate::util::parallel`]) and merge — [`ShardedPerfDb::nearest`]
//! reproduces [`crate::perfdb::native::NativeNn`]'s tie-breaking exactly
//! (lowest global index among minimal distances), and
//! [`ShardedPerfDb::time_at`] delegates to the same interpolation code
//! path as the flat DB, so sharded answers are bit-identical to flat ones
//! (asserted in the test suite). The `Sharded ⇄ flat` conversion
//! round-trips byte-identically through [`crate::perfdb::store`].
//!
//! On-disk layout of one sharded database directory:
//!
//! ```text
//! MANIFEST      magic "TUNASHM1", shard/size/record counts, fractions,
//!               per-segment (record count, payload CRC), manifest CRC
//! seg-NNN.bin   magic "TUNASEG1", then per record:
//!               global u32 · raw f64×8 · vec f32×8 · times f32×n_sizes
//! ```
//!
//! Segment payloads are CRC'd in the manifest, which is written last and
//! atomically. Rebuilds into an existing directory stream to unique
//! temps, so a previous generation stays loadable until the new one's
//! commit point ([`ShardedWriter::finish`]): the old manifest is removed
//! first (every later crash window reads as "no database here", never an
//! old manifest checksumming new segments), stale segments from a wider
//! previous generation are swept, and the new manifest lands atomically.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::wire::{self, Reader};
use super::{unique_tmp_path, write_atomic};
use crate::perfdb::native::{dist2, NnQuery};
use crate::perfdb::store::{crc32, Crc32};
use crate::perfdb::{PerfDb, Record, DIMS};
use crate::util::parallel::{default_threads, parallel_map};

const MANIFEST_MAGIC: &[u8; 8] = b"TUNASHM1";
const SEGMENT_MAGIC: &[u8; 8] = b"TUNASEG1";
const MANIFEST_NAME: &str = "MANIFEST";

/// Default shard count for CLI builds.
pub const DEFAULT_SHARDS: usize = 8;

/// Below this many total records a query scans shards serially: spawning
/// scoped worker threads per lookup costs more than the scan itself.
const SERIAL_QUERY_THRESHOLD: usize = 8192;

/// Shard a configuration vector: FNV-1a over the raw f64 bits. A pure
/// function of (raw, n_shards), so routing is identical across builds,
/// saves and loads.
pub fn shard_of(raw: &[f64; DIMS], n_shards: usize) -> usize {
    let mut bytes = [0u8; DIMS * 8];
    for (i, x) in raw.iter().enumerate() {
        bytes[i * 8..(i + 1) * 8].copy_from_slice(&x.to_le_bytes());
    }
    (super::fnv1a64(&bytes) % n_shards.max(1) as u64) as usize
}

fn segment_name(si: usize) -> String {
    format!("seg-{si:03}.bin")
}

fn record_size(n_sizes: usize) -> usize {
    4 + DIMS * 8 + DIMS * 4 + n_sizes * 4
}

/// One shard: its records (as a [`PerfDb`] over the shared fraction grid,
/// so every query delegates to the flat code path) plus each record's
/// global index in the flat ordering.
#[derive(Clone, Debug)]
pub struct Shard {
    pub global: Vec<u32>,
    pub db: PerfDb,
}

/// Per-segment metadata from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct SegmentMeta {
    pub n_recs: u64,
    pub payload_crc: u32,
}

/// Parsed manifest of a sharded database directory.
#[derive(Clone, Debug)]
pub struct ManifestInfo {
    pub fractions: Vec<f32>,
    pub n_records: u64,
    pub segments: Vec<SegmentMeta>,
}

/// Read and validate the `MANIFEST` file of a sharded DB directory.
pub fn read_manifest(dir: &Path) -> Result<ManifestInfo> {
    let path = dir.join(MANIFEST_NAME);
    let data = std::fs::read(&path)
        .with_context(|| format!("opening sharded-perfdb manifest {}", path.display()))?;
    if data.len() < 8 + 4 || &data[..8] != MANIFEST_MAGIC {
        bail!("bad manifest magic in {}", path.display());
    }
    let body = &data[8..data.len() - 4];
    let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let computed = crc32(body);
    if stored != computed {
        bail!("manifest CRC mismatch in {}: stored {stored:#x}, computed {computed:#x}",
            path.display());
    }
    let mut r = Reader::new(body);
    let n_shards = r.u32()? as usize;
    let n_sizes = r.u32()? as usize;
    let n_records = r.u64()?;
    if n_shards == 0 || n_shards > 4096 || n_sizes == 0 || n_sizes > 1_000 {
        bail!("implausible manifest header: {n_shards} shards, {n_sizes} sizes");
    }
    let mut fractions = Vec::with_capacity(n_sizes);
    for _ in 0..n_sizes {
        fractions.push(r.f32()?);
    }
    let mut segments = Vec::with_capacity(n_shards);
    let mut total = 0u64;
    for _ in 0..n_shards {
        let seg = SegmentMeta { n_recs: r.u64()?, payload_crc: r.u32()? };
        // Bound per-segment counts like every other codec (records cap
        // mirrors the flat store's): a crafted/corrupt n_recs must fail
        // parsing, never reach a Vec::with_capacity or a wrapping
        // multiply against the payload length.
        if seg.n_recs > 10_000_000 {
            bail!("implausible segment record count {}", seg.n_recs);
        }
        total += seg.n_recs; // ≤ 4096 × 1e7 — cannot overflow u64
        segments.push(seg);
    }
    r.done()?;
    if total != n_records {
        bail!("manifest record counts sum to {total}, header says {n_records}");
    }
    Ok(ManifestInfo { fractions, n_records, segments })
}

/// The sharded database: shards plus a global-index → (shard, local)
/// lookup so flat-indexed queries ([`Self::time_at`]) stay O(1).
#[derive(Clone, Debug)]
pub struct ShardedPerfDb {
    pub fractions: Vec<f32>,
    pub shards: Vec<Shard>,
    loc: Vec<(u32, u32)>,
}

impl ShardedPerfDb {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.loc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.loc.is_empty()
    }

    /// Split a flat database into `n_shards` shards (routing by
    /// [`shard_of`]). Converting back with [`Self::to_flat`] reproduces
    /// the input bit-for-bit.
    pub fn from_flat(db: &PerfDb, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        assert!(db.records.len() < u32::MAX as usize, "record count overflows u32 indices");
        let mut shards: Vec<Shard> = (0..n_shards)
            .map(|_| Shard {
                global: Vec::new(),
                db: PerfDb { fractions: db.fractions.clone(), records: Vec::new() },
            })
            .collect();
        let mut loc = Vec::with_capacity(db.records.len());
        for (g, r) in db.records.iter().enumerate() {
            let si = shard_of(&r.raw, n_shards);
            loc.push((si as u32, shards[si].db.records.len() as u32));
            shards[si].global.push(g as u32);
            shards[si].db.records.push(r.clone());
        }
        ShardedPerfDb { fractions: db.fractions.clone(), shards, loc }
    }

    /// Reassemble the flat database in original global order.
    pub fn to_flat(&self) -> PerfDb {
        let records = self
            .loc
            .iter()
            .map(|&(si, li)| self.shards[si as usize].db.records[li as usize].clone())
            .collect();
        PerfDb { fractions: self.fractions.clone(), records }
    }

    /// The record at a flat (global) index.
    pub fn record(&self, global: usize) -> &Record {
        let (si, li) = self.loc[global];
        &self.shards[si as usize].db.records[li as usize]
    }

    /// Predicted execution time at an arbitrary fraction — same code path
    /// as [`PerfDb::time_at`], so sharded and flat answers are
    /// bit-identical.
    pub fn time_at(&self, global: usize, fraction: f64) -> f64 {
        let (si, li) = self.loc[global];
        self.shards[si as usize].db.time_at(li as usize, fraction)
    }

    /// Nearest record to `q`: fan out one brute-force scan per shard on
    /// the worker pool, then merge. Tie-breaking matches
    /// [`crate::perfdb::native::NativeNn::nearest`]: the lowest global
    /// index among minimal distances. `threads == 0` means one per core.
    pub fn nearest(&self, q: &[f32; DIMS], threads: usize) -> Option<(usize, f32)> {
        if self.is_empty() {
            return None;
        }
        let scan = |si: usize| -> Option<(usize, f32)> {
            let sh = &self.shards[si];
            let mut best: Option<(usize, f32)> = None;
            for (li, r) in sh.db.records.iter().enumerate() {
                let d = dist2(q, &r.vec);
                let g = sh.global[li] as usize;
                let better = match best {
                    None => true,
                    Some((bg, bd)) => d < bd || (d == bd && g < bg),
                };
                if better {
                    best = Some((g, d));
                }
            }
            best
        };
        let per = self.fan_out(threads, scan);
        per.into_iter().flatten().reduce(|a, b| {
            if b.1 < a.1 || (b.1 == a.1 && b.0 < a.0) {
                b
            } else {
                a
            }
        })
    }

    /// Evaluate `scan` on every shard: serially when the database is too
    /// small for fan-out to beat thread-spawn cost (or one worker was
    /// requested), otherwise on the worker pool. Both paths return
    /// results in shard order, so the merge is scheduling-independent.
    fn fan_out<T: Send, F: Fn(usize) -> T + Sync>(&self, threads: usize, scan: F) -> Vec<T> {
        let serial = threads == 1
            || self.shards.len() == 1
            || self.len() <= SERIAL_QUERY_THRESHOLD;
        if serial {
            (0..self.shards.len()).map(scan).collect()
        } else {
            let threads = if threads == 0 { default_threads() } else { threads };
            parallel_map(self.shards.len(), threads, scan)
        }
    }

    /// `k` nearest records, ascending by (distance, global index) — the
    /// same ordering as [`crate::perfdb::native::NativeNn::top_k`]. Each
    /// shard returns its local top-k; the merge keeps the global top-k.
    pub fn top_k(&self, q: &[f32; DIMS], k: usize, threads: usize) -> Vec<(usize, f32)> {
        if self.is_empty() || k == 0 {
            return Vec::new();
        }
        let per = self.fan_out(threads, |si| {
            let sh = &self.shards[si];
            let mut all: Vec<(usize, f32)> = sh
                .db
                .records
                .iter()
                .enumerate()
                .map(|(li, r)| (sh.global[li] as usize, dist2(q, &r.vec)))
                .collect();
            all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            all.truncate(k);
            all
        });
        let mut merged: Vec<(usize, f32)> = per.into_iter().flatten().collect();
        merged.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        merged.truncate(k);
        merged
    }

    /// Write the database to `dir` (segments streamed, manifest written
    /// atomically last).
    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut w = ShardedWriter::create(dir, &self.fractions, self.n_shards())?;
        for g in 0..self.len() {
            w.push(self.record(g))?;
        }
        w.finish()?;
        Ok(())
    }

    /// Load a sharded database from `dir`, validating the manifest CRC,
    /// every segment's payload CRC, and that the global indices form a
    /// permutation of `0..n_records`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = read_manifest(dir)?;
        let n_sizes = manifest.fractions.len();
        let rec_size = record_size(n_sizes);
        let mut shards = Vec::with_capacity(manifest.segments.len());
        for (si, seg) in manifest.segments.iter().enumerate() {
            let path = dir.join(segment_name(si));
            let data = std::fs::read(&path)
                .with_context(|| format!("opening segment {}", path.display()))?;
            if data.len() < 8 || &data[..8] != SEGMENT_MAGIC {
                bail!("bad segment magic in {}", path.display());
            }
            let payload = &data[8..];
            let computed = crc32(payload);
            if computed != seg.payload_crc {
                bail!(
                    "segment {} CRC mismatch: manifest {:#x}, computed {computed:#x}",
                    path.display(),
                    seg.payload_crc
                );
            }
            if payload.len() as u64 != seg.n_recs * rec_size as u64 {
                bail!(
                    "segment {} holds {} bytes, manifest expects {} records of {} bytes",
                    path.display(),
                    payload.len(),
                    seg.n_recs,
                    rec_size
                );
            }
            let mut global = Vec::with_capacity(seg.n_recs as usize);
            let mut records = Vec::with_capacity(seg.n_recs as usize);
            let mut r = Reader::new(payload);
            for _ in 0..seg.n_recs {
                global.push(r.u32()?);
                let mut raw = [0f64; DIMS];
                for x in &mut raw {
                    *x = r.f64()?;
                }
                let mut vec = [0f32; DIMS];
                for x in &mut vec {
                    *x = r.f32()?;
                }
                let mut times_ns = Vec::with_capacity(n_sizes);
                for _ in 0..n_sizes {
                    times_ns.push(r.f32()?);
                }
                records.push(Record { raw, vec, times_ns });
            }
            r.done()?;
            shards.push(Shard {
                global,
                db: PerfDb { fractions: manifest.fractions.clone(), records },
            });
        }
        let loc = build_loc(&shards, manifest.n_records as usize)?;
        Ok(ShardedPerfDb { fractions: manifest.fractions, shards, loc })
    }
}

fn build_loc(shards: &[Shard], n_records: usize) -> Result<Vec<(u32, u32)>> {
    const HOLE: (u32, u32) = (u32::MAX, u32::MAX);
    let mut loc = vec![HOLE; n_records];
    for (si, sh) in shards.iter().enumerate() {
        if sh.global.len() != sh.db.records.len() {
            bail!("shard {si}: {} indices for {} records", sh.global.len(), sh.db.records.len());
        }
        for (li, &g) in sh.global.iter().enumerate() {
            let g = g as usize;
            if g >= n_records {
                bail!("shard {si}: global index {g} out of range (n_records {n_records})");
            }
            if loc[g] != HOLE {
                bail!("duplicate global index {g} across segments");
            }
            loc[g] = (si as u32, li as u32);
        }
    }
    if let Some(g) = loc.iter().position(|&x| x == HOLE) {
        bail!("global index {g} missing from every segment");
    }
    Ok(loc)
}

/// Streaming writer: routes each completed record straight into its
/// segment file, so multi-million-record builds never hold the whole
/// database in memory. Segments stream to unique temps and are renamed at
/// [`Self::finish`]; the manifest (with final counts and CRCs) is written
/// atomically last.
pub struct ShardedWriter {
    dir: PathBuf,
    fractions: Vec<f32>,
    segments: Vec<SegmentWriter>,
    n_records: u64,
}

impl ShardedWriter {
    pub fn create(dir: &Path, fractions: &[f32], n_shards: usize) -> Result<Self> {
        let n_shards = n_shards.max(1);
        // The writer holds one open temp file per shard, so the build cap
        // sits well under common fd soft limits (1024); the *read* path
        // opens segments sequentially and accepts up to the 4096 the
        // manifest format allows.
        if n_shards > 512 {
            bail!("{n_shards} shards exceeds the build limit of 512 (one open file per shard)");
        }
        if fractions.is_empty() {
            bail!("sharded perfdb needs a non-empty fraction grid");
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating sharded-perfdb dir {}", dir.display()))?;
        let mut segments = Vec::with_capacity(n_shards);
        for si in 0..n_shards {
            segments.push(SegmentWriter::create(dir.join(segment_name(si)))?);
        }
        Ok(ShardedWriter {
            dir: dir.to_path_buf(),
            fractions: fractions.to_vec(),
            segments,
            n_records: 0,
        })
    }

    /// Append one record (the next global index). Routing is by
    /// [`shard_of`], so push order defines the flat ordering.
    pub fn push(&mut self, r: &Record) -> Result<()> {
        if r.times_ns.len() != self.fractions.len() {
            bail!(
                "record has {} times for {} fractions",
                r.times_ns.len(),
                self.fractions.len()
            );
        }
        if self.n_records >= u32::MAX as u64 {
            bail!("sharded perfdb overflows u32 global indices");
        }
        let si = shard_of(&r.raw, self.segments.len());
        self.segments[si].push(self.n_records as u32, r)?;
        self.n_records += 1;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.n_records as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n_records == 0
    }

    /// Finalize the commit point. A previous generation in the same
    /// directory survives untouched right up to here (segments stream to
    /// unique temps), so a build that *fails* leaves the old database
    /// loadable; `finish` then (1) sets the old manifest aside as
    /// `MANIFEST.old` — every later crash window reads as "no database",
    /// never an old manifest checksumming new segments, and a failure is
    /// recoverable by renaming it back — (2) renames the new segments
    /// into place, (3) sweeps stale segments from a wider previous
    /// generation, and (4) writes the new manifest atomically, removing
    /// `MANIFEST.old` on success. Returns the directory written.
    pub fn finish(self) -> Result<PathBuf> {
        let ShardedWriter { dir, fractions, segments, n_records } = self;
        let n_shards = segments.len();
        // Set the old manifest ASIDE (not unlink): a failure before any
        // new segment lands can be rolled back by renaming it back; once
        // segments start overwriting, the previous generation is gone
        // either way and the directory correctly reads as "no database".
        let manifest_path = dir.join(MANIFEST_NAME);
        let manifest_old = dir.join("MANIFEST.old");
        match std::fs::rename(&manifest_path, &manifest_old) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("setting aside old manifest in {}", dir.display()))
            }
        }
        let commit = || -> Result<()> {
            let mut metas = Vec::with_capacity(n_shards);
            for seg in segments {
                metas.push(seg.finish()?);
            }
            // Remove segments a previous build left behind (e.g. 8
            // shards rebuilt as 4): they are unreferenced by the new
            // manifest but would count into listings and confuse
            // inspection.
            for entry in std::fs::read_dir(&dir)? {
                let path = entry?.path();
                let name = path.file_name().map(|s| s.to_string_lossy().into_owned());
                if let Some(name) = name {
                    // Orphaned temps from builds that were SIGKILLed or
                    // lost power (Drop never ran): this build's own temps
                    // were renamed away before this sweep, and the dir is
                    // single-writer, so any remaining .tmp is garbage.
                    if name.ends_with(".tmp") {
                        std::fs::remove_file(&path).ok();
                        continue;
                    }
                    if let Some(idx) = name
                        .strip_prefix("seg-")
                        .and_then(|s| s.strip_suffix(".bin"))
                        .and_then(|s| s.parse::<usize>().ok())
                    {
                        if idx >= n_shards {
                            std::fs::remove_file(&path)
                                .with_context(|| format!("sweeping stale {}", path.display()))?;
                        }
                    }
                }
            }
            let mut body = Vec::new();
            wire::put_u32(&mut body, n_shards as u32);
            wire::put_u32(&mut body, fractions.len() as u32);
            wire::put_u64(&mut body, n_records);
            for &f in &fractions {
                wire::put_f32(&mut body, f);
            }
            for m in &metas {
                wire::put_u64(&mut body, m.n_recs);
                wire::put_u32(&mut body, m.payload_crc);
            }
            let mut out = Vec::with_capacity(8 + body.len() + 4);
            out.extend_from_slice(MANIFEST_MAGIC);
            out.extend_from_slice(&body);
            out.extend_from_slice(&crc32(&body).to_le_bytes());
            write_atomic(&manifest_path, &out)
        };
        match commit() {
            Ok(()) => {
                std::fs::remove_file(&manifest_old).ok();
                Ok(dir)
            }
            Err(e) => Err(e.context(format!(
                "sharded rebuild failed; old manifest kept at {} (renaming it back \
                 restores the previous database ONLY if no new segment was renamed \
                 into place yet — after that, segments are mixed-generation and the \
                 directory must be rebuilt)",
                manifest_old.display()
            ))),
        }
    }
}

struct SegmentWriter {
    /// `Some` until [`Self::finish`] closes it (needed so [`Drop`] can
    /// close before unlinking an abandoned temp).
    file: Option<std::io::BufWriter<std::fs::File>>,
    tmp: PathBuf,
    dest: PathBuf,
    crc: Crc32,
    n_recs: u64,
    finished: bool,
    /// Reusable serialization scratch — the streaming build path exists
    /// for multi-million-record databases, so no per-record allocation.
    buf: Vec<u8>,
}

impl SegmentWriter {
    fn create(dest: PathBuf) -> Result<Self> {
        let tmp = unique_tmp_path(&dest);
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("creating segment temp {}", tmp.display()))?,
        );
        file.write_all(SEGMENT_MAGIC)?;
        Ok(SegmentWriter {
            file: Some(file),
            tmp,
            dest,
            crc: Crc32::new(),
            n_recs: 0,
            finished: false,
            buf: Vec::new(),
        })
    }

    fn push(&mut self, global: u32, r: &Record) -> Result<()> {
        self.buf.clear();
        wire::put_u32(&mut self.buf, global);
        for &x in &r.raw {
            wire::put_f64(&mut self.buf, x);
        }
        for &x in &r.vec {
            wire::put_f32(&mut self.buf, x);
        }
        for &t in &r.times_ns {
            wire::put_f32(&mut self.buf, t);
        }
        self.crc.update(&self.buf);
        self.file.as_mut().expect("segment writer already finished").write_all(&self.buf)?;
        self.n_recs += 1;
        Ok(())
    }

    fn finish(mut self) -> Result<SegmentMeta> {
        let mut file = self.file.take().expect("segment writer already finished");
        file.flush().with_context(|| format!("flushing segment {}", self.tmp.display()))?;
        // durability before the rename: see `write_atomic` (the manifest
        // write at the end of the build syncs the directory itself)
        file.get_ref()
            .sync_all()
            .with_context(|| format!("syncing segment {}", self.tmp.display()))?;
        drop(file);
        std::fs::rename(&self.tmp, &self.dest).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.dest.display())
        })?;
        self.finished = true;
        Ok(SegmentMeta { n_recs: self.n_recs, payload_crc: self.crc.finish() })
    }
}

impl Drop for SegmentWriter {
    /// An abandoned or failed build must not leak its uniquely-named
    /// temp (nothing ever overwrites or sweeps `.tmp` files).
    fn drop(&mut self) {
        if !self.finished {
            self.file.take();
            std::fs::remove_file(&self.tmp).ok();
        }
    }
}

/// [`NnQuery`] adapter over a sharded database — pluggable wherever the
/// native or XLA backends go (tuner, benches).
pub struct ShardedNn {
    db: std::sync::Arc<ShardedPerfDb>,
    threads: usize,
}

impl ShardedNn {
    /// `threads == 0` means one worker per core.
    pub fn new(db: std::sync::Arc<ShardedPerfDb>, threads: usize) -> Self {
        ShardedNn { db, threads }
    }
}

impl NnQuery for ShardedNn {
    fn nearest(&mut self, q: &[f32; DIMS]) -> crate::Result<(usize, f32)> {
        self.db.nearest(q, self.threads).ok_or_else(|| anyhow::anyhow!("empty database"))
    }

    fn top_k(&mut self, q: &[f32; DIMS], k: usize) -> crate::Result<Vec<(usize, f32)>> {
        anyhow::ensure!(!self.db.is_empty(), "empty database");
        Ok(self.db.top_k(q, k, self.threads))
    }

    fn backend(&self) -> &'static str {
        "sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::native::NativeNn;
    use crate::perfdb::{normalize, store};
    use crate::util::rng::Rng;

    fn sample_db(n: usize, seed: u64) -> PerfDb {
        let mut rng = Rng::new(seed);
        let fractions = vec![1.0, 0.9, 0.8, 0.6, 0.4];
        let records = (0..n)
            .map(|_| {
                let raw = [
                    rng.range_f64(100.0, 50_000.0),
                    rng.range_f64(0.0, 10_000.0),
                    rng.range_f64(0.0, 400.0),
                    rng.range_f64(0.0, 400.0),
                    rng.range_f64(0.05, 20.0),
                    rng.range_f64(3_000.0, 40_000.0),
                    2.0,
                    16.0,
                ];
                Record {
                    raw,
                    vec: normalize(&raw),
                    times_ns: (0..fractions.len())
                        .map(|i| 100.0 + i as f32 * (1.0 + rng.f32()))
                        .collect(),
                }
            })
            .collect();
        PerfDb { fractions, records }
    }

    #[test]
    fn flat_sharded_flat_is_bit_identical() {
        let db = sample_db(41, 3);
        for n_shards in [1, 2, 5, 64] {
            let sharded = ShardedPerfDb::from_flat(&db, n_shards);
            assert_eq!(sharded.len(), db.records.len());
            assert_eq!(
                store::to_bytes(&sharded.to_flat()),
                store::to_bytes(&db),
                "{n_shards} shards"
            );
        }
    }

    #[test]
    fn sharded_queries_match_flat_exactly() {
        let db = sample_db(37, 7);
        let sharded = ShardedPerfDb::from_flat(&db, 4);
        let mut native = NativeNn::new(&db);
        let mut rng = Rng::new(9);
        for _ in 0..32 {
            let raw = [
                rng.range_f64(100.0, 50_000.0),
                rng.range_f64(0.0, 10_000.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.05, 20.0),
                rng.range_f64(3_000.0, 40_000.0),
                2.0,
                16.0,
            ];
            let q = normalize(&raw);
            let (fi, fd) = native.nearest(&q).unwrap();
            let (si, sd) = sharded.nearest(&q, 2).unwrap();
            assert_eq!((si, sd.to_bits()), (fi, fd.to_bits()));
            let ft = NativeNn::new(&db).top_k(&q, 5);
            let st = sharded.top_k(&q, 5, 2);
            assert_eq!(st.len(), ft.len());
            for (a, b) in ft.iter().zip(&st) {
                assert_eq!((a.0, a.1.to_bits()), (b.0, b.1.to_bits()));
            }
            let frac = rng.range_f64(0.3, 1.0);
            assert_eq!(db.time_at(fi, frac).to_bits(), sharded.time_at(fi, frac).to_bits());
        }
    }

    #[test]
    fn parallel_fan_out_path_matches_flat_above_threshold() {
        // enough records that fan_out takes the parallel_map branch —
        // the merge/tie-break there must agree with the flat argmin too
        let db = sample_db(SERIAL_QUERY_THRESHOLD + 64, 29);
        let sharded = ShardedPerfDb::from_flat(&db, 6);
        assert!(sharded.len() > SERIAL_QUERY_THRESHOLD);
        let mut native = NativeNn::new(&db);
        let mut rng = Rng::new(31);
        for _ in 0..8 {
            let raw = [
                rng.range_f64(100.0, 50_000.0),
                rng.range_f64(0.0, 10_000.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.05, 20.0),
                rng.range_f64(3_000.0, 40_000.0),
                2.0,
                16.0,
            ];
            let q = normalize(&raw);
            let (fi, fd) = native.nearest(&q).unwrap();
            let (si, sd) = sharded.nearest(&q, 4).unwrap();
            assert_eq!((si, sd.to_bits()), (fi, fd.to_bits()));
            let ft = NativeNn::new(&db).top_k(&q, 4);
            let st = sharded.top_k(&q, 4, 4);
            for (a, b) in ft.iter().zip(&st) {
                assert_eq!((a.0, a.1.to_bits()), (b.0, b.1.to_bits()));
            }
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_bytes() {
        let db = sample_db(23, 11);
        let sharded = ShardedPerfDb::from_flat(&db, 3);
        let dir = std::env::temp_dir()
            .join(format!("tuna_shard_rt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        sharded.save(&dir).unwrap();
        let back = ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(back.n_shards(), 3);
        assert_eq!(store::to_bytes(&back.to_flat()), store::to_bytes(&db));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_segment_or_manifest_is_rejected() {
        let db = sample_db(12, 13);
        let dir = std::env::temp_dir()
            .join(format!("tuna_shard_corrupt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, 2).save(&dir).unwrap();

        // flip a byte in a non-empty segment → CRC mismatch
        let seg = (0..2)
            .map(|si| dir.join(segment_name(si)))
            .find(|p| std::fs::metadata(p).unwrap().len() > 8)
            .unwrap();
        let mut bytes = std::fs::read(&seg).unwrap();
        let mid = 8 + (bytes.len() - 8) / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&seg, &bytes).unwrap();
        assert!(ShardedPerfDb::load(&dir).is_err());

        // corrupt manifest magic
        let manifest = dir.join(MANIFEST_NAME);
        let mut m = std::fs::read(&manifest).unwrap();
        m[0] = b'X';
        std::fs::write(&manifest, &m).unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_writer_matches_from_flat_save() {
        let db = sample_db(19, 17);
        let a = std::env::temp_dir().join(format!("tuna_shard_wa_{}", std::process::id()));
        let b = std::env::temp_dir().join(format!("tuna_shard_wb_{}", std::process::id()));
        std::fs::remove_dir_all(&a).ok();
        std::fs::remove_dir_all(&b).ok();
        ShardedPerfDb::from_flat(&db, 4).save(&a).unwrap();
        let mut w = ShardedWriter::create(&b, &db.fractions, 4).unwrap();
        for r in &db.records {
            w.push(r).unwrap();
        }
        assert_eq!(w.len(), db.records.len());
        w.finish().unwrap();
        for si in 0..4 {
            assert_eq!(
                std::fs::read(a.join(segment_name(si))).unwrap(),
                std::fs::read(b.join(segment_name(si))).unwrap(),
                "segment {si}"
            );
        }
        assert_eq!(
            std::fs::read(a.join(MANIFEST_NAME)).unwrap(),
            std::fs::read(b.join(MANIFEST_NAME)).unwrap()
        );
        std::fs::remove_dir_all(&a).ok();
        std::fs::remove_dir_all(&b).ok();
    }

    #[test]
    fn rebuild_with_fewer_shards_sweeps_stale_segments() {
        let db = sample_db(20, 23);
        let dir = std::env::temp_dir()
            .join(format!("tuna_shard_rebuild_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, 8).save(&dir).unwrap();
        assert!(dir.join(segment_name(7)).exists());
        // rebuild narrower into the same directory
        ShardedPerfDb::from_flat(&db, 3).save(&dir).unwrap();
        let back = ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(back.n_shards(), 3);
        assert_eq!(store::to_bytes(&back.to_flat()), store::to_bytes(&db));
        for si in 3..8 {
            assert!(!dir.join(segment_name(si)).exists(), "stale segment {si} not swept");
        }
        // an abandoned rebuild (writer dropped before finish) must leave
        // the previous generation fully loadable and sweep its own temps
        let mut w = ShardedWriter::create(&dir, &db.fractions, 5).unwrap();
        w.push(&db.records[0]).unwrap();
        drop(w);
        let still = ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(still.n_shards(), 3);
        assert_eq!(store::to_bytes(&still.to_flat()), store::to_bytes(&db));
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "abandoned build leaked temps: {stray:?}");
        // a crashed rebuild (manifest removed, segments half-written)
        // reads as "no database", not a CRC-corrupt one
        std::fs::remove_file(dir.join(MANIFEST_NAME)).unwrap();
        let err = format!("{:#}", ShardedPerfDb::load(&dir).unwrap_err());
        assert!(err.contains("manifest"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_nn_backend_works() {
        let db = sample_db(15, 19);
        let sharded = std::sync::Arc::new(ShardedPerfDb::from_flat(&db, 3));
        let mut nn = ShardedNn::new(sharded, 2);
        let q = db.records[7].vec;
        let (idx, d) = nn.nearest(&q).unwrap();
        assert_eq!(idx, 7);
        assert!(d < 1e-9);
        assert_eq!(nn.backend(), "sharded");
        let top = nn.top_k(&q, 3).unwrap();
        assert_eq!(top[0].0, 7);
    }
}
