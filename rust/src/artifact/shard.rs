//! Sharded performance database: the flat [`PerfDb`] record vector split
//! into N on-disk segment files (hash of configuration vector → shard)
//! under a manifest carrying per-segment CRCs.
//!
//! Queries fan out across shards on the shared worker pool
//! ([`crate::util::parallel`]) and merge — [`ShardedPerfDb::nearest`]
//! reproduces [`crate::perfdb::native::NativeNn`]'s tie-breaking exactly
//! (lowest global index among minimal distances), and
//! [`ShardedPerfDb::time_at`] delegates to the same interpolation code
//! path as the flat DB, so sharded answers are bit-identical to flat ones
//! (asserted in the test suite). The `Sharded ⇄ flat` conversion
//! round-trips byte-identically through [`crate::perfdb::store`].
//!
//! On-disk layout of one sharded database directory:
//!
//! ```text
//! MANIFEST      magic "TUNASHM1", shard/size/record counts, fractions,
//!               per-segment (record count, payload CRC), manifest CRC
//! seg-NNN.bin   magic "TUNASEG1", then per record:
//!               global u32 · raw f64×8 · vec f32×8 · times f32×n_sizes
//! ```
//!
//! Segment payloads are CRC'd in the manifest, which is written last and
//! atomically. Rebuilds into an existing directory stream to unique
//! temps, so a previous generation stays loadable until the new one's
//! commit point ([`ShardedWriter::finish`]): the old manifest is removed
//! first (every later crash window reads as "no database here", never an
//! old manifest checksumming new segments), stale segments from a wider
//! previous generation are swept, and the new manifest lands atomically.
//!
//! ## Bounded-resident lazy loading
//!
//! [`ShardedPerfDb::load`] materializes every segment — fine up to
//! resident memory, a hard wall past it. [`LazyShardedPerfDb`] removes
//! the wall: it reads only the manifest at open, faults segment payloads
//! in on first query touch (verifying each segment's CRC once, at that
//! first touch — never at open), and evicts least-recently-touched
//! segments past a [`ResidencyLimit`] (segment-count and/or byte
//! budget) before admitting a new one. Query answers are **bit-identical
//! to the fully-resident path for any eviction schedule and any thread
//! count** because both paths run the same per-shard scan and the same
//! [`dist_then_index`] merge over the same on-disk bytes; only *when*
//! bytes are resident changes. The kept-forever metadata is O(records):
//! the global→(shard, local) index built incrementally as segments are
//! first touched — the "management metadata small relative to the data"
//! that admission-controlled tiering systems rely on.
//!
//! Concurrency: one mutex per segment slot (so concurrent queries never
//! load the same segment twice — a loader holds its slot lock for the
//! duration of the read) plus one residency mutex for LRU stamps and
//! accounting. Admission is check-AND-reserve in a single residency
//! critical section (`resident + in-flight` is what the cap bounds), so
//! concurrent segment faults cannot race past the limit — the cached
//! set never exceeds the cap, not even transiently, at any thread
//! count; a fault that finds all capacity held by in-flight loads
//! blocks on a condvar until one commits or fails. Lock order is
//! strictly `slot → residency/index`; no path acquires a slot lock
//! while holding the residency or index lock, and no path holds two
//! slot locks, so eviction cannot deadlock with loading. Scans hold
//! `Arc`s, not locks — evicting a segment mid-scan is safe (memory is
//! freed when the last reader drops its `Arc`), so in-flight queries
//! may pin evicted payloads briefly beyond what the cache itself holds.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::wire::{self, Reader};
use super::{unique_tmp_path, write_atomic};
use crate::perfdb::native::{dist2, dist_then_index, NnQuery};
use crate::perfdb::store::{crc32, Crc32};
use crate::perfdb::{PerfDb, PerfSource, Record, DIMS};
use crate::util::parallel::{default_threads, parallel_map};

const MANIFEST_MAGIC: &[u8; 8] = b"TUNASHM1";
const SEGMENT_MAGIC: &[u8; 8] = b"TUNASEG1";
const MANIFEST_NAME: &str = "MANIFEST";

/// Default shard count for CLI builds.
pub const DEFAULT_SHARDS: usize = 8;

/// Below this many total records a query scans shards serially: spawning
/// scoped worker threads per lookup costs more than the scan itself.
const SERIAL_QUERY_THRESHOLD: usize = 8192;

/// Shard a configuration vector: FNV-1a over the raw f64 bits. A pure
/// function of (raw, n_shards), so routing is identical across builds,
/// saves and loads.
pub fn shard_of(raw: &[f64; DIMS], n_shards: usize) -> usize {
    let mut bytes = [0u8; DIMS * 8];
    for (i, x) in raw.iter().enumerate() {
        bytes[i * 8..(i + 1) * 8].copy_from_slice(&x.to_le_bytes());
    }
    (super::fnv1a64(&bytes) % n_shards.max(1) as u64) as usize
}

fn segment_name(si: usize) -> String {
    format!("seg-{si:03}.bin")
}

fn record_size(n_sizes: usize) -> usize {
    4 + DIMS * 8 + DIMS * 4 + n_sizes * 4
}

/// One shard: its records (as a [`PerfDb`] over the shared fraction grid,
/// so every query delegates to the flat code path) plus each record's
/// global index in the flat ordering.
#[derive(Clone, Debug)]
pub struct Shard {
    pub global: Vec<u32>,
    pub db: PerfDb,
}

/// Per-segment metadata from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct SegmentMeta {
    pub n_recs: u64,
    pub payload_crc: u32,
}

/// Parsed manifest of a sharded database directory.
#[derive(Clone, Debug)]
pub struct ManifestInfo {
    pub fractions: Vec<f32>,
    pub n_records: u64,
    pub segments: Vec<SegmentMeta>,
}

/// Read and validate the `MANIFEST` file of a sharded DB directory.
pub fn read_manifest(dir: &Path) -> Result<ManifestInfo> {
    let path = dir.join(MANIFEST_NAME);
    let data = std::fs::read(&path)
        .with_context(|| format!("opening sharded-perfdb manifest {}", path.display()))?;
    if data.len() < 8 + 4 || &data[..8] != MANIFEST_MAGIC {
        bail!("bad manifest magic in {}", path.display());
    }
    let body = &data[8..data.len() - 4];
    let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let computed = crc32(body);
    if stored != computed {
        bail!("manifest CRC mismatch in {}: stored {stored:#x}, computed {computed:#x}",
            path.display());
    }
    let mut r = Reader::new(body);
    let n_shards = r.u32()? as usize;
    let n_sizes = r.u32()? as usize;
    let n_records = r.u64()?;
    if n_shards == 0 || n_shards > 4096 || n_sizes == 0 || n_sizes > 1_000 {
        bail!("implausible manifest header: {n_shards} shards, {n_sizes} sizes");
    }
    let mut fractions = Vec::with_capacity(n_sizes);
    for _ in 0..n_sizes {
        fractions.push(r.f32()?);
    }
    let mut segments = Vec::with_capacity(n_shards);
    let mut total = 0u64;
    for _ in 0..n_shards {
        let seg = SegmentMeta { n_recs: r.u64()?, payload_crc: r.u32()? };
        // Bound per-segment counts like every other codec (records cap
        // mirrors the flat store's): a crafted/corrupt n_recs must fail
        // parsing, never reach a Vec::with_capacity or a wrapping
        // multiply against the payload length.
        if seg.n_recs > 10_000_000 {
            bail!("implausible segment record count {}", seg.n_recs);
        }
        total += seg.n_recs; // ≤ 4096 × 1e7 — cannot overflow u64
        segments.push(seg);
    }
    r.done()?;
    if total != n_records {
        bail!("manifest record counts sum to {total}, header says {n_records}");
    }
    Ok(ManifestInfo { fractions, n_records, segments })
}

/// The sharded database: shards plus a global-index → (shard, local)
/// lookup so flat-indexed queries ([`Self::time_at`]) stay O(1).
#[derive(Clone, Debug)]
pub struct ShardedPerfDb {
    pub fractions: Vec<f32>,
    pub shards: Vec<Shard>,
    loc: Vec<(u32, u32)>,
}

impl ShardedPerfDb {
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.loc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.loc.is_empty()
    }

    /// Split a flat database into `n_shards` shards (routing by
    /// [`shard_of`]). Converting back with [`Self::to_flat`] reproduces
    /// the input bit-for-bit.
    pub fn from_flat(db: &PerfDb, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        assert!(db.records.len() < u32::MAX as usize, "record count overflows u32 indices");
        let mut shards: Vec<Shard> = (0..n_shards)
            .map(|_| Shard {
                global: Vec::new(),
                db: PerfDb { fractions: db.fractions.clone(), records: Vec::new() },
            })
            .collect();
        let mut loc = Vec::with_capacity(db.records.len());
        for (g, r) in db.records.iter().enumerate() {
            let si = shard_of(&r.raw, n_shards);
            loc.push((si as u32, shards[si].db.records.len() as u32));
            shards[si].global.push(g as u32);
            shards[si].db.records.push(r.clone());
        }
        ShardedPerfDb { fractions: db.fractions.clone(), shards, loc }
    }

    /// Reassemble the flat database in original global order.
    pub fn to_flat(&self) -> PerfDb {
        let records = self
            .loc
            .iter()
            .map(|&(si, li)| self.shards[si as usize].db.records[li as usize].clone())
            .collect();
        PerfDb { fractions: self.fractions.clone(), records }
    }

    /// The record at a flat (global) index.
    pub fn record(&self, global: usize) -> &Record {
        let (si, li) = self.loc[global];
        &self.shards[si as usize].db.records[li as usize]
    }

    /// Predicted execution time at an arbitrary fraction — same code path
    /// as [`PerfDb::time_at`], so sharded and flat answers are
    /// bit-identical.
    pub fn time_at(&self, global: usize, fraction: f64) -> f64 {
        let (si, li) = self.loc[global];
        self.shards[si as usize].db.time_at(li as usize, fraction)
    }

    /// Nearest record to `q`: fan out one brute-force scan per shard on
    /// the worker pool, then merge. Tie-breaking matches
    /// [`crate::perfdb::native::NativeNn::nearest`]: the lowest global
    /// index among minimal distances (under the NaN-safe
    /// [`dist_then_index`] total order). `threads == 0` means one per
    /// core.
    pub fn nearest(&self, q: &[f32; DIMS], threads: usize) -> Option<(usize, f32)> {
        if self.is_empty() {
            return None;
        }
        let per = self.fan_out(threads, |si| scan_shard_nearest(&self.shards[si], q));
        per.into_iter().fold(None, merge_nearest)
    }

    /// Evaluate `scan` on every shard (see [`fan_out_shards`]).
    fn fan_out<T: Send, F: Fn(usize) -> T + Sync>(&self, threads: usize, scan: F) -> Vec<T> {
        fan_out_shards(self.shards.len(), self.len(), threads, scan)
    }

    /// `k` nearest records, ascending by (distance, global index) — the
    /// same ordering as [`crate::perfdb::native::NativeNn::top_k`]. Each
    /// shard returns its local top-k; the merge keeps the global top-k.
    pub fn top_k(&self, q: &[f32; DIMS], k: usize, threads: usize) -> Vec<(usize, f32)> {
        if self.is_empty() || k == 0 {
            return Vec::new();
        }
        let per = self.fan_out(threads, |si| scan_shard_top_k(&self.shards[si], q, k));
        merge_top_k(per, k)
    }

    /// Write the database to `dir` (segments streamed, manifest written
    /// atomically last).
    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut w = ShardedWriter::create(dir, &self.fractions, self.n_shards())?;
        for g in 0..self.len() {
            w.push(self.record(g))?;
        }
        w.finish()?;
        Ok(())
    }

    /// Load a sharded database from `dir`, validating the manifest CRC,
    /// every segment's payload CRC, and that the global indices form a
    /// permutation of `0..n_records`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = read_manifest(dir)?;
        let mut shards = Vec::with_capacity(manifest.segments.len());
        for (si, seg) in manifest.segments.iter().enumerate() {
            let path = dir.join(segment_name(si));
            shards.push(read_segment_file(&path, seg, &manifest.fractions, true)?);
        }
        let loc = build_loc(&shards, manifest.n_records as usize)?;
        Ok(ShardedPerfDb { fractions: manifest.fractions, shards, loc })
    }
}

/// Read one segment file end-to-end: magic check, payload CRC against the
/// manifest (skippable when a lazy reload already verified this segment
/// on its first touch), length check, record decode. Shared by the
/// fully-resident [`ShardedPerfDb::load`] and the lazy fault-in path, so
/// both produce identical [`Shard`]s from identical bytes.
fn read_segment_file(
    path: &Path,
    seg: &SegmentMeta,
    fractions: &[f32],
    verify_crc: bool,
) -> Result<Shard> {
    let n_sizes = fractions.len();
    let rec_size = record_size(n_sizes);
    let data = std::fs::read(path)
        .with_context(|| format!("opening segment {}", path.display()))?;
    if data.len() < 8 || &data[..8] != SEGMENT_MAGIC {
        bail!("bad segment magic in {}", path.display());
    }
    let payload = &data[8..];
    if verify_crc {
        let computed = crc32(payload);
        if computed != seg.payload_crc {
            bail!(
                "segment {} CRC mismatch: manifest {:#x}, computed {computed:#x}",
                path.display(),
                seg.payload_crc
            );
        }
    }
    if payload.len() as u64 != seg.n_recs * rec_size as u64 {
        bail!(
            "segment {} holds {} bytes, manifest expects {} records of {} bytes",
            path.display(),
            payload.len(),
            seg.n_recs,
            rec_size
        );
    }
    let mut global = Vec::with_capacity(seg.n_recs as usize);
    let mut records = Vec::with_capacity(seg.n_recs as usize);
    let mut r = Reader::new(payload);
    for _ in 0..seg.n_recs {
        global.push(r.u32()?);
        let mut raw = [0f64; DIMS];
        for x in &mut raw {
            *x = r.f64()?;
        }
        let mut vec = [0f32; DIMS];
        for x in &mut vec {
            *x = r.f32()?;
        }
        let mut times_ns = Vec::with_capacity(n_sizes);
        for _ in 0..n_sizes {
            times_ns.push(r.f32()?);
        }
        records.push(Record { raw, vec, times_ns });
    }
    r.done()
        .with_context(|| format!("decoding segment {}", path.display()))?;
    Ok(Shard { global, db: PerfDb { fractions: fractions.to_vec(), records } })
}

/// Brute-force scan of one shard: best `(global, distance)` under the
/// shared [`dist_then_index`] total order (lowest global index among
/// minimal distances; NaN-safe).
fn scan_shard_nearest(sh: &Shard, q: &[f32; DIMS]) -> Option<(usize, f32)> {
    let mut best: Option<(usize, f32)> = None;
    for (li, r) in sh.db.records.iter().enumerate() {
        let cand = (sh.global[li] as usize, dist2(q, &r.vec));
        let better = match &best {
            None => true,
            Some(b) => dist_then_index(&cand, b) == std::cmp::Ordering::Less,
        };
        if better {
            best = Some(cand);
        }
    }
    best
}

/// One shard's local top-k, ascending by `(distance, global index)`.
fn scan_shard_top_k(sh: &Shard, q: &[f32; DIMS], k: usize) -> Vec<(usize, f32)> {
    let mut all: Vec<(usize, f32)> = sh
        .db
        .records
        .iter()
        .enumerate()
        .map(|(li, r)| (sh.global[li] as usize, dist2(q, &r.vec)))
        .collect();
    all.sort_by(dist_then_index);
    all.truncate(k);
    all
}

/// Fold two per-shard `nearest` candidates under the shared total order.
fn merge_nearest(a: Option<(usize, f32)>, b: Option<(usize, f32)>) -> Option<(usize, f32)> {
    match (a, b) {
        (None, x) | (x, None) => x,
        (Some(x), Some(y)) => {
            if dist_then_index(&y, &x) == std::cmp::Ordering::Less {
                Some(y)
            } else {
                Some(x)
            }
        }
    }
}

/// Merge per-shard top-k lists into the global top-k (each element of the
/// global top-k is within its own shard's top-k, so the union suffices).
fn merge_top_k(per: Vec<Vec<(usize, f32)>>, k: usize) -> Vec<(usize, f32)> {
    let mut merged: Vec<(usize, f32)> = per.into_iter().flatten().collect();
    merged.sort_by(dist_then_index);
    merged.truncate(k);
    merged
}

/// Evaluate `scan` on every shard: serially when the database is too
/// small for fan-out to beat thread-spawn cost (or one worker was
/// requested), otherwise on the worker pool. Results come back in shard
/// order, so merges are scheduling-independent. ONE implementation
/// shared by the resident and lazy query paths, so their serial/parallel
/// selection can never drift apart.
fn fan_out_shards<T: Send>(
    n_shards: usize,
    n_records: usize,
    threads: usize,
    scan: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let serial = threads == 1 || n_shards == 1 || n_records <= SERIAL_QUERY_THRESHOLD;
    if serial {
        (0..n_shards).map(scan).collect()
    } else {
        let threads = if threads == 0 { default_threads() } else { threads };
        parallel_map(n_shards, threads, scan)
    }
}

fn build_loc(shards: &[Shard], n_records: usize) -> Result<Vec<(u32, u32)>> {
    const HOLE: (u32, u32) = (u32::MAX, u32::MAX);
    let mut loc = vec![HOLE; n_records];
    for (si, sh) in shards.iter().enumerate() {
        if sh.global.len() != sh.db.records.len() {
            bail!("shard {si}: {} indices for {} records", sh.global.len(), sh.db.records.len());
        }
        for (li, &g) in sh.global.iter().enumerate() {
            let g = g as usize;
            if g >= n_records {
                bail!("shard {si}: global index {g} out of range (n_records {n_records})");
            }
            if loc[g] != HOLE {
                bail!("duplicate global index {g} across segments");
            }
            loc[g] = (si as u32, li as u32);
        }
    }
    if let Some(g) = loc.iter().position(|&x| x == HOLE) {
        bail!("global index {g} missing from every segment");
    }
    Ok(loc)
}

// ---------------------------------------------------------------------------
// Bounded-resident lazy loading
// ---------------------------------------------------------------------------

const LOC_HOLE: (u32, u32) = (u32::MAX, u32::MAX);

/// Cap on cached segment payloads for a [`LazyShardedPerfDb`]. Both axes
/// are enforced together; `0` disables an axis. A single segment larger
/// than the byte budget still loads (the cap then holds only it — a
/// budget that can hold *nothing* would make every query fail).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResidencyLimit {
    /// Maximum segments resident at once (0 = unbounded).
    pub max_segments: usize,
    /// Maximum resident payload bytes (0 = unbounded).
    pub max_bytes: u64,
}

impl ResidencyLimit {
    /// No cap: lazy loading with full residency (segments still fault in
    /// on first touch, but nothing is ever evicted).
    pub const UNBOUNDED: ResidencyLimit = ResidencyLimit { max_segments: 0, max_bytes: 0 };

    /// Cap by segment count (the CLI's `--resident-segments`; 0 means
    /// unbounded).
    pub fn segments(n: usize) -> Self {
        ResidencyLimit { max_segments: n, max_bytes: 0 }
    }

    /// Cap by resident payload bytes (0 means unbounded).
    pub fn bytes(n: u64) -> Self {
        ResidencyLimit { max_segments: 0, max_bytes: n }
    }
}

/// Residency accounting snapshot ([`LazyShardedPerfDb::stats`]) — the
/// proof the cap was honored during a run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Disk loads, first touches and post-eviction reloads alike.
    pub loads: u64,
    /// Segments dropped from the resident set.
    pub evictions: u64,
    /// CRC validations performed (one per segment, on its first touch).
    pub crc_verifies: u64,
    /// Segments resident right now.
    pub resident_segments: usize,
    /// Payload bytes resident right now.
    pub resident_bytes: u64,
    /// High-water marks over the database's lifetime.
    pub peak_resident_segments: usize,
    pub peak_resident_bytes: u64,
}

/// LRU bookkeeping + counters, one mutex for all of it. Never acquires a
/// slot lock (see the module's lock-order contract).
struct Residency {
    clock: u64,
    /// Last-touch stamp per segment (0 = never touched).
    stamps: Vec<u64>,
    resident: Vec<bool>,
    resident_segments: usize,
    resident_bytes: u64,
    /// Capacity reserved by in-flight loads ([`LazyShardedPerfDb::admit`])
    /// that have not committed or failed yet. `resident + pending` is the
    /// quantity the cap bounds, so concurrent faults cannot race past it.
    pending_segments: usize,
    pending_bytes: u64,
    loads: u64,
    evictions: u64,
    crc_verifies: u64,
    peak_resident_segments: usize,
    peak_resident_bytes: u64,
}

impl Residency {
    fn new(n_shards: usize) -> Self {
        Residency {
            clock: 0,
            stamps: vec![0; n_shards],
            resident: vec![false; n_shards],
            resident_segments: 0,
            resident_bytes: 0,
            pending_segments: 0,
            pending_bytes: 0,
            loads: 0,
            evictions: 0,
            crc_verifies: 0,
            peak_resident_segments: 0,
            peak_resident_bytes: 0,
        }
    }
}

/// The global→(shard, local) index, built incrementally as segments are
/// first touched and kept across evictions — the bounded "management
/// metadata" (8 bytes per record) that lets `time_at`/`loss_curve`
/// reach an evicted record without rescanning the directory.
struct LocIndex {
    map: Vec<(u32, u32)>,
    indexed: Vec<bool>,
}

/// A sharded performance database whose segment payloads are **lazily
/// resident**: the manifest is read eagerly at [`Self::open`], segment
/// files are read, CRC-verified (once, on first touch) and parsed on
/// first query contact, and least-recently-touched segments are evicted
/// past the [`ResidencyLimit`] *before* a new segment is admitted — so a
/// database much larger than memory serves `nearest`/`top_k`/`time_at`
/// from a bounded resident set, bit-identically to [`ShardedPerfDb`].
pub struct LazyShardedPerfDb {
    dir: PathBuf,
    manifest: ManifestInfo,
    limit: ResidencyLimit,
    /// One slot per segment; a loader holds the slot's lock for the
    /// duration of its disk read, so concurrent first touches of one
    /// segment collapse into a single load.
    slots: Vec<Mutex<Option<Arc<Shard>>>>,
    /// Set once a segment's payload CRC has been validated; reloads after
    /// eviction skip the re-hash (single-writer store discipline — the
    /// bytes a reload sees are the bytes the first touch verified).
    crc_done: Vec<AtomicBool>,
    loc: Mutex<LocIndex>,
    res: Mutex<Residency>,
    /// Signalled whenever capacity frees up (a load commits, fails, or a
    /// segment is evicted) — what [`Self::admit`] blocks on when every
    /// unit of capacity is an in-flight load with nothing yet evictable.
    res_cv: std::sync::Condvar,
    /// Observability handle: segment loads/evictions/CRC checks become
    /// metrics and journal events. Disabled by default; answers are
    /// bit-identical either way ([`Self::set_obs`]).
    obs: crate::obs::Recorder,
}

impl std::fmt::Debug for LazyShardedPerfDb {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LazyShardedPerfDb")
            .field("dir", &self.dir)
            .field("n_records", &self.manifest.n_records)
            .field("n_shards", &self.slots.len())
            .field("limit", &self.limit)
            .finish_non_exhaustive()
    }
}

impl LazyShardedPerfDb {
    /// Open the database at `dir`: reads and validates the **manifest
    /// only**. No segment payload is read, parsed or CRC'd here — that
    /// happens on first query touch, per segment.
    pub fn open(dir: &Path, limit: ResidencyLimit) -> Result<Self> {
        let manifest = read_manifest(dir)?;
        let n_shards = manifest.segments.len();
        let n_records = manifest.n_records as usize;
        Ok(LazyShardedPerfDb {
            dir: dir.to_path_buf(),
            limit,
            slots: (0..n_shards).map(|_| Mutex::new(None)).collect(),
            crc_done: (0..n_shards).map(|_| AtomicBool::new(false)).collect(),
            loc: Mutex::new(LocIndex {
                map: vec![LOC_HOLE; n_records],
                indexed: vec![false; n_shards],
            }),
            res: Mutex::new(Residency::new(n_shards)),
            res_cv: std::sync::Condvar::new(),
            obs: crate::obs::Recorder::default(),
            manifest,
        })
    }

    /// Attach an observability recorder (call before sharing the DB
    /// across threads — typically right after [`Self::open`]).
    pub fn set_obs(&mut self, obs: crate::obs::Recorder) {
        self.obs = obs;
    }

    pub fn len(&self) -> usize {
        self.manifest.n_records as usize
    }

    pub fn is_empty(&self) -> bool {
        self.manifest.n_records == 0
    }

    pub fn n_shards(&self) -> usize {
        self.slots.len()
    }

    pub fn fractions(&self) -> &[f32] {
        &self.manifest.fractions
    }

    pub fn limit(&self) -> ResidencyLimit {
        self.limit
    }

    /// Residency accounting snapshot.
    pub fn stats(&self) -> ResidencyStats {
        let r = self.res.lock().unwrap();
        ResidencyStats {
            loads: r.loads,
            evictions: r.evictions,
            crc_verifies: r.crc_verifies,
            resident_segments: r.resident_segments,
            resident_bytes: r.resident_bytes,
            peak_resident_segments: r.peak_resident_segments,
            peak_resident_bytes: r.peak_resident_bytes,
        }
    }

    /// Manifest-derived payload size of one segment (exact: the record
    /// encoding is fixed-width), available without touching the file.
    fn segment_payload_bytes(&self, si: usize) -> u64 {
        self.manifest.segments[si].n_recs * record_size(self.manifest.fractions.len()) as u64
    }

    fn touch(&self, si: usize) {
        let mut res = self.res.lock().unwrap();
        res.clock += 1;
        res.stamps[si] = res.clock;
    }

    /// Reserve cache capacity for `incoming` *before* its disk read.
    /// Admission is check-AND-reserve in one residency critical section
    /// (`resident + pending` is what the cap bounds), so concurrent
    /// segment faults cannot race past the limit: the resident set never
    /// exceeds the cap, not even transiently, for any thread count.
    ///
    /// Returns `true` with a reservation held (the caller must release
    /// it via [`Self::load_reserved`]/[`Self::unreserve`]), or `false`
    /// when `incoming` became resident while negotiating (take the hit
    /// path instead). Evicting the LRU victim happens with the residency
    /// lock *dropped* (slot → residency order, one slot at a time), so
    /// eviction can never deadlock against loaders; evicting a segment a
    /// concurrent query is still scanning is safe — scans hold `Arc`s,
    /// not locks. When every unit of capacity is an in-flight load (no
    /// victim resident yet), the caller blocks on [`Self::res_cv`] until
    /// a load commits or fails. A full cache with nothing resident and
    /// nothing pending always admits — a budget smaller than one segment
    /// must not fail every query.
    fn admit(&self, incoming: usize) -> bool {
        let incoming_bytes = self.segment_payload_bytes(incoming);
        let mut res = self.res.lock().unwrap();
        loop {
            if res.resident[incoming] {
                return false;
            }
            let in_use_segments = res.resident_segments + res.pending_segments;
            let in_use_bytes = res.resident_bytes + res.pending_bytes;
            let fits_count = self.limit.max_segments == 0
                || in_use_segments + 1 <= self.limit.max_segments;
            let fits_bytes = self.limit.max_bytes == 0
                || in_use_bytes + incoming_bytes <= self.limit.max_bytes;
            if (fits_count && fits_bytes) || in_use_segments == 0 {
                res.pending_segments += 1;
                res.pending_bytes += incoming_bytes;
                return true;
            }
            // Over the cap: evict the least-recently-touched resident
            // segment (it cannot be `incoming`, which is not resident).
            let victim = res
                .resident
                .iter()
                .enumerate()
                .filter(|&(_, &r)| r)
                .min_by_key(|&(si, _)| res.stamps[si])
                .map(|(si, _)| si);
            match victim {
                Some(victim) => {
                    drop(res);
                    // Victim's slot lock *then* the residency lock: the
                    // slot lock excludes a concurrent re-load of the
                    // victim, so residency flags stay consistent with
                    // slot contents.
                    let mut slot = self.slots[victim].lock().unwrap();
                    if slot.take().is_some() {
                        let resident_now;
                        {
                            let mut r = self.res.lock().unwrap();
                            r.resident[victim] = false;
                            r.resident_segments -= 1;
                            r.resident_bytes -= self.segment_payload_bytes(victim);
                            r.evictions += 1;
                            resident_now = r.resident_segments;
                            self.res_cv.notify_all();
                        }
                        if self.obs.is_enabled() {
                            self.obs.count("perfdb_segment_evictions_total", 1);
                            self.obs.gauge("perfdb_resident_segments", resident_now as f64);
                            self.obs.record(crate::obs::EventKind::SegmentEvict {
                                segment: victim as u32,
                            });
                        }
                    }
                    drop(slot);
                    res = self.res.lock().unwrap();
                }
                None => {
                    // every unit of capacity is an in-flight load; its
                    // commit (or failure) will notify and re-evaluate
                    res = self.res_cv.wait(res).unwrap();
                }
            }
        }
    }

    /// Drop an [`Self::admit`] reservation without admitting (the load
    /// failed, or another thread's load won the slot).
    fn unreserve(&self, si: usize) {
        let mut res = self.res.lock().unwrap();
        res.pending_segments -= 1;
        res.pending_bytes -= self.segment_payload_bytes(si);
        self.res_cv.notify_all();
    }

    /// Populate the global index from a freshly-parsed segment. Validates
    /// before writing, so a failed segment leaves the index untouched and
    /// a retry reports the same error instead of a spurious duplicate.
    fn index_segment(&self, si: usize, shard: &Shard) -> Result<()> {
        let mut loc = self.loc.lock().unwrap();
        if loc.indexed[si] {
            return Ok(());
        }
        let n = loc.map.len();
        let mut seen = std::collections::HashSet::with_capacity(shard.global.len());
        for &g in &shard.global {
            let g = g as usize;
            if g >= n {
                bail!("segment {si}: global index {g} out of range (n_records {n})");
            }
            if loc.map[g] != LOC_HOLE || !seen.insert(g) {
                bail!("duplicate global index {g} across segments");
            }
        }
        for (li, &g) in shard.global.iter().enumerate() {
            loc.map[g as usize] = (si as u32, li as u32);
        }
        loc.indexed[si] = true;
        Ok(())
    }

    /// The segment's payload, faulting it in from disk if not resident.
    /// First touch verifies the manifest CRC; any failure (I/O, CRC,
    /// decode) leaves the slot empty and every other segment untouched,
    /// so one corrupt segment never poisons queries that don't need it.
    pub fn segment(&self, si: usize) -> Result<Arc<Shard>> {
        loop {
            {
                let slot = self.slots[si].lock().unwrap();
                if let Some(s) = slot.as_ref() {
                    let arc = s.clone();
                    drop(slot);
                    self.touch(si);
                    return Ok(arc);
                }
            }
            if self.admit(si) {
                // capacity reserved — load below
                return self.load_reserved(si);
            }
            // `si` became resident while negotiating capacity: retry the
            // hit path (it may have been evicted again meanwhile)
        }
    }

    /// Load `si` into its slot with capacity already reserved by
    /// [`Self::admit`]. The reservation is released on every path: folded
    /// into the residency accounting on success, dropped when another
    /// loader won the slot or the read failed.
    fn load_reserved(&self, si: usize) -> Result<Arc<Shard>> {
        let mut slot = self.slots[si].lock().unwrap();
        if let Some(s) = slot.as_ref() {
            // another thread's load won the slot while we reserved
            let arc = s.clone();
            drop(slot);
            self.unreserve(si);
            self.touch(si);
            return Ok(arc);
        }
        let path = self.dir.join(segment_name(si));
        let first_touch = !self.crc_done[si].load(Ordering::Acquire);
        // timed only when recording — the disabled path stays free
        let load_t0 = self.obs.is_enabled().then(std::time::Instant::now);
        let loaded = read_segment_file(
            &path,
            &self.manifest.segments[si],
            &self.manifest.fractions,
            first_touch,
        )
        .and_then(|shard| self.index_segment(si, &shard).map(|()| shard));
        let shard = match loaded {
            Ok(shard) => shard,
            Err(e) => {
                drop(slot);
                self.unreserve(si);
                return Err(e);
            }
        };
        if first_touch {
            self.crc_done[si].store(true, Ordering::Release);
        }
        let arc = Arc::new(shard);
        *slot = Some(arc.clone());
        let resident_now;
        {
            let mut res = self.res.lock().unwrap();
            res.pending_segments -= 1;
            res.pending_bytes -= self.segment_payload_bytes(si);
            res.resident[si] = true;
            res.resident_segments += 1;
            res.resident_bytes += self.segment_payload_bytes(si);
            res.loads += 1;
            if first_touch {
                res.crc_verifies += 1;
            }
            res.peak_resident_segments = res.peak_resident_segments.max(res.resident_segments);
            res.peak_resident_bytes = res.peak_resident_bytes.max(res.resident_bytes);
            res.clock += 1;
            res.stamps[si] = res.clock;
            resident_now = res.resident_segments;
            self.res_cv.notify_all();
        }
        if let Some(t0) = load_t0 {
            use crate::obs::{EventKind, NS_BUCKETS};
            let wall_ns = t0.elapsed().as_nanos() as u64;
            self.obs.count("perfdb_segment_loads_total", 1);
            if first_touch {
                self.obs.count("perfdb_crc_verifies_total", 1);
            }
            self.obs.gauge("perfdb_resident_segments", resident_now as f64);
            self.obs.observe("perfdb_segment_load_ns", NS_BUCKETS, wall_ns as f64);
            self.obs.record(EventKind::SegmentLoad {
                segment: si as u32,
                records: arc.global.len() as u64,
                crc_checked: first_touch,
                wall_ns,
            });
        }
        Ok(arc)
    }

    fn loc_hit(&self, global: usize) -> Option<(u32, u32)> {
        let loc = self.loc.lock().unwrap();
        let hit = loc.map[global];
        (hit != LOC_HOLE).then_some(hit)
    }

    /// Resolve a global record index to (shard, local), faulting segments
    /// in (in shard order) until found. A segment that fails to load is
    /// skipped — its error surfaces only if the record isn't found in any
    /// readable segment — so a corrupt segment doesn't block lookups of
    /// records that live elsewhere.
    fn locate(&self, global: usize) -> Result<(u32, u32)> {
        let n = self.len();
        if global >= n {
            bail!("record index {global} out of range (database holds {n} records)");
        }
        if let Some(hit) = self.loc_hit(global) {
            return Ok(hit);
        }
        let mut first_err: Option<anyhow::Error> = None;
        for si in 0..self.slots.len() {
            let unindexed = !self.loc.lock().unwrap().indexed[si];
            if !unindexed {
                continue;
            }
            if let Err(e) = self.segment(si) {
                if self.obs.is_enabled() {
                    self.obs
                        .warn("perfdb.locate", &format!("skipping unreadable segment {si}: {e:#}"));
                }
                first_err.get_or_insert(e);
                continue;
            }
            if let Some(hit) = self.loc_hit(global) {
                return Ok(hit);
            }
        }
        match first_err {
            Some(e) => Err(e.context(format!(
                "resolving record {global} (an unreadable segment may hold it)"
            ))),
            None => bail!("global index {global} missing from every segment"),
        }
    }

    /// Predicted execution time at an arbitrary fraction — delegates to
    /// [`PerfDb::time_at`] on the owning segment, so answers are
    /// bit-identical to the flat and fully-resident paths.
    pub fn time_at(&self, global: usize, fraction: f64) -> Result<f64> {
        let (si, li) = self.locate(global)?;
        let sh = self.segment(si as usize)?;
        Ok(sh.db.time_at(li as usize, fraction))
    }

    /// Evaluate `scan` on every shard (see [`fan_out_shards`]).
    fn fan_out<T: Send>(&self, threads: usize, scan: impl Fn(usize) -> T + Sync) -> Vec<T> {
        fan_out_shards(self.slots.len(), self.len(), threads, scan)
    }

    /// Nearest record to `q` (see [`ShardedPerfDb::nearest`] — same scan,
    /// same merge, bit-identical result). `Err` only when a needed
    /// segment fails to load; the first failing shard (in shard order)
    /// reports, deterministically.
    pub fn nearest(&self, q: &[f32; DIMS], threads: usize) -> Result<Option<(usize, f32)>> {
        if self.is_empty() {
            return Ok(None);
        }
        let per = self.fan_out(threads, |si| -> Result<Option<(usize, f32)>> {
            Ok(scan_shard_nearest(&self.segment(si)?, q))
        });
        let mut best = None;
        for r in per {
            best = merge_nearest(best, r?);
        }
        Ok(best)
    }

    /// `k` nearest records (see [`ShardedPerfDb::top_k`]).
    pub fn top_k(&self, q: &[f32; DIMS], k: usize, threads: usize) -> Result<Vec<(usize, f32)>> {
        if self.is_empty() || k == 0 {
            return Ok(Vec::new());
        }
        let per = self.fan_out(threads, |si| -> Result<Vec<(usize, f32)>> {
            Ok(scan_shard_top_k(&self.segment(si)?, q, k))
        });
        let mut lists = Vec::with_capacity(per.len());
        for r in per {
            lists.push(r?);
        }
        Ok(merge_top_k(lists, k))
    }
}

/// On-disk size of every segment file of a sharded database, in segment
/// order (manifest-derived payload size + header when a file is
/// momentarily unreadable) — what `tuna store ls` reports.
pub fn segment_sizes(dir: &Path, manifest: &ManifestInfo) -> Vec<u64> {
    let rec = record_size(manifest.fractions.len()) as u64;
    manifest
        .segments
        .iter()
        .enumerate()
        .map(|(si, seg)| {
            std::fs::metadata(dir.join(segment_name(si)))
                .map(|m| m.len())
                .unwrap_or(8 + seg.n_recs * rec)
        })
        .collect()
}

/// Compact per-segment size listing for store listings: every size for
/// small databases, a min/max/total summary past 8 segments.
pub fn fmt_segment_sizes(sizes: &[u64]) -> String {
    use crate::util::human_bytes;
    if sizes.is_empty() {
        return "no segments".to_string();
    }
    if sizes.len() <= 8 {
        let list: Vec<String> = sizes.iter().map(|&b| human_bytes(b)).collect();
        format!("seg bytes {}", list.join("/"))
    } else {
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        let total: u64 = sizes.iter().sum();
        format!(
            "seg bytes {}..{} (total {})",
            human_bytes(min),
            human_bytes(max),
            human_bytes(total)
        )
    }
}

/// Streaming writer: routes each completed record straight into its
/// segment file, so multi-million-record builds never hold the whole
/// database in memory. Segments stream to unique temps and are renamed at
/// [`Self::finish`]; the manifest (with final counts and CRCs) is written
/// atomically last.
pub struct ShardedWriter {
    dir: PathBuf,
    fractions: Vec<f32>,
    segments: Vec<SegmentWriter>,
    n_records: u64,
}

impl ShardedWriter {
    pub fn create(dir: &Path, fractions: &[f32], n_shards: usize) -> Result<Self> {
        let n_shards = n_shards.max(1);
        // The writer holds one open temp file per shard, so the build cap
        // sits well under common fd soft limits (1024); the *read* path
        // opens segments sequentially and accepts up to the 4096 the
        // manifest format allows.
        if n_shards > 512 {
            bail!("{n_shards} shards exceeds the build limit of 512 (one open file per shard)");
        }
        if fractions.is_empty() {
            bail!("sharded perfdb needs a non-empty fraction grid");
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating sharded-perfdb dir {}", dir.display()))?;
        let mut segments = Vec::with_capacity(n_shards);
        for si in 0..n_shards {
            segments.push(SegmentWriter::create(dir.join(segment_name(si)))?);
        }
        Ok(ShardedWriter {
            dir: dir.to_path_buf(),
            fractions: fractions.to_vec(),
            segments,
            n_records: 0,
        })
    }

    /// Append one record (the next global index). Routing is by
    /// [`shard_of`], so push order defines the flat ordering.
    pub fn push(&mut self, r: &Record) -> Result<()> {
        if r.times_ns.len() != self.fractions.len() {
            bail!(
                "record has {} times for {} fractions",
                r.times_ns.len(),
                self.fractions.len()
            );
        }
        if self.n_records >= u32::MAX as u64 {
            bail!("sharded perfdb overflows u32 global indices");
        }
        let si = shard_of(&r.raw, self.segments.len());
        self.segments[si].push(self.n_records as u32, r)?;
        self.n_records += 1;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.n_records as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n_records == 0
    }

    /// Finalize the commit point. A previous generation in the same
    /// directory survives untouched right up to here (segments stream to
    /// unique temps), so a build that *fails* leaves the old database
    /// loadable; `finish` then (1) sets the old manifest aside as
    /// `MANIFEST.old` — every later crash window reads as "no database",
    /// never an old manifest checksumming new segments, and a failure is
    /// recoverable by renaming it back — (2) renames the new segments
    /// into place, (3) sweeps stale segments from a wider previous
    /// generation, and (4) writes the new manifest atomically, removing
    /// `MANIFEST.old` on success. Returns the directory written.
    pub fn finish(self) -> Result<PathBuf> {
        let ShardedWriter { dir, fractions, segments, n_records } = self;
        let n_shards = segments.len();
        // Set the old manifest ASIDE (not unlink): a failure before any
        // new segment lands can be rolled back by renaming it back; once
        // segments start overwriting, the previous generation is gone
        // either way and the directory correctly reads as "no database".
        let manifest_path = dir.join(MANIFEST_NAME);
        let manifest_old = dir.join("MANIFEST.old");
        match std::fs::rename(&manifest_path, &manifest_old) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("setting aside old manifest in {}", dir.display()))
            }
        }
        let commit = || -> Result<()> {
            let mut metas = Vec::with_capacity(n_shards);
            for seg in segments {
                metas.push(seg.finish()?);
            }
            // Remove segments a previous build left behind (e.g. 8
            // shards rebuilt as 4): they are unreferenced by the new
            // manifest but would count into listings and confuse
            // inspection.
            for entry in std::fs::read_dir(&dir)? {
                let path = entry?.path();
                let name = path.file_name().map(|s| s.to_string_lossy().into_owned());
                if let Some(name) = name {
                    // Orphaned temps from builds that were SIGKILLed or
                    // lost power (Drop never ran): this build's own temps
                    // were renamed away before this sweep, and the dir is
                    // single-writer, so any remaining .tmp is garbage.
                    if name.ends_with(".tmp") {
                        std::fs::remove_file(&path).ok();
                        continue;
                    }
                    if let Some(idx) = name
                        .strip_prefix("seg-")
                        .and_then(|s| s.strip_suffix(".bin"))
                        .and_then(|s| s.parse::<usize>().ok())
                    {
                        if idx >= n_shards {
                            std::fs::remove_file(&path)
                                .with_context(|| format!("sweeping stale {}", path.display()))?;
                        }
                    }
                }
            }
            let mut body = Vec::new();
            wire::put_u32(&mut body, n_shards as u32);
            wire::put_u32(&mut body, fractions.len() as u32);
            wire::put_u64(&mut body, n_records);
            for &f in &fractions {
                wire::put_f32(&mut body, f);
            }
            for m in &metas {
                wire::put_u64(&mut body, m.n_recs);
                wire::put_u32(&mut body, m.payload_crc);
            }
            let mut out = Vec::with_capacity(8 + body.len() + 4);
            out.extend_from_slice(MANIFEST_MAGIC);
            out.extend_from_slice(&body);
            out.extend_from_slice(&crc32(&body).to_le_bytes());
            write_atomic(&manifest_path, &out)
        };
        match commit() {
            Ok(()) => {
                std::fs::remove_file(&manifest_old).ok();
                Ok(dir)
            }
            Err(e) => Err(e.context(format!(
                "sharded rebuild failed; old manifest kept at {} (renaming it back \
                 restores the previous database ONLY if no new segment was renamed \
                 into place yet — after that, segments are mixed-generation and the \
                 directory must be rebuilt)",
                manifest_old.display()
            ))),
        }
    }
}

struct SegmentWriter {
    /// `Some` until [`Self::finish`] closes it (needed so [`Drop`] can
    /// close before unlinking an abandoned temp).
    file: Option<std::io::BufWriter<std::fs::File>>,
    tmp: PathBuf,
    dest: PathBuf,
    crc: Crc32,
    n_recs: u64,
    finished: bool,
    /// Reusable serialization scratch — the streaming build path exists
    /// for multi-million-record databases, so no per-record allocation.
    buf: Vec<u8>,
}

impl SegmentWriter {
    fn create(dest: PathBuf) -> Result<Self> {
        let tmp = unique_tmp_path(&dest);
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("creating segment temp {}", tmp.display()))?,
        );
        file.write_all(SEGMENT_MAGIC)?;
        Ok(SegmentWriter {
            file: Some(file),
            tmp,
            dest,
            crc: Crc32::new(),
            n_recs: 0,
            finished: false,
            buf: Vec::new(),
        })
    }

    fn push(&mut self, global: u32, r: &Record) -> Result<()> {
        self.buf.clear();
        wire::put_u32(&mut self.buf, global);
        for &x in &r.raw {
            wire::put_f64(&mut self.buf, x);
        }
        for &x in &r.vec {
            wire::put_f32(&mut self.buf, x);
        }
        for &t in &r.times_ns {
            wire::put_f32(&mut self.buf, t);
        }
        self.crc.update(&self.buf);
        self.file.as_mut().expect("segment writer already finished").write_all(&self.buf)?;
        self.n_recs += 1;
        Ok(())
    }

    fn finish(mut self) -> Result<SegmentMeta> {
        let mut file = self.file.take().expect("segment writer already finished");
        file.flush().with_context(|| format!("flushing segment {}", self.tmp.display()))?;
        // durability before the rename: see `write_atomic` (the manifest
        // write at the end of the build syncs the directory itself)
        file.get_ref()
            .sync_all()
            .with_context(|| format!("syncing segment {}", self.tmp.display()))?;
        drop(file);
        std::fs::rename(&self.tmp, &self.dest).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.dest.display())
        })?;
        self.finished = true;
        Ok(SegmentMeta { n_recs: self.n_recs, payload_crc: self.crc.finish() })
    }
}

impl Drop for SegmentWriter {
    /// An abandoned or failed build must not leak its uniquely-named
    /// temp (nothing ever overwrites or sweeps `.tmp` files).
    fn drop(&mut self) {
        if !self.finished {
            self.file.take();
            std::fs::remove_file(&self.tmp).ok();
        }
    }
}

/// [`NnQuery`] adapter over a sharded database — pluggable wherever the
/// native or XLA backends go (tuner, benches).
pub struct ShardedNn {
    db: std::sync::Arc<ShardedPerfDb>,
    threads: usize,
}

impl ShardedNn {
    /// `threads == 0` means one worker per core.
    pub fn new(db: std::sync::Arc<ShardedPerfDb>, threads: usize) -> Self {
        ShardedNn { db, threads }
    }
}

impl NnQuery for ShardedNn {
    fn nearest(&mut self, q: &[f32; DIMS]) -> crate::Result<(usize, f32)> {
        self.db.nearest(q, self.threads).ok_or_else(|| anyhow::anyhow!("empty database"))
    }

    fn top_k(&mut self, q: &[f32; DIMS], k: usize) -> crate::Result<Vec<(usize, f32)>> {
        anyhow::ensure!(!self.db.is_empty(), "empty database");
        Ok(self.db.top_k(q, k, self.threads))
    }

    fn backend(&self) -> &'static str {
        "sharded"
    }
}

/// [`NnQuery`] adapter over a bounded-resident lazy database — pluggable
/// wherever the native or fully-resident sharded backends go (tuner
/// service, `tuna tune`/`serve`, benches). Segment faults and evictions
/// happen inside each query; answers stay bit-identical to the resident
/// backends.
pub struct LazyShardedNn {
    db: Arc<LazyShardedPerfDb>,
    threads: usize,
}

impl LazyShardedNn {
    /// `threads == 0` means one worker per core.
    pub fn new(db: Arc<LazyShardedPerfDb>, threads: usize) -> Self {
        LazyShardedNn { db, threads }
    }

    pub fn db(&self) -> &Arc<LazyShardedPerfDb> {
        &self.db
    }
}

impl NnQuery for LazyShardedNn {
    fn nearest(&mut self, q: &[f32; DIMS]) -> crate::Result<(usize, f32)> {
        self.db
            .nearest(q, self.threads)?
            .ok_or_else(|| anyhow::anyhow!("empty database"))
    }

    fn top_k(&mut self, q: &[f32; DIMS], k: usize) -> crate::Result<Vec<(usize, f32)>> {
        anyhow::ensure!(!self.db.is_empty(), "empty database");
        self.db.top_k(q, k, self.threads)
    }

    fn backend(&self) -> &'static str {
        "lazy-sharded"
    }
}

impl PerfSource for ShardedPerfDb {
    fn n_records(&self) -> usize {
        self.len()
    }

    fn fraction_grid(&self) -> &[f32] {
        &self.fractions
    }

    fn loss_curve_of(&self, record: usize) -> crate::Result<Vec<(f64, f64)>> {
        anyhow::ensure!(
            record < self.len(),
            "record index {record} out of range (database holds {} records)",
            self.len()
        );
        let (si, li) = self.loc[record];
        Ok(self.shards[si as usize].db.loss_curve(li as usize))
    }

    fn source_name(&self) -> &'static str {
        "sharded"
    }
}

impl PerfSource for LazyShardedPerfDb {
    fn n_records(&self) -> usize {
        self.len()
    }

    fn fraction_grid(&self) -> &[f32] {
        &self.manifest.fractions
    }

    fn loss_curve_of(&self, record: usize) -> crate::Result<Vec<(f64, f64)>> {
        let (si, li) = self.locate(record)?;
        let sh = self.segment(si as usize)?;
        Ok(sh.db.loss_curve(li as usize))
    }

    fn source_name(&self) -> &'static str {
        "lazy-sharded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::native::NativeNn;
    use crate::perfdb::{normalize, store};
    use crate::util::rng::Rng;

    fn sample_db(n: usize, seed: u64) -> PerfDb {
        let mut rng = Rng::new(seed);
        let fractions = vec![1.0, 0.9, 0.8, 0.6, 0.4];
        let records = (0..n)
            .map(|_| {
                let raw = [
                    rng.range_f64(100.0, 50_000.0),
                    rng.range_f64(0.0, 10_000.0),
                    rng.range_f64(0.0, 400.0),
                    rng.range_f64(0.0, 400.0),
                    rng.range_f64(0.05, 20.0),
                    rng.range_f64(3_000.0, 40_000.0),
                    2.0,
                    16.0,
                ];
                Record {
                    raw,
                    vec: normalize(&raw),
                    times_ns: (0..fractions.len())
                        .map(|i| 100.0 + i as f32 * (1.0 + rng.f32()))
                        .collect(),
                }
            })
            .collect();
        PerfDb { fractions, records }
    }

    #[test]
    fn flat_sharded_flat_is_bit_identical() {
        let db = sample_db(41, 3);
        for n_shards in [1, 2, 5, 64] {
            let sharded = ShardedPerfDb::from_flat(&db, n_shards);
            assert_eq!(sharded.len(), db.records.len());
            assert_eq!(
                store::to_bytes(&sharded.to_flat()),
                store::to_bytes(&db),
                "{n_shards} shards"
            );
        }
    }

    #[test]
    fn sharded_queries_match_flat_exactly() {
        let db = sample_db(37, 7);
        let sharded = ShardedPerfDb::from_flat(&db, 4);
        let mut native = NativeNn::new(&db);
        let mut rng = Rng::new(9);
        for _ in 0..32 {
            let raw = [
                rng.range_f64(100.0, 50_000.0),
                rng.range_f64(0.0, 10_000.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.05, 20.0),
                rng.range_f64(3_000.0, 40_000.0),
                2.0,
                16.0,
            ];
            let q = normalize(&raw);
            let (fi, fd) = native.nearest(&q).unwrap();
            let (si, sd) = sharded.nearest(&q, 2).unwrap();
            assert_eq!((si, sd.to_bits()), (fi, fd.to_bits()));
            let ft = NativeNn::new(&db).top_k(&q, 5);
            let st = sharded.top_k(&q, 5, 2);
            assert_eq!(st.len(), ft.len());
            for (a, b) in ft.iter().zip(&st) {
                assert_eq!((a.0, a.1.to_bits()), (b.0, b.1.to_bits()));
            }
            let frac = rng.range_f64(0.3, 1.0);
            assert_eq!(db.time_at(fi, frac).to_bits(), sharded.time_at(fi, frac).to_bits());
        }
    }

    #[test]
    fn parallel_fan_out_path_matches_flat_above_threshold() {
        // enough records that fan_out takes the parallel_map branch —
        // the merge/tie-break there must agree with the flat argmin too
        let db = sample_db(SERIAL_QUERY_THRESHOLD + 64, 29);
        let sharded = ShardedPerfDb::from_flat(&db, 6);
        assert!(sharded.len() > SERIAL_QUERY_THRESHOLD);
        let mut native = NativeNn::new(&db);
        let mut rng = Rng::new(31);
        for _ in 0..8 {
            let raw = [
                rng.range_f64(100.0, 50_000.0),
                rng.range_f64(0.0, 10_000.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.05, 20.0),
                rng.range_f64(3_000.0, 40_000.0),
                2.0,
                16.0,
            ];
            let q = normalize(&raw);
            let (fi, fd) = native.nearest(&q).unwrap();
            let (si, sd) = sharded.nearest(&q, 4).unwrap();
            assert_eq!((si, sd.to_bits()), (fi, fd.to_bits()));
            let ft = NativeNn::new(&db).top_k(&q, 4);
            let st = sharded.top_k(&q, 4, 4);
            for (a, b) in ft.iter().zip(&st) {
                assert_eq!((a.0, a.1.to_bits()), (b.0, b.1.to_bits()));
            }
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_bytes() {
        let db = sample_db(23, 11);
        let sharded = ShardedPerfDb::from_flat(&db, 3);
        let dir = std::env::temp_dir()
            .join(format!("tuna_shard_rt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        sharded.save(&dir).unwrap();
        let back = ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(back.n_shards(), 3);
        assert_eq!(store::to_bytes(&back.to_flat()), store::to_bytes(&db));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_segment_or_manifest_is_rejected() {
        let db = sample_db(12, 13);
        let dir = std::env::temp_dir()
            .join(format!("tuna_shard_corrupt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, 2).save(&dir).unwrap();

        // flip a byte in a non-empty segment → CRC mismatch
        let seg = (0..2)
            .map(|si| dir.join(segment_name(si)))
            .find(|p| std::fs::metadata(p).unwrap().len() > 8)
            .unwrap();
        let mut bytes = std::fs::read(&seg).unwrap();
        let mid = 8 + (bytes.len() - 8) / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&seg, &bytes).unwrap();
        assert!(ShardedPerfDb::load(&dir).is_err());

        // corrupt manifest magic
        let manifest = dir.join(MANIFEST_NAME);
        let mut m = std::fs::read(&manifest).unwrap();
        m[0] = b'X';
        std::fs::write(&manifest, &m).unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_writer_matches_from_flat_save() {
        let db = sample_db(19, 17);
        let a = std::env::temp_dir().join(format!("tuna_shard_wa_{}", std::process::id()));
        let b = std::env::temp_dir().join(format!("tuna_shard_wb_{}", std::process::id()));
        std::fs::remove_dir_all(&a).ok();
        std::fs::remove_dir_all(&b).ok();
        ShardedPerfDb::from_flat(&db, 4).save(&a).unwrap();
        let mut w = ShardedWriter::create(&b, &db.fractions, 4).unwrap();
        for r in &db.records {
            w.push(r).unwrap();
        }
        assert_eq!(w.len(), db.records.len());
        w.finish().unwrap();
        for si in 0..4 {
            assert_eq!(
                std::fs::read(a.join(segment_name(si))).unwrap(),
                std::fs::read(b.join(segment_name(si))).unwrap(),
                "segment {si}"
            );
        }
        assert_eq!(
            std::fs::read(a.join(MANIFEST_NAME)).unwrap(),
            std::fs::read(b.join(MANIFEST_NAME)).unwrap()
        );
        std::fs::remove_dir_all(&a).ok();
        std::fs::remove_dir_all(&b).ok();
    }

    #[test]
    fn rebuild_with_fewer_shards_sweeps_stale_segments() {
        let db = sample_db(20, 23);
        let dir = std::env::temp_dir()
            .join(format!("tuna_shard_rebuild_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, 8).save(&dir).unwrap();
        assert!(dir.join(segment_name(7)).exists());
        // rebuild narrower into the same directory
        ShardedPerfDb::from_flat(&db, 3).save(&dir).unwrap();
        let back = ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(back.n_shards(), 3);
        assert_eq!(store::to_bytes(&back.to_flat()), store::to_bytes(&db));
        for si in 3..8 {
            assert!(!dir.join(segment_name(si)).exists(), "stale segment {si} not swept");
        }
        // an abandoned rebuild (writer dropped before finish) must leave
        // the previous generation fully loadable and sweep its own temps
        let mut w = ShardedWriter::create(&dir, &db.fractions, 5).unwrap();
        w.push(&db.records[0]).unwrap();
        drop(w);
        let still = ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(still.n_shards(), 3);
        assert_eq!(store::to_bytes(&still.to_flat()), store::to_bytes(&db));
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "abandoned build leaked temps: {stray:?}");
        // a crashed rebuild (manifest removed, segments half-written)
        // reads as "no database", not a CRC-corrupt one
        std::fs::remove_file(dir.join(MANIFEST_NAME)).unwrap();
        let err = format!("{:#}", ShardedPerfDb::load(&dir).unwrap_err());
        assert!(err.contains("manifest"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn random_query(rng: &mut Rng) -> [f32; DIMS] {
        let raw = [
            rng.range_f64(100.0, 50_000.0),
            rng.range_f64(0.0, 10_000.0),
            rng.range_f64(0.0, 400.0),
            rng.range_f64(0.0, 400.0),
            rng.range_f64(0.05, 20.0),
            rng.range_f64(3_000.0, 40_000.0),
            2.0,
            16.0,
        ];
        normalize(&raw)
    }

    #[test]
    fn nan_query_agrees_across_flat_sharded_and_lazy_instead_of_panicking() {
        // A NaN telemetry feature reaching the query vector used to panic
        // the shard merge's `partial_cmp().unwrap()`; under the
        // `total_cmp` order every backend must return the *same*
        // deterministic answer instead.
        let db = sample_db(40, 9);
        let sharded = ShardedPerfDb::from_flat(&db, 4);
        let dir = std::env::temp_dir().join(format!("tuna_shard_nan_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        sharded.save(&dir).unwrap();
        let lazy = LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap();

        let mut q = random_query(&mut Rng::new(5));
        q[2] = f32::NAN;
        let mut native = NativeNn::new(&db);
        let (fi, fd) = native.nearest(&q).unwrap();
        assert!(fd.is_nan(), "all distances to a NaN query are NaN");
        let (si, sd) = sharded.nearest(&q, 2).unwrap();
        assert_eq!((si, sd.to_bits()), (fi, fd.to_bits()));
        let (li, ld) = lazy.nearest(&q, 1).unwrap().unwrap();
        assert_eq!((li, ld.to_bits()), (fi, fd.to_bits()));

        let ft = NativeNn::new(&db).top_k(&q, 6);
        let st = sharded.top_k(&q, 6, 2);
        let lt = lazy.top_k(&q, 6, 1).unwrap();
        assert_eq!(ft.len(), 6);
        for ((a, b), c) in ft.iter().zip(&st).zip(&lt) {
            assert_eq!((a.0, a.1.to_bits()), (b.0, b.1.to_bits()));
            assert_eq!((a.0, a.1.to_bits()), (c.0, c.1.to_bits()));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lazy_queries_bit_identical_to_resident_under_cap_1_adversarial_schedule() {
        let db = sample_db(150, 31);
        let n_shards = 5;
        let dir = std::env::temp_dir().join(format!("tuna_lazy_cap1_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, n_shards).save(&dir).unwrap();
        let resident = ShardedPerfDb::load(&dir).unwrap();
        let lazy = LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap();
        assert_eq!(lazy.len(), resident.len());
        assert_eq!(lazy.stats().loads, 0, "open must not touch segments");

        // Adversarial interleaving: every round mixes fan-out queries
        // (touch all segments, evicting down to 1 between touches) with
        // point lookups of arbitrary globals (reload whatever was just
        // evicted). Every answer must match the fully-resident DB to the
        // bit, regardless of what the eviction schedule did.
        let mut rng = Rng::new(77);
        for _ in 0..24 {
            let q = random_query(&mut rng);
            let (fi, fd) = resident.nearest(&q, 1).unwrap();
            let (li, ld) = lazy.nearest(&q, 1).unwrap().unwrap();
            assert_eq!((li, ld.to_bits()), (fi, fd.to_bits()));
            let ft = resident.top_k(&q, 5, 1);
            let lt = lazy.top_k(&q, 5, 1).unwrap();
            assert_eq!(ft.len(), lt.len());
            for (a, b) in ft.iter().zip(&lt) {
                assert_eq!((a.0, a.1.to_bits()), (b.0, b.1.to_bits()));
            }
            let g = rng.index(resident.len());
            let frac = rng.range_f64(0.3, 1.0);
            assert_eq!(
                resident.time_at(g, frac).to_bits(),
                lazy.time_at(g, frac).unwrap().to_bits(),
                "time_at({g}, {frac})"
            );
        }
        let s = lazy.stats();
        assert_eq!(s.peak_resident_segments, 1, "cap was 1: {s:?}");
        assert!(s.resident_segments <= 1, "{s:?}");
        assert_eq!(s.crc_verifies, n_shards as u64, "one CRC per segment, ever");
        assert!(s.evictions > 0, "cap 1 over {n_shards} segments must evict");
        assert!(s.loads > n_shards as u64, "churn must have reloaded evicted segments: {s:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lazy_concurrent_queries_never_double_load_or_deadlock() {
        let db = sample_db(120, 41);
        let n_shards = 6;
        let dir = std::env::temp_dir().join(format!("tuna_lazy_conc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, n_shards).save(&dir).unwrap();
        let resident = ShardedPerfDb::load(&dir).unwrap();

        // Unbounded: 8 threads race on first touches; the per-slot lock
        // must collapse them so every segment is read exactly once.
        let lazy = std::sync::Arc::new(
            LazyShardedPerfDb::open(&dir, ResidencyLimit::UNBOUNDED).unwrap(),
        );
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let lazy = &lazy;
                let resident = &resident;
                s.spawn(move || {
                    let mut rng = Rng::new(1000 + t);
                    for _ in 0..12 {
                        let q = random_query(&mut rng);
                        let (fi, fd) = resident.nearest(&q, 1).unwrap();
                        let (li, ld) = lazy.nearest(&q, 1).unwrap().unwrap();
                        assert_eq!((li, ld.to_bits()), (fi, fd.to_bits()));
                    }
                });
            }
        });
        let s = lazy.stats();
        assert_eq!(s.loads, n_shards as u64, "concurrent first touches double-loaded");
        assert_eq!(s.crc_verifies, n_shards as u64);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.resident_segments, n_shards);

        // Cap 1 under concurrency: no deadlock between loaders, waiters
        // and evictors, answers stay exact, and the reserve-then-load
        // admission keeps the cached set within the cap at every instant
        // (peak accounting proves it), not just at quiescence.
        let capped = std::sync::Arc::new(
            LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap(),
        );
        std::thread::scope(|s| {
            for t in 0..6u64 {
                let capped = &capped;
                let resident = &resident;
                s.spawn(move || {
                    let mut rng = Rng::new(2000 + t);
                    for _ in 0..8 {
                        let q = random_query(&mut rng);
                        let (fi, fd) = resident.nearest(&q, 1).unwrap();
                        let (li, ld) = capped.nearest(&q, 1).unwrap().unwrap();
                        assert_eq!((li, ld.to_bits()), (fi, fd.to_bits()));
                        let g = rng.index(resident.len());
                        assert_eq!(
                            resident.time_at(g, 0.8).to_bits(),
                            capped.time_at(g, 0.8).unwrap().to_bits()
                        );
                    }
                });
            }
        });
        let q = random_query(&mut Rng::new(3));
        let _ = capped.nearest(&q, 1).unwrap();
        let s = capped.stats();
        assert!(s.evictions > 0);
        assert_eq!(s.crc_verifies, n_shards as u64, "CRC still once per segment");
        assert_eq!(
            s.peak_resident_segments,
            1,
            "concurrent faults must never race the cache past the cap: {s:?}"
        );
        assert_eq!(
            s.resident_segments,
            1,
            "a quiescent serial query must leave exactly the cap resident: {s:?}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lazy_corrupt_segment_detected_at_first_touch_and_does_not_poison_others() {
        let db = sample_db(30, 13);
        let n_shards = 3;
        let dir = std::env::temp_dir().join(format!("tuna_lazy_crc_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, n_shards).save(&dir).unwrap();

        // flip a payload byte in a non-empty segment, remembering which
        let (corrupt_si, seg_path) = (0..n_shards)
            .map(|si| (si, dir.join(segment_name(si))))
            .find(|(_, p)| std::fs::metadata(p).unwrap().len() > 8)
            .unwrap();
        let pristine = std::fs::read(&seg_path).unwrap();
        let mut bytes = pristine.clone();
        let mid = 8 + (bytes.len() - 8) / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&seg_path, &bytes).unwrap();

        // open succeeds — CRC is deferred to first touch, never at open
        let lazy = LazyShardedPerfDb::open(&dir, ResidencyLimit::segments(1)).unwrap();
        let q = random_query(&mut Rng::new(1));
        let err = format!("{:#}", lazy.nearest(&q, 1).unwrap_err());
        assert!(
            err.contains(&segment_name(corrupt_si)) && err.contains("CRC"),
            "error must name the corrupt segment: {err}"
        );

        // records in healthy segments stay reachable (locate skips the
        // unreadable segment), and an affected record names the segment
        let healthy_g = db
            .records
            .iter()
            .position(|r| shard_of(&r.raw, n_shards) != corrupt_si)
            .unwrap();
        let corrupt_g = db
            .records
            .iter()
            .position(|r| shard_of(&r.raw, n_shards) == corrupt_si)
            .unwrap();
        assert_eq!(
            lazy.time_at(healthy_g, 0.8).unwrap().to_bits(),
            db.time_at(healthy_g, 0.8).to_bits()
        );
        let err = format!("{:#}", lazy.time_at(corrupt_g, 0.8).unwrap_err());
        assert!(err.contains(&segment_name(corrupt_si)), "{err}");

        // repairing the file heals the same handle: the failed slot was
        // left empty, not poisoned, and the CRC re-runs on the next touch
        std::fs::write(&seg_path, &pristine).unwrap();
        let (li, ld) = lazy.nearest(&q, 1).unwrap().unwrap();
        let mut native = NativeNn::new(&db);
        let (fi, fd) = native.nearest(&q).unwrap();
        assert_eq!((li, ld.to_bits()), (fi, fd.to_bits()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lazy_byte_budget_caps_resident_bytes() {
        let db = sample_db(90, 59);
        let dir = std::env::temp_dir().join(format!("tuna_lazy_bytes_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, 4).save(&dir).unwrap();
        let manifest = read_manifest(&dir).unwrap();
        let rec = record_size(manifest.fractions.len()) as u64;
        let largest = manifest.segments.iter().map(|s| s.n_recs * rec).max().unwrap();

        let resident = ShardedPerfDb::load(&dir).unwrap();
        let lazy = LazyShardedPerfDb::open(&dir, ResidencyLimit::bytes(largest)).unwrap();
        let mut rng = Rng::new(17);
        for _ in 0..10 {
            let q = random_query(&mut rng);
            let (fi, fd) = resident.nearest(&q, 1).unwrap();
            let (li, ld) = lazy.nearest(&q, 1).unwrap().unwrap();
            assert_eq!((li, ld.to_bits()), (fi, fd.to_bits()));
        }
        let s = lazy.stats();
        assert!(s.peak_resident_bytes <= largest, "budget {largest} exceeded: {s:?}");
        assert!(s.evictions > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn segment_size_listing_helpers() {
        let db = sample_db(25, 3);
        let dir = std::env::temp_dir().join(format!("tuna_segsz_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ShardedPerfDb::from_flat(&db, 3).save(&dir).unwrap();
        let manifest = read_manifest(&dir).unwrap();
        let sizes = segment_sizes(&dir, &manifest);
        assert_eq!(sizes.len(), 3);
        for (si, &sz) in sizes.iter().enumerate() {
            assert_eq!(sz, std::fs::metadata(dir.join(segment_name(si))).unwrap().len());
        }
        let short = fmt_segment_sizes(&sizes);
        assert!(short.starts_with("seg bytes "), "{short}");
        assert_eq!(short.matches('/').count(), 2, "{short}");
        let many: Vec<u64> = (0..20).map(|i| 1000 + i).collect();
        let summary = fmt_segment_sizes(&many);
        assert!(summary.contains("..") && summary.contains("total"), "{summary}");
        assert_eq!(fmt_segment_sizes(&[]), "no segments");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_nn_backend_works() {
        let db = sample_db(15, 19);
        let sharded = std::sync::Arc::new(ShardedPerfDb::from_flat(&db, 3));
        let mut nn = ShardedNn::new(sharded, 2);
        let q = db.records[7].vec;
        let (idx, d) = nn.nearest(&q).unwrap();
        assert_eq!(idx, 7);
        assert!(d < 1e-9);
        assert_eq!(nn.backend(), "sharded");
        let top = nn.top_k(&q, 3).unwrap();
        assert_eq!(top[0].0, 7);
    }
}
