//! Persistent artifact store: durable, diffable modeling artifacts.
//!
//! Tuna's premise is that offline modeling artifacts plus cheap telemetry
//! replace online trial-and-error — which only pays off if those
//! artifacts survive the process that built them. This subsystem is the
//! on-disk home for everything the coordinator produces:
//!
//! * [`shard`] — the performance database split into N segment files
//!   (hash of configuration vector → shard) under a CRC-carrying
//!   manifest; queries fan out across shards and merge, the builder
//!   streams completed records straight into segment writers, and
//!   [`shard::LazyShardedPerfDb`] serves queries from a bounded resident
//!   set (segments faulted in on first touch, evicted past a cap).
//! * [`cells`] — append-only binary tables of executed sweep cells
//!   (workload, policy, fraction, seed, hot_thr → loss/saving/migration
//!   counts), diffable across commits via `tuna store diff`.
//! * [`cache`] — the cross-process baseline cache backing
//!   [`crate::coordinator::sweep::BaselineCache`], so repeated bench or
//!   sweep invocations load memoized fast-memory-only baselines from
//!   disk instead of re-simulating them.
//!
//! All writes are atomic (unique per-process temp file + rename, the same
//! crash-consistency discipline as [`crate::perfdb::store`]) and every
//! payload is CRC-checked on read.
//!
//! Layout under the store root:
//!
//! ```text
//! <root>/
//!   perfdb/<name>/MANIFEST + seg-NNN.bin    sharded performance databases
//!   sweeps/<name>.cells                     sweep cell tables
//!   baselines/<key-hash>.bl                 memoized baseline runs
//!   traces/<name>.trc                       recorded KV op-stream traces
//! ```

pub mod cache;
pub mod cells;
pub mod shard;
pub mod wire;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// FNV-1a 64-bit hash — content addressing for artifact names (CRC-32
/// stays the on-disk integrity check; this is only a filename-sized
/// fingerprint).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(0xcbf2_9ce4_8422_2325, bytes)
}

/// Streaming FNV-1a: fold more bytes into an existing hash state (seed
/// the first call with [`fnv1a64`] of the first chunk, or the FNV offset
/// basis via `fnv1a64(b"")`).
pub fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// A temp path unique to this process *and* call, in the same directory
/// as `path` (so the final rename stays within one filesystem). A plain
/// `path.with_extension("tmp")` collides when two processes write sibling
/// artifacts — e.g. targets `db.bin` and `db.tmp` both map to `db.tmp`.
pub fn unique_tmp_path(path: &Path) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "artifact".to_string());
    path.with_file_name(format!(".{name}.{}.{seq}.tmp", std::process::id()))
}

/// Write `bytes` to `path` atomically and durably: unique temp file in
/// the same directory, fsync, then rename (plus a best-effort directory
/// sync so the rename itself survives power loss). Concurrent writers of
/// the same path race on the rename and the last one wins with a
/// complete file — a reader can never observe a partial write.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating directory {}", dir.display()))?;
    }
    let tmp = unique_tmp_path(path);
    let write = || -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        // without the fsync, a crash after the rename can leave the
        // final name pointing at unwritten blocks — the one failure the
        // rename discipline exists to rule out
        f.sync_all()
    };
    if let Err(e) = write() {
        // temp names are unique per call, so a leaked partial temp would
        // accumulate forever — clean it up on any failure
        std::fs::remove_file(&tmp).ok();
        return Err(e).with_context(|| format!("writing {}", tmp.display()));
    }
    std::fs::rename(&tmp, path).with_context(|| {
        std::fs::remove_file(&tmp).ok();
        format!("renaming {} -> {}", tmp.display(), path.display())
    })?;
    if let Some(dir) = path.parent() {
        // best-effort: not every platform lets you open a directory
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

/// One artifact visible in the store (for `tuna store ls`).
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    /// `perfdb`, `sweep`, `baseline`, `trace` — or `(?)` for a file in a
    /// store subdirectory that no artifact kind claims (foreign or
    /// misnamed; listed rather than silently skipped).
    pub kind: &'static str,
    pub name: String,
    /// Total size on disk (all segment files for a sharded perf DB).
    pub bytes: u64,
    pub path: PathBuf,
    /// One-line summary (record/row counts etc.), best effort.
    pub detail: String,
}

/// Handle on a store root directory.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    root: PathBuf,
    obs: crate::obs::Recorder,
}

impl ArtifactStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<Self> {
        for sub in ["perfdb", "sweeps", "baselines", "traces"] {
            std::fs::create_dir_all(root.join(sub))
                .with_context(|| format!("creating store directory {}", root.display()))?;
        }
        Ok(ArtifactStore { root: root.to_path_buf(), obs: crate::obs::Recorder::default() })
    }

    /// Attach an observability recorder (foreign store entries found by
    /// [`Self::ls`] become structured warn-events).
    pub fn with_obs(mut self, obs: crate::obs::Recorder) -> Self {
        self.obs = obs;
        self
    }

    /// Open a store that must already exist — for read-only commands
    /// (`store ls`, `store diff`), where silently creating an empty tree
    /// would mask a mistyped `--store` path as "0 artifacts".
    pub fn open_existing(root: &Path) -> Result<Self> {
        if !root.is_dir() {
            bail!(
                "no artifact store at {} (create one with `tuna sweep --store` or `tuna build-db --store`)",
                root.display()
            );
        }
        Self::open(root)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn perfdb_dir(&self) -> PathBuf {
        self.root.join("perfdb")
    }

    pub fn sweeps_dir(&self) -> PathBuf {
        self.root.join("sweeps")
    }

    pub fn baselines_dir(&self) -> PathBuf {
        self.root.join("baselines")
    }

    pub fn traces_dir(&self) -> PathBuf {
        self.root.join("traces")
    }

    /// Path of the sweep cell table named `name`.
    pub fn sweep_path(&self, name: &str) -> PathBuf {
        self.sweeps_dir().join(format!("{name}.cells"))
    }

    /// Path of the KV trace artifact named `name`.
    pub fn trace_path(&self, name: &str) -> PathBuf {
        self.traces_dir().join(format!("{name}.trc"))
    }

    /// Resolve a trace argument: a name inside this store first, then a
    /// literal filesystem path (same discipline as [`Self::resolve_sweep`]).
    pub fn resolve_trace(&self, name_or_path: &str) -> PathBuf {
        let named = self.trace_path(name_or_path);
        if named.exists() {
            return named;
        }
        PathBuf::from(name_or_path)
    }

    /// Resolve a sweep table argument: a name inside this store first
    /// (so a stray local file can't shadow a stored table), then a
    /// literal filesystem path.
    pub fn resolve_sweep(&self, name_or_path: &str) -> PathBuf {
        let named = self.sweep_path(name_or_path);
        if named.exists() {
            return named;
        }
        PathBuf::from(name_or_path)
    }

    /// A store-subdirectory entry no artifact kind claims: listed with
    /// kind `(?)` and warned about, instead of silently skipped — a
    /// foreign or misnamed file in the store should be visible in
    /// `tuna store ls` output. In-flight atomic-write temps (the
    /// `.<name>.<pid>.<seq>.tmp` files of [`unique_tmp_path`]) are the
    /// one legitimate transient and stay unlisted.
    fn push_foreign(&self, out: &mut Vec<ArtifactInfo>, entry: PathBuf, expected: &str) {
        let name = entry
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        if name.ends_with(".tmp") {
            return;
        }
        self.obs.warn(
            "store.ls",
            &format!("unrecognized entry in artifact store (expected {expected}): {}",
                entry.display()),
        );
        let bytes = if entry.is_file() { file_bytes(&entry).unwrap_or(0) } else { 0 };
        out.push(ArtifactInfo {
            kind: "(?)",
            name,
            bytes,
            path: entry,
            detail: format!("not a recognized artifact (expected {expected})"),
        });
    }

    /// Enumerate every artifact in the store, stable order (kind, name).
    pub fn ls(&self) -> Result<Vec<ArtifactInfo>> {
        let mut out = Vec::new();
        for entry in sorted_dir(&self.perfdb_dir())? {
            if !entry.is_dir() {
                self.push_foreign(&mut out, entry, "a perf-DB directory");
                continue;
            }
            let name = file_name(&entry);
            let detail = match shard::read_manifest(&entry) {
                Ok(m) => format!(
                    "{} records x {} sizes in {} segments; {}",
                    m.n_records,
                    m.fractions.len(),
                    m.segments.len(),
                    // per-segment sizes: what a residency cap would hold
                    shard::fmt_segment_sizes(&shard::segment_sizes(&entry, &m))
                ),
                Err(e) => format!("unreadable manifest: {e:#}"),
            };
            out.push(ArtifactInfo {
                kind: "perfdb",
                name,
                bytes: dir_bytes(&entry)?,
                path: entry,
                detail,
            });
        }
        for entry in sorted_dir(&self.sweeps_dir())? {
            if entry.extension().map(|e| e != "cells").unwrap_or(true) {
                self.push_foreign(&mut out, entry, "a `.cells` sweep table");
                continue;
            }
            // framing walk only — listing must not parse or CRC payloads
            let detail = match cells::SweepTable::peek_rows(&entry) {
                Ok(n) => format!("{n} cells"),
                Err(e) => format!("unreadable: {e:#}"),
            };
            out.push(ArtifactInfo {
                kind: "sweep",
                name: file_name(&entry),
                bytes: file_bytes(&entry)?,
                path: entry,
                detail,
            });
        }
        for entry in sorted_dir(&self.baselines_dir())? {
            if entry.extension().map(|e| e != "bl").unwrap_or(true) {
                self.push_foreign(&mut out, entry, "a `.bl` baseline");
                continue;
            }
            // header-only peek: listing must not scale with trace bytes
            let detail = match cache::peek_summary(&entry) {
                Ok(s) => s,
                Err(e) => format!("unreadable: {e:#}"),
            };
            out.push(ArtifactInfo {
                kind: "baseline",
                name: file_name(&entry),
                bytes: file_bytes(&entry)?,
                path: entry,
                detail,
            });
        }
        for entry in sorted_dir(&self.traces_dir())? {
            if entry.extension().map(|e| e != "trc").unwrap_or(true) {
                self.push_foreign(&mut out, entry, "a `.trc` trace");
                continue;
            }
            // header-only peek: listing must not CRC megabytes of frames
            let detail = match crate::trace::format::peek(&entry) {
                Ok((h, n_intervals, total_ops)) => format!(
                    "{} seed {}: {total_ops} ops in {n_intervals} intervals, {} keys",
                    h.workload, h.seed, h.n_keys
                ),
                Err(e) => format!("unreadable: {e:#}"),
            };
            out.push(ArtifactInfo {
                kind: "trace",
                name: file_name(&entry),
                bytes: file_bytes(&entry)?,
                path: entry,
                detail,
            });
        }
        Ok(out)
    }
}

fn file_name(path: &Path) -> String {
    path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default()
}

fn sorted_dir(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut v = Vec::new();
    if !dir.exists() {
        return Ok(v);
    }
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?
    {
        v.push(entry?.path());
    }
    v.sort();
    Ok(v)
}

fn file_bytes(path: &Path) -> Result<u64> {
    Ok(std::fs::metadata(path)?.len())
}

fn dir_bytes(dir: &Path) -> Result<u64> {
    let mut total = 0;
    for p in sorted_dir(dir)? {
        if p.is_file() {
            total += file_bytes(&p)?;
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tuna_artifact_{tag}_{}", std::process::id()))
    }

    #[test]
    fn fnv_is_stable_and_spreads() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"a"), fnv1a64(b"b"));
        // reference vector: fnv1a64("a") = 0xaf63dc4c8601ec8c
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn unique_tmp_paths_differ_per_call_and_stay_in_dir() {
        let p = Path::new("/some/dir/db.bin");
        let a = unique_tmp_path(p);
        let b = unique_tmp_path(p);
        assert_ne!(a, b);
        assert_eq!(a.parent(), p.parent());
        // sibling targets `db.bin` / `db.tmp` must not share a temp name
        let c = unique_tmp_path(Path::new("/some/dir/db.tmp"));
        assert_ne!(a.file_name(), c.file_name());
    }

    #[test]
    fn write_atomic_replaces_and_never_leaves_temps() {
        let root = tmp_root("atomic");
        let path = root.join("x.bin");
        write_atomic(&path, b"one").unwrap();
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        let leftovers: Vec<_> = std::fs::read_dir(&root)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "stray temps: {leftovers:?}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn ls_flags_foreign_entries_instead_of_hiding_them() {
        let root = tmp_root("foreign");
        std::fs::remove_dir_all(&root).ok();
        let obs = crate::obs::Recorder::enabled(16);
        let store = ArtifactStore::open(&root).unwrap().with_obs(obs.clone());
        // a foreign file in each subdir, a stray file under perfdb/, and
        // one legitimate in-flight temp that must stay invisible
        std::fs::write(store.sweeps_dir().join("notes.txt"), b"hi").unwrap();
        std::fs::write(store.baselines_dir().join("junk.bin"), b"junk").unwrap();
        std::fs::write(store.traces_dir().join("trace.bak"), b"old").unwrap();
        std::fs::write(store.perfdb_dir().join("loose-file"), b"x").unwrap();
        std::fs::write(
            store.sweeps_dir().join(".t.cells.123.0.tmp"),
            b"partial",
        )
        .unwrap();
        let listed = store.ls().unwrap();
        let foreign: Vec<&ArtifactInfo> =
            listed.iter().filter(|a| a.kind == "(?)").collect();
        assert_eq!(foreign.len(), 4, "every foreign entry is listed: {listed:?}");
        assert!(foreign.iter().any(|a| a.name == "notes.txt"));
        assert!(foreign.iter().any(|a| a.name == "loose-file"));
        assert!(
            !listed.iter().any(|a| a.name.ends_with(".tmp")),
            "in-flight temps stay unlisted: {listed:?}"
        );
        assert!(foreign.iter().all(|a| a.detail.contains("not a recognized artifact")));
        // each foreign entry raised a structured warn-event
        assert_eq!(obs.snapshot().counter("obs_warn_total"), 4);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn open_creates_layout_and_ls_is_empty() {
        let root = tmp_root("layout");
        let store = ArtifactStore::open(&root).unwrap();
        assert!(store.perfdb_dir().is_dir());
        assert!(store.sweeps_dir().is_dir());
        assert!(store.baselines_dir().is_dir());
        assert!(store.traces_dir().is_dir());
        assert!(store.ls().unwrap().is_empty());
        // resolve: nonexistent name falls back to the literal path
        let p = store.resolve_sweep("nope");
        assert_eq!(p, PathBuf::from("nope"));
        assert_eq!(store.resolve_trace("nope"), PathBuf::from("nope"));
        // read-only open of an existing store works...
        assert!(ArtifactStore::open_existing(&root).is_ok());
        std::fs::remove_dir_all(&root).ok();
        // ...but a missing root errors instead of creating an empty tree
        let err = ArtifactStore::open_existing(&root).unwrap_err();
        assert!(format!("{err:#}").contains("no artifact store"), "{err:#}");
        assert!(!root.exists(), "open_existing must not create directories");
    }
}
