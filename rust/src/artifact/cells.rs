//! Durable sweep cells: an append-only binary table of executed sweep
//! cells — (workload, policy, seed, hot_thr, fraction, migration mode) →
//! loss, saving, migration counts (+ Tuna stats when present, + shadow /
//! transactional counters for non-exclusive cells).
//!
//! Tables are the diffable unit of the artifact store: `tuna store diff`
//! compares two of them cell-by-cell and reports loss/saving regressions,
//! giving the cross-commit performance trajectory the roadmap asks for.
//!
//! File format (`TUNACEL1`): the 8-byte magic, then one length-prefixed,
//! individually CRC'd block per row:
//!
//! ```text
//! [len u32][row payload][crc32(payload) u32] ...
//! ```
//!
//! Per-row CRCs localize corruption to single cells, and every write —
//! including [`SweepTable::append`], which is logically append-only —
//! goes through an atomic temp-rename, so a reader never observes a torn
//! tail block.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::wire::{self, Reader};
use super::write_atomic;
use crate::admission::AdmissionConfig;
use crate::coordinator::sweep::{SweepPolicy, SweepResult};
use crate::perfdb::store::crc32;
use crate::sim::MigrationModel;

const MAGIC: &[u8; 8] = b"TUNACEL1";

/// Tuna-policy extras carried by a row (mirrors
/// [`crate::coordinator::sweep::TunaCellStats`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TunaRowStats {
    pub decisions: u64,
    pub mean_fraction: f64,
    pub min_fraction: f64,
    pub decide_ns: u128,
}

/// One persisted sweep cell.
#[derive(Clone, Debug, PartialEq)]
pub struct CellRow {
    pub workload: String,
    pub policy: SweepPolicy,
    pub seed: u64,
    pub hot_thr: u32,
    pub fm_fraction: f64,
    pub loss: f64,
    pub saving: f64,
    pub total_ns: f64,
    pub promoted: u64,
    pub promote_failed: u64,
    pub demoted: u64,
    /// Migration semantics the cell ran under. Serialized as a trailing
    /// payload block *only* when non-exclusive, so tables of exclusive
    /// cells are byte-identical to pre-migration-axis tables (and old
    /// tables load with `Exclusive` + zero counters).
    pub migration: MigrationModel,
    pub shadow_hits: u64,
    pub shadow_free_demotions: u64,
    pub txn_aborts: u64,
    pub txn_retried_copies: u64,
    /// Admission-control knobs the cell ran under. Serialized as a second
    /// trailing block *only* when enabled (or when a verdict counter is
    /// nonzero), so tables of ungated cells keep their existing byte
    /// layout exactly (and old tables load with admission disabled + zero
    /// counters). Writing this block forces the migration block too —
    /// the trailing blocks are positional.
    pub admission: AdmissionConfig,
    pub admission_accepted: u64,
    pub admission_rejected_budget: u64,
    pub admission_rejected_payoff: u64,
    pub admission_rejected_cooldown: u64,
    pub tuna: Option<TunaRowStats>,
}

impl CellRow {
    pub fn migrations(&self) -> u64 {
        self.promoted + self.demoted
    }

    /// Identity of the grid cell this row measures (everything except the
    /// measured outputs), used to match rows across tables.
    pub fn key(&self) -> (String, u8, u64, u32, u64, (u8, u8, u32), (u8, u64, u32, u32)) {
        (
            self.workload.to_ascii_lowercase(),
            self.policy.code(),
            self.seed,
            self.hot_thr,
            self.fm_fraction.to_bits(),
            self.migration.key(),
            self.admission.key(),
        )
    }

    fn to_payload(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(96 + self.workload.len());
        wire::put_str(&mut out, &self.workload);
        wire::put_u8(&mut out, self.policy.code());
        wire::put_u64(&mut out, self.seed);
        wire::put_u32(&mut out, self.hot_thr);
        wire::put_f64(&mut out, self.fm_fraction);
        wire::put_f64(&mut out, self.loss);
        wire::put_f64(&mut out, self.saving);
        wire::put_f64(&mut out, self.total_ns);
        wire::put_u64(&mut out, self.promoted);
        wire::put_u64(&mut out, self.promote_failed);
        wire::put_u64(&mut out, self.demoted);
        match &self.tuna {
            None => wire::put_u8(&mut out, 0),
            Some(t) => {
                wire::put_u8(&mut out, 1);
                wire::put_u64(&mut out, t.decisions);
                wire::put_f64(&mut out, t.mean_fraction);
                wire::put_f64(&mut out, t.min_fraction);
                wire::put_u128(&mut out, t.decide_ns);
            }
        }
        // trailing migration block, written only for non-exclusive rows:
        // exclusive rows keep the pre-migration-axis byte layout exactly
        // (nonzero counters force the block even on a mislabeled row —
        // better an extra block than silently dropped measurements)
        let counters = self.shadow_hits
            + self.shadow_free_demotions
            + self.txn_aborts
            + self.txn_retried_copies;
        let adm_counters = self.admission_accepted
            + self.admission_rejected_budget
            + self.admission_rejected_payoff
            + self.admission_rejected_cooldown;
        // the admission block is positional (it follows the migration
        // block), so writing it forces the migration block too
        let write_admission = self.admission.enabled || adm_counters > 0;
        if !self.migration.is_exclusive() || counters > 0 || write_admission {
            let (mode, abort, copy) = self.migration.key();
            wire::put_u8(&mut out, mode);
            wire::put_u8(&mut out, abort);
            wire::put_u32(&mut out, copy);
            wire::put_u64(&mut out, self.shadow_hits);
            wire::put_u64(&mut out, self.shadow_free_demotions);
            wire::put_u64(&mut out, self.txn_aborts);
            wire::put_u64(&mut out, self.txn_retried_copies);
        }
        if write_admission {
            let (enabled, budget, cooldown, horizon) = self.admission.key();
            wire::put_u8(&mut out, enabled);
            wire::put_u64(&mut out, budget);
            wire::put_u32(&mut out, cooldown);
            wire::put_u32(&mut out, horizon);
            wire::put_u64(&mut out, self.admission_accepted);
            wire::put_u64(&mut out, self.admission_rejected_budget);
            wire::put_u64(&mut out, self.admission_rejected_payoff);
            wire::put_u64(&mut out, self.admission_rejected_cooldown);
        }
        out
    }

    fn from_payload(payload: &[u8]) -> Result<Self> {
        let mut r = Reader::new(payload);
        let workload = r.str()?;
        let policy = SweepPolicy::from_code(r.u8()?)?;
        let seed = r.u64()?;
        let hot_thr = r.u32()?;
        let fm_fraction = r.f64()?;
        let loss = r.f64()?;
        let saving = r.f64()?;
        let total_ns = r.f64()?;
        let promoted = r.u64()?;
        let promote_failed = r.u64()?;
        let demoted = r.u64()?;
        let tuna = match r.u8()? {
            0 => None,
            1 => Some(TunaRowStats {
                decisions: r.u64()?,
                mean_fraction: r.f64()?,
                min_fraction: r.f64()?,
                decide_ns: r.u128()?,
            }),
            other => bail!("bad tuna-stats tag {other} in cell row"),
        };
        // absent trailing block (old tables, exclusive rows) → Exclusive
        let (migration, shadow) = if r.remaining() > 0 {
            let mode = r.u8()?;
            let abort = r.u8()?;
            let copy = r.u32()?;
            let m = MigrationModel::from_key(mode, abort, copy)
                .map_err(|e| anyhow::anyhow!("{e} in cell row"))?;
            (m, (r.u64()?, r.u64()?, r.u64()?, r.u64()?))
        } else {
            (MigrationModel::Exclusive, (0, 0, 0, 0))
        };
        // absent second trailing block (old tables, ungated rows) →
        // admission disabled with zero verdict counters
        let (admission, adm) = if r.remaining() > 0 {
            let enabled = r.u8()?;
            let budget = r.u64()?;
            let cooldown = r.u32()?;
            let horizon = r.u32()?;
            let a = AdmissionConfig::from_key(enabled, budget, cooldown, horizon)
                .map_err(|e| anyhow::anyhow!("{e} in cell row"))?;
            (a, (r.u64()?, r.u64()?, r.u64()?, r.u64()?))
        } else {
            (AdmissionConfig::default(), (0, 0, 0, 0))
        };
        r.done()?;
        Ok(CellRow {
            workload,
            policy,
            seed,
            hot_thr,
            fm_fraction,
            loss,
            saving,
            total_ns,
            promoted,
            promote_failed,
            demoted,
            migration,
            shadow_hits: shadow.0,
            shadow_free_demotions: shadow.1,
            txn_aborts: shadow.2,
            txn_retried_copies: shadow.3,
            admission,
            admission_accepted: adm.0,
            admission_rejected_budget: adm.1,
            admission_rejected_payoff: adm.2,
            admission_rejected_cooldown: adm.3,
            tuna,
        })
    }
}

/// A sweep cell table, rows in the order they were appended (grid order
/// when produced by [`SweepTable::from_sweep`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepTable {
    pub rows: Vec<CellRow>,
}

impl SweepTable {
    /// Capture every cell of an executed sweep, in grid order.
    pub fn from_sweep(res: &SweepResult) -> Self {
        let rows = res
            .cells
            .iter()
            .map(|c| CellRow {
                workload: c.spec.workload.clone(),
                policy: c.spec.policy,
                seed: c.spec.seed,
                hot_thr: c.spec.hot_thr,
                fm_fraction: c.spec.fm_fraction,
                loss: c.loss,
                saving: c.saving,
                total_ns: c.result.total_ns,
                promoted: c.result.total_promoted(),
                promote_failed: c.result.total_promote_failed(),
                demoted: c.result.total_demoted(),
                migration: c.spec.migration,
                shadow_hits: c.result.total_shadow_hits(),
                shadow_free_demotions: c.result.total_shadow_free_demotions(),
                txn_aborts: c.result.total_txn_aborts(),
                txn_retried_copies: c.result.total_txn_retried_copies(),
                admission: c.spec.admission,
                admission_accepted: c.result.total_admission_accepted(),
                admission_rejected_budget: c.result.total_admission_rejected_budget(),
                admission_rejected_payoff: c.result.total_admission_rejected_payoff(),
                admission_rejected_cooldown: c.result.total_admission_rejected_cooldown(),
                tuna: c.tuna.as_ref().map(|t| TunaRowStats {
                    decisions: t.decisions as u64,
                    mean_fraction: t.mean_fraction,
                    min_fraction: t.min_fraction,
                    decide_ns: t.decide_ns,
                }),
            })
            .collect();
        SweepTable { rows }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize the whole table (magic + row blocks). What
    /// [`Self::save`] writes and what a [`Self::load`] of that file
    /// reproduces byte-for-byte.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        for row in &self.rows {
            push_block(&mut out, &row.to_payload());
        }
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        if data.len() < 8 || &data[..8] != MAGIC {
            bail!("bad sweep-table magic");
        }
        let mut rows = Vec::new();
        let mut r = Reader::new(&data[8..]);
        while r.remaining() > 0 {
            let len = r.u32()? as usize;
            if len > 1 << 24 {
                bail!("implausible row length {len} in sweep table");
            }
            let payload = r.take(len)?;
            let stored = r.u32()?;
            let computed = crc32(payload);
            if stored != computed {
                bail!(
                    "sweep-table row {} CRC mismatch: stored {stored:#x}, computed {computed:#x}",
                    rows.len()
                );
            }
            rows.push(CellRow::from_payload(payload)?);
        }
        Ok(SweepTable { rows })
    }

    /// Write the table atomically.
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_bytes())
            .with_context(|| format!("saving sweep table {}", path.display()))
    }

    /// Append rows to a table file (created if absent). Logically
    /// append-only — existing blocks are never modified — but physically
    /// an atomic rewrite (read + extend + temp-rename), so a crash or
    /// ENOSPC mid-append can never tear the tail and brick the
    /// previously valid rows.
    ///
    /// Single writer per table: two *concurrent* appenders race the
    /// read-extend-rename and the last rename wins, dropping the other
    /// writer's rows. Concurrent processes should append to distinct
    /// tables (they remain diffable/mergeable) — unlike the baseline
    /// cache, appended measurements are not identical-bytes and cannot
    /// race benignly.
    pub fn append(path: &Path, rows: &[CellRow]) -> Result<()> {
        let mut data = match std::fs::read(path) {
            Ok(existing) => {
                // full validation up front: appending valid rows after a
                // corrupt block would bury them in a file load() rejects,
                // while this call still reports success
                Self::from_bytes(&existing).with_context(|| {
                    format!("refusing to append to corrupt table {}", path.display())
                })?;
                existing
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => MAGIC.to_vec(),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("opening sweep table {} for append", path.display()))
            }
        };
        for row in rows {
            push_block(&mut data, &row.to_payload());
        }
        write_atomic(path, &data)
            .with_context(|| format!("appending to sweep table {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let data = std::fs::read(path)
            .with_context(|| format!("opening sweep table {}", path.display()))?;
        Self::from_bytes(&data)
            .with_context(|| format!("parsing sweep table {}", path.display()))
    }

    /// Count a table's rows by walking the block framing with seeks —
    /// no CRC, no payload parsing, no per-row allocation. Listings use
    /// this so they scale with row *count*, not table bytes.
    pub fn peek_rows(path: &Path) -> Result<usize> {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening sweep table {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if magic != *MAGIC {
            bail!("bad sweep-table magic in {}", path.display());
        }
        let end = f.seek(SeekFrom::End(0))?;
        f.seek(SeekFrom::Start(8))?;
        let mut pos = 8u64;
        let mut rows = 0usize;
        let mut lenbuf = [0u8; 4];
        while pos < end {
            if pos + 4 > end {
                bail!("torn block header in {}", path.display());
            }
            f.read_exact(&mut lenbuf)?;
            let len = u32::from_le_bytes(lenbuf) as u64;
            if len > 1 << 24 {
                bail!("implausible row length {len} in {}", path.display());
            }
            pos += 4 + len + 4;
            if pos > end {
                bail!("torn tail block in {}", path.display());
            }
            f.seek(SeekFrom::Start(pos))?;
            rows += 1;
        }
        Ok(rows)
    }
}

fn push_block(out: &mut Vec<u8>, payload: &[u8]) {
    wire::put_u32(out, payload.len() as u32);
    out.extend_from_slice(payload);
    wire::put_u32(out, crc32(payload));
}

/// One matched cell whose measurements moved between two tables.
#[derive(Clone, Debug)]
pub struct RowDelta {
    pub a: CellRow,
    pub b: CellRow,
    pub d_loss: f64,
    pub d_saving: f64,
    pub d_migrations: i64,
}

/// Cell-by-cell comparison of two sweep tables.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Cells present in both tables.
    pub matched: usize,
    /// Matched cells whose loss grew (or saving shrank) beyond `tol`.
    pub regressions: Vec<RowDelta>,
    /// Matched cells whose loss shrank (or saving grew) beyond `tol`.
    pub improvements: Vec<RowDelta>,
    /// Cells only in the first / second table.
    pub only_in_a: Vec<CellRow>,
    pub only_in_b: Vec<CellRow>,
}

/// Compare `b` against baseline `a`: a regression is a matched cell whose
/// loss increased by more than `tol` (or whose saving dropped by more
/// than `tol` at unchanged loss).
///
/// Appended tables can hold the same grid cell several times; diffing is
/// **last-wins per key on both sides** (the latest appended measurement
/// is the cell's current value), and `matched` counts distinct keys.
pub fn diff(a: &SweepTable, b: &SweepTable, tol: f64) -> DiffReport {
    use std::collections::{HashMap, HashSet};
    let mut report = DiffReport::default();
    // HashMap insertion overwrites → the last occurrence of a key wins.
    let last_a: HashMap<_, &CellRow> = a.rows.iter().map(|r| (r.key(), r)).collect();
    let last_b: HashMap<_, &CellRow> = b.rows.iter().map(|r| (r.key(), r)).collect();
    let mut processed = HashSet::new();
    for row in &a.rows {
        let key = row.key();
        if !processed.insert(key.clone()) {
            continue; // duplicate key: already handled via last_a
        }
        let ra = last_a[&key];
        match last_b.get(&key) {
            None => report.only_in_a.push(ra.clone()),
            Some(rb) => {
                report.matched += 1;
                let delta = RowDelta {
                    a: ra.clone(),
                    b: (*rb).clone(),
                    d_loss: rb.loss - ra.loss,
                    d_saving: rb.saving - ra.saving,
                    d_migrations: rb.migrations() as i64 - ra.migrations() as i64,
                };
                // Worsening on EITHER axis is a regression, even if the
                // other axis improved — a Tuna cell trading most of its
                // memory saving for a small loss win must not pass a
                // --strict gate as an "improvement".
                if delta.d_loss > tol || delta.d_saving < -tol {
                    report.regressions.push(delta);
                } else if delta.d_loss < -tol || delta.d_saving > tol {
                    report.improvements.push(delta);
                }
            }
        }
    }
    for row in &b.rows {
        let key = row.key();
        if !processed.insert(key.clone()) {
            continue; // either matched above or a duplicate in b
        }
        report.only_in_b.push(last_b[&key].clone());
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(workload: &str, fraction: f64, loss: f64) -> CellRow {
        CellRow {
            workload: workload.to_string(),
            policy: SweepPolicy::Tpp,
            seed: 42,
            hot_thr: 2,
            fm_fraction: fraction,
            loss,
            saving: 1.0 - fraction,
            total_ns: 1e9 * (1.0 + loss),
            promoted: 100,
            promote_failed: 3,
            demoted: 90,
            migration: MigrationModel::Exclusive,
            shadow_hits: 0,
            shadow_free_demotions: 0,
            txn_aborts: 0,
            txn_retried_copies: 0,
            admission: AdmissionConfig::default(),
            admission_accepted: 0,
            admission_rejected_budget: 0,
            admission_rejected_payoff: 0,
            admission_rejected_cooldown: 0,
            tuna: None,
        }
    }

    fn table() -> SweepTable {
        let mut t = SweepTable { rows: vec![row("BFS", 0.9, 0.04), row("BFS", 0.7, 0.12)] };
        t.rows.push(CellRow {
            policy: SweepPolicy::Tuna,
            fm_fraction: 1.0,
            tuna: Some(TunaRowStats {
                decisions: 12,
                mean_fraction: 0.85,
                min_fraction: 0.7,
                decide_ns: 123_456_789_000,
            }),
            ..row("Btree", 1.0, 0.02)
        });
        t
    }

    #[test]
    fn bytes_roundtrip_bit_identical() {
        let t = table();
        let bytes = t.to_bytes();
        let back = SweepTable::from_bytes(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn non_exclusive_rows_roundtrip_and_key_on_migration() {
        let mut nx = row("kv-drift", 0.6, 0.07);
        nx.policy = SweepPolicy::TppNomad;
        nx.migration = MigrationModel::NonExclusive { abort_on_write: true, copy_intervals: 3 };
        nx.shadow_hits = 12_345;
        nx.shadow_free_demotions = 678;
        nx.txn_aborts = 90;
        nx.txn_retried_copies = 12;
        let t = SweepTable { rows: vec![row("kv-drift", 0.6, 0.05), nx.clone()] };
        let back = SweepTable::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back, t);
        // migration is part of the cell identity: same grid coordinates
        // under different semantics are different cells
        assert_ne!(back.rows[0].key(), back.rows[1].key());
        assert_eq!(back.rows[1].shadow_free_demotions, 678);
    }

    #[test]
    fn gated_rows_roundtrip_and_key_on_admission() {
        let mut gated = row("kv-drift", 0.6, 0.05);
        gated.policy = SweepPolicy::TppGated;
        gated.admission = AdmissionConfig::enabled_default();
        gated.admission_accepted = 1_234;
        gated.admission_rejected_budget = 56;
        gated.admission_rejected_payoff = 789;
        gated.admission_rejected_cooldown = 321;
        let plain = row("kv-drift", 0.6, 0.07);
        let t = SweepTable { rows: vec![plain, gated.clone()] };
        let back = SweepTable::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back, t);
        // admission knobs are part of the cell identity
        assert_ne!(back.rows[0].key(), back.rows[1].key());
        assert_eq!(back.rows[1].admission_rejected_cooldown, 321);
        // an exclusive-but-gated row still writes the (all-exclusive)
        // migration block, because the admission block is positional
        let solo = CellRow::from_payload(&gated.to_payload()).unwrap();
        assert_eq!(solo, gated);
        assert_eq!(solo.migration, MigrationModel::Exclusive);
    }

    #[test]
    fn exclusive_rows_keep_the_pre_migration_axis_byte_layout() {
        // a table of exclusive cells must serialize to the exact bytes the
        // format produced before the migration axis existed, so `store
        // diff --strict` across the change sees unchanged cells — the row
        // payload is reproduced field-by-field here as the old writer
        // emitted it
        let r = row("BFS", 0.9, 0.04);
        let mut old = Vec::new();
        wire::put_str(&mut old, &r.workload);
        wire::put_u8(&mut old, r.policy.code());
        wire::put_u64(&mut old, r.seed);
        wire::put_u32(&mut old, r.hot_thr);
        wire::put_f64(&mut old, r.fm_fraction);
        wire::put_f64(&mut old, r.loss);
        wire::put_f64(&mut old, r.saving);
        wire::put_f64(&mut old, r.total_ns);
        wire::put_u64(&mut old, r.promoted);
        wire::put_u64(&mut old, r.promote_failed);
        wire::put_u64(&mut old, r.demoted);
        wire::put_u8(&mut old, 0); // no tuna stats
        assert_eq!(r.to_payload(), old, "exclusive rows must not grow a trailing block");
        // and the old payload parses as Exclusive with zero counters
        let back = CellRow::from_payload(&old).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn file_roundtrip_and_append() {
        let dir = std::env::temp_dir().join(format!("tuna_cells_{}", std::process::id()));
        let path = dir.join("t.cells");
        std::fs::remove_dir_all(&dir).ok();
        let t = table();
        t.save(&path).unwrap();
        assert_eq!(SweepTable::load(&path).unwrap(), t);
        // append two more rows without rewriting
        let extra = vec![row("SSSP", 0.8, 0.06), row("SSSP", 0.5, 0.2)];
        SweepTable::append(&path, &extra).unwrap();
        let all = SweepTable::load(&path).unwrap();
        assert_eq!(all.len(), t.len() + 2);
        assert_eq!(&all.rows[t.len()..], &extra[..]);
        // append to a fresh path creates a valid table
        let p2 = dir.join("fresh.cells");
        SweepTable::append(&p2, &extra).unwrap();
        assert_eq!(SweepTable::load(&p2).unwrap().rows, extra);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_and_corruption_are_rejected() {
        let bytes = table().to_bytes();
        // truncate mid-block
        assert!(SweepTable::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // flip a payload byte
        let mut bad = bytes.clone();
        bad[14] ^= 0xFF;
        assert!(SweepTable::from_bytes(&bad).is_err());
        // bad magic
        let mut bad2 = bytes;
        bad2[0] = b'X';
        assert!(SweepTable::from_bytes(&bad2).is_err());
    }

    #[test]
    fn diff_flags_regressions_and_membership() {
        let a = table();
        let same = diff(&a, &a, 1e-12);
        assert_eq!(same.matched, 3);
        assert!(same.regressions.is_empty() && same.improvements.is_empty());
        assert!(same.only_in_a.is_empty() && same.only_in_b.is_empty());

        let mut b = a.clone();
        b.rows[0].loss += 0.05; // regression
        b.rows[1].loss -= 0.03; // improvement
        b.rows.pop(); // Btree cell missing from b
        b.rows.push(row("XSBench", 0.9, 0.01)); // new in b
        let d = diff(&a, &b, 1e-9);
        assert_eq!(d.matched, 2);
        assert_eq!(d.regressions.len(), 1);
        assert!((d.regressions[0].d_loss - 0.05).abs() < 1e-12);
        assert_eq!(d.improvements.len(), 1);
        assert_eq!(d.only_in_a.len(), 1);
        assert_eq!(d.only_in_a[0].workload, "Btree");
        assert_eq!(d.only_in_b.len(), 1);
        assert_eq!(d.only_in_b[0].workload, "XSBench");
    }

    #[test]
    fn peek_rows_counts_without_parsing() {
        let dir = std::env::temp_dir().join(format!("tuna_cells_peek_{}", std::process::id()));
        let path = dir.join("t.cells");
        std::fs::remove_dir_all(&dir).ok();
        table().save(&path).unwrap();
        assert_eq!(SweepTable::peek_rows(&path).unwrap(), 3);
        // torn tail is still reported
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(SweepTable::peek_rows(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn diff_is_last_wins_for_duplicate_keys() {
        // the same cell appended twice: only the latest measurement counts
        let mut a = SweepTable { rows: vec![row("BFS", 0.9, 0.50), row("BFS", 0.9, 0.04)] };
        let b = SweepTable { rows: vec![row("BFS", 0.9, 0.04)] };
        let d = diff(&a, &b, 1e-9);
        assert_eq!(d.matched, 1, "duplicates collapse to one distinct cell");
        assert!(d.regressions.is_empty() && d.improvements.is_empty());
        // a regression only present in an early b occurrence is ignored;
        // one in the *last* occurrence is caught
        a.rows = vec![row("BFS", 0.9, 0.04)];
        let b2 = SweepTable { rows: vec![row("BFS", 0.9, 0.04), row("BFS", 0.9, 0.09)] };
        let d2 = diff(&a, &b2, 1e-9);
        assert_eq!(d2.regressions.len(), 1);
        assert!((d2.regressions[0].d_loss - 0.05).abs() < 1e-12);
    }

    #[test]
    fn saving_drop_at_equal_loss_is_a_regression() {
        let a = SweepTable { rows: vec![row("BFS", 0.9, 0.04)] };
        let mut b = a.clone();
        b.rows[0].saving -= 0.02;
        let d = diff(&a, &b, 1e-9);
        assert_eq!(d.regressions.len(), 1);
    }

    #[test]
    fn saving_collapse_beats_a_loss_improvement() {
        // Tuna-style cell: loss improves slightly but the saving — the
        // paper's headline metric — collapses; must gate as regression.
        let mut ra = row("Btree", 1.0, 0.05);
        ra.policy = SweepPolicy::Tuna;
        ra.saving = 0.30;
        let mut rb = ra.clone();
        rb.loss = 0.03;
        rb.saving = 0.05;
        let d = diff(
            &SweepTable { rows: vec![ra] },
            &SweepTable { rows: vec![rb] },
            1e-9,
        );
        assert_eq!(d.regressions.len(), 1, "saving collapse must not read as improvement");
        assert!(d.improvements.is_empty());
    }
}
