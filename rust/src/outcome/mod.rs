//! Decision-outcome accountability: predicted-vs-realized loss
//! tracking, drift detection and re-tune scheduling.
//!
//! Tuna's value proposition is a *predicted* loss at a chosen
//! fast-memory fraction. This module closes the loop: an
//! [`OutcomeTracker`], owned per session by the tuner state,
//! accumulates the realized loss over each decision period directly
//! from the telemetry samples (same loss definition as
//! `perf_loss_vs` — relative slowdown against the session's own
//! pre-decision full-fast-memory baseline, allocation epoch excluded —
//! computed incrementally, never with a second pass over the trace),
//! joins it to the decision's `predicted_loss`, and feeds a signed
//! EWMA drift detector with hysteresis.
//!
//! Three modes, selected by [`RetuneMode`]:
//!
//! * `off` — the tracker is inert: no state accumulates, no events or
//!   metrics are emitted, and the legacy decision path is untouched.
//! * `observe` — outcomes and drift are tracked and journaled, but the
//!   decision cadence is never altered: decisions are bit-identical to
//!   `off` (proven by integration tests and the CI smoke).
//! * `on` — `observe`, plus: when the detector arms, the next decision
//!   is scheduled early ([`RetuneConfig::early_intervals`] instead of
//!   the full tuning period). That early decision is a *re-tune*; a
//!   cool-down of [`RetuneConfig::cooldown_periods`] decision periods
//!   then suppresses re-arming so adaptation cannot thrash.
//!
//! The tracker is deliberately decoupled from the telemetry and obs
//! types: it consumes `(interval, wall_ns)` pairs and decision
//! boundaries, and returns plain records/feedback structs that the
//! tuner turns into `Outcome`/`Drift` journal events and
//! `tuner_realized_loss` / `tuner_prediction_error` /
//! `tuner_drift_state` / `tuner_retunes_total` metric families.

/// How the accountability layer is allowed to act.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RetuneMode {
    /// Tracker inert; legacy behavior bit-identical.
    #[default]
    Off,
    /// Track + journal outcomes and drift, never alter cadence.
    Observe,
    /// Observe, plus early re-decides when the detector arms.
    On,
}

impl RetuneMode {
    /// Canonical flag/config spelling (`--retune MODE`).
    pub fn name(&self) -> &'static str {
        match self {
            RetuneMode::Off => "off",
            RetuneMode::Observe => "observe",
            RetuneMode::On => "on",
        }
    }
}

/// The `[retune]` config table / `--retune*` flag set.
///
/// The numeric knobs are kept (and layered by the CLI) even in `off`
/// mode, so `--retune on` can be flipped on top of a config file that
/// tuned the detector but left it disabled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetuneConfig {
    pub mode: RetuneMode,
    /// EWMA smoothing factor over the signed prediction error, in
    /// (0, 1]. Higher = reacts faster, damps less.
    pub ewma_alpha: f64,
    /// |EWMA error| above this arms the detector.
    pub trigger: f64,
    /// Intervals until the re-decide once armed (must be ≥ 1 and is
    /// clamped to the normal tuning period).
    pub early_intervals: u32,
    /// Decision periods after a re-tune during which the detector
    /// cannot re-arm (the hysteresis that prevents thrashing).
    pub cooldown_periods: u32,
}

impl Default for RetuneConfig {
    fn default() -> Self {
        RetuneConfig {
            mode: RetuneMode::Off,
            ewma_alpha: 0.4,
            trigger: 0.04,
            early_intervals: 2,
            cooldown_periods: 2,
        }
    }
}

impl RetuneConfig {
    /// Parse and validate the flag/config surface. Mirrors
    /// `AdmissionConfig::parse`: the mode string picks the behavior,
    /// the numeric knobs always survive validation so they can be
    /// layered before the mode is flipped on.
    pub fn parse(
        mode: &str,
        ewma_alpha: f64,
        trigger: f64,
        early_intervals: u32,
        cooldown_periods: u32,
    ) -> Result<RetuneConfig, String> {
        let mode = match mode {
            "off" | "false" | "0" => RetuneMode::Off,
            "observe" => RetuneMode::Observe,
            "on" | "true" | "1" => RetuneMode::On,
            other => {
                return Err(format!(
                    "bad retune mode `{other}` (expected on, observe or off)"
                ))
            }
        };
        if !(ewma_alpha > 0.0 && ewma_alpha <= 1.0) {
            return Err(format!("retune ewma_alpha must be in (0, 1], got {ewma_alpha}"));
        }
        if !(trigger > 0.0) || !trigger.is_finite() {
            return Err(format!("retune trigger must be a positive number, got {trigger}"));
        }
        if early_intervals == 0 {
            return Err("retune early_intervals must be >= 1".to_string());
        }
        Ok(RetuneConfig { mode, ewma_alpha, trigger, early_intervals, cooldown_periods })
    }

    /// Canonical mode spelling for CLI layering / report rows.
    pub fn mode_name(&self) -> &'static str {
        self.mode.name()
    }

    /// Is the tracker doing anything at all?
    pub fn enabled(&self) -> bool {
        self.mode != RetuneMode::Off
    }
}

/// One joined predicted-vs-realized record: the outcome of a single
/// decision, finalized at the next decision boundary (or at session
/// close).
#[derive(Clone, Debug, PartialEq)]
pub struct OutcomeRecord {
    /// Interval the decision was taken at.
    pub decision_interval: u32,
    /// Interval the outcome window closed at.
    pub end_interval: u32,
    /// The decision's `predicted_loss`.
    pub predicted: f64,
    /// Realized loss over the decision period: (mean interval wall
    /// time − baseline mean) / baseline mean, with the `perf_loss_vs`
    /// guard (0.0 when the baseline is unusable).
    pub realized: f64,
    /// |realized − predicted|.
    pub abs_err: f64,
}

/// What the drift detector concluded at a decision boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DriftAction {
    /// No previous outcome to judge (first decision).
    None,
    /// Prediction error within the trigger band.
    Stable,
    /// |EWMA error| crossed the trigger; in `on` mode the next
    /// decision will be scheduled early.
    Armed,
    /// This decision *was* the early re-decide.
    Retune,
    /// Detector suppressed by post-re-tune hysteresis.
    Cooldown,
}

impl DriftAction {
    pub fn name(&self) -> &'static str {
        match self {
            DriftAction::None => "none",
            DriftAction::Stable => "stable",
            DriftAction::Armed => "armed",
            DriftAction::Retune => "retune",
            DriftAction::Cooldown => "cooldown",
        }
    }

    /// Numeric encoding for the `tuner_drift_state` gauge.
    pub fn gauge(&self) -> f64 {
        match self {
            DriftAction::None | DriftAction::Stable => 0.0,
            DriftAction::Armed => 1.0,
            DriftAction::Retune => 2.0,
            DriftAction::Cooldown => 3.0,
        }
    }
}

/// Everything the tuner needs to journal after a decision boundary.
#[derive(Clone, Debug)]
pub struct DecisionFeedback {
    /// The previous decision's outcome, if one closed at this boundary.
    pub outcome: Option<OutcomeRecord>,
    /// Detector state after ingesting that outcome's error.
    pub ewma_err: f64,
    pub action: DriftAction,
    /// This decision happened on a shortened (re-tune) schedule.
    pub was_retune: bool,
}

/// Signed-EWMA drift detector with arm/cool-down hysteresis.
#[derive(Clone, Debug)]
struct DriftDetector {
    alpha: f64,
    trigger: f64,
    ewma: f64,
    cooldown_left: u32,
    seen: u64,
}

impl DriftDetector {
    fn new(cfg: &RetuneConfig) -> DriftDetector {
        DriftDetector {
            alpha: cfg.ewma_alpha,
            trigger: cfg.trigger,
            ewma: 0.0,
            cooldown_left: 0,
            seen: 0,
        }
    }

    /// Fold one signed prediction error in and classify the boundary.
    fn update(&mut self, err: f64) -> DriftAction {
        // Seed the EWMA with the first observation instead of decaying
        // up from zero — one decision period is already a whole window
        // of samples, not a noisy point.
        self.ewma = if self.seen == 0 {
            err
        } else {
            self.alpha * err + (1.0 - self.alpha) * self.ewma
        };
        self.seen += 1;
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return DriftAction::Cooldown;
        }
        if self.ewma.abs() > self.trigger {
            return DriftAction::Armed;
        }
        DriftAction::Stable
    }

    fn start_cooldown(&mut self, periods: u32) {
        self.cooldown_left = periods;
    }
}

/// Incremental wall-time accumulator for one decision period.
#[derive(Clone, Debug)]
struct Pending {
    decision_interval: u32,
    predicted: f64,
    sum_ns: f64,
    n: u64,
}

/// Per-session predicted-vs-realized loss tracker (see module docs).
///
/// Lifecycle: [`observe`](OutcomeTracker::observe) on every telemetry
/// sample, [`on_decision`](OutcomeTracker::on_decision) at every
/// decision boundary, [`finish`](OutcomeTracker::finish) at close.
#[derive(Clone, Debug)]
pub struct OutcomeTracker {
    cfg: RetuneConfig,
    // Baseline: the session's own pre-first-decision samples at full
    // fast memory. Two accumulators so the allocation epoch
    // (interval 1) is excluded exactly like `overall_loss`'s skip(1),
    // with an everything-seen fallback for degenerate one-sample runs.
    base_sum_skip1: f64,
    base_n_skip1: u64,
    base_sum_all: f64,
    base_n_all: u64,
    pending: Option<Pending>,
    drift: DriftDetector,
    /// A re-decide is scheduled for `early_intervals` from now.
    early_pending: bool,
    /// Finalized outcomes, in decision order.
    pub outcomes: Vec<OutcomeRecord>,
    /// Early re-decides actually taken.
    pub retunes: u64,
}

impl OutcomeTracker {
    pub fn new(cfg: RetuneConfig) -> OutcomeTracker {
        OutcomeTracker {
            drift: DriftDetector::new(&cfg),
            cfg,
            base_sum_skip1: 0.0,
            base_n_skip1: 0,
            base_sum_all: 0.0,
            base_n_all: 0,
            pending: None,
            early_pending: false,
            outcomes: Vec::new(),
            retunes: 0,
        }
    }

    pub fn config(&self) -> &RetuneConfig {
        &self.cfg
    }

    /// Anything to do at all? `off` mode keeps every call site a
    /// branch-and-return.
    pub fn active(&self) -> bool {
        self.cfg.enabled()
    }

    /// Detector state (for gauges / reports).
    pub fn ewma_err(&self) -> f64 {
        self.drift.ewma
    }

    /// Feed one telemetry sample's interval wall time.
    pub fn observe(&mut self, interval: u32, wall_ns: u64) {
        if !self.active() {
            return;
        }
        let w = wall_ns as f64;
        match &mut self.pending {
            Some(p) => {
                p.sum_ns += w;
                p.n += 1;
            }
            None => {
                // Pre-first-decision: this is the baseline window.
                self.base_sum_all += w;
                self.base_n_all += 1;
                if interval >= 2 {
                    self.base_sum_skip1 += w;
                    self.base_n_skip1 += 1;
                }
            }
        }
    }

    /// Mean baseline interval wall time (allocation epoch excluded when
    /// possible), or 0.0 when no baseline sample was ever seen.
    fn baseline_mean(&self) -> f64 {
        if self.base_n_skip1 > 0 {
            self.base_sum_skip1 / self.base_n_skip1 as f64
        } else if self.base_n_all > 0 {
            self.base_sum_all / self.base_n_all as f64
        } else {
            0.0
        }
    }

    /// Close the pending window (if it saw any samples) into an
    /// [`OutcomeRecord`].
    fn finalize(&mut self, end_interval: u32) -> Option<OutcomeRecord> {
        let p = self.pending.take()?;
        if p.n == 0 {
            return None;
        }
        let mean = p.sum_ns / p.n as f64;
        let base = self.baseline_mean();
        // Same guard as `perf_loss_vs` / `overall_loss`: an unusable
        // baseline reports zero loss rather than a NaN/inf.
        let realized = if !(base > 0.0) || !base.is_finite() {
            0.0
        } else {
            (mean - base) / base
        };
        let rec = OutcomeRecord {
            decision_interval: p.decision_interval,
            end_interval,
            predicted: p.predicted,
            realized,
            abs_err: (realized - p.predicted).abs(),
        };
        self.outcomes.push(rec.clone());
        Some(rec)
    }

    /// A decision was just taken at `interval` predicting `predicted`
    /// loss: finalize the previous decision's outcome, run the drift
    /// detector, account a re-tune if this decision was the early
    /// re-decide, and start tracking the new decision.
    pub fn on_decision(&mut self, interval: u32, predicted: f64) -> DecisionFeedback {
        if !self.active() {
            return DecisionFeedback {
                outcome: None,
                ewma_err: 0.0,
                action: DriftAction::None,
                was_retune: false,
            };
        }
        let was_retune = self.early_pending;
        self.early_pending = false;
        let outcome = self.finalize(interval);
        let mut action = DriftAction::None;
        if let Some(o) = &outcome {
            action = self.drift.update(o.realized - o.predicted);
        }
        if was_retune {
            self.retunes += 1;
            self.drift.start_cooldown(self.cfg.cooldown_periods);
            action = DriftAction::Retune;
        } else if action == DriftAction::Armed && self.cfg.mode == RetuneMode::On {
            self.early_pending = true;
        }
        self.pending = Some(Pending {
            decision_interval: interval,
            predicted,
            sum_ns: 0.0,
            n: 0,
        });
        DecisionFeedback { outcome, ewma_err: self.drift.ewma, action, was_retune }
    }

    /// Intervals until the next decision, given the normal tuning
    /// period. Only `on` mode with an armed detector shortens it;
    /// `off`/`observe` return `normal` untouched (the cadence
    /// bit-identity guarantee).
    pub fn next_period(&self, normal: u32) -> u32 {
        if self.cfg.mode == RetuneMode::On && self.early_pending {
            self.cfg.early_intervals.min(normal).max(1)
        } else {
            normal
        }
    }

    /// Session is closing: finalize the last decision's outcome (the
    /// window that never reached another boundary).
    pub fn finish(&mut self, end_interval: u32) -> Option<OutcomeRecord> {
        if !self.active() {
            return None;
        }
        self.finalize(end_interval)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: RetuneMode) -> RetuneConfig {
        RetuneConfig { mode, ..RetuneConfig::default() }
    }

    /// Drive `n` samples of constant wall time through the tracker.
    fn feed(t: &mut OutcomeTracker, from: u32, n: u32, wall: u64) -> u32 {
        for i in 0..n {
            t.observe(from + i, wall);
        }
        from + n
    }

    #[test]
    fn off_mode_is_inert() {
        let mut t = OutcomeTracker::new(cfg(RetuneMode::Off));
        feed(&mut t, 1, 10, 100);
        let fb = t.on_decision(10, 0.05);
        assert!(fb.outcome.is_none());
        assert_eq!(fb.action, DriftAction::None);
        assert_eq!(t.next_period(25), 25);
        assert!(t.finish(20).is_none());
        assert!(t.outcomes.is_empty());
        assert_eq!(t.retunes, 0);
    }

    #[test]
    fn realized_loss_is_relative_to_own_baseline_excluding_epoch() {
        let mut t = OutcomeTracker::new(cfg(RetuneMode::Observe));
        // Allocation epoch is huge and must be excluded from the
        // baseline; the real baseline is 100ns/interval.
        t.observe(1, 10_000);
        feed(&mut t, 2, 4, 100);
        t.on_decision(5, 0.05);
        // The decision period runs 20% slower than the baseline.
        feed(&mut t, 6, 5, 120);
        let fb = t.on_decision(10, 0.05);
        let o = fb.outcome.expect("outcome closes at the next boundary");
        assert_eq!(o.decision_interval, 5);
        assert_eq!(o.end_interval, 10);
        assert!((o.realized - 0.2).abs() < 1e-12, "realized {}", o.realized);
        assert!((o.abs_err - 0.15).abs() < 1e-12);
    }

    #[test]
    fn observe_mode_never_shortens_the_period() {
        let mut t = OutcomeTracker::new(RetuneConfig {
            mode: RetuneMode::Observe,
            trigger: 0.01,
            ..RetuneConfig::default()
        });
        feed(&mut t, 1, 5, 100);
        t.on_decision(5, 0.0);
        feed(&mut t, 6, 5, 200); // huge error, detector arms
        let fb = t.on_decision(10, 0.0);
        assert_eq!(fb.action, DriftAction::Armed);
        assert_eq!(t.next_period(25), 25, "observe mode must not act");
        assert_eq!(t.retunes, 0);
    }

    #[test]
    fn on_mode_retunes_once_then_cools_down() {
        let mut t = OutcomeTracker::new(RetuneConfig {
            mode: RetuneMode::On,
            trigger: 0.01,
            early_intervals: 2,
            cooldown_periods: 2,
            ..RetuneConfig::default()
        });
        feed(&mut t, 1, 5, 100);
        t.on_decision(5, 0.0);
        feed(&mut t, 6, 5, 200);
        let fb = t.on_decision(10, 0.0);
        assert_eq!(fb.action, DriftAction::Armed);
        assert_eq!(t.next_period(5), 2, "armed + on => early re-decide");
        feed(&mut t, 11, 2, 200);
        let fb = t.on_decision(12, 0.0);
        assert_eq!(fb.action, DriftAction::Retune);
        assert!(fb.was_retune);
        assert_eq!(t.retunes, 1);
        assert_eq!(t.next_period(5), 5, "cadence restored after the re-tune");
        // Error stays large, but the cool-down suppresses re-arming for
        // two decision periods.
        feed(&mut t, 13, 5, 200);
        assert_eq!(t.on_decision(17, 0.0).action, DriftAction::Cooldown);
        feed(&mut t, 18, 5, 200);
        assert_eq!(t.on_decision(22, 0.0).action, DriftAction::Cooldown);
        feed(&mut t, 23, 5, 200);
        assert_eq!(t.on_decision(27, 0.0).action, DriftAction::Armed);
    }

    #[test]
    fn accurate_predictions_stay_stable() {
        let mut t = OutcomeTracker::new(cfg(RetuneMode::On));
        feed(&mut t, 1, 5, 100);
        t.on_decision(5, 0.2);
        feed(&mut t, 6, 5, 120); // realized 0.2 == predicted
        let fb = t.on_decision(10, 0.2);
        assert_eq!(fb.action, DriftAction::Stable);
        assert_eq!(t.next_period(25), 25);
    }

    #[test]
    fn finish_closes_the_last_window() {
        let mut t = OutcomeTracker::new(cfg(RetuneMode::Observe));
        feed(&mut t, 1, 5, 100);
        t.on_decision(5, 0.1);
        feed(&mut t, 6, 3, 110);
        let o = t.finish(8).expect("trailing window closes at finish");
        assert_eq!(o.end_interval, 8);
        assert!((o.realized - 0.1).abs() < 1e-9);
        assert_eq!(t.outcomes.len(), 1);
        assert!(t.finish(9).is_none(), "finish is idempotent");
    }

    #[test]
    fn empty_decision_window_produces_no_record() {
        let mut t = OutcomeTracker::new(cfg(RetuneMode::Observe));
        feed(&mut t, 1, 5, 100);
        t.on_decision(5, 0.1);
        // No samples before the next boundary (back-to-back decisions).
        let fb = t.on_decision(5, 0.1);
        assert!(fb.outcome.is_none());
        assert!(t.outcomes.is_empty());
    }

    #[test]
    fn parse_validates_and_roundtrips_mode_names() {
        for mode in [RetuneMode::Off, RetuneMode::Observe, RetuneMode::On] {
            let c = RetuneConfig { mode, ..RetuneConfig::default() };
            let back = RetuneConfig::parse(
                c.mode_name(),
                c.ewma_alpha,
                c.trigger,
                c.early_intervals,
                c.cooldown_periods,
            )
            .unwrap();
            assert_eq!(back, c);
        }
        assert!(RetuneConfig::parse("sideways", 0.4, 0.04, 2, 2).is_err());
        assert!(RetuneConfig::parse("on", 0.0, 0.04, 2, 2).is_err());
        assert!(RetuneConfig::parse("on", 1.5, 0.04, 2, 2).is_err());
        assert!(RetuneConfig::parse("on", 0.4, 0.0, 2, 2).is_err());
        assert!(RetuneConfig::parse("on", 0.4, 0.04, 0, 2).is_err());
        assert!(RetuneConfig::parse("on", 0.4, 0.04, 2, 0).is_ok());
    }

    #[test]
    fn default_is_off_and_disabled() {
        let c = RetuneConfig::default();
        assert_eq!(c.mode, RetuneMode::Off);
        assert!(!c.enabled());
        assert_eq!(c.mode_name(), "off");
    }
}
