//! `tpp-gated`: TPP's control loop behind the migration admission gate
//! (see [`crate::admission`]).
//!
//! The policy logic is exactly [`Tpp`]'s — same promotion threshold, scan
//! budget, watermark handling and victim order — but every promotion
//! candidate that crosses `hot_thr` must additionally clear the three
//! admission filters before the copy is issued: the demotion cool-down
//! (ping-pong suppression), the payoff predicate (predicted fast-tier
//! hits over the residency horizon must exceed the copy cost) and the
//! per-interval bandwidth budget. Rejected candidates keep their window
//! history and are re-considered in later intervals. The registry name
//! is `tpp-gated`.

use super::watermarks::Watermarks;
use super::{PagePolicy, Tpp};
use crate::admission::AdmissionConfig;
use crate::sim::mem::TieredMemory;
use crate::workloads::PageAccess;

/// TPP + admission-controlled promotion (see module docs).
#[derive(Clone, Debug)]
pub struct TppGated {
    inner: Tpp,
}

impl TppGated {
    /// Default two-touch threshold and the default admission knobs
    /// (budget 128 pages/interval, cool-down 16 intervals, horizon 32).
    pub fn new(wm: Watermarks) -> Self {
        Self::with_hot_thr(wm, 2)
    }

    pub fn with_hot_thr(wm: Watermarks, hot_thr: u32) -> Self {
        TppGated {
            inner: Tpp::with_hot_thr(wm, hot_thr)
                .with_admission(AdmissionConfig::enabled_default()),
        }
    }

    /// Override the admission knobs (a disabled config is clamped to the
    /// enabled defaults — `tpp-gated` *is* the admission-controlled
    /// variant; run plain `tpp` for ungated promotion).
    pub fn with_admission(mut self, cfg: AdmissionConfig) -> Self {
        let cfg = if cfg.enabled { cfg } else { AdmissionConfig::enabled_default() };
        self.inner = self.inner.with_admission(cfg);
        self
    }

    /// The installed gate's configuration (always present: the gate is
    /// what distinguishes this policy from plain `tpp`).
    pub fn admission(&self) -> AdmissionConfig {
        self.inner.admission().expect("tpp-gated always carries a gate")
    }

    /// Promotion-scan budget passthrough (mirrors [`Tpp::scan_budget`]).
    pub fn set_scan_budget(&mut self, budget: u64) {
        self.inner.scan_budget = budget;
    }
}

impl PagePolicy for TppGated {
    fn name(&self) -> &'static str {
        "tpp-gated"
    }

    fn hot_thr(&self) -> u32 {
        self.inner.hot_thr()
    }

    fn watermarks(&self) -> Watermarks {
        self.inner.watermarks()
    }

    fn set_watermarks(&mut self, wm: Watermarks) {
        self.inner.set_watermarks(wm);
    }

    fn alloc_reserve(&self) -> u64 {
        self.inner.alloc_reserve()
    }

    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        now: u32,
        kswapd_budget: u64,
    ) {
        self.inner.run_interval(mem, touched, now, kswapd_budget);
    }
    // migration_model stays the trait default (Exclusive): admission is
    // orthogonal to migration semantics, and run specs may still override
    // the model per run exactly as they do for plain `tpp`.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::MigrationModel;
    use crate::sim::{Engine, IntervalModel, MachineModel};

    #[test]
    fn registry_name_and_admission_config() {
        let p = TppGated::new(Watermarks::default_for_capacity(100));
        assert_eq!(p.name(), "tpp-gated");
        assert_eq!(p.migration_model(), MigrationModel::Exclusive);
        assert_eq!(p.admission(), AdmissionConfig::enabled_default());
        // a disabled override is clamped back to the enabled defaults
        let p = p.with_admission(AdmissionConfig::default());
        assert_eq!(p.admission(), AdmissionConfig::enabled_default());
        let custom = AdmissionConfig {
            enabled: true,
            budget_pages: 7,
            cooldown_intervals: 3,
            horizon_intervals: 9,
        };
        let p = p.with_admission(custom);
        assert_eq!(p.admission(), custom);
    }

    #[test]
    fn engine_runs_tpp_gated_and_counts_admission_verdicts() {
        let mut w = crate::workloads::by_name("Btree", 3, 40).unwrap();
        let cap = Engine::fm_capacity(w.rss_pages(), 0.7);
        let mut p = TppGated::new(Watermarks::default_for_capacity(cap));
        let engine = Engine::new(IntervalModel::new(MachineModel::default()));
        let res = engine.run(w.as_mut(), &mut p, cap, |_| None);
        assert_eq!(res.policy, "tpp-gated");
        let c = res.total_migration_counters();
        assert_eq!(
            c.admission_accepted, c.promoted + c.promote_failed,
            "every copy the engine saw must have been admitted first: {c:?}"
        );
        assert!(
            c.admission_accepted
                + c.admission_rejected_budget
                + c.admission_rejected_payoff
                + c.admission_rejected_cooldown
                > 0,
            "gated run must exercise the gate: {c:?}"
        );
    }
}
