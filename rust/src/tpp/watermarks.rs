//! Page-reclaim watermarks (§4 of the paper).
//!
//! Linux expresses watermarks as *free-page* thresholds per zone; Tuna
//! controls the usable fast-memory size by programming them
//! (`/proc/sys/vm/min_free_kbytes`, `high_free_kbytes` on the testbed;
//! fields of this struct here):
//!
//! * `free < min`  → **direct reclaim**: the faulting application thread
//!   itself demotes pages — blocking, the case Tuna avoids;
//! * `free < low`  → **kswapd** wakes and demotes in the background until
//!   `free ≥ high`;
//! * promotions are denied (counted as migration failures) when they would
//!   push `free` below `min`.
//!
//! To cap usable fast memory at `new_fm` pages out of `capacity`, Tuna
//! needs `free ≥ capacity − new_fm`, so it programs
//! `low = high = capacity − new_fm` and `min = 0.8 × low` (the paper keeps
//! Linux's `min ≈ 0.8 × low` coupling, and sets `high` to exactly the
//! target so kswapd "does not reclaim too many pages").

/// Free-page thresholds for the fast tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Watermarks {
    /// Below this many free pages: direct (blocking) reclaim.
    pub min: u64,
    /// Below this many free pages: kswapd starts demoting.
    pub low: u64,
    /// kswapd demotes until this many pages are free.
    pub high: u64,
}

impl Watermarks {
    /// Linux-flavoured defaults for a fast tier of `capacity` pages:
    /// min 0.5%, low 1%, high 1.5% (small reserve so TPP promotions have
    /// headroom, as TPP's decoupled allocation/reclaim design intends).
    pub fn default_for_capacity(capacity: u64) -> Self {
        let min = (capacity / 200).max(2);
        let low = (capacity / 100).max(min + 1);
        let high = (capacity * 3 / 200).max(low + 1);
        Watermarks { min, low, high }
    }

    /// Program the watermarks so at most `new_fm` pages of a `capacity`-
    /// page fast tier are usable (§4). Keeps `min = 0.8 × low`.
    pub fn for_target_fm(capacity: u64, new_fm: u64) -> Self {
        let new_fm = new_fm.min(capacity);
        let target_free = capacity - new_fm;
        let defaults = Self::default_for_capacity(capacity);
        let low = target_free.max(defaults.low);
        let high = low; // stop reclaim exactly at the target
        let min = ((low as f64 * 0.8) as u64).max(1).min(low.saturating_sub(1)).max(1);
        Watermarks { min, low, high }
    }

    /// Usable fast-memory pages under these watermarks.
    pub fn usable(&self, capacity: u64) -> u64 {
        capacity.saturating_sub(self.low)
    }

    /// Watermark ordering invariant: `min < low ≤ high < capacity`.
    pub fn check(&self, capacity: u64) -> Result<(), String> {
        if !(self.min < self.low) {
            return Err(format!("min {} !< low {}", self.min, self.low));
        }
        if !(self.low <= self.high) {
            return Err(format!("low {} !<= high {}", self.low, self.high));
        }
        if self.high >= capacity {
            return Err(format!("high {} >= capacity {capacity}", self.high));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_ordered() {
        for cap in [100u64, 1000, 50_000, 1_000_000] {
            let wm = Watermarks::default_for_capacity(cap);
            wm.check(cap).unwrap();
        }
    }

    #[test]
    fn target_fm_reserves_free_space() {
        let cap = 10_000;
        let wm = Watermarks::for_target_fm(cap, 9_000);
        assert_eq!(wm.low, 1_000);
        assert_eq!(wm.high, 1_000);
        assert_eq!(wm.min, 800);
        assert_eq!(wm.usable(cap), 9_000);
        wm.check(cap).unwrap();
    }

    #[test]
    fn target_fm_full_capacity_falls_back_to_defaults() {
        let cap = 10_000;
        let wm = Watermarks::for_target_fm(cap, cap);
        // Can't usefully ask for 100%: the default reserve applies.
        assert_eq!(wm.low, Watermarks::default_for_capacity(cap).low);
        wm.check(cap).unwrap();
    }

    #[test]
    fn target_clamps_above_capacity() {
        let wm = Watermarks::for_target_fm(1_000, 5_000);
        wm.check(1_000).unwrap();
    }

    #[test]
    fn min_tracks_80_percent_of_low() {
        let wm = Watermarks::for_target_fm(100_000, 60_000);
        assert_eq!(wm.low, 40_000);
        assert_eq!(wm.min, 32_000);
    }
}
