//! `tpp-nomad`: TPP's control loop under Nomad-style non-exclusive
//! tiering (PAPERS.md).
//!
//! The policy logic is exactly [`Tpp`]'s — same promotion threshold, scan
//! budget, watermark handling and victim order — but the policy asks the
//! engine for [`MigrationModel::NonExclusive`] semantics: promotions are
//! transactional copies that abort on write, completed promotions keep
//! their slow-tier source frame as a shadow copy, and the shared
//! shadow-preferring victim order turns pressure demotions of clean pages
//! into free unmaps. The registry name is `tpp-nomad`.

use super::watermarks::Watermarks;
use super::{PagePolicy, Tpp};
use crate::sim::mem::{MigrationModel, TieredMemory};
use crate::workloads::PageAccess;

/// TPP + transactional non-exclusive migration (see module docs).
#[derive(Clone, Debug)]
pub struct TppNomad {
    inner: Tpp,
    migration: MigrationModel,
}

impl TppNomad {
    /// Default two-touch threshold and the default transactional mode
    /// (abort on write, two-interval copy window).
    pub fn new(wm: Watermarks) -> Self {
        Self::with_hot_thr(wm, 2)
    }

    pub fn with_hot_thr(wm: Watermarks, hot_thr: u32) -> Self {
        TppNomad {
            inner: Tpp::with_hot_thr(wm, hot_thr),
            migration: MigrationModel::non_exclusive_default(),
        }
    }

    /// Override the transactional knobs (an exclusive model is clamped to
    /// the default non-exclusive one — `tpp-nomad` *is* the transactional
    /// variant; run plain `tpp` for exclusive semantics).
    pub fn with_migration(mut self, migration: MigrationModel) -> Self {
        self.migration = match migration {
            MigrationModel::Exclusive => MigrationModel::non_exclusive_default(),
            m => m,
        };
        self
    }

    /// Promotion-scan budget passthrough (mirrors [`Tpp::scan_budget`]).
    pub fn set_scan_budget(&mut self, budget: u64) {
        self.inner.scan_budget = budget;
    }
}

impl PagePolicy for TppNomad {
    fn name(&self) -> &'static str {
        "tpp-nomad"
    }

    fn hot_thr(&self) -> u32 {
        self.inner.hot_thr()
    }

    fn watermarks(&self) -> Watermarks {
        self.inner.watermarks()
    }

    fn set_watermarks(&mut self, wm: Watermarks) {
        self.inner.set_watermarks(wm);
    }

    fn alloc_reserve(&self) -> u64 {
        self.inner.alloc_reserve()
    }

    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        now: u32,
        kswapd_budget: u64,
    ) {
        self.inner.run_interval(mem, touched, now, kswapd_budget);
    }

    fn migration_model(&self) -> MigrationModel {
        self.migration
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Engine, IntervalModel, MachineModel};

    #[test]
    fn registry_name_and_migration_model() {
        let p = TppNomad::new(Watermarks::default_for_capacity(100));
        assert_eq!(p.name(), "tpp-nomad");
        assert_eq!(p.migration_model(), MigrationModel::non_exclusive_default());
        // exclusive override is clamped back to transactional
        let p = p.with_migration(MigrationModel::Exclusive);
        assert!(!p.migration_model().is_exclusive());
        let custom = MigrationModel::NonExclusive { abort_on_write: false, copy_intervals: 4 };
        let p = p.with_migration(custom);
        assert_eq!(p.migration_model(), custom);
    }

    #[test]
    fn engine_runs_tpp_nomad_with_transactional_semantics() {
        let mut w = crate::workloads::by_name("Btree", 3, 40).unwrap();
        let cap = Engine::fm_capacity(w.rss_pages(), 0.7);
        let mut p = TppNomad::new(Watermarks::default_for_capacity(cap));
        let engine = Engine::new(IntervalModel::new(MachineModel::default()));
        let res = engine.run(w.as_mut(), &mut p, cap, |_| None);
        assert_eq!(res.policy, "tpp-nomad");
        assert!(res.total_promoted() > 0, "nomad must still migrate under pressure");
        let c = res.total_migration_counters();
        assert!(
            c.shadow_hits + c.shadow_free_demotions + c.txn_aborts > 0,
            "transactional mode must exercise shadow/txn accounting: {c:?}"
        );
    }
}
