//! NUMA first-touch baseline (no page migration).
//!
//! Fig. 1's "w/o TPP" configuration: pages are allocated to fast memory
//! first and spill to slow memory once fast is full; they never move
//! afterwards. Hot pages that happen to land in slow memory stay there —
//! the reason the paper measures an 8.8% loss at 89.5% fast memory where
//! TPP loses only 4.4%.

use super::watermarks::Watermarks;
use super::PagePolicy;
use crate::sim::mem::TieredMemory;
use crate::workloads::PageAccess;

#[derive(Clone, Debug)]
pub struct FirstTouch {
    wm: Watermarks,
}

impl FirstTouch {
    pub fn new(capacity: u64) -> Self {
        FirstTouch { wm: Watermarks::default_for_capacity(capacity) }
    }
}

impl PagePolicy for FirstTouch {
    fn name(&self) -> &'static str {
        "first-touch"
    }

    fn hot_thr(&self) -> u32 {
        u32::MAX // never promotes
    }

    fn watermarks(&self) -> Watermarks {
        self.wm
    }

    fn set_watermarks(&mut self, wm: Watermarks) {
        self.wm = wm;
    }

    fn alloc_reserve(&self) -> u64 {
        0 // use every fast page before spilling
    }

    fn run_interval(
        &mut self,
        _mem: &mut TieredMemory,
        _touched: &[PageAccess],
        _now: u32,
        _kswapd_budget: u64,
    ) {
        // No migration of any kind.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::Tier;

    #[test]
    fn never_migrates_even_under_pressure() {
        let cap = 50u64;
        let mut mem = TieredMemory::new(100, cap);
        let mut ft = FirstTouch::new(cap);
        for id in 0..100u32 {
            mem.allocate(id, 0, ft.alloc_reserve());
        }
        assert_eq!(mem.fast_used(), 50);
        // Heat up a slow page far past any threshold.
        mem.touch(99, 100, 1);
        ft.run_interval(&mut mem, &[PageAccess { page: 99, random: 100, streamed: 0 }], 1, 1000);
        assert_eq!(mem.page(99).tier, Tier::Slow);
        let c = mem.take_counters();
        assert_eq!(c.promoted + c.demoted_kswapd + c.demoted_direct, 0);
    }
}
