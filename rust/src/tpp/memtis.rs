//! MEMTIS-style page management (SOSP'23), the second policy family the
//! paper discusses: instead of TPP's fixed promotion threshold, MEMTIS
//! keeps a **histogram of page access counts** and dynamically picks the
//! hot threshold so that exactly the pages that fit in fast memory are
//! classified hot.
//!
//! Tuna's handling of such policies (§3.2): "for such a dynamic
//! `hot_thr`, its value is given as an input when the runtime queries the
//! performance database" — which works unchanged here because `hot_thr`
//! is a dimension of the configuration vector and the database samples
//! several values of it. [`Memtis::hot_thr`] reports the *current*
//! dynamic threshold, and that is what telemetry feeds into the query.

use super::watermarks::Watermarks;
use super::PagePolicy;
use crate::sim::mem::{Tier, TieredMemory};
use crate::workloads::PageAccess;
use crate::PageId;

/// Histogram buckets: window counts are clamped into `0..=MAX_BUCKET`.
const MAX_BUCKET: usize = 16;

#[derive(Clone, Debug)]
pub struct Memtis {
    wm: Watermarks,
    /// Current dynamically-chosen promotion threshold.
    hot_thr: u32,
    /// Bounds for the dynamic threshold.
    min_thr: u32,
    max_thr: u32,
    /// Access-count histogram over *all* allocated pages (rebuilt each
    /// interval from the per-page window counters).
    histogram: [u64; MAX_BUCKET + 1],
    scan_budget: u64,
    victims: Vec<(u32, u32, u32, PageId)>,
}

impl Memtis {
    pub fn new(wm: Watermarks) -> Self {
        Memtis {
            wm,
            hot_thr: 2,
            min_thr: 1,
            max_thr: MAX_BUCKET as u32,
            histogram: [0; MAX_BUCKET + 1],
            scan_budget: 384,
            victims: Vec::new(),
        }
    }

    /// Rebuild the histogram and pick the smallest threshold T such that
    /// the pages with window count ≥ T fit within the usable fast size
    /// (MEMTIS's "hot set sized to fast memory" rule).
    fn retune_threshold(&mut self, mem: &TieredMemory) {
        self.histogram = [0; MAX_BUCKET + 1];
        for id in 0..mem.rss_pages() as u32 {
            let p = mem.page(id);
            if p.allocated {
                let b = (p.window_count as usize).min(MAX_BUCKET);
                self.histogram[b] += 1;
            }
        }
        let budget = self.wm.usable(mem.fast_capacity());
        let mut cum = 0u64;
        let mut thr = self.min_thr;
        // walk the histogram from the hottest bucket down until the
        // cumulative hot set would overflow fast memory
        for b in (self.min_thr as usize..=MAX_BUCKET).rev() {
            cum += self.histogram[b];
            if cum > budget {
                thr = (b as u32 + 1).min(self.max_thr);
                self.hot_thr = thr.max(self.min_thr);
                return;
            }
            thr = b as u32;
        }
        self.hot_thr = thr.max(self.min_thr);
    }

    pub fn histogram(&self) -> &[u64; MAX_BUCKET + 1] {
        &self.histogram
    }

    /// Demote up to `want` coldest fast pages (same victim order as TPP:
    /// clean shadowed pages first — free unmaps under non-exclusive
    /// migration — then coldest; identical to the pre-refactor order in
    /// exclusive runs where no page is shadowed).
    fn demote_coldest(&mut self, mem: &mut TieredMemory, want: u64) -> u64 {
        if want == 0 {
            return 0;
        }
        self.victims.clear();
        for id in 0..mem.rss_pages() as u32 {
            let p = mem.page(id);
            if p.allocated && p.tier == Tier::Fast {
                self.victims.push((!p.shadowed as u32, p.window_count, p.last_touch, id));
            }
        }
        let n = (want as usize).min(self.victims.len());
        if n == 0 {
            return 0;
        }
        if n < self.victims.len() {
            self.victims.select_nth_unstable_by_key(n - 1, |&(s, w, t, _)| (s, w, t));
        }
        self.victims[..n].sort_unstable_by_key(|&(s, w, t, id)| (s, w, t, id));
        let ids: Vec<PageId> = self.victims[..n].iter().map(|&(_, _, _, id)| id).collect();
        for id in ids {
            mem.demote(id, false);
        }
        n as u64
    }
}

impl PagePolicy for Memtis {
    fn name(&self) -> &'static str {
        "memtis"
    }

    fn hot_thr(&self) -> u32 {
        self.hot_thr
    }

    fn watermarks(&self) -> Watermarks {
        self.wm
    }

    fn set_watermarks(&mut self, wm: Watermarks) {
        self.wm = wm;
    }

    fn alloc_reserve(&self) -> u64 {
        self.wm.low
    }

    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        _now: u32,
        kswapd_budget: u64,
    ) {
        // 1. retune the dynamic threshold from the fresh histogram
        self.retune_threshold(mem);

        // 2. promotion pass with the dynamic threshold (scan-budgeted)
        let mut attempts = 0u64;
        for a in touched {
            if attempts >= self.scan_budget {
                break;
            }
            let p = mem.page(a.page);
            if p.tier == Tier::Slow && p.window_count >= self.hot_thr {
                attempts += 1;
                if !mem.promote(a.page, self.wm.min) {
                    mem.page_mut(a.page).window_count = 0;
                }
            }
        }

        // 3. background demotion toward the high watermark
        let free = mem.fast_free();
        if free < self.wm.low {
            let want = (self.wm.high - free).min(kswapd_budget);
            self.demote_coldest(mem, want);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(rss: usize, cap: u64) -> TieredMemory {
        let mut mem = TieredMemory::new(rss, cap);
        for id in 0..rss as u32 {
            mem.allocate(id, 0, Watermarks::default_for_capacity(cap).low);
        }
        mem
    }

    #[test]
    fn threshold_rises_under_memory_pressure() {
        // plenty of hot pages, small fast memory ⇒ threshold must rise
        let cap = 200u64;
        let mut mem = filled(1000, cap);
        for id in 0..600u32 {
            mem.touch(id, 8, 1); // 600 pages at bucket 8 ≫ capacity
        }
        let mut m = Memtis::new(Watermarks::default_for_capacity(cap));
        m.run_interval(&mut mem, &[], 1, 0);
        assert!(m.hot_thr() > 8, "hot_thr={} should exceed the crowd", m.hot_thr());

        // roomy fast memory ⇒ threshold relaxes
        let cap2 = 5_000u64;
        let mut mem2 = filled(1000, cap2);
        for id in 0..600u32 {
            mem2.touch(id, 8, 1);
        }
        let mut m2 = Memtis::new(Watermarks::default_for_capacity(cap2));
        m2.run_interval(&mut mem2, &[], 1, 0);
        assert!(m2.hot_thr() <= 2, "hot_thr={} should relax", m2.hot_thr());
    }

    #[test]
    fn histogram_counts_all_allocated_pages() {
        let cap = 500u64;
        let mut mem = filled(100, cap);
        for id in 0..10u32 {
            mem.touch(id, 3, 1);
        }
        let mut m = Memtis::new(Watermarks::default_for_capacity(cap));
        m.run_interval(&mut mem, &[], 1, 0);
        let h = m.histogram();
        assert_eq!(h.iter().sum::<u64>(), 100);
        assert_eq!(h[3], 10);
        assert_eq!(h[0], 90);
    }

    #[test]
    fn promotes_with_dynamic_threshold_and_respects_watermarks() {
        let cap = 120u64;
        let wm = Watermarks { min: 5, low: 10, high: 15 };
        let mut mem = TieredMemory::new(300, cap);
        for id in 0..300u32 {
            mem.allocate(id, 0, 0);
        }
        // hot slow page
        let hot = 250u32;
        mem.touch(hot, 12, 1);
        let mut m = Memtis::new(wm);
        let touched = [PageAccess { page: hot, random: 12, streamed: 0 }];
        m.run_interval(&mut mem, &touched, 1, 50);
        // free was 0 < min ⇒ promotion failed first, kswapd freed pages
        assert!(mem.fast_free() >= wm.low.min(50));
        // second interval: now there is room
        mem.touch(hot, 12, 2);
        m.run_interval(&mut mem, &touched, 2, 50);
        assert_eq!(mem.page(hot).tier, Tier::Fast, "hot page promoted (thr={})", m.hot_thr());
    }

    #[test]
    fn works_under_the_engine_with_real_workloads() {
        use crate::sim::{Engine, IntervalModel, MachineModel};
        let mut w = crate::workloads::by_name("Btree", 3, 40).unwrap();
        let cap = Engine::fm_capacity(w.rss_pages(), 0.85);
        let mut m = Memtis::new(Watermarks::default_for_capacity(cap));
        let engine = Engine::new(IntervalModel::new(MachineModel::default()));
        let res = engine.run(w.as_mut(), &mut m, cap, |_| None);
        assert_eq!(res.policy, "memtis");
        assert!(res.total_promoted() > 0, "memtis must migrate under pressure");
    }
}
