//! Page-management policies for the tiered-memory simulator.
//!
//! [`Tpp`] reimplements the control loop of *TPP: Transparent Page
//! Placement for CXL-Enabled Tiered-Memory* (ASPLOS'23), the policy the
//! paper deploys:
//!
//! * **Promotion** on access frequency: a slow-tier page whose profiling-
//!   window access count reaches `hot_thr` is promoted on its next access
//!   (TPP's NUMA-hint-fault path; blocking for the faulting thread). If
//!   fewer than `min`-watermark pages are free, the promotion *fails* —
//!   the "page migration failure" counter of the paper's motivation study.
//! * **Background demotion** by a kswapd model: when free pages fall below
//!   the `low` watermark, the coldest fast-tier pages are demoted until
//!   the `high` watermark is restored, subject to a per-interval reclaim
//!   throughput budget (when promotions outpace this budget, failures
//!   accumulate — the Fig. 1 cliff at 26.6% fast memory).
//! * **Direct reclaim** below the `min` watermark: blocking demotions,
//!   charged to application time (what Tuna's watermark programming is
//!   designed to avoid, §4).
//!
//! [`firsttouch::FirstTouch`] is the no-migration NUMA first-touch
//! baseline of Fig. 1, and [`memtis::Memtis`] the dynamic-`hot_thr`
//! policy family (MEMTIS) whose threshold Tuna feeds into the database
//! query as a vector dimension (§3.2).

pub mod firsttouch;
pub mod memtis;
pub mod nomad;
pub mod watermarks;

pub use firsttouch::FirstTouch;
pub use memtis::Memtis;
pub use nomad::TppNomad;
pub use watermarks::Watermarks;

use crate::sim::mem::{MigrationModel, TieredMemory, Tier};
use crate::workloads::PageAccess;
use crate::PageId;

/// A page-management policy the engine invokes once per profiling interval.
pub trait PagePolicy {
    fn name(&self) -> &'static str;
    /// Promotion threshold (accesses in the profiling window).
    fn hot_thr(&self) -> u32;
    fn watermarks(&self) -> Watermarks;
    /// Reprogram the watermarks (Tuna's §4 control knob).
    fn set_watermarks(&mut self, wm: Watermarks);
    /// Free pages to reserve when placing *new* allocations in fast.
    fn alloc_reserve(&self) -> u64;
    /// React to this interval's accesses: promote/demote/reclaim.
    /// `touched` is the interval's page-access histogram; `kswapd_budget`
    /// is how many pages kswapd may demote this interval (derived from the
    /// previous interval's wall time and the machine's reclaim rate).
    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        now: u32,
        kswapd_budget: u64,
    );
    /// Migration semantics this policy asks the engine for when the run
    /// doesn't override them. Every stock policy is exclusive (the
    /// pre-refactor behavior); [`TppNomad`] opts into the transactional
    /// non-exclusive mode.
    fn migration_model(&self) -> MigrationModel {
        MigrationModel::Exclusive
    }
}

/// The TPP policy.
#[derive(Clone, Debug)]
pub struct Tpp {
    wm: Watermarks,
    hot_thr: u32,
    /// NUMA-hint-fault scan budget: promotion attempts per interval
    /// (see [`crate::sim::MachineModel::promote_scan_pages_per_interval`]).
    pub scan_budget: u64,
    /// Scratch buffer reused across intervals for victim selection
    /// (hot-loop allocation hygiene; see EXPERIMENTS.md §Perf). The
    /// leading component is the shadow-preference flag (see
    /// [`Tpp::demote_coldest`]).
    victims: Vec<(u32, u32, u32, PageId)>,
}

impl Tpp {
    /// TPP with its default two-touch promotion threshold.
    pub fn new(wm: Watermarks) -> Self {
        Self::with_hot_thr(wm, 2)
    }

    pub fn with_hot_thr(wm: Watermarks, hot_thr: u32) -> Self {
        assert!(hot_thr >= 1);
        Tpp { wm, hot_thr, scan_budget: 384, victims: Vec::new() }
    }

    /// Demote up to `want` of the coldest fast-tier pages. Victims are
    /// ordered by (shadow-preference, window_count, last_touch): under
    /// watermark pressure, clean shadowed pages demote first (their
    /// demotion is a free unmap — non-exclusive mode only), then
    /// cold-and-old first, which is TPP's "inactive LRU first" reclaim
    /// order collapsed to one scan. In exclusive runs no page is ever
    /// shadowed, so the flag is a constant and the comparisons — and
    /// therefore the selected victims — are identical to the pre-refactor
    /// (window_count, last_touch) order.
    fn demote_coldest(&mut self, mem: &mut TieredMemory, want: u64, direct: bool) -> u64 {
        if want == 0 {
            return 0;
        }
        self.victims.clear();
        for id in 0..mem.rss_pages() as u32 {
            let p = mem.page(id);
            if p.allocated && p.tier == Tier::Fast {
                self.victims.push((!p.shadowed as u32, p.window_count, p.last_touch, id));
            }
        }
        let n = (want as usize).min(self.victims.len());
        if n == 0 {
            return 0;
        }
        if n < self.victims.len() {
            self.victims
                .select_nth_unstable_by_key(n - 1, |&(s, w, t, _)| (s, w, t));
        }
        // Deterministic demotion order within the selected cold set.
        self.victims[..n].sort_unstable_by_key(|&(s, w, t, id)| (s, w, t, id));
        let ids: Vec<PageId> = self.victims[..n].iter().map(|&(_, _, _, id)| id).collect();
        for id in ids {
            mem.demote(id, direct);
        }
        n as u64
    }
}

impl PagePolicy for Tpp {
    fn name(&self) -> &'static str {
        "tpp"
    }

    fn hot_thr(&self) -> u32 {
        self.hot_thr
    }

    fn watermarks(&self) -> Watermarks {
        self.wm
    }

    fn set_watermarks(&mut self, wm: Watermarks) {
        self.wm = wm;
    }

    fn alloc_reserve(&self) -> u64 {
        self.wm.low
    }

    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        now: u32,
        kswapd_budget: u64,
    ) {
        let _ = now;
        // --- promotion pass (NUMA hint faults on hot slow pages) ---
        // Attempts are bounded by the AutoNUMA scan budget: pages beyond
        // it simply don't take a hint fault this interval.
        let mut attempts = 0u64;
        for a in touched {
            let id = a.page;
            if attempts >= self.scan_budget {
                break;
            }
            let p = mem.page(id);
            if p.tier == Tier::Slow && p.window_count >= self.hot_thr {
                attempts += 1;
                // Denied below the min watermark → migration failure.
                // On failure the hint fault is consumed without a retry
                // until the page re-heats (fault-sampling backoff) — TPP
                // never direct-reclaims on the promotion path; that
                // decoupling is its headline design point.
                if !mem.promote(id, self.wm.min) {
                    mem.page_mut(id).window_count = 0;
                }
            }
        }

        // --- kswapd background demotion ---
        let free = mem.fast_free();
        if free < self.wm.low {
            let want = (self.wm.high - free).min(kswapd_budget);
            self.demote_coldest(mem, want, false);
        }
        // NOTE: no spontaneous direct reclaim here. Direct (blocking)
        // reclaim happens only on allocation pressure below `min`, which
        // the engine's allocation reserve prevents in steady state; the
        // `direct-resize` ablation policy exercises that path instead.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::TieredMemory;

    fn setup(rss: usize, cap: u64) -> (TieredMemory, Tpp) {
        let wm = Watermarks::default_for_capacity(cap);
        let mut mem = TieredMemory::new(rss, cap);
        let tpp = Tpp::new(wm);
        for id in 0..rss as u32 {
            mem.allocate(id, 0, tpp.alloc_reserve());
        }
        (mem, tpp)
    }

    #[test]
    fn hot_slow_pages_get_promoted() {
        let (mut mem, mut tpp) = setup(1000, 800);
        // pages ≥ usable fast live in slow; heat one up
        let victim = 999u32;
        assert_eq!(mem.page(victim).tier, Tier::Slow);
        mem.touch(victim, 3, 1);
        tpp.run_interval(&mut mem, &[PageAccess { page: victim, random: 3, streamed: 0 }], 1, 100);
        assert_eq!(mem.page(victim).tier, Tier::Fast);
        assert_eq!(mem.counters.promoted, 1);
        mem.check_invariants().unwrap();
    }

    #[test]
    fn cold_slow_pages_stay_put() {
        let (mut mem, mut tpp) = setup(1000, 800);
        let victim = 999u32;
        mem.touch(victim, 1, 1); // below hot_thr=2
        tpp.run_interval(&mut mem, &[PageAccess { page: victim, random: 1, streamed: 0 }], 1, 100);
        assert_eq!(mem.page(victim).tier, Tier::Slow);
        assert_eq!(mem.counters.promoted, 0);
    }

    #[test]
    fn kswapd_restores_high_watermark_and_prefers_cold_victims() {
        let cap = 100u64;
        let wm = Watermarks { min: 5, low: 10, high: 15 };
        let mut mem = TieredMemory::new(200, cap);
        let mut tpp = Tpp::with_hot_thr(wm, 2);
        for id in 0..200u32 {
            mem.allocate(id, 0, 0); // fill fast completely
        }
        assert_eq!(mem.fast_free(), 0);
        // heat up pages 0..50 so they are NOT victims
        let touched: Vec<PageAccess> =
            (0..50u32).map(|id| PageAccess { page: id, random: 8, streamed: 0 }).collect();
        for a in &touched {
            mem.touch(a.page, a.random, 1);
        }
        tpp.run_interval(&mut mem, &touched, 1, 1000);
        assert_eq!(mem.fast_free(), wm.high);
        assert_eq!(mem.counters.demoted_kswapd, wm.high);
        for id in 0..50u32 {
            assert_eq!(mem.page(id).tier, Tier::Fast, "hot page {id} demoted");
        }
        mem.check_invariants().unwrap();
    }

    #[test]
    fn kswapd_budget_limits_reclaim_and_never_direct_reclaims() {
        let cap = 100u64;
        let wm = Watermarks { min: 8, low: 20, high: 30 };
        let mut mem = TieredMemory::new(150, cap);
        let mut tpp = Tpp::new(wm);
        for id in 0..150u32 {
            mem.allocate(id, 0, 0);
        }
        // budget 4 < needed 30 ⇒ kswapd demotes exactly 4; TPP never
        // blocks the app with direct reclaim on its own.
        tpp.run_interval(&mut mem, &[], 1, 4);
        assert_eq!(mem.counters.demoted_kswapd, 4);
        assert_eq!(mem.counters.demoted_direct, 0);
        assert_eq!(mem.fast_free(), 4);
        // next interval kswapd continues
        tpp.run_interval(&mut mem, &[], 2, 4);
        assert_eq!(mem.counters.demoted_kswapd, 8);
    }

    #[test]
    fn promotion_fails_below_min_watermark_and_backs_off() {
        let cap = 100u64;
        let wm = Watermarks { min: 10, low: 20, high: 25 };
        let mut mem = TieredMemory::new(200, cap);
        let mut tpp = Tpp::new(wm);
        for id in 0..200u32 {
            mem.allocate(id, 0, 0); // free = 0 < min
        }
        let hot = 150u32;
        mem.touch(hot, 5, 1);
        // kswapd_budget 0: nothing reclaimed, promotion must fail
        tpp.run_interval(&mut mem, &[PageAccess { page: hot, random: 5, streamed: 0 }], 1, 0);
        assert_eq!(mem.counters.promoted, 0);
        assert_eq!(mem.counters.promote_failed, 1);
        // fault backoff: window reset so the page must re-heat
        assert_eq!(mem.page(hot).window_count, 0);
        // second interval without re-heating: no second failure
        tpp.run_interval(&mut mem, &[PageAccess { page: hot, random: 0, streamed: 0 }], 2, 0);
        assert_eq!(mem.counters.promote_failed, 1);
    }

    #[test]
    fn hot_thr_is_respected() {
        let cap = 80u64;
        let wm = Watermarks::default_for_capacity(cap);
        let mut mem = TieredMemory::new(100, cap);
        let mut tpp = Tpp::with_hot_thr(wm, 4);
        for id in 0..100u32 {
            mem.allocate(id, 0, tpp.alloc_reserve());
        }
        let page = 99u32;
        assert_eq!(mem.page(page).tier, Tier::Slow);
        mem.touch(page, 3, 1);
        tpp.run_interval(&mut mem, &[PageAccess { page, random: 3, streamed: 0 }], 1, 10);
        assert_eq!(mem.page(page).tier, Tier::Slow, "below hot_thr=4");
        mem.touch(page, 1, 2);
        tpp.run_interval(&mut mem, &[PageAccess { page, random: 1, streamed: 0 }], 2, 10);
        assert_eq!(mem.page(page).tier, Tier::Fast, "reached hot_thr=4");
    }
}
