//! Page-management policies for the tiered-memory simulator.
//!
//! [`Tpp`] reimplements the control loop of *TPP: Transparent Page
//! Placement for CXL-Enabled Tiered-Memory* (ASPLOS'23), the policy the
//! paper deploys:
//!
//! * **Promotion** on access frequency: a slow-tier page whose profiling-
//!   window access count reaches `hot_thr` is promoted on its next access
//!   (TPP's NUMA-hint-fault path; blocking for the faulting thread). If
//!   fewer than `min`-watermark pages are free, the promotion *fails* —
//!   the "page migration failure" counter of the paper's motivation study.
//! * **Background demotion** by a kswapd model: when free pages fall below
//!   the `low` watermark, the coldest fast-tier pages are demoted until
//!   the `high` watermark is restored, subject to a per-interval reclaim
//!   throughput budget (when promotions outpace this budget, failures
//!   accumulate — the Fig. 1 cliff at 26.6% fast memory).
//! * **Direct reclaim** below the `min` watermark: blocking demotions,
//!   charged to application time (what Tuna's watermark programming is
//!   designed to avoid, §4).
//!
//! [`firsttouch::FirstTouch`] is the no-migration NUMA first-touch
//! baseline of Fig. 1, and [`memtis::Memtis`] the dynamic-`hot_thr`
//! policy family (MEMTIS) whose threshold Tuna feeds into the database
//! query as a vector dimension (§3.2).

pub mod firsttouch;
pub mod gated;
pub mod memtis;
pub mod nomad;
pub mod watermarks;

pub use firsttouch::FirstTouch;
pub use gated::TppGated;
pub use memtis::Memtis;
pub use nomad::TppNomad;
pub use watermarks::Watermarks;

use crate::admission::{AdmissionConfig, AdmissionGate, Verdict};
use crate::sim::mem::{MigrationModel, TieredMemory, Tier};
use crate::workloads::PageAccess;
use crate::PageId;

/// A page-management policy the engine invokes once per profiling interval.
pub trait PagePolicy {
    fn name(&self) -> &'static str;
    /// Promotion threshold (accesses in the profiling window).
    fn hot_thr(&self) -> u32;
    fn watermarks(&self) -> Watermarks;
    /// Reprogram the watermarks (Tuna's §4 control knob).
    fn set_watermarks(&mut self, wm: Watermarks);
    /// Free pages to reserve when placing *new* allocations in fast.
    fn alloc_reserve(&self) -> u64;
    /// React to this interval's accesses: promote/demote/reclaim.
    /// `touched` is the interval's page-access histogram; `kswapd_budget`
    /// is how many pages kswapd may demote this interval (derived from the
    /// previous interval's wall time and the machine's reclaim rate).
    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        now: u32,
        kswapd_budget: u64,
    );
    /// Migration semantics this policy asks the engine for when the run
    /// doesn't override them. Every stock policy is exclusive (the
    /// pre-refactor behavior); [`TppNomad`] opts into the transactional
    /// non-exclusive mode.
    fn migration_model(&self) -> MigrationModel {
        MigrationModel::Exclusive
    }
}

/// The TPP policy.
#[derive(Clone, Debug)]
pub struct Tpp {
    wm: Watermarks,
    hot_thr: u32,
    /// NUMA-hint-fault scan budget: promotion attempts per interval
    /// (see [`crate::sim::MachineModel::promote_scan_pages_per_interval`]).
    pub scan_budget: u64,
    /// Scratch buffer reused across intervals for victim selection
    /// (hot-loop allocation hygiene; see EXPERIMENTS.md §Perf). The
    /// leading component is the shadow-preference flag (see
    /// [`Tpp::demote_coldest`]).
    victims: Vec<(u32, u32, u32, PageId)>,
    /// Optional admission gate (see [`crate::admission`]). `None` — the
    /// default — is bit-identical to the pre-admission policy: every
    /// candidate that crosses `hot_thr` is promoted unconditionally.
    gate: Option<AdmissionGate>,
}

impl Tpp {
    /// TPP with its default two-touch promotion threshold.
    pub fn new(wm: Watermarks) -> Self {
        Self::with_hot_thr(wm, 2)
    }

    pub fn with_hot_thr(wm: Watermarks, hot_thr: u32) -> Self {
        assert!(hot_thr >= 1);
        Tpp { wm, hot_thr, scan_budget: 384, victims: Vec::new(), gate: None }
    }

    /// Install (or, when `cfg.enabled` is false, remove) the admission
    /// gate. A disabled config installs nothing, keeping the no-gate
    /// path bit-identical to the pre-admission policy.
    pub fn with_admission(mut self, cfg: AdmissionConfig) -> Self {
        self.gate = cfg.enabled.then(|| AdmissionGate::new(cfg));
        self
    }

    /// The installed gate's configuration, if any.
    pub fn admission(&self) -> Option<AdmissionConfig> {
        self.gate.as_ref().map(|g| g.config())
    }

    /// Demote up to `want` of the coldest fast-tier pages. Victims are
    /// ordered by (shadow-preference, window_count, last_touch): under
    /// watermark pressure, clean shadowed pages demote first (their
    /// demotion is a free unmap — non-exclusive mode only), then
    /// cold-and-old first, which is TPP's "inactive LRU first" reclaim
    /// order collapsed to one scan. In exclusive runs no page is ever
    /// shadowed, so the flag is a constant and the comparisons — and
    /// therefore the selected victims — are identical to the pre-refactor
    /// (window_count, last_touch) order.
    fn demote_coldest(&mut self, mem: &mut TieredMemory, want: u64, direct: bool, now: u32) -> u64 {
        if want == 0 {
            return 0;
        }
        self.victims.clear();
        for id in 0..mem.rss_pages() as u32 {
            let p = mem.page(id);
            if p.allocated && p.tier == Tier::Fast {
                self.victims.push((!p.shadowed as u32, p.window_count, p.last_touch, id));
            }
        }
        let n = (want as usize).min(self.victims.len());
        if n == 0 {
            return 0;
        }
        if n < self.victims.len() {
            self.victims
                .select_nth_unstable_by_key(n - 1, |&(s, w, t, _)| (s, w, t));
        }
        // Deterministic demotion order within the selected cold set.
        self.victims[..n].sort_unstable_by_key(|&(s, w, t, id)| (s, w, t, id));
        let ids: Vec<PageId> = self.victims[..n].iter().map(|&(_, _, _, id)| id).collect();
        for id in ids {
            // A clean shadowed victim demotes by a free unmap — no copy
            // traffic for the admission budget; the cool-down stamp
            // applies either way (the page left fast memory).
            let copied = !mem.page(id).shadowed;
            mem.demote(id, direct);
            if let Some(gate) = &mut self.gate {
                gate.note_demotion(id, now, copied);
            }
        }
        n as u64
    }
}

impl PagePolicy for Tpp {
    fn name(&self) -> &'static str {
        "tpp"
    }

    fn hot_thr(&self) -> u32 {
        self.hot_thr
    }

    fn watermarks(&self) -> Watermarks {
        self.wm
    }

    fn set_watermarks(&mut self, wm: Watermarks) {
        self.wm = wm;
    }

    fn alloc_reserve(&self) -> u64 {
        self.wm.low
    }

    fn run_interval(
        &mut self,
        mem: &mut TieredMemory,
        touched: &[PageAccess],
        now: u32,
        kswapd_budget: u64,
    ) {
        // --- admission bookkeeping (gated runs only) ---
        // The engine runs note_access before the policy and resets the
        // counters after it, so `txn_retried_copies` here is exactly this
        // interval's forced re-copies: traffic the gate never saw at
        // admit time, charged against the budget as carried debt.
        if let Some(gate) = &mut self.gate {
            gate.begin_interval(mem.counters.txn_retried_copies);
        }

        // --- promotion pass (NUMA hint faults on hot slow pages) ---
        // Attempts are bounded by the AutoNUMA scan budget: pages beyond
        // it simply don't take a hint fault this interval. Only true
        // promotion candidates (hot slow-tier pages) consume an attempt;
        // everything else never takes a hint fault at all.
        let mut attempts = 0u64;
        for a in touched {
            if attempts >= self.scan_budget {
                break;
            }
            let id = a.page;
            let (tier, window_count) = {
                let p = mem.page(id);
                (p.tier, p.window_count)
            };
            let candidate = tier == Tier::Slow && window_count >= self.hot_thr;
            if !candidate {
                continue;
            }
            attempts += 1;
            if let Some(gate) = &mut self.gate {
                // An admission rejection consumes the hint fault (the
                // fault fired; the gate refused the migration) but keeps
                // the page's window history — the benefit signal must
                // survive for later intervals.
                match gate.admit(id, window_count, now) {
                    Verdict::Accept => mem.counters.admission_accepted += 1,
                    Verdict::RejectBudget => {
                        mem.counters.admission_rejected_budget += 1;
                        continue;
                    }
                    Verdict::RejectPayoff => {
                        mem.counters.admission_rejected_payoff += 1;
                        continue;
                    }
                    Verdict::RejectCooldown => {
                        mem.counters.admission_rejected_cooldown += 1;
                        continue;
                    }
                }
            }
            // Denied below the min watermark → migration failure.
            // On failure the hint fault is consumed without a retry
            // until the page re-heats (fault-sampling backoff) — TPP
            // never direct-reclaims on the promotion path; that
            // decoupling is its headline design point.
            if !mem.promote(id, self.wm.min) {
                mem.page_mut(id).window_count = 0;
            }
        }

        // --- kswapd background demotion ---
        let free = mem.fast_free();
        if free < self.wm.low {
            let want = (self.wm.high - free).min(kswapd_budget);
            self.demote_coldest(mem, want, false, now);
        }
        // NOTE: no spontaneous direct reclaim here. Direct (blocking)
        // reclaim happens only on allocation pressure below `min`, which
        // the engine's allocation reserve prevents in steady state; the
        // `direct-resize` ablation policy exercises that path instead.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::mem::TieredMemory;

    fn setup(rss: usize, cap: u64) -> (TieredMemory, Tpp) {
        let wm = Watermarks::default_for_capacity(cap);
        let mut mem = TieredMemory::new(rss, cap);
        let tpp = Tpp::new(wm);
        for id in 0..rss as u32 {
            mem.allocate(id, 0, tpp.alloc_reserve());
        }
        (mem, tpp)
    }

    #[test]
    fn hot_slow_pages_get_promoted() {
        let (mut mem, mut tpp) = setup(1000, 800);
        // pages ≥ usable fast live in slow; heat one up
        let victim = 999u32;
        assert_eq!(mem.page(victim).tier, Tier::Slow);
        mem.touch(victim, 3, 1);
        tpp.run_interval(&mut mem, &[PageAccess { page: victim, random: 3, streamed: 0 }], 1, 100);
        assert_eq!(mem.page(victim).tier, Tier::Fast);
        assert_eq!(mem.counters.promoted, 1);
        mem.check_invariants().unwrap();
    }

    #[test]
    fn cold_slow_pages_stay_put() {
        let (mut mem, mut tpp) = setup(1000, 800);
        let victim = 999u32;
        mem.touch(victim, 1, 1); // below hot_thr=2
        tpp.run_interval(&mut mem, &[PageAccess { page: victim, random: 1, streamed: 0 }], 1, 100);
        assert_eq!(mem.page(victim).tier, Tier::Slow);
        assert_eq!(mem.counters.promoted, 0);
    }

    #[test]
    fn kswapd_restores_high_watermark_and_prefers_cold_victims() {
        let cap = 100u64;
        let wm = Watermarks { min: 5, low: 10, high: 15 };
        let mut mem = TieredMemory::new(200, cap);
        let mut tpp = Tpp::with_hot_thr(wm, 2);
        for id in 0..200u32 {
            mem.allocate(id, 0, 0); // fill fast completely
        }
        assert_eq!(mem.fast_free(), 0);
        // heat up pages 0..50 so they are NOT victims
        let touched: Vec<PageAccess> =
            (0..50u32).map(|id| PageAccess { page: id, random: 8, streamed: 0 }).collect();
        for a in &touched {
            mem.touch(a.page, a.random, 1);
        }
        tpp.run_interval(&mut mem, &touched, 1, 1000);
        assert_eq!(mem.fast_free(), wm.high);
        assert_eq!(mem.counters.demoted_kswapd, wm.high);
        for id in 0..50u32 {
            assert_eq!(mem.page(id).tier, Tier::Fast, "hot page {id} demoted");
        }
        mem.check_invariants().unwrap();
    }

    #[test]
    fn kswapd_budget_limits_reclaim_and_never_direct_reclaims() {
        let cap = 100u64;
        let wm = Watermarks { min: 8, low: 20, high: 30 };
        let mut mem = TieredMemory::new(150, cap);
        let mut tpp = Tpp::new(wm);
        for id in 0..150u32 {
            mem.allocate(id, 0, 0);
        }
        // budget 4 < needed 30 ⇒ kswapd demotes exactly 4; TPP never
        // blocks the app with direct reclaim on its own.
        tpp.run_interval(&mut mem, &[], 1, 4);
        assert_eq!(mem.counters.demoted_kswapd, 4);
        assert_eq!(mem.counters.demoted_direct, 0);
        assert_eq!(mem.fast_free(), 4);
        // next interval kswapd continues
        tpp.run_interval(&mut mem, &[], 2, 4);
        assert_eq!(mem.counters.demoted_kswapd, 8);
    }

    #[test]
    fn promotion_fails_below_min_watermark_and_backs_off() {
        let cap = 100u64;
        let wm = Watermarks { min: 10, low: 20, high: 25 };
        let mut mem = TieredMemory::new(200, cap);
        let mut tpp = Tpp::new(wm);
        for id in 0..200u32 {
            mem.allocate(id, 0, 0); // free = 0 < min
        }
        let hot = 150u32;
        mem.touch(hot, 5, 1);
        // kswapd_budget 0: nothing reclaimed, promotion must fail
        tpp.run_interval(&mut mem, &[PageAccess { page: hot, random: 5, streamed: 0 }], 1, 0);
        assert_eq!(mem.counters.promoted, 0);
        assert_eq!(mem.counters.promote_failed, 1);
        // fault backoff: window reset so the page must re-heat
        assert_eq!(mem.page(hot).window_count, 0);
        // second interval without re-heating: no second failure
        tpp.run_interval(&mut mem, &[PageAccess { page: hot, random: 0, streamed: 0 }], 2, 0);
        assert_eq!(mem.counters.promote_failed, 1);
    }

    /// Satellite fix pin: the scan budget counts *hint-fault attempts*,
    /// and only true promotion candidates (hot slow-tier pages) take a
    /// hint fault — cold or fast-tier entries in the histogram must not
    /// consume budget, and the boundary lands exactly on the last
    /// admitted candidate.
    #[test]
    fn scan_budget_attempts_count_only_true_candidates() {
        let (mut mem, mut tpp) = setup(1000, 800);
        tpp.scan_budget = 3;
        // 5 cold slow pages lead the interval's histogram, then 4 hot
        // candidates; budget 3 must skip the cold pages without charge
        // and exhaust exactly on the third candidate.
        let cold: Vec<u32> = (990..995).collect();
        let hot: Vec<u32> = (995..999).collect();
        let mut touched = Vec::new();
        for &id in &cold {
            assert_eq!(mem.page(id).tier, Tier::Slow);
            mem.touch(id, 1, 1); // below hot_thr=2: not a candidate
            touched.push(PageAccess { page: id, random: 1, streamed: 0 });
        }
        for &id in &hot {
            assert_eq!(mem.page(id).tier, Tier::Slow);
            mem.touch(id, 5, 1);
            touched.push(PageAccess { page: id, random: 5, streamed: 0 });
        }
        tpp.run_interval(&mut mem, &touched, 1, 100);
        assert_eq!(mem.counters.promoted, 3, "budget must exhaust on the 3rd candidate");
        for &id in &hot[..3] {
            assert_eq!(mem.page(id).tier, Tier::Fast, "candidate {id} within budget");
        }
        // the 4th candidate never took a hint fault: not promoted, not
        // failure-counted, and its window history is intact (no backoff)
        assert_eq!(mem.page(998).tier, Tier::Slow);
        assert_eq!(mem.page(998).window_count, 5);
        assert_eq!(mem.counters.promote_failed, 0);
        // cold pages were skipped entirely, not budget-charged
        for &id in &cold {
            assert_eq!(mem.page(id).tier, Tier::Slow);
            assert_eq!(mem.page(id).window_count, 1);
        }
        mem.check_invariants().unwrap();
    }

    #[test]
    fn disabled_admission_installs_no_gate() {
        let wm = Watermarks::default_for_capacity(100);
        let tpp = Tpp::new(wm).with_admission(crate::admission::AdmissionConfig::default());
        assert!(tpp.admission().is_none());
        let tpp = Tpp::new(wm).with_admission(crate::admission::AdmissionConfig::enabled_default());
        assert_eq!(tpp.admission(), Some(crate::admission::AdmissionConfig::enabled_default()));
    }

    #[test]
    fn gate_vetoes_marginal_candidates_and_counts_verdicts() {
        use crate::admission::AdmissionConfig;
        let (mut mem, tpp) = setup(1000, 800);
        let mut tpp = tpp.with_admission(AdmissionConfig {
            enabled: true,
            budget_pages: 0, // unlimited: isolate the payoff predicate
            cooldown_intervals: 4,
            horizon_intervals: 32,
        });
        let (marginal, hot) = (998u32, 999u32);
        mem.touch(marginal, 3, 1); // candidate, but 3·16 = 48 ≤ 64 cost
        mem.touch(hot, 8, 1); // 8·16 = 128 > 64: worth the copy
        tpp.run_interval(
            &mut mem,
            &[
                PageAccess { page: marginal, random: 3, streamed: 0 },
                PageAccess { page: hot, random: 8, streamed: 0 },
            ],
            1,
            100,
        );
        assert_eq!(mem.page(marginal).tier, Tier::Slow, "payoff-rejected");
        assert_eq!(mem.page(marginal).window_count, 3, "rejection keeps the benefit signal");
        assert_eq!(mem.page(hot).tier, Tier::Fast);
        assert_eq!(mem.counters.admission_accepted, 1);
        assert_eq!(mem.counters.admission_rejected_payoff, 1);
        assert_eq!(mem.counters.admission_rejected_budget, 0);
        assert_eq!(mem.counters.admission_rejected_cooldown, 0);
        assert_eq!(mem.counters.promoted, 1);
        mem.check_invariants().unwrap();
    }

    #[test]
    fn gate_budget_admits_up_to_the_interval_allowance() {
        use crate::admission::AdmissionConfig;
        let (mut mem, tpp) = setup(1000, 800);
        let mut tpp = tpp.with_admission(AdmissionConfig {
            enabled: true,
            budget_pages: 1,
            cooldown_intervals: 4,
            horizon_intervals: 32,
        });
        let mut touched = Vec::new();
        for id in [997u32, 998, 999] {
            mem.touch(id, 8, 1);
            touched.push(PageAccess { page: id, random: 8, streamed: 0 });
        }
        tpp.run_interval(&mut mem, &touched, 1, 0);
        assert_eq!(mem.counters.admission_accepted, 1);
        assert_eq!(mem.counters.admission_rejected_budget, 2);
        assert_eq!(mem.counters.promoted, 1);
        // next interval the allowance refreshes
        mem.counters = Default::default();
        tpp.run_interval(&mut mem, &touched, 2, 0);
        assert_eq!(mem.counters.admission_accepted, 1);
        mem.check_invariants().unwrap();
    }

    #[test]
    fn gate_cooldown_rejects_repromotion_of_fresh_demotions() {
        use crate::admission::AdmissionConfig;
        let cap = 100u64;
        let wm = Watermarks { min: 5, low: 10, high: 15 };
        let mut mem = TieredMemory::new(200, cap);
        let mut tpp = Tpp::with_hot_thr(wm, 2).with_admission(AdmissionConfig {
            enabled: true,
            budget_pages: 0,
            cooldown_intervals: 16,
            horizon_intervals: 32,
        });
        for id in 0..200u32 {
            mem.allocate(id, 0, 0); // fill fast completely
        }
        // interval 1: watermark pressure demotes the coldest pages
        // (ids 0..high by the deterministic victim order), stamping them
        tpp.run_interval(&mut mem, &[], 1, 1000);
        assert_eq!(mem.counters.demoted_kswapd, wm.high);
        assert_eq!(mem.page(0).tier, Tier::Slow);
        // interval 2: the freshly demoted page is hot again — a classic
        // ping-pong candidate the cool-down filter must refuse outright
        mem.touch(0, 32, 2);
        tpp.run_interval(&mut mem, &[PageAccess { page: 0, random: 32, streamed: 0 }], 2, 1000);
        assert_eq!(mem.counters.admission_rejected_cooldown, 1);
        assert_eq!(mem.page(0).tier, Tier::Slow, "ping-pong promotion vetoed");
        assert_eq!(mem.page(0).window_count, 32, "window history preserved");
        // interval 18 (16 intervals after the demotion): cool-down served
        mem.touch(0, 32, 18);
        tpp.run_interval(&mut mem, &[PageAccess { page: 0, random: 32, streamed: 0 }], 18, 1000);
        assert_eq!(mem.counters.admission_accepted, 1);
        assert_eq!(mem.page(0).tier, Tier::Fast);
        mem.check_invariants().unwrap();
    }

    #[test]
    fn hot_thr_is_respected() {
        let cap = 80u64;
        let wm = Watermarks::default_for_capacity(cap);
        let mut mem = TieredMemory::new(100, cap);
        let mut tpp = Tpp::with_hot_thr(wm, 4);
        for id in 0..100u32 {
            mem.allocate(id, 0, tpp.alloc_reserve());
        }
        let page = 99u32;
        assert_eq!(mem.page(page).tier, Tier::Slow);
        mem.touch(page, 3, 1);
        tpp.run_interval(&mut mem, &[PageAccess { page, random: 3, streamed: 0 }], 1, 10);
        assert_eq!(mem.page(page).tier, Tier::Slow, "below hot_thr=4");
        mem.touch(page, 1, 2);
        tpp.run_interval(&mut mem, &[PageAccess { page, random: 1, streamed: 0 }], 2, 10);
        assert_eq!(mem.page(page).tier, Tier::Fast, "reached hot_thr=4");
    }
}
