//! SSSP (GAP style): frontier-based Bellman–Ford relaxation from random
//! sources over the weighted synthetic power-law graph.
//!
//! Layout: `offsets | edges | weights | dist | frontier(×2) | pad`.
//! SSSP re-relaxes vertices whose distance improves, so it performs more
//! passes over hub pages than BFS and has the largest RSS of the five
//! workloads (23.5 paper-GB) — the combination the paper uses for its
//! sensitivity studies (Table 3, §6.3).

use std::sync::Arc;

use super::graph::{build_graph, Csr, GraphSpec, Layout, PageHisto, Region};
use super::{AccessProfile, Workload, PAGES_PER_PAPER_GB};
use crate::util::rng::Rng;

const INF: u32 = u32::MAX;

pub struct Sssp {
    g: Arc<Csr>,
    r_offsets: Region,
    r_edges: Region,
    r_weights: Region,
    r_dist: Region,
    r_frontier: Region,
    rss: usize,
    histo: PageHisto,
    dist: Vec<u32>,
    in_next: Vec<bool>,
    frontier: Vec<u32>,
    next: Vec<u32>,
    cursor: usize,
    edge_budget: u64,
    intervals_left: u32,
    first_interval: bool,
    rng: Rng,
    threads: u32,
}

impl Sssp {
    /// Paper-scale instance: RSS = 23.5 paper-GB (Table 1).
    pub fn paper_scale(seed: u64, intervals: u32) -> Self {
        let rss_pages = (23.5 * PAGES_PER_PAPER_GB) as usize;
        Self::with_rss(rss_pages, seed, intervals)
    }

    pub fn with_rss(rss_pages: usize, seed: u64, intervals: u32) -> Self {
        // bytes/vertex (94% of RSS), avg degree 12: offsets 8 + edges 48
        // + weights 48 + dist 4 + frontiers 8 + in_next 1 ≈ 117
        let n = ((rss_pages as u64 * crate::PAGE_BYTES * 94 / 100) / 117).max(4096) as u32;
        let m = n as u64 * 12;
        Self::new(GraphSpec::new(n, m, true, seed), rss_pages, seed, intervals)
    }

    pub fn new(spec: GraphSpec, rss_pages: usize, seed: u64, intervals: u32) -> Self {
        let g = build_graph(&spec);
        let n = g.n as u64;
        let mut l = Layout::new();
        // init-only I/O staging buffer FIRST (GAP load order; the
        // first-touch baseline then spills the *hot* late allocations —
        // see bfs.rs module doc)
        let _r_input = l.region((rss_pages as u64 * 6 / 100).max(16), crate::PAGE_BYTES);
        let r_offsets = l.region(n + 1, 8);
        let r_edges = l.region(g.m() as u64, 4);
        let r_weights = l.region(g.m() as u64, 4);
        let r_dist = l.region(n, 4);
        let r_frontier = l.region(2 * n, 4);
        l.pad_to(rss_pages);
        let rss = l.total_pages().max(rss_pages);
        let mut rng = Rng::new(seed ^ 0x555);
        let source = rng.index(g.n as usize) as u32;
        let mut w = Sssp {
            g,
            r_offsets,
            r_edges,
            r_weights,
            r_dist,
            r_frontier,
            rss,
            histo: PageHisto::new(rss),
            dist: vec![INF; n as usize],
            in_next: vec![false; n as usize],
            frontier: vec![source],
            next: Vec::new(),
            cursor: 0,
            edge_budget: 200_000,
            intervals_left: intervals,
            first_interval: true,
            rng,
            threads: 16,
        };
        w.dist[source as usize] = 0;
        w
    }

    fn restart(&mut self) {
        self.dist.fill(INF);
        self.histo.touch_span(&self.r_dist, 0, self.g.n as u64);
        let source = self.rng.index(self.g.n as usize) as u32;
        self.dist[source as usize] = 0;
        self.frontier.clear();
        self.frontier.push(source);
        self.next.clear();
        self.in_next.fill(false);
        self.cursor = 0;
    }
}

impl Workload for Sssp {
    fn name(&self) -> &'static str {
        "SSSP"
    }

    fn rss_pages(&self) -> usize {
        self.rss
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            self.first_interval = false;
            for p in 0..self.rss as u32 {
                self.histo.touch(p, 1);
            }
            return Some(AccessProfile {
                accesses: self.histo.drain(),
                flops: 0,
                iops: self.rss as u64 * 16,
            });
        }

        let mut edges_done: u64 = 0;
        let mut iops: u64 = 0;
        while edges_done < self.edge_budget {
            if self.cursor >= self.frontier.len() {
                std::mem::swap(&mut self.frontier, &mut self.next);
                self.next.clear();
                self.cursor = 0;
                for &v in &self.frontier {
                    self.in_next[v as usize] = false;
                }
                if self.frontier.is_empty() {
                    self.restart();
                }
                continue;
            }
            let v = self.frontier[self.cursor];
            self.cursor += 1;
            self.histo.touch(self.r_frontier.page_of(self.cursor as u64 - 1), 1);
            self.histo.touch(self.r_offsets.page_of(v as u64), 1);
            self.histo.touch(self.r_dist.page_of(v as u64), 1);
            let (a, b) = (self.g.offsets[v as usize], self.g.offsets[v as usize + 1]);
            if a < b {
                self.histo.touch_span(&self.r_edges, a, b);
                self.histo.touch_span(&self.r_weights, a, b);
            }
            let dv = self.dist[v as usize];
            let nbrs = self.g.neighbors(v);
            let ws = self.g.weights_of(v);
            for i in 0..nbrs.len() {
                let u = nbrs[i];
                let cand = dv.saturating_add(ws[i]);
                self.histo.touch(self.r_dist.page_of(u as u64), 1);
                iops += 4;
                if cand < self.dist[u as usize] {
                    self.dist[u as usize] = cand;
                    iops += 2;
                    if !self.in_next[u as usize] {
                        self.in_next[u as usize] = true;
                        self.histo.touch(
                            self.r_frontier
                                .page_of(self.g.n as u64 + self.next.len() as u64),
                            1,
                        );
                        self.next.push(u);
                    }
                }
            }
            edges_done += (b - a).max(1);
        }

        Some(AccessProfile { accesses: self.histo.drain(), flops: 0, iops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_matches_paper_scale() {
        let w = Sssp::paper_scale(1, 5);
        let want = (23.5 * PAGES_PER_PAPER_GB) as usize;
        assert!(w.rss_pages() >= want && w.rss_pages() < want + 200);
    }

    #[test]
    fn distances_decrease_monotonically_and_are_reachable() {
        let mut w = Sssp::with_rss(3000, 11, 40);
        while w.next_interval().is_some() {}
        let reachable = w.dist.iter().filter(|&&d| d != INF).count();
        assert!(reachable > 100, "reachable={reachable}");
        // source has distance 0
        assert!(w.dist.iter().any(|&d| d == 0));
    }

    #[test]
    fn relaxation_revisits_make_more_work_than_bfs() {
        // SSSP must produce at least as many accesses as BFS on the same
        // budget (re-relaxations + weights region).
        let mut s = Sssp::with_rss(3000, 5, 10);
        let mut b = super::super::bfs::Bfs::with_rss(3000, 5, 10);
        let sa: u64 = std::iter::from_fn(|| s.next_interval())
            .map(|p| p.total_accesses())
            .sum();
        let ba: u64 = std::iter::from_fn(|| b.next_interval())
            .map(|p| p.total_accesses())
            .sum();
        assert!(sa > ba / 2, "sssp={sa} bfs={ba}");
    }

    #[test]
    fn deterministic_per_seed() {
        let sig = |seed| {
            let mut w = Sssp::with_rss(2000, seed, 6);
            std::iter::from_fn(move || w.next_interval())
                .map(|p| p.total_accesses())
                .collect::<Vec<_>>()
        };
        assert_eq!(sig(3), sig(3));
    }
}
