//! BFS (GAP benchmark suite style): repeated direction-optimizing
//! breadth-first traversals from random sources over the synthetic
//! power-law graph.
//!
//! Memory layout mirrors GAP's allocation order — the serialized input
//! edge list is loaded *first*, then the CSR is built, then the per-
//! traversal state:
//!
//! ```text
//! input (.sg buffer) | offsets | edges | dist | visited bitmap | frontier
//! ```
//!
//! Putting the init-only input first matters: under the NUMA first-touch
//! baseline the *late* (hot) allocations — dist, bitmap, frontier — are
//! the ones that spill to slow memory when fast memory shrinks, which is
//! exactly the 8.8%-loss-at-89.5% behaviour of Fig. 1; TPP fixes it by
//! demoting the cold input buffer instead.
//!
//! Direction optimization (Beamer's push/pull switch) is what grades the
//! edge-page heat: small frontiers stream the full adjacency of (mostly
//! hub) frontier vertices, while large frontiers run bottom-up scans that
//! touch only each unvisited vertex's adjacency *prefix* until a visited
//! parent is found. Hub-adjacency and prefix pages are warm every
//! traversal; deep adjacency tails are touched rarely — an organic,
//! graded hot set over most of the RSS.

use std::sync::Arc;

use super::graph::{build_graph, Csr, GraphSpec, Layout, PageHisto, Region};
use super::{AccessProfile, Workload, PAGES_PER_PAPER_GB};
use crate::util::rng::Rng;

const UNSET: u32 = u32::MAX;

/// Frontier share of |V| above which a level runs bottom-up.
const BOTTOM_UP_THRESHOLD: f64 = 0.05;

pub struct Bfs {
    g: Arc<Csr>,
    pub r_input: Region,
    r_offsets: Region,
    r_edges: Region,
    r_dist: Region,
    r_bitmap: Region,
    r_frontier: Region,
    rss: usize,
    histo: PageHisto,
    dist: Vec<u32>,
    in_frontier: Vec<bool>,
    frontier: Vec<u32>,
    next: Vec<u32>,
    /// Cursor within the current level: frontier index (top-down) or
    /// vertex id (bottom-up).
    cursor: usize,
    bottom_up: bool,
    depth: u32,
    edge_budget: u64,
    intervals_left: u32,
    first_interval: bool,
    rng: Rng,
    threads: u32,
    pub traversals_done: u32,
}

impl Bfs {
    /// Paper-scale instance: RSS = 12.4 paper-GB (Table 1).
    pub fn paper_scale(seed: u64, intervals: u32) -> Self {
        let rss_pages = (12.4 * PAGES_PER_PAPER_GB) as usize;
        Self::with_rss(rss_pages, seed, intervals)
    }

    /// Size the graph so the GAP data structures fill `rss_pages`.
    pub fn with_rss(rss_pages: usize, seed: u64, intervals: u32) -> Self {
        // bytes/vertex (94% of RSS), avg degree 12: offsets 8 + edges 48
        // + dist 4 + bitmap ~0.2 + frontiers 8 ≈ 68; ~6% is the init-only
        // I/O staging buffer
        let n = ((rss_pages as u64 * crate::PAGE_BYTES * 94 / 100) / 68).max(4096) as u32;
        let m = n as u64 * 12;
        Self::new(GraphSpec::new(n, m, false, seed), rss_pages, seed, intervals)
    }

    pub fn new(spec: GraphSpec, rss_pages: usize, seed: u64, intervals: u32) -> Self {
        let g = build_graph(&spec);
        let n = g.n as u64;
        let mut l = Layout::new();
        // input first — loaded before anything else exists (see module
        // doc). GAP deserializes .sg straight into the CSR, so only a
        // small I/O staging buffer stays resident (~6% of RSS).
        let r_input = l.region((rss_pages as u64 * 6 / 100).max(16), crate::PAGE_BYTES);
        let r_offsets = l.region(n + 1, 8);
        let r_edges = l.region(g.m() as u64, 4);
        let r_dist = l.region(n, 4);
        let r_bitmap = l.region(n.div_ceil(8).max(1), 1);
        let r_frontier = l.region(2 * n, 4);
        l.pad_to(rss_pages);
        let rss = l.total_pages().max(rss_pages);
        let mut rng = Rng::new(seed ^ 0xbf5);
        let source = rng.index(g.n as usize) as u32;
        let mut w = Bfs {
            g,
            r_input,
            r_offsets,
            r_edges,
            r_dist,
            r_bitmap,
            r_frontier,
            rss,
            histo: PageHisto::new(rss),
            dist: vec![UNSET; n as usize],
            in_frontier: vec![false; n as usize],
            frontier: vec![source],
            next: Vec::new(),
            cursor: 0,
            bottom_up: false,
            depth: 0,
            edge_budget: 200_000,
            intervals_left: intervals,
            first_interval: true,
            rng,
            threads: 16,
            traversals_done: 0,
        };
        w.dist[source as usize] = 0;
        w.in_frontier[source as usize] = true;
        w
    }

    fn restart(&mut self) {
        self.traversals_done += 1;
        // New source: reset dist + bitmap (streaming memsets).
        self.dist.fill(UNSET);
        self.in_frontier.fill(false);
        self.histo.touch_span(&self.r_dist, 0, self.g.n as u64);
        self.histo.touch_span(&self.r_bitmap, 0, self.r_bitmap.n_elems);
        let source = self.rng.index(self.g.n as usize) as u32;
        self.dist[source as usize] = 0;
        self.in_frontier[source as usize] = true;
        self.frontier.clear();
        self.frontier.push(source);
        self.next.clear();
        self.cursor = 0;
        self.depth = 0;
        self.bottom_up = false;
    }

    /// Finish a level: swap frontiers, pick the direction for the next.
    fn advance_level(&mut self) {
        for &v in &self.frontier {
            self.in_frontier[v as usize] = false;
        }
        std::mem::swap(&mut self.frontier, &mut self.next);
        self.next.clear();
        for &v in &self.frontier {
            self.in_frontier[v as usize] = true;
        }
        self.cursor = 0;
        self.depth += 1;
        if self.frontier.is_empty() {
            self.restart();
            return;
        }
        self.bottom_up =
            self.frontier.len() as f64 > BOTTOM_UP_THRESHOLD * self.g.n as f64;
    }

    fn discover(&mut self, u: u32) {
        self.dist[u as usize] = self.depth + 1;
        self.histo.touch(self.r_dist.page_of(u as u64), 1);
        self.histo
            .touch(self.r_frontier.page_of(self.g.n as u64 + self.next.len() as u64), 1);
        self.next.push(u);
    }
}

impl Workload for Bfs {
    fn name(&self) -> &'static str {
        "BFS"
    }

    fn rss_pages(&self) -> usize {
        self.rss
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            // Allocation epoch: load input, build CSR — faults in the
            // whole address space in layout order (RSS peaks at Table 1).
            self.first_interval = false;
            for p in 0..self.rss as u32 {
                self.histo.touch(p, 1);
            }
            return Some(AccessProfile {
                accesses: self.histo.drain(),
                flops: 0,
                iops: self.rss as u64 * 16,
            });
        }

        let g = self.g.clone();
        let mut edges_done: u64 = 0;
        let mut iops: u64 = 0;
        while edges_done < self.edge_budget {
            if self.bottom_up {
                // --- bottom-up: scan unvisited vertices' adjacency
                //     prefixes until a frontier parent is found ---
                if self.cursor >= self.g.n as usize {
                    self.advance_level();
                    continue;
                }
                let v = self.cursor as u32;
                self.cursor += 1;
                if self.dist[v as usize] != UNSET {
                    continue;
                }
                self.histo.touch(self.r_offsets.page_of(v as u64), 1);
                let off = g.offsets[v as usize];
                let nbrs = g.neighbors(v);
                for (i, &u) in nbrs.iter().enumerate() {
                    self.histo.touch(self.r_edges.page_of(off + i as u64), 1);
                    self.histo.touch(self.r_bitmap.page_of(u as u64 / 8), 1);
                    edges_done += 1;
                    iops += 4;
                    if self.in_frontier[u as usize] {
                        self.discover(v);
                        break;
                    }
                }
            } else {
                // --- top-down: stream the frontier's full adjacency ---
                if self.cursor >= self.frontier.len() {
                    self.advance_level();
                    continue;
                }
                let v = self.frontier[self.cursor];
                self.cursor += 1;
                self.histo.touch(self.r_frontier.page_of(self.cursor as u64 - 1), 1);
                self.histo.touch(self.r_offsets.page_of(v as u64), 1);
                let (a, b) = (g.offsets[v as usize], g.offsets[v as usize + 1]);
                if a < b {
                    self.histo.touch_span(&self.r_edges, a, b);
                }
                for &u in g.neighbors(v) {
                    self.histo.touch(self.r_bitmap.page_of(u as u64 / 8), 1);
                    iops += 3;
                    if self.dist[u as usize] == UNSET {
                        self.discover(u);
                        iops += 2;
                    }
                }
                edges_done += (b - a).max(1);
            }
        }

        Some(AccessProfile { accesses: self.histo.drain(), flops: 0, iops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Bfs {
        Bfs::with_rss(2000, 42, 50)
    }

    #[test]
    fn rss_matches_request() {
        let w = small();
        assert!(w.rss_pages() >= 2000);
        assert!(w.rss_pages() < 2200, "rss={}", w.rss_pages());
        let paper = Bfs::paper_scale(1, 10);
        let want = (12.4 * PAGES_PER_PAPER_GB) as usize;
        assert!(paper.rss_pages() >= want && paper.rss_pages() < want + 200);
    }

    #[test]
    fn input_region_is_first_and_cold_after_allocation() {
        let mut w = small();
        assert_eq!(w.r_input.first_page, 0);
        let _ = w.next_interval(); // allocation epoch
        let input_pages = w.r_input.pages() as usize;
        let mut heat = vec![0u64; w.rss_pages()];
        while let Some(p) = w.next_interval() {
            for a in p.accesses {
                heat[a.page as usize] += a.total() as u64;
            }
        }
        let input_heat: u64 = heat[..input_pages].iter().sum();
        let live_heat: u64 = heat[input_pages..].iter().sum();
        assert_eq!(input_heat, 0, "input buffer must never be re-read");
        assert!(live_heat > 0);
    }

    #[test]
    fn first_interval_touches_all_pages() {
        let mut w = small();
        let p = w.next_interval().unwrap();
        assert_eq!(p.accesses.len(), w.rss_pages());
    }

    #[test]
    fn traversal_visits_vertices_and_uses_both_directions() {
        let mut w = Bfs::with_rss(2000, 42, 40);
        let mut saw_bottom_up = false;
        while w.next_interval().is_some() {
            saw_bottom_up |= w.bottom_up;
        }
        let visited = w.dist.iter().filter(|&&d| d != UNSET).count();
        assert!(visited > 100, "visited={visited}");
        assert!(saw_bottom_up, "power-law graphs must trigger bottom-up levels");
    }

    #[test]
    fn deterministic_per_seed() {
        let runs = |seed| {
            let mut w = Bfs::with_rss(1500, seed, 5);
            let mut sig = Vec::new();
            while let Some(p) = w.next_interval() {
                sig.push((p.accesses.len(), p.total_accesses(), p.iops));
            }
            sig
        };
        assert_eq!(runs(9), runs(9));
        assert_ne!(runs(9), runs(10));
    }

    #[test]
    fn live_heat_is_graded_not_flat() {
        // after several traversals, live pages (excluding the input
        // buffer) must show a popularity gradient: top decile of live
        // pages ≫ bottom decile
        let mut w = Bfs::with_rss(2000, 7, 60);
        let input_pages = w.r_input.pages() as usize;
        let mut heat = vec![0u64; w.rss_pages()];
        let _ = w.next_interval();
        while let Some(p) = w.next_interval() {
            for a in p.accesses {
                heat[a.page as usize] += a.total() as u64;
            }
        }
        let mut live: Vec<u64> = heat[input_pages..].to_vec();
        live.sort_unstable_by(|a, b| b.cmp(a));
        let n = live.len();
        let top: u64 = live[..n / 10].iter().sum();
        let bottom: u64 = live[n * 9 / 10..].iter().sum();
        let all: u64 = live.iter().sum();
        assert!(
            top as f64 > 0.12 * all as f64,
            "top decile {top}/{all} not hot enough (uniform would be 0.10)"
        );
        assert!(
            (bottom as f64) < 0.05 * all as f64,
            "bottom decile {bottom}/{all} not cold enough"
        );
    }
}
