//! The workload registry: the paper's five evaluation workloads
//! (Table 1) plus the trace-driven KV family ([`kv`], backed by
//! [`crate::trace`]), the workload trait the simulator drives, and the
//! synthetic graph substrate the Table 1 workloads share.
//!
//! | Workload | paper RSS | here (scaled 1 GiB → 4 MiB)  |
//! |----------|-----------|------------------------------|
//! | PageRank | 15.8 GB   | 16 179 pages (63.2 MiB)      |
//! | XSBench  | 16.4 GB   | 16 793 pages (65.6 MiB)      |
//! | BFS      | 12.4 GB   | 12 697 pages (49.6 MiB)      |
//! | SSSP     | 23.5 GB   | 24 064 pages (94.0 MiB)      |
//! | Btree    | 10.8 GB   | 11 059 pages (43.2 MiB)      |
//!
//! The algorithms run for real (frontier expansion, PR iterations, B-tree
//! descents, MC lookups); what the simulator consumes is each interval's
//! page-access histogram + op counts, so access skew and phase behaviour
//! are organic rather than synthesized.

pub mod bfs;
pub mod btree;
pub mod graph;
pub mod kv;
pub mod pagerank;
pub mod sssp;
pub mod xsbench;

use anyhow::bail;

use crate::PageId;

/// Pages per paper-GB after the 1 GiB → 4 MiB scale-down (DESIGN.md §6).
pub const PAGES_PER_PAPER_GB: f64 = 1024.0;

/// One page's accesses within an interval, split by access kind:
/// `random` accesses are latency-exposed (pointer chases, scattered
/// gathers); `streamed` accesses are sequential scans that hardware
/// prefetchers cover — they consume bandwidth but hide latency. The
/// split is what lets slow-tier *streaming* (e.g. CSR edge scans from
/// Optane) stay cheap while slow-tier *random* access hurts, matching
/// the testbed's behaviour.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageAccess {
    pub page: PageId,
    pub random: u32,
    pub streamed: u32,
}

impl PageAccess {
    pub fn total(&self) -> u32 {
        self.random + self.streamed
    }
}

/// One profiling interval's work, as presented to the simulator.
#[derive(Clone, Debug, Default)]
pub struct AccessProfile {
    /// Page-access histogram. A page appears at most once per interval.
    pub accesses: Vec<PageAccess>,
    /// Floating-point ops executed alongside those accesses.
    pub flops: u64,
    /// Integer/address ops executed alongside those accesses.
    pub iops: u64,
}

impl AccessProfile {
    pub fn total_accesses(&self) -> u64 {
        self.accesses.iter().map(|a| a.total() as u64).sum()
    }

    /// Arithmetic intensity in ops per byte touched (the paper's `AI`).
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = self.total_accesses() * crate::LINE_BYTES;
        if bytes == 0 {
            0.0
        } else {
            (self.flops + self.iops) as f64 / bytes as f64
        }
    }

    /// First page that appears more than once in the histogram, if any.
    ///
    /// "A page appears at most once per interval" is a documented
    /// invariant of [`AccessProfile::accesses`]: [`graph::PageHisto`]
    /// guarantees it by construction, the per-page interval cap and the
    /// KV replayer's random/streamed merge path both depend on it, and
    /// the engine asserts it (debug builds) on every interval.
    pub fn duplicate_page(&self) -> Option<PageId> {
        let mut seen =
            std::collections::HashSet::with_capacity(self.accesses.len());
        self.accesses
            .iter()
            .find_map(|a| (!seen.insert(a.page)).then_some(a.page))
    }
}

/// A workload the engine can drive. Implementations are deterministic per
/// seed; `next_interval` returns `None` when the workload finishes.
pub trait Workload {
    fn name(&self) -> &'static str;
    /// Peak resident set size in pages (the "100% fast memory" size).
    fn rss_pages(&self) -> usize;
    /// Worker threads the workload runs with.
    fn threads(&self) -> u32;
    /// Produce the next profiling interval's accesses, or `None` at end.
    fn next_interval(&mut self) -> Option<AccessProfile>;
}

/// Descriptor used by Table 1 / reports.
#[derive(Clone, Debug)]
pub struct WorkloadInfo {
    pub name: &'static str,
    pub paper_rss_gb: f64,
    pub description: &'static str,
}

/// Table 1 of the paper.
pub const TABLE1: [WorkloadInfo; 5] = [
    WorkloadInfo {
        name: "PageRank",
        paper_rss_gb: 15.8,
        description: "Compute PageRank score (GAP)",
    },
    WorkloadInfo {
        name: "XSBench",
        paper_rss_gb: 16.4,
        description: "Monte Carlo neutron transport algorithm computation",
    },
    WorkloadInfo { name: "BFS", paper_rss_gb: 12.4, description: "Breadth-First Search (GAP)" },
    WorkloadInfo {
        name: "SSSP",
        paper_rss_gb: 23.5,
        description: "Single-Source Shortest Path (GAP)",
    },
    WorkloadInfo {
        name: "Btree",
        paper_rss_gb: 10.8,
        description: "Retrieve data by in-memory index",
    },
];

/// One constructible workload in the registry.
pub struct WorkloadEntry {
    /// Canonical name (what tables, traces and cell stores carry).
    pub name: &'static str,
    /// Extra accepted spellings (all matching is case-insensitive).
    pub aliases: &'static [&'static str],
    /// `"table1"` for the paper's five applications, `"kv"` for the
    /// trace-driven key-value family.
    pub family: &'static str,
    ctor: fn(u64, u32) -> crate::Result<Box<dyn Workload>>,
}

/// The single workload registry: the five Table 1 applications plus the
/// KV trace family (see [`crate::trace`]). [`by_name`], the CLI error
/// message and the KV sweep/bench axes all derive from this list — add
/// a workload here and every entry point picks it up.
pub static REGISTRY: &[WorkloadEntry] = &[
    WorkloadEntry {
        name: "PageRank",
        aliases: &["pr"],
        family: "table1",
        ctor: |s, i| Ok(Box::new(pagerank::PageRank::paper_scale(s, i))),
    },
    WorkloadEntry {
        name: "XSBench",
        aliases: &[],
        family: "table1",
        ctor: |s, i| Ok(Box::new(xsbench::XsBench::paper_scale(s, i))),
    },
    WorkloadEntry {
        name: "BFS",
        aliases: &[],
        family: "table1",
        ctor: |s, i| Ok(Box::new(bfs::Bfs::paper_scale(s, i))),
    },
    WorkloadEntry {
        name: "SSSP",
        aliases: &[],
        family: "table1",
        ctor: |s, i| Ok(Box::new(sssp::Sssp::paper_scale(s, i))),
    },
    WorkloadEntry {
        name: "Btree",
        aliases: &[],
        family: "table1",
        ctor: |s, i| Ok(Box::new(btree::Btree::paper_scale(s, i))),
    },
    WorkloadEntry {
        name: "kv-uniform",
        aliases: &[],
        family: "kv",
        ctor: |s, i| kv::build("kv-uniform", s, i),
    },
    WorkloadEntry {
        name: "kv-zipfian",
        aliases: &["kv-zipf"],
        family: "kv",
        ctor: |s, i| kv::build("kv-zipfian", s, i),
    },
    WorkloadEntry {
        name: "kv-latest",
        aliases: &[],
        family: "kv",
        ctor: |s, i| kv::build("kv-latest", s, i),
    },
    WorkloadEntry {
        name: "kv-hotspot",
        aliases: &[],
        family: "kv",
        ctor: |s, i| kv::build("kv-hotspot", s, i),
    },
    WorkloadEntry {
        name: "kv-scan",
        aliases: &[],
        family: "kv",
        ctor: |s, i| kv::build("kv-scan", s, i),
    },
    WorkloadEntry {
        name: "kv-drift",
        aliases: &[],
        family: "kv",
        ctor: |s, i| kv::build("kv-drift", s, i),
    },
];

/// Every canonical workload name, in registry order.
pub fn all_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Is `name` a constructible workload (registry entry, alias or
/// `trace:FILE` pseudo-name)? Does not touch the filesystem.
pub fn is_known(name: &str) -> bool {
    name.starts_with("trace:")
        || REGISTRY.iter().any(|e| {
            e.name.eq_ignore_ascii_case(name)
                || e.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
        })
}

/// Construct any registered workload by name with a deterministic seed;
/// `intervals` bounds the run length. The pseudo-name `trace:FILE`
/// replays a recorded `TUNATRC1` op-stream artifact through the KV
/// replay engine. Unknown names produce an error listing every valid
/// workload (derived from [`REGISTRY`], so it can never drift).
pub fn by_name(name: &str, seed: u64, intervals: u32) -> crate::Result<Box<dyn Workload>> {
    if let Some(path) = name.strip_prefix("trace:") {
        let w = crate::trace::replay::KvReplay::from_file(
            std::path::Path::new(path),
            intervals,
        )?;
        return Ok(Box::new(w));
    }
    let wanted = name.trim();
    for e in REGISTRY {
        if e.name.eq_ignore_ascii_case(wanted)
            || e.aliases.iter().any(|a| a.eq_ignore_ascii_case(wanted))
        {
            return (e.ctor)(seed, intervals);
        }
    }
    bail!(
        "unknown workload `{name}`; valid workloads: {} (or `trace:FILE` to replay a \
         recorded KV trace)",
        all_names().join(", ")
    )
}

/// All five paper workload names, in Table 1 order (the KV family is in
/// [`REGISTRY`]/[`all_names`]; this constant keeps the paper-figure
/// benches and examples on exactly the Table 1 set).
pub const ALL_NAMES: [&str; 5] = ["PageRank", "XSBench", "BFS", "SSSP", "Btree"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_ai() {
        let p = AccessProfile {
            accesses: vec![
                PageAccess { page: 0, random: 10, streamed: 0 },
                PageAccess { page: 1, random: 4, streamed: 6 },
            ],
            flops: 640,
            iops: 640,
        };
        assert_eq!(p.total_accesses(), 20);
        // 1280 ops / (20 * 64 bytes) = 1.0
        assert!((p.arithmetic_intensity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn by_name_constructs_every_registry_entry() {
        for e in REGISTRY {
            let w = by_name(e.name, 1, 4).unwrap();
            assert!(w.rss_pages() > 1000, "{} rss", e.name);
            for alias in e.aliases {
                assert!(by_name(alias, 1, 2).is_ok(), "alias {alias}");
            }
        }
        // legacy Table 1 constant stays a subset of the registry
        for name in ALL_NAMES {
            assert!(is_known(name), "{name} missing from registry");
            assert_eq!(
                REGISTRY.iter().find(|e| e.name == name).unwrap().family,
                "table1"
            );
        }
    }

    #[test]
    fn unknown_workload_error_lists_the_registry() {
        let err = format!("{:#}", by_name("nope", 1, 1).unwrap_err());
        for e in REGISTRY {
            assert!(err.contains(e.name), "error must name `{}`: {err}", e.name);
        }
        assert!(err.contains("trace:FILE"), "error must mention trace replay: {err}");
        assert!(!is_known("nope"));
        assert!(is_known("trace:/some/file.trc"));
        assert!(is_known("KV-ZIPFIAN"), "matching is case-insensitive");
    }

    #[test]
    fn duplicate_page_detection() {
        let clean = AccessProfile {
            accesses: vec![
                PageAccess { page: 0, random: 1, streamed: 0 },
                PageAccess { page: 1, random: 0, streamed: 2 },
            ],
            ..AccessProfile::default()
        };
        assert_eq!(clean.duplicate_page(), None);
        let dup = AccessProfile {
            accesses: vec![
                PageAccess { page: 3, random: 1, streamed: 0 },
                PageAccess { page: 7, random: 1, streamed: 0 },
                PageAccess { page: 3, random: 0, streamed: 1 },
            ],
            ..AccessProfile::default()
        };
        assert_eq!(dup.duplicate_page(), Some(3));
    }
}
