//! The paper's five evaluation workloads (Table 1), the workload trait the
//! simulator drives, and the synthetic graph substrate they share.
//!
//! | Workload | paper RSS | here (scaled 1 GiB → 4 MiB)  |
//! |----------|-----------|------------------------------|
//! | PageRank | 15.8 GB   | 16 179 pages (63.2 MiB)      |
//! | XSBench  | 16.4 GB   | 16 793 pages (65.6 MiB)      |
//! | BFS      | 12.4 GB   | 12 697 pages (49.6 MiB)      |
//! | SSSP     | 23.5 GB   | 24 064 pages (94.0 MiB)      |
//! | Btree    | 10.8 GB   | 11 059 pages (43.2 MiB)      |
//!
//! The algorithms run for real (frontier expansion, PR iterations, B-tree
//! descents, MC lookups); what the simulator consumes is each interval's
//! page-access histogram + op counts, so access skew and phase behaviour
//! are organic rather than synthesized.

pub mod bfs;
pub mod btree;
pub mod graph;
pub mod pagerank;
pub mod sssp;
pub mod xsbench;

use crate::PageId;

/// Pages per paper-GB after the 1 GiB → 4 MiB scale-down (DESIGN.md §6).
pub const PAGES_PER_PAPER_GB: f64 = 1024.0;

/// One page's accesses within an interval, split by access kind:
/// `random` accesses are latency-exposed (pointer chases, scattered
/// gathers); `streamed` accesses are sequential scans that hardware
/// prefetchers cover — they consume bandwidth but hide latency. The
/// split is what lets slow-tier *streaming* (e.g. CSR edge scans from
/// Optane) stay cheap while slow-tier *random* access hurts, matching
/// the testbed's behaviour.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageAccess {
    pub page: PageId,
    pub random: u32,
    pub streamed: u32,
}

impl PageAccess {
    pub fn total(&self) -> u32 {
        self.random + self.streamed
    }
}

/// One profiling interval's work, as presented to the simulator.
#[derive(Clone, Debug, Default)]
pub struct AccessProfile {
    /// Page-access histogram. A page appears at most once per interval.
    pub accesses: Vec<PageAccess>,
    /// Floating-point ops executed alongside those accesses.
    pub flops: u64,
    /// Integer/address ops executed alongside those accesses.
    pub iops: u64,
}

impl AccessProfile {
    pub fn total_accesses(&self) -> u64 {
        self.accesses.iter().map(|a| a.total() as u64).sum()
    }

    /// Arithmetic intensity in ops per byte touched (the paper's `AI`).
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = self.total_accesses() * crate::LINE_BYTES;
        if bytes == 0 {
            0.0
        } else {
            (self.flops + self.iops) as f64 / bytes as f64
        }
    }
}

/// A workload the engine can drive. Implementations are deterministic per
/// seed; `next_interval` returns `None` when the workload finishes.
pub trait Workload {
    fn name(&self) -> &'static str;
    /// Peak resident set size in pages (the "100% fast memory" size).
    fn rss_pages(&self) -> usize;
    /// Worker threads the workload runs with.
    fn threads(&self) -> u32;
    /// Produce the next profiling interval's accesses, or `None` at end.
    fn next_interval(&mut self) -> Option<AccessProfile>;
}

/// Descriptor used by Table 1 / reports.
#[derive(Clone, Debug)]
pub struct WorkloadInfo {
    pub name: &'static str,
    pub paper_rss_gb: f64,
    pub description: &'static str,
}

/// Table 1 of the paper.
pub const TABLE1: [WorkloadInfo; 5] = [
    WorkloadInfo {
        name: "PageRank",
        paper_rss_gb: 15.8,
        description: "Compute PageRank score (GAP)",
    },
    WorkloadInfo {
        name: "XSBench",
        paper_rss_gb: 16.4,
        description: "Monte Carlo neutron transport algorithm computation",
    },
    WorkloadInfo { name: "BFS", paper_rss_gb: 12.4, description: "Breadth-First Search (GAP)" },
    WorkloadInfo {
        name: "SSSP",
        paper_rss_gb: 23.5,
        description: "Single-Source Shortest Path (GAP)",
    },
    WorkloadInfo {
        name: "Btree",
        paper_rss_gb: 10.8,
        description: "Retrieve data by in-memory index",
    },
];

/// Construct any of the five paper workloads by name with its paper-scaled
/// RSS and a deterministic seed. `intervals` bounds the run length.
pub fn by_name(name: &str, seed: u64, intervals: u32) -> Option<Box<dyn Workload>> {
    match name.to_ascii_lowercase().as_str() {
        "bfs" => Some(Box::new(bfs::Bfs::paper_scale(seed, intervals))),
        "sssp" => Some(Box::new(sssp::Sssp::paper_scale(seed, intervals))),
        "pagerank" | "pr" => Some(Box::new(pagerank::PageRank::paper_scale(seed, intervals))),
        "xsbench" => Some(Box::new(xsbench::XsBench::paper_scale(seed, intervals))),
        "btree" => Some(Box::new(btree::Btree::paper_scale(seed, intervals))),
        _ => None,
    }
}

/// All five paper workload names, in Table 1 order.
pub const ALL_NAMES: [&str; 5] = ["PageRank", "XSBench", "BFS", "SSSP", "Btree"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_ai() {
        let p = AccessProfile {
            accesses: vec![
                PageAccess { page: 0, random: 10, streamed: 0 },
                PageAccess { page: 1, random: 4, streamed: 6 },
            ],
            flops: 640,
            iops: 640,
        };
        assert_eq!(p.total_accesses(), 20);
        // 1280 ops / (20 * 64 bytes) = 1.0
        assert!((p.arithmetic_intensity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn by_name_constructs_all() {
        for name in ALL_NAMES {
            let w = by_name(name, 1, 4).unwrap();
            assert!(w.rss_pages() > 1000, "{name} rss");
        }
        assert!(by_name("nope", 1, 1).is_none());
    }
}
