//! In-memory B-tree index (mitosis-workload-btree style): point lookups
//! with a zipf-distributed key popularity.
//!
//! Layout: `root | internal nodes | leaf nodes | value heap | pad`.
//! The tree is page-sized-node (4 KiB) with fanout 256: a three-level
//! descent touches root → internal → leaf → value. Key popularity follows
//! a zipf law, so cold leaves/values form a large reclaimable tail — this
//! is why the paper's biggest fast-memory saving (16%, Fig. 7) comes from
//! Btree.

use super::graph::{Layout, PageHisto, Region};
use super::{AccessProfile, Workload, PAGES_PER_PAPER_GB};
use crate::util::rng::{Rng, Zipf};

/// Keys per leaf page (16-byte records: 8 B key + 8 B value pointer).
const LEAF_FANOUT: u64 = 256;
/// Children per internal page.
const INNER_FANOUT: u64 = 256;

pub struct Btree {
    r_root: Region,
    r_inner: Region,
    r_leaves: Region,
    r_values: Region,
    /// Total keys indexed (reported by Table 1-style summaries).
    pub n_keys: u64,
    n_leaves: u64,
    n_inner: u64,
    rss: usize,
    histo: PageHisto,
    zipf: Zipf,
    lookups_per_interval: u32,
    update_fraction: f64,
    intervals_left: u32,
    first_interval: bool,
    rng: Rng,
    threads: u32,
    pub lookups_done: u64,
    pub updates_done: u64,
}

impl Btree {
    /// Paper-scale instance: RSS = 10.8 paper-GB (Table 1).
    pub fn paper_scale(seed: u64, intervals: u32) -> Self {
        let rss_pages = (10.8 * PAGES_PER_PAPER_GB) as usize;
        Self::with_rss(rss_pages, seed, intervals)
    }

    pub fn with_rss(rss_pages: usize, seed: u64, intervals: u32) -> Self {
        // Split RSS: ~55% leaves, ~40% value heap, rest index.
        let n_leaves = (rss_pages as u64 * 55 / 100).max(64);
        let n_keys = n_leaves * LEAF_FANOUT;
        let n_inner = n_leaves.div_ceil(INNER_FANOUT).max(1);
        let value_pages = (rss_pages as u64 * 40 / 100).max(64);
        let mut l = Layout::new();
        let r_root = l.region(1, crate::PAGE_BYTES);
        let r_inner = l.region(n_inner, crate::PAGE_BYTES);
        let r_leaves = l.region(n_leaves, crate::PAGE_BYTES);
        let r_values = l.region(value_pages, crate::PAGE_BYTES);
        l.pad_to(rss_pages);
        let rss = l.total_pages().max(rss_pages);
        Btree {
            r_root,
            r_inner,
            r_leaves,
            r_values,
            n_keys,
            n_leaves,
            n_inner,
            rss,
            histo: PageHisto::new(rss),
            // popularity at *leaf* granularity: recently inserted /
            // trending items cluster in leaves, which is what gives the
            // index its page-level skew (and the paper its 16% saving)
            zipf: Zipf::new(n_leaves as usize, 0.8),
            lookups_per_interval: 40_000,
            update_fraction: 0.05,
            intervals_left: intervals,
            first_interval: true,
            rng: Rng::new(seed ^ 0xb7ee),
            threads: 16,
            lookups_done: 0,
            updates_done: 0,
        }
    }
}

impl Workload for Btree {
    fn name(&self) -> &'static str {
        "Btree"
    }

    fn rss_pages(&self) -> usize {
        self.rss
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            self.first_interval = false;
            for p in 0..self.rss as u32 {
                self.histo.touch(p, 1);
            }
            return Some(AccessProfile {
                accesses: self.histo.drain(),
                flops: 0,
                iops: self.rss as u64 * 16,
            });
        }

        let mut iops: u64 = 0;
        for _ in 0..self.lookups_per_interval {
            self.lookups_done += 1;
            // zipf rank → leaf. Popularity ranks are scattered over leaf
            // ids by a fixed permutation (hot leaves are not physically
            // adjacent), and the key within the leaf is uniform.
            let rank = self.zipf.sample(&mut self.rng) as u64;
            let leaf = rank.wrapping_mul(0x9E37_79B9_7F4A_7C15) % self.n_leaves;
            let key = leaf * LEAF_FANOUT + self.rng.below(LEAF_FANOUT);
            let inner = leaf / INNER_FANOUT;

            self.histo.touch(self.r_root.page_of(0), 1);
            self.histo.touch(self.r_inner.page_of(inner.min(self.n_inner - 1)), 1);
            self.histo.touch(self.r_leaves.page_of(leaf.min(self.n_leaves - 1)), 1);
            // binary search inside two nodes + pointer chase
            iops += 2 * 8 + 4;

            // value heap access: a value page cluster per leaf (values
            // are allocated alongside their keys), so heap heat follows
            // leaf popularity.
            let vpage = (leaf.wrapping_mul(0xD1B5_4A32_D192_ED03)
                .wrapping_add(key & 7))
                % self.r_values.n_elems;
            self.histo.touch(self.r_values.page_of(vpage), 1);
            iops += 4;

            if self.rng.chance(self.update_fraction) {
                self.updates_done += 1;
                // in-place value update: one more touch of the same pages
                self.histo.touch(self.r_leaves.page_of(leaf.min(self.n_leaves - 1)), 1);
                self.histo.touch(self.r_values.page_of(vpage), 1);
                iops += 6;
            }
        }

        Some(AccessProfile { accesses: self.histo.drain(), flops: 0, iops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_matches_paper_scale() {
        let w = Btree::paper_scale(1, 5);
        let want = (10.8 * PAGES_PER_PAPER_GB) as usize;
        assert!(w.rss_pages() >= want && w.rss_pages() < want + 200);
    }

    #[test]
    fn access_skew_leaves_a_cold_tail() {
        let mut w = Btree::with_rss(4000, 3, 15);
        let mut total = vec![0u64; w.rss_pages()];
        let _ = w.next_interval();
        while let Some(p) = w.next_interval() {
            for a in p.accesses {
                total[a.page as usize] += a.total() as u64;
            }
        }
        // the coldest 20% of pages should carry almost none of the heat —
        // that's the reclaimable tail Tuna exploits (16% saving, Fig. 7)
        let mut sorted = total.clone();
        sorted.sort_unstable();
        let cold_fifth: u64 = sorted[..w.rss_pages() / 5].iter().sum();
        let all: u64 = sorted.iter().sum();
        assert!(
            (cold_fifth as f64) < 0.05 * all as f64,
            "cold 20% holds {cold_fifth}/{all}"
        );
        // ... while the root page is the hottest thing in the run
        let root_heat = total[w.r_root.first_page as usize];
        let median = {
            let mut s: Vec<u64> = total.iter().copied().filter(|&c| c > 0).collect();
            s.sort_unstable();
            s[s.len() / 2]
        };
        // (the per-interval cache cap flattens the root's true heat)
        assert!(root_heat > 3 * median.max(1), "root={root_heat} median={median}");
    }

    #[test]
    fn updates_happen_at_the_configured_fraction() {
        let mut w = Btree::with_rss(3000, 9, 10);
        while w.next_interval().is_some() {}
        let frac = w.updates_done as f64 / w.lookups_done as f64;
        assert!((frac - 0.05).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let sig = |seed| {
            let mut w = Btree::with_rss(2000, seed, 5);
            std::iter::from_fn(move || w.next_interval())
                .map(|p| p.total_accesses())
                .collect::<Vec<_>>()
        };
        assert_eq!(sig(4), sig(4));
        assert_ne!(sig(4), sig(5));
    }
}
