//! Synthetic graph substrate + page-accounting helpers shared by the
//! workloads.
//!
//! Graphs are power-law (zipf-distributed in-degree, the RMAT/GAP regime)
//! in CSR layout with degree-descending vertex ids — the common GAP
//! preprocessing — so hub vertices cluster at low ids and page-level access
//! skew is organic. Graph construction is deterministic per seed and
//! cached process-wide (benches re-run the same workload dozens of times).

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::{Mutex, OnceLock};

use crate::util::rng::{Rng, Zipf};
use crate::{PageId, PAGE_BYTES};

/// A directed graph in CSR form with optional edge weights.
#[derive(Debug)]
pub struct Csr {
    pub n: u32,
    /// offsets[v]..offsets[v+1] indexes `dst` (and `weight`).
    pub offsets: Vec<u64>,
    pub dst: Vec<u32>,
    /// Edge weights (present iff built with `weighted = true`).
    pub weight: Vec<u32>,
}

impl Csr {
    pub fn m(&self) -> usize {
        self.dst.len()
    }

    pub fn degree(&self, v: u32) -> u64 {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    pub fn neighbors(&self, v: u32) -> &[u32] {
        let a = self.offsets[v as usize] as usize;
        let b = self.offsets[v as usize + 1] as usize;
        &self.dst[a..b]
    }

    pub fn weights_of(&self, v: u32) -> &[u32] {
        let a = self.offsets[v as usize] as usize;
        let b = self.offsets[v as usize + 1] as usize;
        &self.weight[a..b]
    }
}

/// Parameters for the synthetic power-law generator.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct GraphSpec {
    pub n: u32,
    pub m: u64,
    pub weighted: bool,
    pub seed: u64,
    /// zipf skew ×1000 (stored as integer so the spec is hashable).
    pub skew_milli: u32,
}

impl GraphSpec {
    pub fn new(n: u32, m: u64, weighted: bool, seed: u64) -> Self {
        GraphSpec { n, m, weighted, seed, skew_milli: 750 }
    }
}

/// Build (or fetch from the process-wide cache) the graph for `spec`.
pub fn build_graph(spec: &GraphSpec) -> Arc<Csr> {
    static CACHE: OnceLock<Mutex<HashMap<GraphSpec, Arc<Csr>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(g) = cache.lock().unwrap().get(spec) {
        return g.clone();
    }
    let g = Arc::new(generate(spec));
    cache.lock().unwrap().insert(spec.clone(), g.clone());
    g
}

fn generate(spec: &GraphSpec) -> Csr {
    let n = spec.n;
    let m = spec.m as usize;
    let mut rng = Rng::new(spec.seed ^ 0x6772_6170_685f_6765);
    let zipf = Zipf::new(n as usize, spec.skew_milli as f64 / 1000.0);

    // Degree-descending labeling: zipf rank IS the vertex id, so hubs sit
    // at low ids (GAP's -o degree ordering).
    // Sources: mildly skewed too (edges originate from active regions).
    let src_zipf = Zipf::new(n as usize, 0.3);
    let mut srcs: Vec<u32> = Vec::with_capacity(m);
    let mut dsts: Vec<u32> = Vec::with_capacity(m);
    for _ in 0..m {
        let s = src_zipf.sample(&mut rng) as u32;
        let mut d = zipf.sample(&mut rng) as u32;
        if d == s {
            d = (d + 1) % n;
        }
        srcs.push(s);
        dsts.push(d);
    }

    // Counting-sort into CSR.
    let mut offsets = vec![0u64; n as usize + 1];
    for &s in &srcs {
        offsets[s as usize + 1] += 1;
    }
    for i in 0..n as usize {
        offsets[i + 1] += offsets[i];
    }
    let mut cursor = offsets.clone();
    let mut dst = vec![0u32; m];
    let mut weight = if spec.weighted { vec![0u32; m] } else { Vec::new() };
    for i in 0..m {
        let s = srcs[i] as usize;
        let at = cursor[s] as usize;
        dst[at] = dsts[i];
        if spec.weighted {
            weight[at] = 1 + (rng.next_u64() % 255) as u32;
        }
        cursor[s] += 1;
    }

    Csr { n, offsets, dst, weight }
}

// ---------------------------------------------------------------------------
// Page accounting helpers
// ---------------------------------------------------------------------------

/// A contiguous region of a workload's virtual address space holding an
/// array of fixed-size elements. Maps element indices → page ids.
#[derive(Clone, Copy, Debug)]
pub struct Region {
    pub first_page: PageId,
    pub elem_bytes: u64,
    pub n_elems: u64,
}

impl Region {
    /// Pages this region spans.
    pub fn pages(&self) -> u64 {
        (self.n_elems * self.elem_bytes).div_ceil(PAGE_BYTES).max(1)
    }

    #[inline]
    pub fn page_of(&self, idx: u64) -> PageId {
        debug_assert!(idx < self.n_elems, "idx {idx} >= {}", self.n_elems);
        self.first_page + ((idx * self.elem_bytes) / PAGE_BYTES) as PageId
    }

    /// Page range `[first, last]` of elements `[a, b)`.
    pub fn page_span(&self, a: u64, b: u64) -> (PageId, PageId) {
        debug_assert!(a < b && b <= self.n_elems);
        (self.page_of(a), self.page_of(b - 1))
    }
}

/// Lay out regions back-to-back (page aligned) and report the total.
pub struct Layout {
    next_page: PageId,
}

impl Layout {
    pub fn new() -> Self {
        Layout { next_page: 0 }
    }

    pub fn region(&mut self, n_elems: u64, elem_bytes: u64) -> Region {
        let r = Region { first_page: self.next_page, elem_bytes, n_elems };
        self.next_page += r.pages() as PageId;
        r
    }

    pub fn total_pages(&self) -> usize {
        self.next_page as usize
    }

    /// Pad the address space to exactly `pages` (e.g. to hit a Table 1
    /// RSS figure); returns the padding region (buffers, allocator slack).
    pub fn pad_to(&mut self, pages: usize) -> Option<Region> {
        let have = self.total_pages();
        if have >= pages {
            return None;
        }
        let extra = (pages - have) as u64;
        Some(self.region(extra, PAGE_BYTES))
    }
}

impl Default for Layout {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-interval page-access histogram builder.
///
/// Counts are capped per page per interval (default 64 = lines/page):
/// accesses beyond the cap hit the CPU cache hierarchy, which neither the
/// paper's NUMA-hint-fault-based profiling nor the memory system observes.
///
/// Two access kinds are tracked (see [`super::PageAccess`]): `touch`
/// records latency-exposed random accesses; `touch_span` records
/// prefetch-covered sequential streaming.
pub struct PageHisto {
    rand: Vec<u32>,
    seq: Vec<u32>,
    touched: Vec<PageId>,
    cap: u32,
}

/// Default per-page per-interval access cap (cache-filter model; 64 is
/// the number of cache lines in a 4 KiB page).
pub const DEFAULT_PAGE_CAP: u32 = 64;

impl PageHisto {
    pub fn new(rss_pages: usize) -> Self {
        Self::with_cap(rss_pages, DEFAULT_PAGE_CAP)
    }

    pub fn with_cap(rss_pages: usize, cap: u32) -> Self {
        PageHisto {
            rand: vec![0; rss_pages],
            seq: vec![0; rss_pages],
            touched: Vec::new(),
            cap,
        }
    }

    #[inline]
    fn note(&mut self, page: PageId) {
        if self.rand[page as usize] == 0 && self.seq[page as usize] == 0 {
            self.touched.push(page);
        }
    }

    /// Record `n` random (latency-exposed) accesses to a page.
    #[inline]
    pub fn touch(&mut self, page: PageId, n: u32) {
        self.note(page);
        let c = &mut self.rand[page as usize];
        *c = (*c + n).min(self.cap);
    }

    /// Touch every page overlapped by elements `[a, b)` of `region` as a
    /// sequential stream, crediting each page with the lines it holds
    /// (subject to the per-page cap).
    pub fn touch_span(&mut self, region: &Region, a: u64, b: u64) {
        if a >= b {
            return;
        }
        let (p0, p1) = region.page_span(a, b);
        let per_page = if p0 == p1 {
            ((b - a) as u32).max(1)
        } else {
            (PAGE_BYTES / region.elem_bytes).max(1) as u32
        };
        for p in p0..=p1 {
            self.note(p);
            let c = &mut self.seq[p as usize];
            *c = (*c + per_page).min(self.cap);
        }
    }

    /// Drain into a sorted histogram and reset.
    pub fn drain(&mut self) -> Vec<super::PageAccess> {
        self.touched.sort_unstable();
        let mut out = Vec::with_capacity(self.touched.len());
        for &p in &self.touched {
            out.push(super::PageAccess {
                page: p,
                random: self.rand[p as usize],
                streamed: self.seq[p as usize],
            });
            self.rand[p as usize] = 0;
            self.seq[p as usize] = 0;
        }
        self.touched.clear();
        out
    }

    pub fn touched_pages(&self) -> usize {
        self.touched.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_cached() {
        let spec = GraphSpec::new(1000, 8000, false, 7);
        let a = build_graph(&spec);
        let b = build_graph(&spec);
        assert!(Arc::ptr_eq(&a, &b), "cache must return the same graph");
        assert_eq!(a.n, 1000);
        assert_eq!(a.m(), 8000);
        assert_eq!(*a.offsets.last().unwrap(), 8000);
    }

    #[test]
    fn degree_distribution_is_skewed_toward_low_ids() {
        let spec = GraphSpec::new(2000, 40_000, false, 3);
        let g = build_graph(&spec);
        // in-degree of the top-32 ids should dwarf a middle slice
        let mut indeg = vec![0u64; g.n as usize];
        for &d in &g.dst {
            indeg[d as usize] += 1;
        }
        let head: u64 = indeg[..32].iter().sum();
        let mid: u64 = indeg[1000..1032].iter().sum();
        assert!(head > 10 * mid.max(1), "head={head} mid={mid}");
    }

    #[test]
    fn weighted_graphs_have_weights_in_range() {
        let spec = GraphSpec::new(500, 4000, true, 5);
        let g = build_graph(&spec);
        assert_eq!(g.weight.len(), g.m());
        assert!(g.weight.iter().all(|&w| (1..=255).contains(&w)));
    }

    #[test]
    fn csr_edges_belong_to_their_vertex() {
        let spec = GraphSpec::new(300, 3000, false, 9);
        let g = build_graph(&spec);
        let mut total = 0u64;
        for v in 0..g.n {
            total += g.degree(v);
            for &u in g.neighbors(v) {
                assert!(u < g.n);
            }
        }
        assert_eq!(total, 3000);
    }

    #[test]
    fn layout_packs_regions_contiguously() {
        let mut l = Layout::new();
        let a = l.region(1024, 8); // 8 KiB = 2 pages
        let b = l.region(1, 1); // 1 page
        assert_eq!(a.first_page, 0);
        assert_eq!(a.pages(), 2);
        assert_eq!(b.first_page, 2);
        assert_eq!(l.total_pages(), 3);
        let pad = l.pad_to(10).unwrap();
        assert_eq!(pad.pages(), 7);
        assert_eq!(l.total_pages(), 10);
        assert!(l.pad_to(5).is_none());
    }

    #[test]
    fn region_page_mapping() {
        let r = Region { first_page: 10, elem_bytes: 8, n_elems: 1024 };
        assert_eq!(r.page_of(0), 10);
        assert_eq!(r.page_of(511), 10);
        assert_eq!(r.page_of(512), 11);
        assert_eq!(r.page_span(0, 1024), (10, 11));
    }

    #[test]
    fn histo_caps_and_drains_sorted() {
        let mut h = PageHisto::with_cap(10, 8);
        h.touch(5, 3);
        h.touch(2, 100); // capped at 8
        h.touch(5, 2);
        let v = h.drain();
        let pa = |page, random| super::super::PageAccess { page, random, streamed: 0 };
        assert_eq!(v, vec![pa(2, 8), pa(5, 5)]);
        // reset works
        assert!(h.drain().is_empty());
        h.touch(1, 1);
        assert_eq!(h.drain(), vec![pa(1, 1)]);
    }

    #[test]
    fn touch_span_credits_bulk_pages() {
        let mut h = PageHisto::new(100);
        let r = Region { first_page: 0, elem_bytes: 4, n_elems: 4096 };
        // elements 0..2048 = 8 KiB ⇒ pages 0 and 1, 1024 elems each
        h.touch_span(&r, 0, 2048);
        let v = h.drain();
        assert_eq!(v.len(), 2);
        // per-page credit (1024) is capped at DEFAULT_PAGE_CAP
        assert!(v.iter().all(|a| a.streamed == DEFAULT_PAGE_CAP && a.random == 0));
    }
}
