//! XSBench-style Monte Carlo neutron-transport macroscopic cross-section
//! lookups (the HPC workload of Table 1).
//!
//! Layout: `unionized energy grid | per-nuclide XS tables | pad`.
//! Each lookup draws a random energy, binary-searches the unionized grid
//! (the search path concentrates on "landmark" pages — a natural small hot
//! set), then gathers the bracketing grid points of every nuclide in the
//! sampled material and interpolates five reaction channels (FLOP-heavy).
//! The interpolation work gives XSBench the highest arithmetic intensity
//! of the five workloads, which is why the paper measures only a 1.8%
//! overall loss for it — the compute roofline hides most of the extra
//! slow-memory latency (§3's second interaction).

use super::graph::{Layout, PageHisto, Region};
use super::{AccessProfile, Workload, PAGES_PER_PAPER_GB};
use crate::util::rng::Rng;

/// XSBench's large benchmark uses 355 nuclides in the fuel material; we
/// keep the default ("small") set of 68 with GAP-scale tables.
const N_NUCLIDES: u64 = 68;

/// Materials: (number of nuclides consulted, sampling weight) — fuel
/// consults 34 nuclides and dominates lookups, the rest are light
/// (cladding, moderator, ...), mirroring XSBench's material table.
const MATERIALS: [(u64, f64); 5] = [(34, 0.50), (12, 0.20), (5, 0.15), (4, 0.10), (2, 0.05)];

/// FLOPs per nuclide lookup: 5 reaction channels × (interpolation factor
/// + 2 FMAs) + tally accumulation.
const FLOPS_PER_NUCLIDE: u64 = 150;

pub struct XsBench {
    r_grid: Region,
    r_tables: Region,
    n_grid: u64,
    pts_per_nuclide: u64,
    rss: usize,
    histo: PageHisto,
    lookups_per_interval: u32,
    intervals_left: u32,
    first_interval: bool,
    rng: Rng,
    threads: u32,
    lookups_done: u64,
}

impl XsBench {
    /// Paper-scale instance: RSS = 16.4 paper-GB (Table 1).
    pub fn paper_scale(seed: u64, intervals: u32) -> Self {
        let rss_pages = (16.4 * PAGES_PER_PAPER_GB) as usize;
        Self::with_rss(rss_pages, seed, intervals)
    }

    pub fn with_rss(rss_pages: usize, seed: u64, intervals: u32) -> Self {
        let total_bytes = rss_pages as u64 * crate::PAGE_BYTES;
        // grid ≈ 25% of RSS (energy f64 + index u64 = 16 B/point),
        // tables = rest (6 channels × f64 = 48 B/point per nuclide).
        let n_grid = (total_bytes / 4 / 16).max(1024);
        let table_bytes = total_bytes - n_grid * 16;
        let pts_per_nuclide = (table_bytes / (N_NUCLIDES * 48)).max(256);
        let mut l = Layout::new();
        let r_grid = l.region(n_grid, 16);
        let r_tables = l.region(N_NUCLIDES * pts_per_nuclide, 48);
        l.pad_to(rss_pages);
        let rss = l.total_pages().max(rss_pages);
        XsBench {
            r_grid,
            r_tables,
            n_grid,
            pts_per_nuclide,
            rss,
            histo: PageHisto::new(rss),
            lookups_per_interval: 3000,
            intervals_left: intervals,
            first_interval: true,
            rng: Rng::new(seed ^ 0x5be),
            threads: 16,
            lookups_done: 0,
        }
    }

    /// Pages touched by a binary search for `target` over the grid: the
    /// actual probe sequence of the bisection (landmark pages near the
    /// midpoints are revisited by every lookup → organic hot set).
    fn binary_search_pages(&mut self, target: u64) {
        let mut lo = 0u64;
        let mut hi = self.n_grid;
        while lo < hi {
            let mid = (lo + hi) / 2;
            self.histo.touch(self.r_grid.page_of(mid), 1);
            if mid < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
    }
}

impl Workload for XsBench {
    fn name(&self) -> &'static str {
        "XSBench"
    }

    fn rss_pages(&self) -> usize {
        self.rss
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            self.first_interval = false;
            for p in 0..self.rss as u32 {
                self.histo.touch(p, 1);
            }
            return Some(AccessProfile {
                accesses: self.histo.drain(),
                flops: self.rss as u64,
                iops: self.rss as u64 * 16,
            });
        }

        let mut flops: u64 = 0;
        let mut iops: u64 = 0;
        for _ in 0..self.lookups_per_interval {
            self.lookups_done += 1;
            // sample energy → grid position
            let grid_idx = self.rng.below(self.n_grid);
            self.binary_search_pages(grid_idx);
            iops += 64; // bisection compares + address math

            // sample material
            let mut pick = self.rng.f64();
            let mut n_nuc = MATERIALS[0].0;
            for &(n, w) in &MATERIALS {
                if pick < w {
                    n_nuc = n;
                    break;
                }
                pick -= w;
            }

            // gather bracketing points for each consulted nuclide
            let rel = grid_idx as f64 / self.n_grid as f64;
            for nuc in 0..n_nuc {
                // nuclide table offset: same relative energy position
                let base = nuc * self.pts_per_nuclide;
                let p = base + ((rel * (self.pts_per_nuclide - 2) as f64) as u64);
                self.histo.touch(self.r_tables.page_of(p), 1);
                self.histo.touch(self.r_tables.page_of(p + 1), 1);
                flops += FLOPS_PER_NUCLIDE;
                iops += 8;
            }
        }

        Some(AccessProfile { accesses: self.histo.drain(), flops, iops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_matches_paper_scale() {
        let w = XsBench::paper_scale(1, 5);
        let want = (16.4 * PAGES_PER_PAPER_GB) as usize;
        assert!(w.rss_pages() >= want && w.rss_pages() < want + 200);
    }

    #[test]
    fn has_high_arithmetic_intensity() {
        let mut w = XsBench::with_rss(4000, 2, 4);
        let _ = w.next_interval();
        let p = w.next_interval().unwrap();
        let ai = p.arithmetic_intensity();
        assert!(ai > 1.0, "AI={ai} should be compute-leaning");
    }

    #[test]
    fn search_landmarks_are_hot_but_tables_are_uniform() {
        let mut w = XsBench::with_rss(4000, 2, 12);
        let mut total = vec![0u64; w.rss_pages()];
        let _ = w.next_interval();
        while let Some(p) = w.next_interval() {
            for a in p.accesses {
                total[a.page as usize] += a.total() as u64;
            }
        }
        // the hottest grid (landmark) page must be at least as hot as the
        // hottest table page — the bisection path is the hot set
        let grid_last = (w.r_grid.first_page as u64 + w.r_grid.pages() - 1) as usize;
        let grid_max = *total[..=grid_last].iter().max().unwrap();
        let table_max = *total[grid_last + 1..].iter().max().unwrap();
        assert!(grid_max >= table_max, "grid_max={grid_max} table_max={table_max}");
    }

    #[test]
    fn deterministic_per_seed() {
        let sig = |seed| {
            let mut w = XsBench::with_rss(3000, seed, 5);
            std::iter::from_fn(move || w.next_interval())
                .map(|p| (p.total_accesses(), p.flops))
                .collect::<Vec<_>>()
        };
        assert_eq!(sig(8), sig(8));
        assert_ne!(sig(8), sig(9));
    }
}
