//! PageRank (GAP style): push-based rank propagation, full passes over the
//! edge list every iteration.
//!
//! Layout: `offsets | edges | rank | new_rank | pad`.
//! PageRank streams the whole graph each iteration — its working set is
//! close to its RSS with only the hub `new_rank` pages as a hot set, so
//! it is the most bandwidth-bound and least compressible of the five
//! workloads (paper overall loss 4.6%, right at the τ=5% target).

use std::sync::Arc;

use super::graph::{build_graph, Csr, GraphSpec, Layout, PageHisto, Region};
use super::{AccessProfile, Workload, PAGES_PER_PAPER_GB};

pub struct PageRank {
    g: Arc<Csr>,
    r_offsets: Region,
    r_edges: Region,
    r_rank: Region,
    r_new_rank: Region,
    rss: usize,
    histo: PageHisto,
    rank: Vec<f32>,
    new_rank: Vec<f32>,
    cursor: u32,
    iterations_done: u32,
    edge_budget: u64,
    intervals_left: u32,
    first_interval: bool,
    threads: u32,
}

impl PageRank {
    /// Paper-scale instance: RSS = 15.8 paper-GB (Table 1).
    pub fn paper_scale(seed: u64, intervals: u32) -> Self {
        let rss_pages = (15.8 * PAGES_PER_PAPER_GB) as usize;
        Self::with_rss(rss_pages, seed, intervals)
    }

    pub fn with_rss(rss_pages: usize, seed: u64, intervals: u32) -> Self {
        // bytes/vertex (94% of RSS), avg degree 12: offsets 8 + edges 48
        // + rank 4 + new_rank 4 = 64
        let n = ((rss_pages as u64 * crate::PAGE_BYTES * 94 / 100) / 64).max(4096) as u32;
        let m = n as u64 * 12;
        Self::new(GraphSpec::new(n, m, false, seed), rss_pages, intervals)
    }

    pub fn new(spec: GraphSpec, rss_pages: usize, intervals: u32) -> Self {
        let g = build_graph(&spec);
        let n = g.n as u64;
        let mut l = Layout::new();
        // init-only I/O staging buffer FIRST (see bfs.rs module doc)
        let _r_input = l.region((rss_pages as u64 * 6 / 100).max(16), crate::PAGE_BYTES);
        let r_offsets = l.region(n + 1, 8);
        let r_edges = l.region(g.m() as u64, 4);
        let r_rank = l.region(n, 4);
        let r_new_rank = l.region(n, 4);
        l.pad_to(rss_pages);
        let rss = l.total_pages().max(rss_pages);
        let init = 1.0 / g.n as f32;
        PageRank {
            g: g.clone(),
            r_offsets,
            r_edges,
            r_rank,
            r_new_rank,
            rss,
            histo: PageHisto::new(rss),
            rank: vec![init; n as usize],
            new_rank: vec![0.0; n as usize],
            cursor: 0,
            iterations_done: 0,
            edge_budget: 200_000,
            intervals_left: intervals,
            first_interval: true,
            threads: 16,
        }
    }

    pub fn iterations_done(&self) -> u32 {
        self.iterations_done
    }
}

impl Workload for PageRank {
    fn name(&self) -> &'static str {
        "PageRank"
    }

    fn rss_pages(&self) -> usize {
        self.rss
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            self.first_interval = false;
            for p in 0..self.rss as u32 {
                self.histo.touch(p, 1);
            }
            return Some(AccessProfile {
                accesses: self.histo.drain(),
                flops: self.rss as u64,
                iops: self.rss as u64 * 16,
            });
        }

        const DAMP: f32 = 0.85;
        let n = self.g.n;
        let mut edges_done: u64 = 0;
        let mut flops: u64 = 0;
        let mut iops: u64 = 0;
        while edges_done < self.edge_budget {
            if self.cursor >= n {
                // iteration finished: swap rank arrays (streaming pass)
                let base = (1.0 - DAMP) / n as f32;
                for v in 0..n as usize {
                    self.rank[v] = base + DAMP * self.new_rank[v];
                    self.new_rank[v] = 0.0;
                }
                self.histo.touch_span(&self.r_rank, 0, n as u64);
                self.histo.touch_span(&self.r_new_rank, 0, n as u64);
                flops += 2 * n as u64;
                self.cursor = 0;
                self.iterations_done += 1;
                continue;
            }
            let v = self.cursor;
            self.cursor += 1;
            self.histo.touch(self.r_offsets.page_of(v as u64), 1);
            self.histo.touch(self.r_rank.page_of(v as u64), 1);
            let (a, b) = (self.g.offsets[v as usize], self.g.offsets[v as usize + 1]);
            let deg = b - a;
            if deg == 0 {
                edges_done += 1;
                continue;
            }
            self.histo.touch_span(&self.r_edges, a, b);
            let contrib = self.rank[v as usize] / deg as f32;
            flops += 1;
            for &u in self.g.neighbors(v) {
                self.new_rank[u as usize] += contrib;
                self.histo.touch(self.r_new_rank.page_of(u as u64), 1);
                flops += 1;
                iops += 2;
            }
            edges_done += deg;
        }

        Some(AccessProfile { accesses: self.histo.drain(), flops, iops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_matches_paper_scale() {
        let w = PageRank::paper_scale(1, 5);
        let want = (15.8 * PAGES_PER_PAPER_GB) as usize;
        assert!(w.rss_pages() >= want && w.rss_pages() < want + 200);
    }

    #[test]
    fn ranks_stay_normalized_across_iterations() {
        let mut w = PageRank::with_rss(2000, 3, 60);
        while w.next_interval().is_some() {}
        assert!(w.iterations_done() >= 1, "must finish ≥1 iteration");
        let sum: f32 = w.rank.iter().sum();
        // push-PR without dangling-mass redistribution leaks a little
        // mass at dangling vertices; allow a loose band.
        assert!(sum > 0.2 && sum < 1.5, "sum={sum}");
    }

    #[test]
    fn touches_most_of_rss_every_iteration() {
        // PR streams edges: over one full iteration nearly every edge
        // page must appear.
        let mut w = PageRank::with_rss(2000, 5, 200);
        let mut seen = vec![false; w.rss_pages()];
        let _ = w.next_interval(); // allocation epoch
        while w.iterations_done() < 1 {
            match w.next_interval() {
                Some(p) => {
                    for a in p.accesses {
                        seen[a.page as usize] = true;
                    }
                }
                None => break,
            }
        }
        // live structures = offsets..new_rank (the input buffer is first)
        let lo = w.r_offsets.first_page as usize;
        let hi = (w.r_new_rank.first_page as u64 + w.r_new_rank.pages()) as usize;
        let covered = seen[lo..hi].iter().filter(|&&s| s).count();
        assert!(
            covered as f64 > 0.8 * (hi - lo) as f64,
            "covered {covered}/{}",
            hi - lo
        );
    }
}
