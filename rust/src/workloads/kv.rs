//! The KV workload family: registry glue binding the trace subsystem's
//! generators ([`crate::trace::gen`]) and replay engine
//! ([`crate::trace::replay`]) into the workload registry, so
//! `kv-zipfian` & co. are ordinary workload names everywhere —
//! `tuna run|tune|sweep`, the tuner service, benches.
//!
//! The family (one entry per generator spec in
//! [`crate::trace::gen::FAMILY`]):
//!
//! | name         | distribution                  | mix                   |
//! |--------------|-------------------------------|-----------------------|
//! | `kv-uniform` | uniform                       | 95% read / 5% update  |
//! | `kv-zipfian` | zipf(0.99) over value pages   | 95% read / 5% update  |
//! | `kv-latest`  | recency-zipf behind the head  | 85% read / 15% insert |
//! | `kv-hotspot` | 90% of ops on 10% of keys     | 95% read / 5% update  |
//! | `kv-scan`    | zipf(0.8) scan starts         | 95% scan / 5% insert  |
//! | `kv-drift`   | zipf hot set migrating in time| 95% read / 5% update  |
//!
//! Recorded traces replay through the same engine via the pseudo-name
//! `trace:FILE` (see [`crate::workloads::by_name`]).

use anyhow::Result;

use super::Workload;
use crate::trace::gen::{spec_by_name, FAMILY};
use crate::trace::replay::KvReplay;

/// Construct a live-generated KV workload by family name.
pub fn build(name: &str, seed: u64, intervals: u32) -> Result<Box<dyn Workload>> {
    let spec = spec_by_name(name)
        .ok_or_else(|| anyhow::anyhow!("`{name}` is not a KV workload family"))?;
    Ok(Box::new(KvReplay::live(&spec, seed, intervals)))
}

/// Family names, re-exported for the registry.
pub use crate::trace::gen::FAMILY as KV_NAMES;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_member_builds_and_runs() {
        for name in FAMILY {
            let mut w = build(name, 1, 3).unwrap();
            assert_eq!(w.name(), name);
            assert!(w.rss_pages() > 1_000, "{name} rss");
            assert!(w.threads() > 0);
            let mut n = 0;
            while w.next_interval().is_some() {
                n += 1;
            }
            assert_eq!(n, 3, "{name} honors the interval bound");
        }
        assert!(build("kv-bogus", 1, 1).is_err());
    }
}
