//! # Tuna — tuning fast memory size based on modeling of page migration
//!
//! Reproduction of *"Tuna: Tuning Fast Memory Size based on Modeling of Page
//! Migration for Tiered Memory"* (CS.PF 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: a tiered-memory simulator
//!   substrate ([`sim`]), a TPP page-management reimplementation ([`tpp`]),
//!   the five paper workloads ([`workloads`]), the §3.2 micro-benchmark
//!   generator ([`microbench`]), the performance database ([`perfdb`]),
//!   runtime telemetry ([`telemetry`]) and the online tuner ([`tuner`]).
//! * **L2/L1 (python, build-time only)** — the perf-DB nearest-neighbour
//!   query as a JAX pipeline around a Pallas blocked-distance kernel,
//!   AOT-lowered to HLO text and executed from [`runtime`] via PJRT.
//!
//! The public entry points most users want:
//!
//! * [`coordinator::run_tpp`] / [`coordinator::run_tuna`] — run a workload
//!   under TPP (± Tuna) and get a full trace: per-interval times,
//!   migrations, fast-memory size.
//! * [`coordinator::sweep::run_sweep`] — the batched multi-run executor:
//!   a workload × fraction × seed × policy grid across threads, with
//!   memoized fast-memory-only baselines.
//! * [`perfdb::builder::build_database`] — offline micro-benchmark sweep
//!   (parallel over configuration × fraction cells, byte-deterministic).
//! * [`service::TunerService`] — the tuner as a service: one shared
//!   database backend, many concurrent telemetry sessions over a bounded
//!   channel (`tuna serve` ingests sessions from outside the process).
//! * [`tuner::Tuner`] — the in-loop online controller (watermark
//!   programming), the reference the service path is proven against.
//! * [`runtime::PerfDbExec`] — the AOT query executable (PJRT CPU).
//! * [`artifact::ArtifactStore`] — the persistent artifact store: sharded
//!   perf-DB segments (fully resident or served lazily from a bounded
//!   resident set via [`artifact::shard::LazyShardedPerfDb`]), durable
//!   sweep cell tables, KV trace artifacts and the cross-process baseline
//!   cache (`tuna store ls|diff`).
//! * [`trace`] — the trace-driven KV workload subsystem: YCSB-style op
//!   generators, the durable `TUNATRC1` trace format and the replay
//!   engine behind the `kv-*` workload family and `tuna trace` verbs.
//! * [`admission`] — migration admission control: a per-interval
//!   bandwidth budget, a payoff predicate (predicted fast-tier hits vs
//!   copy cost) and a demotion cool-down filter, exposed as the
//!   `tpp-gated` policy and the `[admission]` config table / sweep axis.
//! * [`obs::Recorder`] — the observability layer: per-thread-sharded
//!   metrics with Prometheus exposition, a bounded structured event
//!   journal persisted as durable `TUNAOBS1` artifacts, and the
//!   `tuna obs dump|summary|diff|outcomes` introspection verbs —
//!   zero-cost when disabled and proven bit-identical when enabled.
//! * [`outcome::OutcomeTracker`] — decision-outcome accountability:
//!   per-session predicted-vs-realized loss tracking, a signed-EWMA
//!   drift detector with hysteresis, and the `[retune]` / `--retune
//!   on|observe|off` re-tuning actuator behind `tuna obs outcomes`
//!   and `tuna whatif`.
//!
//! See `DESIGN.md` for the hardware-substitution rationale and the
//! experiment index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod admission;
pub mod artifact;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod microbench;
pub mod obs;
pub mod outcome;
pub mod perfdb;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod telemetry;
pub mod tpp;
pub mod trace;
pub mod tuner;
pub mod util;
pub mod workloads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// A virtual page number inside one workload's address space
/// (`0..rss_pages`). Pages are 4 KiB, as on the paper's testbed.
pub type PageId = u32;

/// Bytes per page (4 KiB, the Linux base page size used by TPP).
pub const PAGE_BYTES: u64 = 4096;

/// Bytes touched per page access (one cache line).
pub const LINE_BYTES: u64 = 64;
