//! Machine model: the paper's evaluation platform in numbers.
//!
//! Defaults approximate one socket of the paper's testbed: Intel Xeon Gold
//! 6252 (24 cores @ 2.1 GHz) with local DRAM as the fast tier and Intel
//! Optane DC Persistent Memory as the slow tier. Sources for the Optane
//! figures: the usual single-socket App-Direct measurements (~300–350 ns
//! load latency, ~30 GB/s read, ~12 GB/s write for 6 interleaved DIMMs).

use crate::PAGE_BYTES;

/// Static hardware parameters of the simulated two-tier machine.
#[derive(Clone, Debug)]
pub struct MachineModel {
    /// Physical cores available to the workload.
    pub cores: u32,
    /// Core frequency in GHz.
    pub freq_ghz: f64,
    /// Sustainable instructions per cycle per core (compute roofline).
    pub ipc: f64,
    /// Fast-tier (DRAM) load-to-use latency in ns.
    pub fast_lat_ns: f64,
    /// Slow-tier (Optane) load-to-use latency in ns.
    pub slow_lat_ns: f64,
    /// Fast-tier bandwidth, bytes/ns (== GB/s). Reads and writes share it.
    pub fast_bw: f64,
    /// Slow-tier read bandwidth, bytes/ns.
    pub slow_read_bw: f64,
    /// Slow-tier write bandwidth, bytes/ns (Optane writes are much slower).
    pub slow_write_bw: f64,
    /// Maximum outstanding memory requests per core (MLP ceiling).
    pub mlp_per_core: f64,
    /// Per-page serialization ceiling: how many concurrent outstanding
    /// accesses a single page can sustain (row-buffer / bank conflicts).
    pub mlp_per_page: f64,
    /// CPU-side cost of one page promotion (NUMA hint fault + unmap +
    /// remap + copy issue), ns. TPP promotes in the faulting task's
    /// context, so this is *blocking* time for the application.
    pub promote_cpu_ns: f64,
    /// CPU-side cost charged for a failed promotion attempt (fault taken,
    /// no free space found, page left in place), ns.
    pub promote_fail_cpu_ns: f64,
    /// CPU-side cost of one kswapd demotion, ns. kswapd runs in the
    /// background, so this consumes bandwidth/CPU but does not block the
    /// application.
    pub demote_cpu_ns: f64,
    /// Blocking cost of one *direct-reclaim* demotion, ns (the application
    /// thread performs the reclaim itself — the case Tuna's watermark
    /// programming is designed to avoid, §4).
    pub direct_reclaim_ns: f64,
    /// Pages kswapd can demote per profiling interval. One interval is
    /// 0.1 paper-seconds and the address-space scale is 1024× (DESIGN.md
    /// §6), so the default 32 corresponds to ~330 K pages/s of reclaim
    /// throughput on the real testbed. When promotions need free pages
    /// faster than this, promotion failures pile up (the Fig. 1 cliff).
    pub kswapd_pages_per_interval: u64,
    /// NUMA-hint-fault scan budget: promotion *attempts* per profiling
    /// interval (AutoNUMA scans a bounded number of MBs per scan period,
    /// so only this many hot slow pages can even take the hint fault).
    /// Bounds how fast failures can pile up under pressure.
    pub promote_scan_pages_per_interval: u64,
}

impl Default for MachineModel {
    fn default() -> Self {
        MachineModel {
            cores: 24,
            freq_ghz: 2.1,
            ipc: 2.0,
            fast_lat_ns: 100.0,
            slow_lat_ns: 350.0,
            fast_bw: 100.0,
            slow_read_bw: 30.0,
            slow_write_bw: 12.0,
            mlp_per_core: 10.0,
            mlp_per_page: 4.0,
            promote_cpu_ns: 2_500.0,
            promote_fail_cpu_ns: 400.0,
            demote_cpu_ns: 2_000.0,
            direct_reclaim_ns: 6_000.0,
            kswapd_pages_per_interval: 32,
            promote_scan_pages_per_interval: 384,
        }
    }
}

impl MachineModel {
    /// Peak ops/ns for `threads` active threads (≤ cores).
    pub fn peak_ops_per_ns(&self, threads: u32) -> f64 {
        let t = threads.min(self.cores) as f64;
        t * self.freq_ghz * self.ipc
    }

    /// Total MLP available to `threads` threads.
    pub fn total_mlp(&self, threads: u32) -> f64 {
        threads.min(self.cores) as f64 * self.mlp_per_core
    }

    /// Time for the *slow tier* to move `pages` promoted pages (reads) in
    /// ns of tier busy time.
    pub fn promote_slow_bytes(&self, pages: u64) -> f64 {
        (pages * PAGE_BYTES) as f64
    }

    /// Bandwidth-balanced sanity check used by tests and config loading.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.cores > 0, "cores must be > 0");
        anyhow::ensure!(self.freq_ghz > 0.0 && self.ipc > 0.0, "compute peak must be positive");
        anyhow::ensure!(
            self.fast_lat_ns > 0.0 && self.slow_lat_ns >= self.fast_lat_ns,
            "slow tier must not be faster than fast tier (lat)"
        );
        anyhow::ensure!(
            self.fast_bw > 0.0
                && self.slow_read_bw > 0.0
                && self.slow_write_bw > 0.0
                && self.fast_bw >= self.slow_read_bw,
            "slow tier must not have more bandwidth than fast tier"
        );
        anyhow::ensure!(self.mlp_per_core >= 1.0 && self.mlp_per_page >= 1.0, "mlp >= 1");
        anyhow::ensure!(self.kswapd_pages_per_interval > 0, "kswapd throughput must be positive");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        MachineModel::default().validate().unwrap();
    }

    #[test]
    fn peaks_scale_with_threads_but_cap_at_cores() {
        let m = MachineModel::default();
        assert!(m.peak_ops_per_ns(2) < m.peak_ops_per_ns(4));
        assert_eq!(m.peak_ops_per_ns(24), m.peak_ops_per_ns(48));
        assert_eq!(m.total_mlp(24), m.total_mlp(200));
    }

    #[test]
    fn invalid_models_rejected() {
        // slow tier faster than fast tier
        let m = MachineModel { slow_lat_ns: 10.0, ..MachineModel::default() };
        assert!(m.validate().is_err());
        let m2 = MachineModel { cores: 0, ..MachineModel::default() };
        assert!(m2.validate().is_err());
        // more slow-read bandwidth than the fast tier
        let m3 = MachineModel { slow_read_bw: 1000.0, ..MachineModel::default() };
        assert!(m3.validate().is_err());
    }
}
