//! The simulation engine: drives a workload against a page-management
//! policy over the tiered memory, interval by interval, producing a full
//! run trace (wall times, migrations, occupancy) for reports and benches.

use super::interval::{IntervalInputs, IntervalModel, IntervalOutcome};
use super::mem::{MigrationCounters, MigrationModel, TieredMemory};
use crate::tpp::{PagePolicy, Watermarks};
use crate::workloads::Workload;

/// Per-interval trace record.
#[derive(Clone, Copy, Debug)]
pub struct RunTrace {
    pub interval: u32,
    /// Simulated clock at the *end* of this interval, ns.
    pub clock_ns: f64,
    pub wall_ns: f64,
    pub acc_fast: u64,
    pub acc_slow: u64,
    /// "Sampled" page accesses per tier: per-page counts saturated at the
    /// policy's `hot_thr`. This is what TPP-style NUMA-hint-fault
    /// profiling actually observes (a page's PTE faults at most a few
    /// times per scan window), and it is the `pacc` the paper's Eq. (1)–(4)
    /// are written in: the micro-benchmark's resident sets reproduce
    /// exactly these counts.
    pub sacc_fast: u64,
    pub sacc_slow: u64,
    pub flops: u64,
    pub iops: u64,
    pub promoted: u64,
    pub promote_failed: u64,
    pub demoted_kswapd: u64,
    pub demoted_direct: u64,
    /// Accesses served by pages holding a valid shadow copy (always 0 in
    /// exclusive mode, like the three counters below).
    pub shadow_hits: u64,
    /// Free-unmap demotions of clean shadowed pages (not in
    /// `demoted_kswapd`/`demoted_direct`).
    pub shadow_free_demotions: u64,
    /// Transactional copies aborted by write traffic.
    pub txn_aborts: u64,
    /// Aborted copies restarted because the page was still hot.
    pub txn_retried_copies: u64,
    /// Promotion candidates the admission gate accepted (always 0 when no
    /// gate is installed, like the three rejection counters below).
    pub admission_accepted: u64,
    /// Candidates rejected because the interval's migration budget was
    /// exhausted.
    pub admission_rejected_budget: u64,
    /// Candidates rejected because predicted fast-tier hits over the
    /// residency horizon did not exceed the copy cost.
    pub admission_rejected_payoff: u64,
    /// Candidates rejected because the page was demoted too recently
    /// (ping-pong suppression).
    pub admission_rejected_cooldown: u64,
    pub fast_used: u64,
    pub fast_free: u64,
    /// Usable fast-memory size implied by the watermarks at this interval.
    pub usable_fm: u64,
    pub outcome: IntervalOutcome,
}

impl RunTrace {
    /// Emit this interval's telemetry as a plain, engine-independent
    /// sample — what the engine publishes to a tuner-service session
    /// instead of mutating a tuner in-loop.
    pub fn sample(&self) -> crate::telemetry::TelemetrySample {
        crate::telemetry::TelemetrySample::from_trace(self)
    }
}

/// Result of a complete run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub workload: &'static str,
    pub policy: &'static str,
    pub fast_capacity: u64,
    pub total_ns: f64,
    pub trace: Vec<RunTrace>,
}

impl RunResult {
    /// Total page accesses (fast + slow) across the run.
    pub fn total_accesses(&self) -> u64 {
        self.trace.iter().map(|t| t.acc_fast + t.acc_slow).sum()
    }

    /// Sum of the per-interval migration counters over the whole trace.
    ///
    /// Exhaustive by construction: the accumulator is destructured without
    /// `..`, so adding a `MigrationCounters` field without deciding how it
    /// aggregates here is a compile error — new counters can't silently
    /// drop out of run totals.
    pub fn total_migration_counters(&self) -> MigrationCounters {
        let mut total = MigrationCounters::default();
        let MigrationCounters {
            promoted,
            promote_failed,
            demoted_kswapd,
            demoted_direct,
            // Allocation counters are not carried in the trace (they are
            // nonzero only during the allocation epoch, which every
            // consumer excludes); they stay 0 in the totals.
            alloc_fast: _,
            alloc_slow: _,
            shadow_hits,
            shadow_free_demotions,
            txn_aborts,
            txn_retried_copies,
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
        } = &mut total;
        for t in &self.trace {
            *promoted += t.promoted;
            *promote_failed += t.promote_failed;
            *demoted_kswapd += t.demoted_kswapd;
            *demoted_direct += t.demoted_direct;
            *shadow_hits += t.shadow_hits;
            *shadow_free_demotions += t.shadow_free_demotions;
            *txn_aborts += t.txn_aborts;
            *txn_retried_copies += t.txn_retried_copies;
            *admission_accepted += t.admission_accepted;
            *admission_rejected_budget += t.admission_rejected_budget;
            *admission_rejected_payoff += t.admission_rejected_payoff;
            *admission_rejected_cooldown += t.admission_rejected_cooldown;
        }
        total
    }

    pub fn total_promoted(&self) -> u64 {
        self.trace.iter().map(|t| t.promoted).sum()
    }

    pub fn total_promote_failed(&self) -> u64 {
        self.trace.iter().map(|t| t.promote_failed).sum()
    }

    /// All demotions, copying (kswapd + direct) and free shadow unmaps.
    /// Exclusive runs have no shadow unmaps, so their value is unchanged.
    pub fn total_demoted(&self) -> u64 {
        self.trace
            .iter()
            .map(|t| t.demoted_kswapd + t.demoted_direct + t.shadow_free_demotions)
            .sum()
    }

    pub fn total_migrations(&self) -> u64 {
        self.total_promoted() + self.total_demoted()
    }

    pub fn total_shadow_hits(&self) -> u64 {
        self.trace.iter().map(|t| t.shadow_hits).sum()
    }

    pub fn total_shadow_free_demotions(&self) -> u64 {
        self.trace.iter().map(|t| t.shadow_free_demotions).sum()
    }

    pub fn total_txn_aborts(&self) -> u64 {
        self.trace.iter().map(|t| t.txn_aborts).sum()
    }

    pub fn total_txn_retried_copies(&self) -> u64 {
        self.trace.iter().map(|t| t.txn_retried_copies).sum()
    }

    pub fn total_admission_accepted(&self) -> u64 {
        self.trace.iter().map(|t| t.admission_accepted).sum()
    }

    pub fn total_admission_rejected_budget(&self) -> u64 {
        self.trace.iter().map(|t| t.admission_rejected_budget).sum()
    }

    pub fn total_admission_rejected_payoff(&self) -> u64 {
        self.trace.iter().map(|t| t.admission_rejected_payoff).sum()
    }

    pub fn total_admission_rejected_cooldown(&self) -> u64 {
        self.trace.iter().map(|t| t.admission_rejected_cooldown).sum()
    }

    /// All admission verdicts (accept + the three rejection classes);
    /// 0 exactly when no gate was installed.
    pub fn total_admission_verdicts(&self) -> u64 {
        self.total_admission_accepted()
            + self.total_admission_rejected_budget()
            + self.total_admission_rejected_payoff()
            + self.total_admission_rejected_cooldown()
    }

    /// Relative slowdown vs a baseline run of the same work:
    /// `(T - T_base) / T_base` (the paper's `pd`). A degenerate baseline
    /// (zero, negative or NaN total time — e.g. an empty run) yields 0.0
    /// rather than `NaN`/`inf`, so downstream aggregation stays finite.
    /// A non-finite *run* time still propagates — a broken measurement
    /// must not read as "no loss".
    pub fn perf_loss_vs(&self, baseline: &RunResult) -> f64 {
        if !(baseline.total_ns > 0.0) || !baseline.total_ns.is_finite() {
            return 0.0;
        }
        (self.total_ns - baseline.total_ns) / baseline.total_ns
    }
}

/// The engine. Holds the interval model; memory/policy/workload are per-run.
pub struct Engine {
    pub model: IntervalModel,
    /// Migration-semantics override for runs. `None` (the default) defers
    /// to the policy's [`crate::tpp::PagePolicy::migration_model`], which
    /// is [`MigrationModel::Exclusive`] for every policy except
    /// `tpp-nomad` — so existing callers are bit-identical to the
    /// pre-refactor engine.
    pub migration: Option<MigrationModel>,
    /// Observability handle (disabled by default). The recorder only
    /// *reads* trace records the run already produced — it never feeds
    /// back into memory, policy or model state, so enabled runs are
    /// bit-identical to disabled ones.
    pub obs: crate::obs::Recorder,
}

impl Engine {
    pub fn new(model: IntervalModel) -> Self {
        Engine { model, migration: None, obs: crate::obs::Recorder::default() }
    }

    /// Builder-style migration override (see [`Self::migration`]).
    pub fn with_migration(mut self, migration: MigrationModel) -> Self {
        self.migration = Some(migration);
        self
    }

    /// Builder-style observability handle (see [`Self::obs`]).
    pub fn with_obs(mut self, obs: crate::obs::Recorder) -> Self {
        self.obs = obs;
        self
    }

    /// Fast-tier capacity (pages) whose *usable* size under default
    /// watermarks is `fraction` of `rss_pages`. Fig. 1-style sweeps use
    /// this so "100%" really fits the whole RSS in fast memory.
    ///
    /// The fixed-point iteration converges geometrically (the watermark
    /// reserve is ~1% of capacity); the trailing correction loop absorbs
    /// integer-division boundary effects so `usable ≥ target` holds for
    /// every rss/fraction pair (property-tested in the integration suite).
    pub fn fm_capacity(rss_pages: usize, fraction: f64) -> u64 {
        let target = (rss_pages as f64 * fraction).ceil() as u64;
        let mut cap = target.max(16);
        for _ in 0..4 {
            cap = target + Watermarks::default_for_capacity(cap).low;
        }
        while cap - Watermarks::default_for_capacity(cap).low < target {
            cap += 1;
        }
        cap
    }

    /// Run `workload` to completion under `policy`. The `observer` is
    /// invoked after every interval with the fresh trace record and may
    /// return new watermarks to program. This is how tuning attaches
    /// without the engine knowing about it: a service-managed run
    /// publishes `|t| session.publish(t.sample())`, and the watermarks a
    /// decision sends back through the session mailbox are programmed at
    /// the same interval boundary the in-loop tuner used to program them.
    pub fn run(
        &self,
        workload: &mut dyn Workload,
        policy: &mut dyn PagePolicy,
        fast_capacity: u64,
        mut observer: impl FnMut(&RunTrace) -> Option<Watermarks>,
    ) -> RunResult {
        let migration = self.migration.unwrap_or_else(|| policy.migration_model());
        // Every non-exclusive hook below is guarded by this flag, so the
        // exclusive path executes exactly the pre-refactor arithmetic
        // (the bit-identity invariant the artifact store depends on).
        let nonexclusive = !migration.is_exclusive();
        let mut mem =
            TieredMemory::with_migration(workload.rss_pages(), fast_capacity, migration);
        let mut trace: Vec<RunTrace> = Vec::new();
        let mut clock_ns = 0.0f64;
        let mut interval: u32 = 0;

        while let Some(profile) = workload.next_interval() {
            interval += 1;
            // Histogram invariant: a page appears at most once per
            // interval (per-page caps, sampled-access saturation and the
            // KV replayer's random/streamed merge all depend on it).
            debug_assert!(
                profile.duplicate_page().is_none(),
                "workload `{}` emitted page {:?} more than once in interval {interval}",
                workload.name(),
                profile.duplicate_page()
            );
            // --- classify accesses against current placement ---
            let mut inputs = IntervalInputs {
                threads: workload.threads(),
                flops: profile.flops,
                iops: profile.iops,
                ..Default::default()
            };
            let hot_thr = policy.hot_thr().max(1);
            let (mut sacc_fast, mut sacc_slow) = (0u64, 0u64);
            for a in &profile.accesses {
                let (id, count) = (a.page, a.total());
                if !mem.page(id).allocated {
                    mem.allocate(id, interval, policy.alloc_reserve());
                }
                match mem.touch(id, count, interval) {
                    super::mem::Tier::Fast => {
                        inputs.rand_fast += a.random as u64;
                        inputs.seq_fast += a.streamed as u64;
                        sacc_fast += count.min(hot_thr) as u64;
                        inputs.max_page_fast = inputs.max_page_fast.max(a.random);
                    }
                    super::mem::Tier::Slow => {
                        inputs.rand_slow += a.random as u64;
                        inputs.seq_slow += a.streamed as u64;
                        sacc_slow += count.min(hot_thr) as u64;
                        inputs.max_page_slow = inputs.max_page_slow.max(a.random);
                    }
                }
                if nonexclusive {
                    // shadow hits, shadow invalidation, copy aborts
                    mem.note_access(id, a.random, a.streamed, hot_thr);
                }
            }

            // --- policy reacts (promotions, kswapd, direct reclaim) ---
            let kswapd_budget = self.model.machine.kswapd_pages_per_interval;
            policy.run_interval(&mut mem, &profile.accesses, interval, kswapd_budget);
            if nonexclusive {
                mem.advance_transactions();
            }
            // Per-interval accounting invariant (debug builds): tier
            // occupancy, shadow frames and in-flight reservations must
            // reconcile with the page table after every policy step.
            if cfg!(debug_assertions) {
                if let Err(e) = mem.check_invariants() {
                    panic!("interval {interval}: tier accounting invariant violated: {e}");
                }
            }
            inputs.migrations = mem.take_counters();

            // --- time model ---
            let outcome = self.model.evaluate(&inputs);
            clock_ns += outcome.wall_ns;

            let wm = policy.watermarks();
            // Exhaustive over counters: a `MigrationCounters` field that is
            // neither carried into the trace nor explicitly dropped here is
            // a compile error.
            let MigrationCounters {
                promoted,
                promote_failed,
                demoted_kswapd,
                demoted_direct,
                alloc_fast: _,
                alloc_slow: _,
                shadow_hits,
                shadow_free_demotions,
                txn_aborts,
                txn_retried_copies,
                admission_accepted,
                admission_rejected_budget,
                admission_rejected_payoff,
                admission_rejected_cooldown,
            } = inputs.migrations;
            let rec = RunTrace {
                interval,
                clock_ns,
                wall_ns: outcome.wall_ns,
                acc_fast: inputs.acc_fast(),
                acc_slow: inputs.acc_slow(),
                sacc_fast,
                sacc_slow,
                flops: profile.flops,
                iops: profile.iops,
                promoted,
                promote_failed,
                demoted_kswapd,
                demoted_direct,
                shadow_hits,
                shadow_free_demotions,
                txn_aborts,
                txn_retried_copies,
                admission_accepted,
                admission_rejected_budget,
                admission_rejected_payoff,
                admission_rejected_cooldown,
                fast_used: mem.fast_used(),
                fast_free: mem.fast_free(),
                usable_fm: wm.usable(fast_capacity),
                outcome,
            };
            if self.obs.is_enabled() {
                self.observe_interval(
                    workload.name(),
                    policy.name(),
                    fast_capacity,
                    &inputs.migrations,
                    &rec,
                );
            }
            if let Some(new_wm) = observer(&rec) {
                policy.set_watermarks(new_wm);
            }
            trace.push(rec);

            mem.decay_windows();
        }

        debug_assert!(mem.check_invariants().is_ok());
        RunResult {
            workload: {
                // `&'static str` names from the trait
                let n = workload.name();
                n
            },
            policy: policy.name(),
            fast_capacity,
            total_ns: clock_ns,
            trace,
        }
    }

    /// Record one interval boundary: the exhaustive `mem_*` migration
    /// transaction counter families, migration/residency histograms,
    /// and a structured [`crate::obs::EventKind::Interval`] journal
    /// event. Only called when the recorder is enabled.
    fn observe_interval(
        &self,
        workload: &'static str,
        policy: &'static str,
        fast_capacity: u64,
        counters: &MigrationCounters,
        rec: &RunTrace,
    ) {
        use crate::obs::{EventKind, FRACTION_BUCKETS, NS_BUCKETS, PAGES_BUCKETS};
        let demoted = rec.demoted_kswapd + rec.demoted_direct;
        self.obs.count("engine_intervals_total", 1);
        for (family, value) in counters.metric_families() {
            self.obs.count(family, value);
        }
        self.obs
            .observe("engine_interval_model_ns", NS_BUCKETS, rec.wall_ns);
        self.obs
            .observe("engine_promoted_per_interval", PAGES_BUCKETS, rec.promoted as f64);
        self.obs
            .observe("engine_demoted_per_interval", PAGES_BUCKETS, demoted as f64);
        self.obs.observe(
            "engine_fast_used_fraction",
            FRACTION_BUCKETS,
            rec.fast_used as f64 / fast_capacity.max(1) as f64,
        );
        self.obs.record(EventKind::Interval {
            workload: workload.to_string(),
            policy: policy.to_string(),
            interval: rec.interval,
            wall_ns: rec.wall_ns,
            fast_used: rec.fast_used,
            promoted: rec.promoted,
            demoted,
            txn_aborts: rec.txn_aborts,
            shadow_free_demotions: rec.shadow_free_demotions,
            admission_accepted: rec.admission_accepted,
            admission_rejected_budget: rec.admission_rejected_budget,
            admission_rejected_payoff: rec.admission_rejected_payoff,
            admission_rejected_cooldown: rec.admission_rejected_cooldown,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::machine::MachineModel;
    use crate::tpp::{FirstTouch, Tpp};
    use crate::workloads::{AccessProfile, PageAccess, Workload};

    /// Toy workload: a hot set accessed heavily plus a cold sweep.
    struct Toy {
        rss: usize,
        hot: usize,
        left: u32,
        tick: u32,
    }

    impl Workload for Toy {
        fn name(&self) -> &'static str {
            "toy"
        }

        fn rss_pages(&self) -> usize {
            self.rss
        }

        fn threads(&self) -> u32 {
            4
        }

        fn next_interval(&mut self) -> Option<AccessProfile> {
            if self.left == 0 {
                return None;
            }
            self.left -= 1;
            self.tick += 1;
            let mut accesses = Vec::new();
            if self.tick == 1 {
                // allocation epoch: fault in the whole address space
                for p in 0..self.rss {
                    accesses.push(PageAccess { page: p as u32, random: 1, streamed: 0 });
                }
                return Some(AccessProfile { accesses, flops: 0, iops: 1000 });
            }
            for p in 0..self.hot {
                accesses.push(PageAccess { page: p as u32, random: 16, streamed: 0 });
            }
            // cold rotating sweep over the rest
            let cold_start = self.hot + (self.tick as usize * 97) % (self.rss - self.hot);
            for i in 0..64 {
                let p = self.hot + (cold_start + i - self.hot) % (self.rss - self.hot);
                accesses.push(PageAccess { page: p as u32, random: 1, streamed: 0 });
            }
            Some(AccessProfile { accesses, flops: 10_000, iops: 50_000 })
        }
    }

    fn engine() -> Engine {
        Engine::new(IntervalModel::new(MachineModel::default()))
    }

    #[test]
    fn perf_loss_vs_guards_degenerate_baseline() {
        let empty = RunResult {
            workload: "toy",
            policy: "tpp",
            fast_capacity: 0,
            total_ns: 0.0,
            trace: vec![],
        };
        let mut run = empty.clone();
        run.total_ns = 10.0;
        assert_eq!(run.perf_loss_vs(&empty), 0.0, "zero-time baseline must not yield inf");
        assert_eq!(empty.perf_loss_vs(&empty), 0.0, "0/0 must not yield NaN");
        let mut base = empty.clone();
        base.total_ns = 5.0;
        assert_eq!(run.perf_loss_vs(&base), 1.0);
    }

    #[test]
    fn fm_capacity_usable_matches_fraction() {
        for rss in [10_000usize, 50_000] {
            for frac in [1.0, 0.9, 0.5, 0.25] {
                let cap = Engine::fm_capacity(rss, frac);
                let wm = Watermarks::default_for_capacity(cap);
                let usable = wm.usable(cap);
                let target = (rss as f64 * frac).ceil() as u64;
                assert!(
                    usable >= target && usable <= target + 8,
                    "rss={rss} frac={frac} usable={usable} target={target}"
                );
            }
        }
    }

    #[test]
    fn full_fast_memory_run_has_no_slow_accesses() {
        let mut w = Toy { rss: 2_000, hot: 100, left: 10, tick: 0 };
        let cap = Engine::fm_capacity(2_000, 1.0);
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let res = engine().run(&mut w, &mut tpp, cap, |_| None);
        assert_eq!(res.trace.len(), 10);
        let slow: u64 = res.trace.iter().map(|t| t.acc_slow).sum();
        assert_eq!(slow, 0, "everything must fit in fast memory");
    }

    #[test]
    fn tpp_beats_first_touch_under_pressure() {
        // 60% fast memory: first-touch strands the hot set partly in slow
        // (hot pages were allocated first here, so invert: hot set last).
        // Use a toy where the hot set is the LAST allocated pages.
        struct HotLast {
            rss: usize,
            left: u32,
            total: u32,
        }
        impl Workload for HotLast {
            fn name(&self) -> &'static str {
                "hotlast"
            }
            fn rss_pages(&self) -> usize {
                self.rss
            }
            fn threads(&self) -> u32 {
                4
            }
            fn next_interval(&mut self) -> Option<AccessProfile> {
                if self.left == 0 {
                    return None;
                }
                self.left -= 1;
                let mut accesses = Vec::new();
                if self.left + 1 == self.total {
                    // first interval: touch everything once (allocation)
                    for p in 0..self.rss {
                        accesses.push(PageAccess { page: p as u32, random: 1, streamed: 0 });
                    }
                } else {
                    // hot set = last 10% of the address space
                    for p in (self.rss * 9 / 10)..self.rss {
                        accesses.push(PageAccess { page: p as u32, random: 16, streamed: 0 });
                    }
                }
                Some(AccessProfile { accesses, flops: 0, iops: 10_000 })
            }
        }

        let cap = Engine::fm_capacity(4_000, 0.6);
        let mut w1 = HotLast { rss: 4_000, left: 60, total: 60 };
        let mut ft = FirstTouch::new(cap);
        let r_ft = engine().run(&mut w1, &mut ft, cap, |_| None);

        let mut w2 = HotLast { rss: 4_000, left: 60, total: 60 };
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let r_tpp = engine().run(&mut w2, &mut tpp, cap, |_| None);

        assert!(r_tpp.total_promoted() > 0, "TPP must migrate");
        assert_eq!(r_ft.total_migrations(), 0);
        assert!(
            r_tpp.total_ns < r_ft.total_ns,
            "tpp={} ft={}",
            r_tpp.total_ns,
            r_ft.total_ns
        );
    }

    #[test]
    fn observer_can_reprogram_watermarks() {
        let mut w = Toy { rss: 2_000, hot: 100, left: 50, tick: 0 };
        let cap = Engine::fm_capacity(2_000, 1.0);
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let shrink_to = Watermarks::for_target_fm(cap, cap * 6 / 10);
        let mut fired = false;
        let res = engine().run(&mut w, &mut tpp, cap, |t| {
            if t.interval == 2 && !fired {
                fired = true;
                Some(shrink_to)
            } else {
                None
            }
        });
        // After the watermark change kswapd demotes (budget-limited, so it
        // converges gradually) until the new free target is reached.
        let last = res.trace.last().unwrap();
        assert!(
            last.fast_free >= shrink_to.low.min(cap),
            "free={} want>={}",
            last.fast_free,
            shrink_to.low
        );
        assert!(res.total_demoted() > 0);
        // usable_fm in the trace reflects the change
        assert!(res.trace.last().unwrap().usable_fm < res.trace[0].usable_fm);
        // ... and the shrink was gradual (kswapd budget per interval)
        let per_interval_max = res
            .trace
            .iter()
            .map(|t| t.demoted_kswapd)
            .max()
            .unwrap();
        assert!(per_interval_max <= engine().model.machine.kswapd_pages_per_interval);
    }

    /// A workload that violates the "page appears at most once per
    /// interval" histogram invariant must trip the engine's debug
    /// assertion instead of silently double-counting.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "more than once")]
    fn duplicate_pages_in_a_profile_trip_the_debug_assertion() {
        struct Dup;
        impl Workload for Dup {
            fn name(&self) -> &'static str {
                "dup"
            }
            fn rss_pages(&self) -> usize {
                64
            }
            fn threads(&self) -> u32 {
                1
            }
            fn next_interval(&mut self) -> Option<AccessProfile> {
                Some(AccessProfile {
                    accesses: vec![
                        PageAccess { page: 5, random: 1, streamed: 0 },
                        PageAccess { page: 5, random: 2, streamed: 0 },
                    ],
                    flops: 0,
                    iops: 10,
                })
            }
        }
        let cap = Engine::fm_capacity(64, 1.0);
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        engine().run(&mut Dup, &mut tpp, cap, |_| None);
    }

    /// Satellite: a policy that desynchronizes the occupancy accounting
    /// must trip the engine's per-interval invariant assertion.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "tier accounting invariant violated")]
    fn corrupted_tier_accounting_trips_the_per_interval_assertion() {
        struct Corrupting {
            wm: Watermarks,
        }
        impl crate::tpp::PagePolicy for Corrupting {
            fn name(&self) -> &'static str {
                "corrupting"
            }
            fn hot_thr(&self) -> u32 {
                2
            }
            fn watermarks(&self) -> Watermarks {
                self.wm
            }
            fn set_watermarks(&mut self, wm: Watermarks) {
                self.wm = wm;
            }
            fn alloc_reserve(&self) -> u64 {
                0
            }
            fn run_interval(
                &mut self,
                mem: &mut TieredMemory,
                _touched: &[PageAccess],
                _now: u32,
                _kswapd_budget: u64,
            ) {
                mem.corrupt_accounting_for_test();
            }
        }
        let mut w = Toy { rss: 128, hot: 16, left: 3, tick: 0 };
        let cap = Engine::fm_capacity(128, 1.0);
        let mut bad = Corrupting { wm: Watermarks::default_for_capacity(cap) };
        engine().run(&mut w, &mut bad, cap, |_| None);
    }

    #[test]
    fn explicit_exclusive_override_matches_the_default_engine() {
        let run = |e: Engine| {
            let mut w = Toy { rss: 2_000, hot: 400, left: 15, tick: 0 };
            let cap = Engine::fm_capacity(2_000, 0.5);
            let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
            e.run(&mut w, &mut tpp, cap, |_| None)
        };
        let a = run(engine());
        let b = run(engine().with_migration(MigrationModel::Exclusive));
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits());
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.wall_ns.to_bits(), y.wall_ns.to_bits());
            assert_eq!(x.promoted, y.promoted);
            assert_eq!(x.demoted_kswapd, y.demoted_kswapd);
        }
    }

    /// Hot set in the *last* 30% of the address space (allocated after
    /// fast memory filled, so it lands in slow and must be promoted),
    /// with dirtying (random) or clean (streamed) hot traffic.
    struct HotTail {
        rss: usize,
        left: u32,
        total: u32,
        random_hot: bool,
    }

    impl Workload for HotTail {
        fn name(&self) -> &'static str {
            "hottail"
        }
        fn rss_pages(&self) -> usize {
            self.rss
        }
        fn threads(&self) -> u32 {
            4
        }
        fn next_interval(&mut self) -> Option<AccessProfile> {
            if self.left == 0 {
                return None;
            }
            self.left -= 1;
            let mut accesses = Vec::new();
            if self.left + 1 == self.total {
                for p in 0..self.rss {
                    accesses.push(PageAccess { page: p as u32, random: 1, streamed: 0 });
                }
            } else {
                for p in (self.rss * 7 / 10)..self.rss {
                    let (random, streamed) = if self.random_hot { (8, 0) } else { (0, 8) };
                    accesses.push(PageAccess { page: p as u32, random, streamed });
                }
            }
            Some(AccessProfile { accesses, flops: 0, iops: 10_000 })
        }
    }

    #[test]
    fn exclusive_runs_report_zero_shadow_and_txn_counters() {
        let mut w = HotTail { rss: 4_000, left: 30, total: 30, random_hot: true };
        let cap = Engine::fm_capacity(4_000, 0.5);
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let res = engine().run(&mut w, &mut tpp, cap, |_| None);
        assert!(res.total_promoted() > 0, "pressure must migrate");
        assert_eq!(res.total_shadow_hits(), 0);
        assert_eq!(res.total_shadow_free_demotions(), 0);
        assert_eq!(res.total_txn_aborts(), 0);
        assert_eq!(res.total_txn_retried_copies(), 0);
        assert_eq!(res.total_admission_verdicts(), 0, "ungated tpp never consults a gate");
    }

    /// Read-mostly hot set under pressure: transactional promotions
    /// complete with shadows, and kswapd's shadow-preferring victim order
    /// turns demotions into free unmaps.
    #[test]
    fn non_exclusive_clean_hot_set_yields_free_demotions() {
        let cap = Engine::fm_capacity(4_000, 0.5);
        let mut w = HotTail { rss: 4_000, left: 60, total: 60, random_hot: false };
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let res = engine()
            .with_migration(MigrationModel::non_exclusive_default())
            .run(&mut w, &mut tpp, cap, |_| None);
        assert!(res.total_promoted() > 0, "transactional copies must complete");
        assert!(res.total_shadow_free_demotions() > 0, "pressure must find shadowed victims");
        assert!(res.total_shadow_hits() > 0);
        assert_eq!(res.total_txn_aborts(), 0, "clean traffic never aborts");
    }

    /// Write-heavy hot set: in-flight copies are raced by the next
    /// interval's writes, so the transactional path aborts and retries.
    #[test]
    fn non_exclusive_write_heavy_hot_set_aborts_copies() {
        let cap = Engine::fm_capacity(4_000, 0.5);
        let mut w = HotTail { rss: 4_000, left: 30, total: 30, random_hot: true };
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let res = engine()
            .with_migration(MigrationModel::non_exclusive_default())
            .run(&mut w, &mut tpp, cap, |_| None);
        assert!(res.total_txn_aborts() > 0, "random writes must race copies");
        assert!(res.total_txn_retried_copies() > 0, "hot pages retry the copy");
    }

    #[test]
    fn obs_recording_does_not_perturb_and_counts_intervals() {
        let run = |e: Engine| {
            let mut w = Toy { rss: 2_000, hot: 400, left: 10, tick: 0 };
            let cap = Engine::fm_capacity(2_000, 0.5);
            let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
            e.run(&mut w, &mut tpp, cap, |_| None)
        };
        let a = run(engine());
        let rec = crate::obs::Recorder::enabled(8);
        let b = run(engine().with_obs(rec.clone()));
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits(), "obs must not perturb");
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.wall_ns.to_bits(), y.wall_ns.to_bits());
            assert_eq!(x.promoted, y.promoted);
            assert_eq!(x.fast_used, y.fast_used);
        }
        let snap = rec.snapshot();
        assert_eq!(snap.counter("engine_intervals_total"), 10);
        assert!(snap.counter("mem_alloc_fast_total") > 0, "allocation epoch must count");
        assert!(snap.hists.contains_key("engine_fast_used_fraction"));
        assert!(snap.hists.contains_key("engine_promoted_per_interval"));
        // a 10-interval run overflows the 8-slot ring: oldest dropped
        assert!(rec.journal().dropped >= 2);
    }

    #[test]
    fn smaller_fast_memory_is_slower() {
        let run_at = |frac: f64| {
            let mut w = Toy { rss: 2_000, hot: 400, left: 15, tick: 0 };
            let cap = Engine::fm_capacity(2_000, frac);
            let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
            engine().run(&mut w, &mut tpp, cap, |_| None).total_ns
        };
        let t100 = run_at(1.0);
        let t50 = run_at(0.5);
        let t15 = run_at(0.15);
        assert!(t50 > t100, "t50={t50} t100={t100}");
        assert!(t15 > t50, "t15={t15} t50={t50}");
    }
}
