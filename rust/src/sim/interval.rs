//! Interval wall-time model (DESIGN.md §7).
//!
//! Converts one profiling interval's traffic into nanoseconds with a
//! roofline-style composition:
//!
//! ```text
//! t_comp   = flops/peak + iops/peak                      (AI knob)
//! t_lat_t  = max(acc_t·lat_t/MLP, max_page_t·lat_t/mlp_page)   per tier
//! t_bw_f   = (acc_f·64B + (pm_pr + pm_de)·4K) / BW_fast
//! t_bw_s   = (acc_s·64B + pm_pr·4K) / BW_s_read + pm_de·4K / BW_s_write
//! T        = max(t_comp, t_lat_f + t_lat_s, t_bw_f, t_bw_s) + t_block
//! t_block  = promote faults + failed faults + direct reclaim   (blocking)
//! ```
//!
//! The per-page serialization term (`max_page_t·lat_t/mlp_page`) is what
//! separates real applications (concentrated accesses) from the §3.2
//! micro-benchmark (even spread): the micro-benchmark models *best-case*
//! memory-level parallelism, exactly the "Limitation" the paper calls out,
//! and the Table 2 error trend falls out of this asymmetry.

use super::machine::MachineModel;
use super::mem::MigrationCounters;
use crate::{LINE_BYTES, PAGE_BYTES};

/// Aggregated traffic of one interval, produced by the engine while it
/// classifies the workload's accesses against the page table.
///
/// Random accesses are latency-exposed; streamed accesses are sequential
/// scans the prefetchers cover — they only consume bandwidth (the reason
/// Optane-resident CSR edge streaming is survivable while Optane-resident
/// pointer chasing is not).
#[derive(Clone, Copy, Debug, Default)]
pub struct IntervalInputs {
    /// Random page accesses served by the fast / slow tier.
    pub rand_fast: u64,
    pub rand_slow: u64,
    /// Streamed (sequential) accesses served by the fast / slow tier.
    pub seq_fast: u64,
    pub seq_slow: u64,
    /// Largest single-page *random* count in each tier this interval
    /// (per-page serialization input).
    pub max_page_fast: u32,
    pub max_page_slow: u32,
    /// Floating-point ops executed this interval.
    pub flops: u64,
    /// Integer ops executed this interval.
    pub iops: u64,
    /// Worker threads driving the accesses.
    pub threads: u32,
    /// Migration activity (from [`super::mem::TieredMemory::take_counters`]).
    pub migrations: MigrationCounters,
}

impl IntervalInputs {
    pub fn acc_fast(&self) -> u64 {
        self.rand_fast + self.seq_fast
    }

    pub fn acc_slow(&self) -> u64 {
        self.rand_slow + self.seq_slow
    }
}

/// Wall time and its breakdown for one interval.
#[derive(Clone, Copy, Debug, Default)]
pub struct IntervalOutcome {
    pub wall_ns: f64,
    pub t_comp_ns: f64,
    pub t_lat_ns: f64,
    pub t_bw_fast_ns: f64,
    pub t_bw_slow_ns: f64,
    pub t_block_ns: f64,
    /// Which roofline term bound the interval (for reports/debugging).
    pub bound: Bound,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Bound {
    #[default]
    Compute,
    Latency,
    FastBw,
    SlowBw,
}

/// The interval time model. `serialization` can be disabled for the
/// ablation bench (`benches/ablations.rs`) that shows Table 2's error
/// trend disappears without it.
#[derive(Clone, Debug)]
pub struct IntervalModel {
    pub machine: MachineModel,
    /// Model per-page serialization (on for real runs; the micro-benchmark
    /// sidesteps it by construction because its accesses are evenly
    /// spread — max_page counts stay tiny).
    pub serialization: bool,
}

impl IntervalModel {
    pub fn new(machine: MachineModel) -> Self {
        IntervalModel { machine, serialization: true }
    }

    pub fn evaluate(&self, x: &IntervalInputs) -> IntervalOutcome {
        let m = &self.machine;
        let threads = x.threads.max(1);

        // --- compute roofline ---
        let peak = m.peak_ops_per_ns(threads);
        let t_comp = (x.flops + x.iops) as f64 / peak;

        // --- latency term (per tier, additive phases) ---
        // Only *random* accesses are latency-exposed; streamed traffic is
        // prefetch-covered and shows up in the bandwidth terms only.
        let mlp = m.total_mlp(threads);
        let lat_f_pipe = x.rand_fast as f64 * m.fast_lat_ns / mlp;
        let lat_s_pipe = x.rand_slow as f64 * m.slow_lat_ns / mlp;
        let (lat_f_ser, lat_s_ser) = if self.serialization {
            (
                x.max_page_fast as f64 * m.fast_lat_ns / m.mlp_per_page,
                x.max_page_slow as f64 * m.slow_lat_ns / m.mlp_per_page,
            )
        } else {
            (0.0, 0.0)
        };
        let t_lat = lat_f_pipe.max(lat_f_ser) + lat_s_pipe.max(lat_s_ser);

        // --- bandwidth terms ---
        let mig = &x.migrations;
        let pm_pr = mig.promoted;
        let pm_de = mig.demoted_total();
        // Fast tier sees: app lines + promoted pages written + demoted
        // read. Aborted transactional copies (non-exclusive mode) wasted a
        // partial page write into the reserved fast frame; free shadow
        // demotions deliberately appear nowhere — they move no bytes.
        // The abort terms are integer byte additions, so they are exactly
        // zero (bit-identical arithmetic) for exclusive runs.
        let fast_bytes =
            x.acc_fast() * LINE_BYTES + (pm_pr + pm_de) * PAGE_BYTES + mig.txn_aborts * PAGE_BYTES;
        let t_bw_fast = fast_bytes as f64 / m.fast_bw;
        // Slow tier: app lines (loads) + promotion reads at read bw
        // (aborted copies read their source pages too), demotion writes at
        // (much lower) write bw.
        let slow_read_bytes =
            x.acc_slow() * LINE_BYTES + pm_pr * PAGE_BYTES + mig.txn_aborts * PAGE_BYTES;
        let slow_write_bytes = pm_de * PAGE_BYTES;
        let t_bw_slow = slow_read_bytes as f64 / m.slow_read_bw
            + slow_write_bytes as f64 / m.slow_write_bw;

        // --- blocking time (spread across threads) ---
        // TPP promotes in the faulting task's context ⇒ blocking. Failed
        // promotions still take the fault. Direct reclaim blocks too.
        // Aborted transactional copies are charged like failed promotions
        // (fault taken, no page landed); the term is appended *last* so an
        // exclusive run adds a trailing +0.0 — bit-identical for the
        // finite non-negative sums this expression produces.
        let t_block = (pm_pr as f64 * m.promote_cpu_ns
            + mig.promote_failed as f64 * m.promote_fail_cpu_ns
            + mig.demoted_direct as f64 * m.direct_reclaim_ns
            + mig.txn_aborts as f64 * m.promote_fail_cpu_ns)
            / threads as f64;

        let (mut bound, mut roof) = (Bound::Compute, t_comp);
        if t_lat > roof {
            bound = Bound::Latency;
            roof = t_lat;
        }
        if t_bw_fast > roof {
            bound = Bound::FastBw;
            roof = t_bw_fast;
        }
        if t_bw_slow > roof {
            bound = Bound::SlowBw;
            roof = t_bw_slow;
        }

        IntervalOutcome {
            wall_ns: roof + t_block,
            t_comp_ns: t_comp,
            t_lat_ns: t_lat,
            t_bw_fast_ns: t_bw_fast,
            t_bw_slow_ns: t_bw_slow,
            t_block_ns: t_block,
            bound,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_inputs() -> IntervalInputs {
        IntervalInputs {
            rand_fast: 1_000_000,
            max_page_fast: 10,
            threads: 8,
            ..Default::default()
        }
    }

    fn model() -> IntervalModel {
        IntervalModel::new(MachineModel::default())
    }

    #[test]
    fn slow_accesses_cost_more_than_fast() {
        let m = model();
        let fast = m.evaluate(&base_inputs());
        let mut slow_in = base_inputs();
        slow_in.rand_fast = 0;
        slow_in.rand_slow = 1_000_000;
        let slow = m.evaluate(&slow_in);
        assert!(
            slow.wall_ns > 2.0 * fast.wall_ns,
            "slow={} fast={}",
            slow.wall_ns,
            fast.wall_ns
        );
    }

    #[test]
    fn streamed_slow_traffic_is_much_cheaper_than_random() {
        let m = model();
        let mut random = base_inputs();
        random.rand_fast = 0;
        random.rand_slow = 1_000_000;
        let mut streamed = base_inputs();
        streamed.rand_fast = 0;
        streamed.seq_slow = 1_000_000;
        let tr = m.evaluate(&random);
        let ts = m.evaluate(&streamed);
        assert!(
            ts.wall_ns < 0.6 * tr.wall_ns,
            "streamed={} random={}",
            ts.wall_ns,
            tr.wall_ns
        );
        // but streaming still pays the slow tier's bandwidth
        let mut fast_stream = base_inputs();
        fast_stream.rand_fast = 0;
        fast_stream.seq_fast = 1_000_000;
        let tf = m.evaluate(&fast_stream);
        assert!(ts.wall_ns > 2.0 * tf.wall_ns, "slow bw must bind");
    }

    #[test]
    fn high_ai_hides_memory_latency() {
        // With enormous compute the tier placement stops mattering.
        let m = model();
        let mut a = base_inputs();
        a.flops = 10_000_000_000;
        let mut b = a;
        b.rand_fast = 0;
        b.rand_slow = 1_000_000;
        let ta = m.evaluate(&a);
        let tb = m.evaluate(&b);
        assert_eq!(ta.bound, Bound::Compute);
        assert_eq!(tb.bound, Bound::Compute);
        let rel = (tb.wall_ns - ta.wall_ns) / ta.wall_ns;
        assert!(rel < 0.01, "rel={rel}");
    }

    #[test]
    fn migration_traffic_competes_for_slow_bandwidth() {
        let m = model();
        let mut x = base_inputs();
        x.rand_slow = 2_000_000;
        x.rand_fast = 0;
        let no_mig = m.evaluate(&x);
        x.migrations.promoted = 20_000;
        x.migrations.demoted_kswapd = 20_000;
        let with_mig = m.evaluate(&x);
        assert!(with_mig.wall_ns > no_mig.wall_ns * 1.3);
        assert_eq!(with_mig.bound, Bound::SlowBw);
    }

    #[test]
    fn serialization_term_penalizes_concentration() {
        let mut m = model();
        let mut x = base_inputs();
        x.rand_slow = 100_000;
        x.max_page_slow = 50_000; // half the slow accesses hit one page
        let with = m.evaluate(&x);
        m.serialization = false;
        let without = m.evaluate(&x);
        assert!(with.wall_ns > without.wall_ns, "serialization must cost");
    }

    #[test]
    fn blocking_costs_add_on_top_of_roofline() {
        let m = model();
        let mut x = base_inputs();
        // failed promotions cost fault time but move no bytes, so the
        // roofline term is untouched and the cost is purely additive
        x.migrations.promote_failed = 10_000;
        let out = m.evaluate(&x);
        assert!(out.t_block_ns > 0.0);
        let base = m.evaluate(&base_inputs());
        assert!((out.wall_ns - out.t_block_ns - base.wall_ns).abs() < 1e-6);
        // direct reclaim blocks too and also moves pages (bw term grows)
        let mut y = base_inputs();
        y.migrations.demoted_direct = 5_000;
        let out2 = m.evaluate(&y);
        assert!(out2.t_block_ns > 0.0);
        assert!(out2.wall_ns > base.wall_ns + out2.t_block_ns - 1e-6);
    }

    #[test]
    fn aborted_copies_cost_bandwidth_and_blocking_but_free_demotions_are_free() {
        let m = model();
        let base = m.evaluate(&base_inputs());
        let mut x = base_inputs();
        x.migrations.txn_aborts = 10_000;
        let out = m.evaluate(&x);
        assert!(out.t_block_ns > base.t_block_ns, "aborts must block like failed faults");
        assert!(out.t_bw_fast_ns > base.t_bw_fast_ns, "wasted copy writes hit fast bw");
        assert!(out.t_bw_slow_ns > base.t_bw_slow_ns, "wasted copy reads hit slow bw");
        assert!(out.wall_ns > base.wall_ns);
        // free shadow demotions, shadow hits, retry bookkeeping and the
        // admission verdict counters move no bytes and block nothing:
        // the outcome is bit-identical. (Admission changes *which*
        // migrations happen; its counters must never re-cost them.)
        let mut y = base_inputs();
        y.migrations.shadow_free_demotions = 1_000_000;
        y.migrations.shadow_hits = 123;
        y.migrations.txn_retried_copies = 55;
        y.migrations.admission_accepted = 7_777;
        y.migrations.admission_rejected_budget = 1_000_000;
        y.migrations.admission_rejected_payoff = 42;
        y.migrations.admission_rejected_cooldown = 9_001;
        let free = m.evaluate(&y);
        assert_eq!(free.wall_ns.to_bits(), base.wall_ns.to_bits());
        assert_eq!(free.t_block_ns.to_bits(), base.t_block_ns.to_bits());
    }

    #[test]
    fn more_threads_go_faster_until_cores() {
        let m = model();
        let mut x = base_inputs();
        x.iops = 1_000_000_000;
        let t4 = {
            x.threads = 4;
            m.evaluate(&x).wall_ns
        };
        let t16 = {
            x.threads = 16;
            m.evaluate(&x).wall_ns
        };
        assert!(t16 < t4);
    }
}
