//! Tiered-memory simulator substrate.
//!
//! The paper's testbed (Xeon Gold 6252 + DRAM fast tier + Optane DC PM slow
//! tier, Linux + TPP) is not available, so the whole platform is simulated
//! (DESIGN.md §2). The simulator is a *discrete-interval* model: a workload
//! presents, for each profiling interval, the multiset of page accesses it
//! performs plus its op counts; the page-management policy reacts
//! (promotions / demotions / reclaim); and [`interval::IntervalModel`]
//! converts the interval's traffic into wall time with a roofline-style
//! `max(compute, latency, per-tier bandwidth)` model that makes the paper's
//! phenomena first-class:
//!
//! * page migration competes with the application for memory bandwidth
//!   (§3 bullet 1),
//! * high arithmetic intensity hides memory performance (§3 bullet 2),
//! * serialized accesses to few pages cap memory-level parallelism (§3.2
//!   "Limitation" — the micro-benchmark's best-case-MLP bias).

pub mod engine;
pub mod interval;
pub mod machine;
pub mod mem;

pub use engine::{Engine, RunResult, RunTrace};
pub use interval::{IntervalInputs, IntervalModel, IntervalOutcome};
pub use machine::MachineModel;
pub use mem::{MigrationCounters, MigrationModel, PageState, TieredMemory, Tier};
