//! Tiered physical memory: page table, per-tier occupancy, migrations.
//!
//! Pages are identified by the workload's virtual page number ([`crate::PageId`]);
//! each page carries its current tier, a decayed access counter (the
//! "profiling window" frequency TPP uses for promotion decisions) and a
//! last-touch timestamp (recency, used for demotion victim selection).

use crate::PageId;

/// Which tier a page currently resides in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Fast,
    Slow,
}

/// Per-page metadata.
#[derive(Clone, Copy, Debug)]
pub struct PageState {
    pub tier: Tier,
    /// Decayed access count over the recent profiling window(s).
    pub window_count: u32,
    /// Interval index of the last access (recency).
    pub last_touch: u32,
    /// Whether the page has ever been touched (physically allocated).
    pub allocated: bool,
}

impl Default for PageState {
    fn default() -> Self {
        PageState { tier: Tier::Slow, window_count: 0, last_touch: 0, allocated: false }
    }
}

/// Counters for one interval's migration activity (consumed by the
/// interval time model and telemetry, then reset).
#[derive(Clone, Copy, Debug, Default)]
pub struct MigrationCounters {
    /// Successful promotions (slow → fast).
    pub promoted: u64,
    /// Promotion attempts that failed for lack of free fast memory
    /// ("page migration failures" in the paper's motivation study).
    pub promote_failed: u64,
    /// kswapd (background, non-blocking) demotions (fast → slow).
    pub demoted_kswapd: u64,
    /// Direct-reclaim (blocking) demotions.
    pub demoted_direct: u64,
    /// New-page allocations that landed in fast memory.
    pub alloc_fast: u64,
    /// New-page allocations that spilled to slow memory.
    pub alloc_slow: u64,
}

impl MigrationCounters {
    pub fn demoted_total(&self) -> u64 {
        self.demoted_kswapd + self.demoted_direct
    }
}

/// The two-tier physical memory state for one workload address space.
#[derive(Clone, Debug)]
pub struct TieredMemory {
    pages: Vec<PageState>,
    /// Fast-tier capacity in pages (the knob Fig. 1 sweeps; fixed for a
    /// run — Tuna varies *watermarks*, not capacity).
    fast_capacity: u64,
    fast_used: u64,
    slow_used: u64,
    pub counters: MigrationCounters,
}

impl TieredMemory {
    /// Create an address space of `rss_pages` (all unallocated) over a
    /// fast tier with `fast_capacity` pages. The slow tier is unbounded
    /// (756 GB on the testbed — never the constraint).
    pub fn new(rss_pages: usize, fast_capacity: u64) -> Self {
        TieredMemory {
            pages: vec![PageState::default(); rss_pages],
            fast_capacity,
            fast_used: 0,
            slow_used: 0,
            counters: MigrationCounters::default(),
        }
    }

    pub fn rss_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn fast_capacity(&self) -> u64 {
        self.fast_capacity
    }

    pub fn fast_used(&self) -> u64 {
        self.fast_used
    }

    pub fn slow_used(&self) -> u64 {
        self.slow_used
    }

    pub fn fast_free(&self) -> u64 {
        self.fast_capacity - self.fast_used
    }

    pub fn page(&self, id: PageId) -> &PageState {
        &self.pages[id as usize]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut PageState {
        &mut self.pages[id as usize]
    }

    /// Allocate a page on first touch. Fast-first (TPP and the NUMA
    /// first-touch baseline both allocate new pages in the top tier),
    /// spilling to slow when fewer than `reserve_free` fast pages would
    /// remain free (the allocation-time watermark).
    pub fn allocate(&mut self, id: PageId, now: u32, reserve_free: u64) {
        let cap = self.fast_capacity;
        let used = self.fast_used;
        let p = &mut self.pages[id as usize];
        debug_assert!(!p.allocated, "double allocation of page {id}");
        p.allocated = true;
        p.last_touch = now;
        if used + reserve_free < cap {
            p.tier = Tier::Fast;
            self.fast_used += 1;
            self.counters.alloc_fast += 1;
        } else {
            p.tier = Tier::Slow;
            self.slow_used += 1;
            self.counters.alloc_slow += 1;
        }
    }

    /// Record `count` accesses to a page during interval `now`.
    /// Returns the tier served. Saturating window counter.
    #[inline]
    pub fn touch(&mut self, id: PageId, count: u32, now: u32) -> Tier {
        let p = &mut self.pages[id as usize];
        debug_assert!(p.allocated, "touch of unallocated page {id}");
        p.window_count = p.window_count.saturating_add(count);
        p.last_touch = now;
        p.tier
    }

    /// Promote a page slow → fast. Fails (returning false and counting a
    /// migration failure) if no free fast page is available above the
    /// `reserve_free` watermark.
    pub fn promote(&mut self, id: PageId, reserve_free: u64) -> bool {
        debug_assert_eq!(self.pages[id as usize].tier, Tier::Slow);
        if self.fast_used + reserve_free >= self.fast_capacity {
            self.counters.promote_failed += 1;
            return false;
        }
        self.pages[id as usize].tier = Tier::Fast;
        self.fast_used += 1;
        self.slow_used -= 1;
        self.counters.promoted += 1;
        true
    }

    /// Demote a page fast → slow. `direct` selects which counter the
    /// demotion is charged to (kswapd vs direct reclaim).
    pub fn demote(&mut self, id: PageId, direct: bool) {
        debug_assert_eq!(self.pages[id as usize].tier, Tier::Fast);
        self.pages[id as usize].tier = Tier::Slow;
        self.fast_used -= 1;
        self.slow_used += 1;
        if direct {
            self.counters.demoted_direct += 1;
        } else {
            self.counters.demoted_kswapd += 1;
        }
    }

    /// Apply the per-interval exponential decay to window counters
    /// (right-shift = halve, the classic CLOCK-with-aging approximation).
    pub fn decay_windows(&mut self) {
        for p in &mut self.pages {
            p.window_count >>= 1;
        }
    }

    /// Iterate over allocated fast-tier page ids (demotion candidates).
    pub fn fast_pages(&self) -> impl Iterator<Item = PageId> + '_ {
        self.pages
            .iter()
            .enumerate()
            .filter(|(_, p)| p.allocated && p.tier == Tier::Fast)
            .map(|(i, _)| i as PageId)
    }

    /// Take and reset this interval's migration counters.
    pub fn take_counters(&mut self) -> MigrationCounters {
        std::mem::take(&mut self.counters)
    }

    /// Internal consistency check (used by tests and the property suite):
    /// tier occupancy counters must match the page table exactly.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut fast = 0u64;
        let mut slow = 0u64;
        for p in &self.pages {
            if p.allocated {
                match p.tier {
                    Tier::Fast => fast += 1,
                    Tier::Slow => slow += 1,
                }
            }
        }
        if fast != self.fast_used {
            return Err(format!("fast_used={} but page table has {fast}", self.fast_used));
        }
        if slow != self.slow_used {
            return Err(format!("slow_used={} but page table has {slow}", self.slow_used));
        }
        if self.fast_used > self.fast_capacity {
            return Err(format!(
                "fast over capacity: {}/{}",
                self.fast_used, self.fast_capacity
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_fast_first_then_spill() {
        let mut m = TieredMemory::new(10, 4);
        for id in 0..10u32 {
            m.allocate(id, 0, 0);
        }
        assert_eq!(m.fast_used(), 4);
        assert_eq!(m.slow_used(), 6);
        assert_eq!(m.counters.alloc_fast, 4);
        assert_eq!(m.counters.alloc_slow, 6);
        m.check_invariants().unwrap();
    }

    #[test]
    fn allocation_respects_reserve_watermark() {
        let mut m = TieredMemory::new(10, 4);
        for id in 0..10u32 {
            m.allocate(id, 0, 2); // keep 2 pages free
        }
        assert_eq!(m.fast_used(), 2);
        assert_eq!(m.fast_free(), 2);
    }

    #[test]
    fn promote_and_demote_roundtrip() {
        let mut m = TieredMemory::new(4, 2);
        for id in 0..4u32 {
            m.allocate(id, 0, 0);
        }
        // fast full (pages 0,1) — promotion of 2 must fail
        assert!(!m.promote(2, 0));
        assert_eq!(m.counters.promote_failed, 1);
        m.demote(0, false);
        assert!(m.promote(2, 0));
        assert_eq!(m.counters.promoted, 1);
        assert_eq!(m.counters.demoted_kswapd, 1);
        assert_eq!(m.page(0).tier, Tier::Slow);
        assert_eq!(m.page(2).tier, Tier::Fast);
        m.check_invariants().unwrap();
    }

    #[test]
    fn promotion_respects_reserve_watermark() {
        let mut m = TieredMemory::new(4, 3);
        for id in 0..4u32 {
            m.allocate(id, 0, 1); // fast holds 2, one reserve
        }
        assert_eq!(m.fast_used(), 2);
        // one slot physically free but reserved ⇒ promotion fails
        assert!(!m.promote(3, 1));
        // without the reserve it succeeds
        assert!(m.promote(3, 0));
    }

    #[test]
    fn touch_updates_window_and_decay_halves() {
        let mut m = TieredMemory::new(2, 2);
        m.allocate(0, 0, 0);
        assert_eq!(m.touch(0, 5, 3), Tier::Fast);
        assert_eq!(m.page(0).window_count, 5);
        assert_eq!(m.page(0).last_touch, 3);
        m.decay_windows();
        assert_eq!(m.page(0).window_count, 2);
    }

    #[test]
    fn take_counters_resets() {
        let mut m = TieredMemory::new(2, 1);
        m.allocate(0, 0, 0);
        m.allocate(1, 0, 0);
        let c = m.take_counters();
        assert_eq!(c.alloc_fast, 1);
        assert_eq!(c.alloc_slow, 1);
        assert_eq!(m.counters.alloc_fast, 0);
    }
}
