//! Tiered physical memory: page table, per-tier occupancy, migrations.
//!
//! Pages are identified by the workload's virtual page number ([`crate::PageId`]);
//! each page carries its current tier, a decayed access counter (the
//! "profiling window" frequency TPP uses for promotion decisions) and a
//! last-touch timestamp (recency, used for demotion victim selection).
//!
//! Migration *semantics* are pluggable via [`MigrationModel`]:
//!
//! * [`MigrationModel::Exclusive`] — the paper's (and TPP's) model: a page
//!   lives in exactly one tier and migration is an instantaneous move.
//!   This mode is bit-identical to the pre-refactor engine.
//! * [`MigrationModel::NonExclusive`] — Nomad-style transactional
//!   migration (PAPERS.md): a promotion *copies* the page while it stays
//!   mapped in the slow tier (the copy reserves a fast frame for
//!   `copy_intervals` intervals before the page flips), a write to an
//!   in-flight page aborts the copy (`abort_on_write`), and a completed
//!   promotion keeps its slow-tier source frame as a **shadow copy**:
//!   until the page is dirtied, demoting it back is a free unmap instead
//!   of a page copy.
//!
//! Dirtiness model: the workloads' access histograms have no read/write
//! split, so *random* accesses are treated as dirtying (they model
//! pointer-chasing read-modify-write traffic) and *streamed* accesses as
//! clean sequential reads. This is a deterministic modeling convention,
//! applied uniformly to shadow invalidation and copy aborts.

use crate::PageId;

/// Which tier a page currently resides in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Fast,
    Slow,
}

/// Migration semantics for a run (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum MigrationModel {
    /// Exclusive tiering: instantaneous move, one resident copy per page.
    #[default]
    Exclusive,
    /// Nomad-style non-exclusive tiering with transactional promotion.
    NonExclusive {
        /// Abort an in-flight copy when the interval's write (random)
        /// traffic touches the page being copied.
        abort_on_write: bool,
        /// Intervals a promotion copy occupies its reserved destination
        /// frame before the page flips tiers (clamped to ≥ 1).
        copy_intervals: u32,
    },
}

impl MigrationModel {
    /// Default transactional configuration (the `tpp-nomad` policy's
    /// built-in mode): abort on write, two-interval copy window.
    pub const DEFAULT_COPY_INTERVALS: u32 = 2;

    pub fn non_exclusive_default() -> Self {
        MigrationModel::NonExclusive {
            abort_on_write: true,
            copy_intervals: Self::DEFAULT_COPY_INTERVALS,
        }
    }

    pub fn is_exclusive(&self) -> bool {
        matches!(self, MigrationModel::Exclusive)
    }

    pub fn mode_name(&self) -> &'static str {
        match self {
            MigrationModel::Exclusive => "exclusive",
            MigrationModel::NonExclusive { .. } => "non-exclusive",
        }
    }

    /// Parse a CLI/config mode string. `abort_on_write`/`copy_intervals`
    /// apply only to non-exclusive mode.
    pub fn parse(mode: &str, abort_on_write: bool, copy_intervals: u32) -> Result<Self, String> {
        match mode.trim().to_ascii_lowercase().as_str() {
            "exclusive" | "excl" => Ok(MigrationModel::Exclusive),
            "non-exclusive" | "nonexclusive" | "non_exclusive" | "nomad" | "transactional" => {
                Ok(MigrationModel::NonExclusive {
                    abort_on_write,
                    copy_intervals: copy_intervals.max(1),
                })
            }
            other => Err(format!(
                "unknown migration mode `{other}` (valid: exclusive, non-exclusive)"
            )),
        }
    }

    /// Stable (mode, abort, copy_intervals) triple for artifact keys and
    /// fingerprints (never renumber mode codes, only extend).
    pub fn key(&self) -> (u8, u8, u32) {
        match self {
            MigrationModel::Exclusive => (0, 0, 0),
            MigrationModel::NonExclusive { abort_on_write, copy_intervals } => {
                (1, *abort_on_write as u8, *copy_intervals)
            }
        }
    }

    /// Inverse of [`Self::key`].
    pub fn from_key(mode: u8, abort: u8, copy_intervals: u32) -> Result<Self, String> {
        match mode {
            0 => Ok(MigrationModel::Exclusive),
            1 => Ok(MigrationModel::NonExclusive {
                abort_on_write: abort != 0,
                copy_intervals,
            }),
            other => Err(format!("unknown migration mode code {other}")),
        }
    }
}

/// Per-page metadata.
#[derive(Clone, Copy, Debug)]
pub struct PageState {
    pub tier: Tier,
    /// Decayed access count over the recent profiling window(s).
    pub window_count: u32,
    /// Interval index of the last access (recency).
    pub last_touch: u32,
    /// Whether the page has ever been touched (physically allocated).
    pub allocated: bool,
    /// Non-exclusive mode: the page is resident in fast memory but its
    /// slow-tier source frame still holds a valid copy (free to demote).
    pub shadowed: bool,
    /// Non-exclusive mode: the page has been written (by random traffic)
    /// since its current copy/shadow epoch began.
    pub dirty: bool,
    /// Non-exclusive mode: intervals left on an in-flight promotion copy
    /// (0 = no transaction). The page stays mapped in Slow while > 0.
    pub copying: u32,
}

impl Default for PageState {
    fn default() -> Self {
        PageState {
            tier: Tier::Slow,
            window_count: 0,
            last_touch: 0,
            allocated: false,
            shadowed: false,
            dirty: false,
            copying: 0,
        }
    }
}

/// Counters for one interval's migration activity (consumed by the
/// interval time model and telemetry, then reset).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MigrationCounters {
    /// Successful promotions (slow → fast). In non-exclusive mode this is
    /// counted when the transactional copy *completes* and the page flips.
    pub promoted: u64,
    /// Promotion attempts that failed for lack of free fast memory
    /// ("page migration failures" in the paper's motivation study).
    pub promote_failed: u64,
    /// kswapd (background, non-blocking) demotions (fast → slow).
    pub demoted_kswapd: u64,
    /// Direct-reclaim (blocking) demotions.
    pub demoted_direct: u64,
    /// New-page allocations that landed in fast memory.
    pub alloc_fast: u64,
    /// New-page allocations that spilled to slow memory.
    pub alloc_slow: u64,
    /// Accesses served by pages holding a valid shadow copy.
    pub shadow_hits: u64,
    /// Demotions of clean shadowed pages: a free unmap, **not** counted in
    /// `demoted_kswapd`/`demoted_direct` and charged no copy bandwidth.
    pub shadow_free_demotions: u64,
    /// In-flight transactional copies aborted by write traffic.
    pub txn_aborts: u64,
    /// Aborted copies immediately restarted because the page is still hot.
    pub txn_retried_copies: u64,
    /// Promotion candidates admitted by the admission gate (gated
    /// policies only; all four admission counters stay 0 when no gate is
    /// installed — the pre-admission behavior).
    pub admission_accepted: u64,
    /// Candidates refused because the interval's migration budget was
    /// exhausted.
    pub admission_rejected_budget: u64,
    /// Candidates whose predicted fast-tier hits over the residency
    /// horizon did not exceed the page-copy cost.
    pub admission_rejected_payoff: u64,
    /// Candidates demoted within the cool-down window (ping-pong
    /// traffic), rejected outright.
    pub admission_rejected_cooldown: u64,
}

impl MigrationCounters {
    /// Demotions that move a page copy (kswapd + direct). Free shadow
    /// demotions are deliberately excluded: they move no bytes.
    pub fn demoted_total(&self) -> u64 {
        self.demoted_kswapd + self.demoted_direct
    }

    /// Metric-name/value view of every counter, consumed by the
    /// observability layer's exposition. Exhaustive by construction
    /// (the destructure has no `..`), so adding a counter field without
    /// naming its metric family here is a compile error — transaction
    /// outcomes can't silently drop out of the `mem_*` metrics.
    pub fn metric_families(&self) -> [(&'static str, u64); 14] {
        let MigrationCounters {
            promoted,
            promote_failed,
            demoted_kswapd,
            demoted_direct,
            alloc_fast,
            alloc_slow,
            shadow_hits,
            shadow_free_demotions,
            txn_aborts,
            txn_retried_copies,
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
        } = *self;
        [
            ("mem_promoted_total", promoted),
            ("mem_promote_failed_total", promote_failed),
            ("mem_demoted_kswapd_total", demoted_kswapd),
            ("mem_demoted_direct_total", demoted_direct),
            ("mem_alloc_fast_total", alloc_fast),
            ("mem_alloc_slow_total", alloc_slow),
            ("mem_shadow_hits_total", shadow_hits),
            ("mem_shadow_free_demotions_total", shadow_free_demotions),
            ("mem_txn_aborts_total", txn_aborts),
            ("mem_txn_retried_copies_total", txn_retried_copies),
            ("mem_admission_accepted_total", admission_accepted),
            ("mem_admission_rejected_budget_total", admission_rejected_budget),
            ("mem_admission_rejected_payoff_total", admission_rejected_payoff),
            ("mem_admission_rejected_cooldown_total", admission_rejected_cooldown),
        ]
    }
}

/// The two-tier physical memory state for one workload address space.
#[derive(Clone, Debug)]
pub struct TieredMemory {
    pages: Vec<PageState>,
    /// Fast-tier capacity in pages (the knob Fig. 1 sweeps; fixed for a
    /// run — Tuna varies *watermarks*, not capacity).
    fast_capacity: u64,
    fast_used: u64,
    slow_used: u64,
    /// Migration semantics for this address space.
    migration: MigrationModel,
    /// In-flight transactional promotions, in start order.
    txns: Vec<PageId>,
    pub counters: MigrationCounters,
}

impl TieredMemory {
    /// Create an address space of `rss_pages` (all unallocated) over a
    /// fast tier with `fast_capacity` pages, under exclusive migration.
    /// The slow tier is unbounded (756 GB on the testbed — never the
    /// constraint).
    pub fn new(rss_pages: usize, fast_capacity: u64) -> Self {
        Self::with_migration(rss_pages, fast_capacity, MigrationModel::Exclusive)
    }

    /// As [`Self::new`] with explicit migration semantics.
    pub fn with_migration(rss_pages: usize, fast_capacity: u64, migration: MigrationModel) -> Self {
        TieredMemory {
            pages: vec![PageState::default(); rss_pages],
            fast_capacity,
            fast_used: 0,
            slow_used: 0,
            migration,
            txns: Vec::new(),
            counters: MigrationCounters::default(),
        }
    }

    pub fn migration(&self) -> MigrationModel {
        self.migration
    }

    pub fn rss_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn fast_capacity(&self) -> u64 {
        self.fast_capacity
    }

    pub fn fast_used(&self) -> u64 {
        self.fast_used
    }

    pub fn slow_used(&self) -> u64 {
        self.slow_used
    }

    pub fn fast_free(&self) -> u64 {
        self.fast_capacity - self.fast_used
    }

    pub fn page(&self, id: PageId) -> &PageState {
        &self.pages[id as usize]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut PageState {
        &mut self.pages[id as usize]
    }

    /// Allocate a page on first touch. Fast-first (TPP and the NUMA
    /// first-touch baseline both allocate new pages in the top tier),
    /// spilling to slow when fewer than `reserve_free` fast pages would
    /// remain free (the allocation-time watermark).
    pub fn allocate(&mut self, id: PageId, now: u32, reserve_free: u64) {
        let cap = self.fast_capacity;
        let used = self.fast_used;
        let p = &mut self.pages[id as usize];
        debug_assert!(!p.allocated, "double allocation of page {id}");
        p.allocated = true;
        p.last_touch = now;
        if used + reserve_free < cap {
            p.tier = Tier::Fast;
            self.fast_used += 1;
            self.counters.alloc_fast += 1;
        } else {
            p.tier = Tier::Slow;
            self.slow_used += 1;
            self.counters.alloc_slow += 1;
        }
    }

    /// Record `count` accesses to a page during interval `now`.
    /// Returns the tier served. Saturating window counter.
    #[inline]
    pub fn touch(&mut self, id: PageId, count: u32, now: u32) -> Tier {
        let p = &mut self.pages[id as usize];
        debug_assert!(p.allocated, "touch of unallocated page {id}");
        p.window_count = p.window_count.saturating_add(count);
        p.last_touch = now;
        p.tier
    }

    /// Non-exclusive bookkeeping for one page's interval traffic, called
    /// by the engine after [`Self::touch`] (never called in exclusive
    /// mode): count shadow hits, invalidate the shadow on a dirtying
    /// (random) access, and abort an in-flight copy the write races with.
    /// An aborted copy restarts immediately (a *retried copy*) when the
    /// page's window count still clears `hot_thr`; otherwise the
    /// transaction is cancelled and its reserved fast frame released.
    pub fn note_access(&mut self, id: PageId, random: u32, streamed: u32, hot_thr: u32) {
        let MigrationModel::NonExclusive { abort_on_write, copy_intervals } = self.migration
        else {
            return;
        };
        let p = &mut self.pages[id as usize];
        if p.shadowed {
            self.counters.shadow_hits += (random + streamed) as u64;
        }
        if random == 0 {
            return; // streamed accesses are clean: shadow and copy survive
        }
        p.dirty = true;
        if p.shadowed {
            // first write since promotion: the slow source copy is stale
            p.shadowed = false;
            self.slow_used -= 1;
        }
        if abort_on_write && p.copying > 0 {
            self.counters.txn_aborts += 1;
            if p.window_count >= hot_thr {
                // still hot: restart the copy, keeping the reservation
                self.counters.txn_retried_copies += 1;
                p.copying = copy_intervals.max(1);
                p.dirty = false;
            } else {
                // cooled off: cancel and release the reserved fast frame
                p.copying = 0;
                self.fast_used -= 1;
            }
        }
    }

    /// Tick every in-flight transactional copy by one interval (engine
    /// calls this once per interval in non-exclusive mode, after the
    /// policy ran). A copy that reaches zero completes: the page flips to
    /// fast and — if still clean — its slow source frame becomes a shadow
    /// copy (so `slow_used` is unchanged; the shadow holds the frame).
    pub fn advance_transactions(&mut self) {
        if self.txns.is_empty() {
            return;
        }
        let mut txns = std::mem::take(&mut self.txns);
        txns.retain(|&id| {
            let p = &mut self.pages[id as usize];
            if p.copying == 0 {
                return false; // aborted and cancelled this interval
            }
            p.copying -= 1;
            if p.copying > 0 {
                return true;
            }
            // copy finished: flip tiers; fast_used already counts the
            // reserved destination frame
            p.tier = Tier::Fast;
            if p.dirty {
                // only reachable with abort_on_write=false: the page was
                // written mid-copy, so no valid shadow survives
                p.shadowed = false;
                self.slow_used -= 1;
            } else {
                p.shadowed = true;
            }
            self.counters.promoted += 1;
            false
        });
        self.txns = txns;
    }

    /// Promote a page slow → fast. Fails (returning false and counting a
    /// migration failure) if no free fast page is available above the
    /// `reserve_free` watermark.
    ///
    /// Non-exclusive mode: starts (or confirms) a transactional copy
    /// instead of moving the page — the destination frame is reserved
    /// immediately, the page stays mapped in Slow until the copy
    /// completes, and `promoted` is counted at completion.
    pub fn promote(&mut self, id: PageId, reserve_free: u64) -> bool {
        debug_assert_eq!(self.pages[id as usize].tier, Tier::Slow);
        if let MigrationModel::NonExclusive { copy_intervals, .. } = self.migration {
            if self.pages[id as usize].copying > 0 {
                return true; // copy already underway
            }
            if self.fast_used + reserve_free >= self.fast_capacity {
                self.counters.promote_failed += 1;
                return false;
            }
            let p = &mut self.pages[id as usize];
            p.copying = copy_intervals.max(1);
            p.dirty = false; // the copy snapshots the page's current state
            self.fast_used += 1; // destination frame reserved for the copy
            self.txns.push(id);
            return true;
        }
        if self.fast_used + reserve_free >= self.fast_capacity {
            self.counters.promote_failed += 1;
            return false;
        }
        self.pages[id as usize].tier = Tier::Fast;
        self.fast_used += 1;
        self.slow_used -= 1;
        self.counters.promoted += 1;
        true
    }

    /// Demote a page fast → slow. `direct` selects which counter the
    /// demotion is charged to (kswapd vs direct reclaim).
    ///
    /// A clean shadowed page (non-exclusive mode only) demotes for free:
    /// its slow source copy is still valid, so the "demotion" is a bare
    /// unmap counted in `shadow_free_demotions` and charged no bandwidth.
    pub fn demote(&mut self, id: PageId, direct: bool) {
        debug_assert_eq!(self.pages[id as usize].tier, Tier::Fast);
        let p = &mut self.pages[id as usize];
        if p.shadowed {
            p.tier = Tier::Slow;
            p.shadowed = false;
            self.fast_used -= 1; // slow_used already counts the shadow frame
            self.counters.shadow_free_demotions += 1;
            return;
        }
        p.tier = Tier::Slow;
        self.fast_used -= 1;
        self.slow_used += 1;
        if direct {
            self.counters.demoted_direct += 1;
        } else {
            self.counters.demoted_kswapd += 1;
        }
    }

    /// Apply the per-interval exponential decay to window counters
    /// (right-shift = halve, the classic CLOCK-with-aging approximation).
    pub fn decay_windows(&mut self) {
        for p in &mut self.pages {
            p.window_count >>= 1;
        }
    }

    /// Iterate over allocated fast-tier page ids (demotion candidates).
    pub fn fast_pages(&self) -> impl Iterator<Item = PageId> + '_ {
        self.pages
            .iter()
            .enumerate()
            .filter(|(_, p)| p.allocated && p.tier == Tier::Fast)
            .map(|(i, _)| i as PageId)
    }

    /// Take and reset this interval's migration counters.
    ///
    /// Exhaustive by construction: the destructuring pattern has no `..`,
    /// so adding a counter field without threading it through here is a
    /// compile error — new counters can't silently drop out of reports.
    pub fn take_counters(&mut self) -> MigrationCounters {
        let MigrationCounters {
            promoted,
            promote_failed,
            demoted_kswapd,
            demoted_direct,
            alloc_fast,
            alloc_slow,
            shadow_hits,
            shadow_free_demotions,
            txn_aborts,
            txn_retried_copies,
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
        } = std::mem::take(&mut self.counters);
        MigrationCounters {
            promoted,
            promote_failed,
            demoted_kswapd,
            demoted_direct,
            alloc_fast,
            alloc_slow,
            shadow_hits,
            shadow_free_demotions,
            txn_aborts,
            txn_retried_copies,
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
        }
    }

    /// Internal consistency check (used by tests, the property suite and
    /// the engine's per-interval debug assertion): tier occupancy counters
    /// must match the page table exactly, including shadow frames and
    /// in-flight copy reservations.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut fast = 0u64;
        let mut slow = 0u64;
        let mut shadowed = 0u64;
        let mut copying = 0u64;
        for (i, p) in self.pages.iter().enumerate() {
            if p.shadowed && p.tier == Tier::Slow {
                return Err(format!("page {i} is shadowed but resident in the Slow tier"));
            }
            if p.shadowed && p.copying > 0 {
                return Err(format!("page {i} is both shadowed and mid-copy"));
            }
            if !p.allocated {
                if p.shadowed || p.copying > 0 {
                    return Err(format!("unallocated page {i} has shadow/copy state"));
                }
                continue;
            }
            match p.tier {
                Tier::Fast => fast += 1,
                Tier::Slow => slow += 1,
            }
            if p.shadowed {
                shadowed += 1;
            }
            if p.copying > 0 {
                copying += 1;
            }
        }
        if fast + copying != self.fast_used {
            return Err(format!(
                "fast_used={} but page table has {fast} fast + {copying} in-flight",
                self.fast_used
            ));
        }
        if slow + shadowed != self.slow_used {
            return Err(format!(
                "slow_used={} but page table has {slow} slow + {shadowed} shadow frames",
                self.slow_used
            ));
        }
        if shadowed > self.slow_used {
            return Err(format!(
                "shadow frames ({shadowed}) exceed slow_used ({})",
                self.slow_used
            ));
        }
        if self.fast_used > self.fast_capacity {
            return Err(format!(
                "fast over capacity: {}/{}",
                self.fast_used, self.fast_capacity
            ));
        }
        Ok(())
    }

    /// Deliberately desynchronize the occupancy accounting — test hook for
    /// the engine's per-interval invariant assertion.
    #[cfg(test)]
    pub(crate) fn corrupt_accounting_for_test(&mut self) {
        self.fast_used += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_fast_first_then_spill() {
        let mut m = TieredMemory::new(10, 4);
        for id in 0..10u32 {
            m.allocate(id, 0, 0);
        }
        assert_eq!(m.fast_used(), 4);
        assert_eq!(m.slow_used(), 6);
        assert_eq!(m.counters.alloc_fast, 4);
        assert_eq!(m.counters.alloc_slow, 6);
        m.check_invariants().unwrap();
    }

    #[test]
    fn allocation_respects_reserve_watermark() {
        let mut m = TieredMemory::new(10, 4);
        for id in 0..10u32 {
            m.allocate(id, 0, 2); // keep 2 pages free
        }
        assert_eq!(m.fast_used(), 2);
        assert_eq!(m.fast_free(), 2);
    }

    #[test]
    fn promote_and_demote_roundtrip() {
        let mut m = TieredMemory::new(4, 2);
        for id in 0..4u32 {
            m.allocate(id, 0, 0);
        }
        // fast full (pages 0,1) — promotion of 2 must fail
        assert!(!m.promote(2, 0));
        assert_eq!(m.counters.promote_failed, 1);
        m.demote(0, false);
        assert!(m.promote(2, 0));
        assert_eq!(m.counters.promoted, 1);
        assert_eq!(m.counters.demoted_kswapd, 1);
        assert_eq!(m.page(0).tier, Tier::Slow);
        assert_eq!(m.page(2).tier, Tier::Fast);
        m.check_invariants().unwrap();
    }

    #[test]
    fn promotion_respects_reserve_watermark() {
        let mut m = TieredMemory::new(4, 3);
        for id in 0..4u32 {
            m.allocate(id, 0, 1); // fast holds 2, one reserve
        }
        assert_eq!(m.fast_used(), 2);
        // one slot physically free but reserved ⇒ promotion fails
        assert!(!m.promote(3, 1));
        // without the reserve it succeeds
        assert!(m.promote(3, 0));
    }

    #[test]
    fn touch_updates_window_and_decay_halves() {
        let mut m = TieredMemory::new(2, 2);
        m.allocate(0, 0, 0);
        assert_eq!(m.touch(0, 5, 3), Tier::Fast);
        assert_eq!(m.page(0).window_count, 5);
        assert_eq!(m.page(0).last_touch, 3);
        m.decay_windows();
        assert_eq!(m.page(0).window_count, 2);
    }

    #[test]
    fn metric_families_cover_every_counter() {
        let c = MigrationCounters {
            promoted: 1,
            promote_failed: 2,
            demoted_kswapd: 3,
            demoted_direct: 4,
            alloc_fast: 5,
            alloc_slow: 6,
            shadow_hits: 7,
            shadow_free_demotions: 8,
            txn_aborts: 9,
            txn_retried_copies: 10,
            admission_accepted: 11,
            admission_rejected_budget: 12,
            admission_rejected_payoff: 13,
            admission_rejected_cooldown: 14,
        };
        let fams = c.metric_families();
        let total: u64 = fams.iter().map(|(_, v)| v).sum();
        assert_eq!(total, 105, "every field must appear exactly once");
        let mut names: Vec<&str> = fams.iter().map(|(n, _)| *n).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 14, "metric family names must be unique");
        assert!(names.iter().all(|n| n.starts_with("mem_") && n.ends_with("_total")));
    }

    #[test]
    fn take_counters_resets() {
        let mut m = TieredMemory::new(2, 1);
        m.allocate(0, 0, 0);
        m.allocate(1, 0, 0);
        let c = m.take_counters();
        assert_eq!(c.alloc_fast, 1);
        assert_eq!(c.alloc_slow, 1);
        assert_eq!(m.counters.alloc_fast, 0);
    }

    #[test]
    fn migration_model_parse_and_key_roundtrip() {
        assert_eq!(MigrationModel::parse("exclusive", true, 5).unwrap(), MigrationModel::Exclusive);
        assert_eq!(
            MigrationModel::parse("non-exclusive", true, 3).unwrap(),
            MigrationModel::NonExclusive { abort_on_write: true, copy_intervals: 3 }
        );
        assert_eq!(
            MigrationModel::parse("nomad", false, 0).unwrap(),
            MigrationModel::NonExclusive { abort_on_write: false, copy_intervals: 1 },
            "copy_intervals must clamp to >= 1"
        );
        assert!(MigrationModel::parse("bogus", true, 2).is_err());
        for m in [
            MigrationModel::Exclusive,
            MigrationModel::non_exclusive_default(),
            MigrationModel::NonExclusive { abort_on_write: false, copy_intervals: 7 },
        ] {
            let (mode, abort, copy) = m.key();
            assert_eq!(MigrationModel::from_key(mode, abort, copy).unwrap(), m);
        }
        assert!(MigrationModel::from_key(9, 0, 0).is_err());
    }

    fn nonexclusive(rss: usize, cap: u64, copy_intervals: u32) -> TieredMemory {
        let mut m = TieredMemory::with_migration(
            rss,
            cap,
            MigrationModel::NonExclusive { abort_on_write: true, copy_intervals },
        );
        for id in 0..rss as u32 {
            m.allocate(id, 0, 0);
        }
        m
    }

    #[test]
    fn transactional_promotion_reserves_then_flips_with_shadow() {
        let mut m = nonexclusive(4, 3, 2); // pages 0..3 fast, 3 slow
        assert_eq!(m.page(3).tier, Tier::Slow);
        m.demote(0, false); // make room
        assert!(m.promote(3, 0));
        // in-flight: page still slow, destination frame reserved
        assert_eq!(m.page(3).tier, Tier::Slow);
        assert_eq!(m.page(3).copying, 2);
        assert_eq!(m.fast_used(), 3, "reservation counts against fast");
        assert_eq!(m.counters.promoted, 0, "promoted counts at completion");
        m.check_invariants().unwrap();
        // re-promoting an in-flight page is a confirming no-op
        assert!(m.promote(3, 0));
        assert_eq!(m.page(3).copying, 2);

        m.advance_transactions();
        assert_eq!(m.page(3).copying, 1);
        assert_eq!(m.page(3).tier, Tier::Slow);
        m.check_invariants().unwrap();

        m.advance_transactions();
        assert_eq!(m.page(3).copying, 0);
        assert_eq!(m.page(3).tier, Tier::Fast);
        assert!(m.page(3).shadowed, "clean completion keeps the slow frame as shadow");
        assert_eq!(m.counters.promoted, 1);
        // the shadow holds the slow frame: slow_used unchanged by the flip
        assert_eq!(m.slow_used(), 2, "demoted page 0 + page 3's shadow frame");
        m.check_invariants().unwrap();
    }

    #[test]
    fn write_aborts_inflight_copy_and_retries_while_hot() {
        let mut m = nonexclusive(4, 3, 2);
        m.demote(0, false);
        m.touch(3, 8, 1); // hot
        assert!(m.promote(3, 0));
        // a dirtying (random) access aborts the copy; page is still hot
        // (window 8 ≥ hot_thr 2) so the copy restarts immediately
        m.note_access(3, 1, 0, 2);
        assert_eq!(m.counters.txn_aborts, 1);
        assert_eq!(m.counters.txn_retried_copies, 1);
        assert_eq!(m.page(3).copying, 2, "retry restarts the copy window");
        assert_eq!(m.fast_used(), 3, "reservation retained across retry");
        m.check_invariants().unwrap();

        // cold abort: zero the window, write again ⇒ cancelled outright
        m.page_mut(3).window_count = 0;
        m.note_access(3, 1, 0, 2);
        assert_eq!(m.counters.txn_aborts, 2);
        assert_eq!(m.counters.txn_retried_copies, 1);
        assert_eq!(m.page(3).copying, 0);
        assert_eq!(m.fast_used(), 2, "cancelled txn releases its reservation");
        m.advance_transactions(); // drops the cancelled entry
        assert_eq!(m.counters.promoted, 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn streamed_accesses_do_not_abort_or_dirty() {
        let mut m = nonexclusive(4, 3, 1);
        m.demote(0, false);
        assert!(m.promote(3, 0));
        m.note_access(3, 0, 16, 2); // clean streamed traffic
        assert_eq!(m.counters.txn_aborts, 0);
        m.advance_transactions();
        assert!(m.page(3).shadowed);
        // shadow hits count accesses to the shadowed page
        m.note_access(3, 0, 4, 2);
        assert_eq!(m.counters.shadow_hits, 4);
        assert!(m.page(3).shadowed, "streamed traffic keeps the shadow valid");
        m.check_invariants().unwrap();
    }

    #[test]
    fn dirty_write_invalidates_shadow_and_demotion_becomes_a_copy() {
        let mut m = nonexclusive(4, 3, 1);
        m.demote(0, false);
        assert!(m.promote(3, 0));
        m.advance_transactions();
        assert!(m.page(3).shadowed);
        let slow_before = m.slow_used();
        m.note_access(3, 2, 0, 2); // dirtying write
        assert!(!m.page(3).shadowed);
        assert_eq!(m.slow_used(), slow_before - 1, "stale shadow frame freed");
        m.check_invariants().unwrap();
        // demoting the now-unshadowed page is a normal copying demotion
        m.demote(3, false);
        assert_eq!(m.counters.shadow_free_demotions, 0);
        assert_eq!(m.counters.demoted_kswapd, 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn clean_shadowed_page_demotes_for_free() {
        let mut m = nonexclusive(4, 3, 1);
        m.demote(0, false);
        assert!(m.promote(3, 0));
        m.advance_transactions();
        assert!(m.page(3).shadowed);
        let (slow_before, kswapd_before) = (m.slow_used(), m.counters.demoted_kswapd);
        m.demote(3, false);
        assert_eq!(m.page(3).tier, Tier::Slow);
        assert!(!m.page(3).shadowed);
        assert_eq!(m.counters.shadow_free_demotions, 1);
        assert_eq!(m.counters.demoted_kswapd, kswapd_before, "free demotion is not a kswapd copy");
        assert_eq!(m.slow_used(), slow_before, "the shadow frame simply becomes the page");
        m.check_invariants().unwrap();
    }

    #[test]
    fn take_counters_resets_shadow_and_txn_counters() {
        let mut m = nonexclusive(4, 3, 1);
        m.demote(0, false);
        assert!(m.promote(3, 0));
        m.advance_transactions();
        m.note_access(3, 0, 1, 2); // shadow hit
        m.demote(3, false); // free demotion
        let c = m.take_counters();
        assert!(c.shadow_hits > 0 && c.shadow_free_demotions == 1);
        assert_eq!(m.counters, MigrationCounters::default());
    }

    #[test]
    fn check_invariants_rejects_corrupted_shadow_state() {
        let mut m = nonexclusive(4, 3, 1);
        m.page_mut(2).shadowed = true; // fast page claims a shadow frame
        assert!(m.check_invariants().is_err());
        let mut m2 = nonexclusive(4, 3, 1);
        m2.page_mut(3).shadowed = true; // slow page can never be shadowed
        assert!(m2.check_invariants().is_err());
    }
}
