//! Text ingestion protocol for the tuner service: telemetry arriving
//! from *outside* the process (`tuna serve`).
//!
//! The stream is line-oriented so any producer — a recorded run, a shell
//! pipe, a fleet agent tailing `/proc/vmstat` — can speak it:
//!
//! ```text
//! # tuna-telemetry v1
//! open <session> <capacity> <rss_pages> <hot_thr> <threads>
//! sample <session> <interval> <acc_fast> <acc_slow> <sacc_fast> <sacc_slow> \
//!        <flops> <iops> <promoted> <promote_failed> <demoted_kswapd> \
//!        <demoted_direct> <fast_free> [<shadow_hits> <shadow_free_demotions> \
//!        <txn_aborts> <txn_retried_copies> [<admission_accepted> \
//!        <admission_rejected_budget> <admission_rejected_payoff> \
//!        <admission_rejected_cooldown> [<wall_ns>]]]
//! close <session>
//! ```
//!
//! (`sample` is one line; it is wrapped here for readability.) Blank
//! lines and `#` comments are skipped. Session names are free-form
//! tokens without whitespace; any number of sessions may be interleaved
//! in one stream. The bracketed counters are optional, newest-last:
//! streams recorded before the migration-model axis existed carry 12
//! sample fields, streams recorded before admission control carry 16,
//! streams recorded before the outcome tracker carry 20 (no interval
//! wall time), and all parse with the missing fields as 0, so replaying
//! an old recording still produces bit-identical decisions. Writers
//! always emit all 21 fields. Replaying a recorded stream through
//! [`Ingestor`] produces decisions bit-identical to the run that
//! recorded it — the determinism tests in the integration suite prove
//! it.

use std::collections::HashMap;
use std::io::BufRead;

use anyhow::{anyhow, bail, Context, Result};

use super::{SessionHandle, SessionReport, SessionSpec, TunerService};
use crate::config::experiment::TunaConfig;
use crate::telemetry::TelemetrySample;
use crate::tpp::Watermarks;

/// Header comment writers emit at the top of a stream (readers treat it
/// as any other comment).
pub const STREAM_HEADER: &str = "# tuna-telemetry v1";

/// One parsed line of the ingestion stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    Open { name: String, capacity: u64, rss_pages: u64, hot_thr: u32, threads: u32 },
    Sample { name: String, sample: TelemetrySample },
    Close { name: String },
}

fn field<T: std::str::FromStr>(
    it: &mut std::str::SplitWhitespace<'_>,
    what: &'static str,
) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    let tok = it.next().ok_or_else(|| anyhow!("missing field `{what}`"))?;
    tok.parse::<T>().map_err(|e| anyhow!("bad {what} `{tok}`: {e}"))
}

/// Optional trailing field: absent means 0 (pre-migration-axis streams),
/// present-but-malformed is still an error.
fn opt_field(it: &mut std::str::SplitWhitespace<'_>, what: &'static str) -> Result<u64> {
    match it.next() {
        None => Ok(0),
        Some(tok) => tok.parse::<u64>().map_err(|e| anyhow!("bad {what} `{tok}`: {e}")),
    }
}

impl Event {
    /// Parse one stream line. Returns `Ok(None)` for blanks and comments.
    pub fn parse(line: &str) -> Result<Option<Event>> {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            return Ok(None);
        }
        let mut it = trimmed.split_whitespace();
        let verb = it.next().expect("non-empty line has a first token");
        let ev = match verb {
            "open" => Event::Open {
                name: field(&mut it, "session name")?,
                capacity: field(&mut it, "capacity")?,
                rss_pages: field(&mut it, "rss_pages")?,
                hot_thr: field(&mut it, "hot_thr")?,
                threads: field(&mut it, "threads")?,
            },
            "sample" => Event::Sample {
                name: field(&mut it, "session name")?,
                sample: TelemetrySample {
                    interval: field(&mut it, "interval")?,
                    acc_fast: field(&mut it, "acc_fast")?,
                    acc_slow: field(&mut it, "acc_slow")?,
                    sacc_fast: field(&mut it, "sacc_fast")?,
                    sacc_slow: field(&mut it, "sacc_slow")?,
                    flops: field(&mut it, "flops")?,
                    iops: field(&mut it, "iops")?,
                    promoted: field(&mut it, "promoted")?,
                    promote_failed: field(&mut it, "promote_failed")?,
                    demoted_kswapd: field(&mut it, "demoted_kswapd")?,
                    demoted_direct: field(&mut it, "demoted_direct")?,
                    fast_free: field(&mut it, "fast_free")?,
                    // optional trailing counters (v1 streams recorded
                    // before the migration-model axis omit all of them;
                    // pre-admission streams omit the last four)
                    shadow_hits: opt_field(&mut it, "shadow_hits")?,
                    shadow_free_demotions: opt_field(&mut it, "shadow_free_demotions")?,
                    txn_aborts: opt_field(&mut it, "txn_aborts")?,
                    txn_retried_copies: opt_field(&mut it, "txn_retried_copies")?,
                    admission_accepted: opt_field(&mut it, "admission_accepted")?,
                    admission_rejected_budget: opt_field(&mut it, "admission_rejected_budget")?,
                    admission_rejected_payoff: opt_field(&mut it, "admission_rejected_payoff")?,
                    admission_rejected_cooldown: opt_field(&mut it, "admission_rejected_cooldown")?,
                    wall_ns: opt_field(&mut it, "wall_ns")?,
                },
            },
            "close" => Event::Close { name: field(&mut it, "session name")? },
            other => bail!("unknown telemetry verb `{other}` (valid verbs: open, sample, close)"),
        };
        if let Some(extra) = it.next() {
            bail!("trailing token `{extra}` after {verb} line");
        }
        Ok(Some(ev))
    }

    /// Serialize to one stream line (no trailing newline).
    pub fn to_line(&self) -> String {
        match self {
            Event::Open { name, capacity, rss_pages, hot_thr, threads } => {
                format!("open {name} {capacity} {rss_pages} {hot_thr} {threads}")
            }
            Event::Sample { name, sample: s } => format!(
                "sample {name} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {}",
                s.interval,
                s.acc_fast,
                s.acc_slow,
                s.sacc_fast,
                s.sacc_slow,
                s.flops,
                s.iops,
                s.promoted,
                s.promote_failed,
                s.demoted_kswapd,
                s.demoted_direct,
                s.fast_free,
                s.shadow_hits,
                s.shadow_free_demotions,
                s.txn_aborts,
                s.txn_retried_copies,
                s.admission_accepted,
                s.admission_rejected_budget,
                s.admission_rejected_payoff,
                s.admission_rejected_cooldown,
                s.wall_ns
            ),
            Event::Close { name } => format!("close {name}"),
        }
    }

    /// The `open` event announcing `spec` (the writer-side counterpart
    /// of what [`Ingestor`] turns back into a [`SessionSpec`]).
    pub fn open_for(spec: &SessionSpec) -> Event {
        Event::Open {
            name: spec.name.clone(),
            capacity: spec.capacity,
            rss_pages: spec.rss_pages,
            hot_thr: spec.hot_thr,
            threads: spec.threads,
        }
    }
}

/// What an ingested stream produces, in stream order.
#[derive(Clone, Debug)]
pub enum IngestOutput {
    /// A period boundary closed and the service reprogrammed the
    /// session's watermarks.
    Decision { session: String, interval: u32, usable_fm: u64, watermarks: Watermarks },
    /// A `close` line arrived; the session's final report.
    Closed(SessionReport),
}

impl IngestOutput {
    /// Canonical text rendering: the `decision …` / `closed …` lines
    /// `tuna serve` emits, each newline-terminated. One shared function
    /// renders both the file/stdin mode's stdout and the network
    /// server's socket write-back, so a recorded stream served over TCP
    /// yields byte-identical decision lines to a file replay (the
    /// socket round-trip test and the CI fleet-serving smoke `cmp` on
    /// it).
    pub fn render_lines(&self) -> String {
        use crate::report::pct;
        use crate::util::human_ns;
        match self {
            IngestOutput::Decision { session, interval, usable_fm, .. } => {
                format!("decision {session} interval={interval} usable_fm={usable_fm}\n")
            }
            IngestOutput::Closed(report) => {
                let mut out = format!(
                    "closed {}: {} samples, {} decisions, mean FM saving {}, max {}, query path {}\n",
                    report.name,
                    report.samples,
                    report.decisions.len(),
                    pct(1.0 - report.mean_fraction),
                    pct(1.0 - report.min_fraction),
                    human_ns(report.decide_ns as u64)
                );
                // Sessions whose telemetry carried transactional-migration
                // counters get one extra line; exclusive-mode streams (and
                // pre-migration-axis recordings) print exactly as before.
                let vm = |name: &str| {
                    report.vmstat.iter().find(|(k, _)| *k == name).map_or(0, |&(_, v)| v)
                };
                let txn = vm("shadow_hits")
                    + vm("shadow_free_demotions")
                    + vm("txn_aborts")
                    + vm("txn_retried_copies");
                if txn > 0 {
                    out.push_str(&format!(
                        "  migration {}: shadow_hits={} shadow_free_demotions={} txn_aborts={} txn_retried_copies={}\n",
                        report.name,
                        vm("shadow_hits"),
                        vm("shadow_free_demotions"),
                        vm("txn_aborts"),
                        vm("txn_retried_copies")
                    ));
                }
                // Same contract as the migration line: sessions whose tuner
                // tracked decision outcomes get one extra line; `--retune
                // off` streams print exactly as before.
                if !report.outcomes.is_empty() || report.retunes > 0 {
                    let mean_abs: f64 = if report.outcomes.is_empty() {
                        0.0
                    } else {
                        report.outcomes.iter().map(|o| o.abs_err).sum::<f64>()
                            / report.outcomes.len() as f64
                    };
                    out.push_str(&format!(
                        "  outcomes {}: {} tracked, mean |prediction error| {}, retunes {}\n",
                        report.name,
                        report.outcomes.len(),
                        pct(mean_abs),
                        report.retunes
                    ));
                }
                out
            }
        }
    }
}

/// Counters for one ingestion pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestStats {
    pub lines: u64,
    pub samples: u64,
    pub decisions: u64,
    pub sessions_opened: u64,
    pub sessions_closed: u64,
}

/// Drives a [`TunerService`] from a parsed event stream: `open` lines
/// register sessions (all sharing the ingestor's [`TunaConfig`]),
/// `sample` lines publish, `close` lines collect reports. The tuning
/// cadence is the same as a live run's: every `period_intervals`-th
/// sample of a session triggers a decision.
pub struct Ingestor<'s> {
    service: &'s TunerService,
    cfg: TunaConfig,
    sessions: HashMap<String, SessionHandle<'s>>,
    obs: crate::obs::Recorder,
}

impl<'s> Ingestor<'s> {
    pub fn new(service: &'s TunerService, cfg: TunaConfig) -> Self {
        Self::new_with_obs(service, cfg, crate::obs::Recorder::default())
    }

    /// As [`Self::new`], with an observability recorder: each
    /// [`Self::ingest`] pass counts its lines/samples/decisions and
    /// journals one `IngestBatch` event.
    pub fn new_with_obs(
        service: &'s TunerService,
        cfg: TunaConfig,
        obs: crate::obs::Recorder,
    ) -> Self {
        Ingestor { service, cfg, sessions: HashMap::new(), obs }
    }

    /// Sessions currently open.
    pub fn open_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Apply one event. Returns the output it produced, if any.
    pub fn apply(&mut self, ev: Event) -> Result<Option<IngestOutput>> {
        match ev {
            Event::Open { name, capacity, rss_pages, hot_thr, threads } => {
                if self.sessions.contains_key(&name) {
                    bail!("session `{name}` is already open");
                }
                // copy the &'s reference out so the handle borrows the
                // service for 's, not this &mut self call
                let service: &'s TunerService = self.service;
                let handle = service.register(SessionSpec {
                    name: name.clone(),
                    capacity,
                    rss_pages,
                    hot_thr,
                    threads,
                    cfg: self.cfg.clone(),
                })?;
                self.sessions.insert(name, handle);
                Ok(None)
            }
            Event::Sample { name, sample } => {
                let handle = self
                    .sessions
                    .get_mut(&name)
                    .ok_or_else(|| anyhow!("sample for unknown session `{name}`"))?;
                let interval = sample.interval;
                Ok(handle.publish(sample).map(|wm| IngestOutput::Decision {
                    usable_fm: wm.usable(handle.capacity()),
                    session: name,
                    interval,
                    watermarks: wm,
                }))
            }
            Event::Close { name } => {
                let handle = self
                    .sessions
                    .remove(&name)
                    .ok_or_else(|| anyhow!("close for unknown session `{name}`"))?;
                Ok(Some(IngestOutput::Closed(handle.finish()?)))
            }
        }
    }

    /// Ingest a whole stream, passing every output to `sink`. Parse and
    /// session errors abort with the offending line number in context.
    pub fn ingest<R: BufRead>(
        &mut self,
        reader: R,
        mut sink: impl FnMut(IngestOutput),
    ) -> Result<IngestStats> {
        let mut stats = IngestStats::default();
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.with_context(|| format!("reading stream line {}", lineno + 1))?;
            stats.lines += 1;
            let Some(ev) = Event::parse(&line)
                .with_context(|| format!("stream line {}: `{line}`", lineno + 1))?
            else {
                continue;
            };
            match &ev {
                Event::Sample { .. } => stats.samples += 1,
                Event::Open { .. } => stats.sessions_opened += 1,
                Event::Close { .. } => stats.sessions_closed += 1,
            }
            if let Some(out) = self.apply(ev)? {
                if matches!(out, IngestOutput::Decision { .. }) {
                    stats.decisions += 1;
                }
                sink(out);
            }
        }
        if self.obs.is_enabled() {
            self.obs.count("service_ingest_lines_total", stats.lines);
            self.obs.count("service_ingest_samples_total", stats.samples);
            self.obs.count("service_ingest_decisions_total", stats.decisions);
            self.obs.record(crate::obs::EventKind::IngestBatch {
                lines: stats.lines,
                samples: stats.samples,
                decisions: stats.decisions,
                sessions_opened: stats.sessions_opened,
                sessions_closed: stats.sessions_closed,
            });
        }
        Ok(stats)
    }

    /// Close every session still open (streams without trailing `close`
    /// lines), reporting each through `sink` in name order — the session
    /// map is a hash map, and replayed output must not depend on its
    /// iteration order.
    pub fn finish_all(&mut self, mut sink: impl FnMut(IngestOutput)) -> Result<()> {
        let mut names: Vec<String> = self.sessions.keys().cloned().collect();
        names.sort();
        for name in names {
            // never panic here: a handle that vanished between listing
            // and removal (a racing close) is a per-session error the
            // caller can report, not a process abort
            let handle = self.sessions.remove(&name).ok_or_else(|| {
                anyhow!("session `{name}` closed while draining remaining sessions")
            })?;
            sink(IngestOutput::Closed(handle.finish()?));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_roundtrip_through_parse() {
        let evs = [
            Event::Open {
                name: "bfs#1".into(),
                capacity: 9_000,
                rss_pages: 8_000,
                hot_thr: 2,
                threads: 16,
            },
            Event::Sample {
                name: "bfs#1".into(),
                sample: TelemetrySample {
                    interval: 7,
                    acc_fast: 1,
                    acc_slow: 2,
                    sacc_fast: 3,
                    sacc_slow: 4,
                    flops: 5,
                    iops: 6,
                    promoted: 7,
                    promote_failed: 8,
                    demoted_kswapd: 9,
                    demoted_direct: 10,
                    shadow_hits: 12,
                    shadow_free_demotions: 13,
                    txn_aborts: 14,
                    txn_retried_copies: 15,
                    admission_accepted: 16,
                    admission_rejected_budget: 17,
                    admission_rejected_payoff: 18,
                    admission_rejected_cooldown: 19,
                    fast_free: 11,
                    wall_ns: 1_234_567,
                },
            },
            Event::Close { name: "bfs#1".into() },
        ];
        for ev in evs {
            let line = ev.to_line();
            let back = Event::parse(&line).unwrap().expect("a real event");
            assert_eq!(back, ev, "line `{line}`");
        }
    }

    #[test]
    fn pre_migration_axis_sample_lines_still_parse() {
        // a 12-field sample line from a stream recorded before the
        // non-exclusive counters existed: the trailing counters read as 0
        let old = "sample bfs#1 7 1 2 3 4 5 6 7 8 9 10 11";
        let Some(Event::Sample { sample, .. }) = Event::parse(old).unwrap() else {
            panic!("old-format sample line must parse");
        };
        assert_eq!(sample.fast_free, 11);
        assert_eq!(
            (
                sample.shadow_hits,
                sample.shadow_free_demotions,
                sample.txn_aborts,
                sample.txn_retried_copies
            ),
            (0, 0, 0, 0)
        );
        // a 16-field line from a pre-admission stream: the four admission
        // counters read as 0
        let pre_adm = format!("{} 12 13 14 15", old);
        let Some(Event::Sample { sample, .. }) = Event::parse(&pre_adm).unwrap() else {
            panic!("pre-admission sample line must parse");
        };
        assert_eq!(sample.txn_retried_copies, 15);
        assert_eq!(
            (
                sample.admission_accepted,
                sample.admission_rejected_budget,
                sample.admission_rejected_payoff,
                sample.admission_rejected_cooldown
            ),
            (0, 0, 0, 0)
        );
        // a 20-field line from a pre-outcome-tracker stream: wall_ns
        // reads as 0 (the tracker reports no realized loss for it)
        let pre_outcome = format!("{} 12 13 14 15 16 17 18 19", old);
        let Some(Event::Sample { sample, .. }) = Event::parse(&pre_outcome).unwrap() else {
            panic!("pre-outcome sample line must parse");
        };
        assert_eq!(sample.admission_rejected_cooldown, 19);
        assert_eq!(sample.wall_ns, 0);
        // a 22nd field is still a trailing-token error
        let long = format!("{} 0 0 0 0 0 0 0 0 0 99", old);
        assert!(Event::parse(&long).is_err(), "overlong sample must be rejected");
        // a present-but-malformed optional field is an error, not a 0
        let bad = format!("{} nope", old);
        assert!(Event::parse(&bad).is_err());
    }

    #[test]
    fn unknown_verb_error_lists_the_valid_verbs() {
        let err = Event::parse("frobnicate x 1").unwrap_err().to_string();
        assert!(err.contains("unknown telemetry verb `frobnicate`"), "got: {err}");
        for verb in ["open", "sample", "close"] {
            assert!(err.contains(verb), "error must catalogue `{verb}`: {err}");
        }
    }

    #[test]
    fn comments_blanks_and_garbage() {
        assert_eq!(Event::parse("").unwrap(), None);
        assert_eq!(Event::parse("   ").unwrap(), None);
        assert_eq!(Event::parse(STREAM_HEADER).unwrap(), None);
        assert!(Event::parse("frobnicate x 1").is_err());
        assert!(Event::parse("open onlyname").is_err(), "missing fields");
        assert!(Event::parse("close a b").is_err(), "trailing token");
        assert!(Event::parse("sample s 1 2 3").is_err(), "short sample");
        assert!(Event::parse("open s 1 2 x 4").is_err(), "non-numeric field");
    }

    #[test]
    fn unknown_session_and_double_open_error() {
        use crate::perfdb::{normalize, Record};
        let raw = [1000.0, 100.0, 10.0, 10.0, 1.0, 4000.0, 2.0, 16.0];
        let db = std::sync::Arc::new(crate::perfdb::PerfDb {
            fractions: vec![1.0, 0.5],
            records: vec![Record { raw, vec: normalize(&raw), times_ns: vec![100.0, 120.0] }],
        });
        let service = TunerService::inline(
            db.clone(),
            Box::new(crate::perfdb::native::NativeNn::new(&db)),
        );
        let mut ing = Ingestor::new(&service, TunaConfig::default());
        assert!(ing
            .apply(Event::Close { name: "ghost".into() })
            .is_err());
        let open = Event::Open {
            name: "a".into(),
            capacity: 1_000,
            rss_pages: 900,
            hot_thr: 2,
            threads: 4,
        };
        assert!(ing.apply(open.clone()).unwrap().is_none());
        assert!(ing.apply(open).is_err(), "double open");
        assert_eq!(ing.open_sessions(), 1);
        let mut closed = 0;
        ing.finish_all(|_| closed += 1).unwrap();
        assert_eq!(closed, 1);
        assert_eq!(ing.open_sessions(), 0);
    }
}
