//! Network ingestion for the tuner service: `tuna serve --listen`.
//!
//! A [`NetServer`] is a `std::net` TCP listener accepting any number of
//! concurrent tuna-telemetry v1 connections. Each accepted connection
//! gets one reader thread that parses the line protocol ([`super::ingest`])
//! and feeds the shared — typically sharded — [`TunerService`];
//! decisions and close reports are written back on the same socket as
//! the exact `decision …` / `closed …` lines the file mode prints
//! ([`IngestOutput::render_lines`] is the single rendering for both, so
//! a stream served over TCP is byte-identical to `tuna serve FILE`).
//!
//! Backpressure is strictly per connection: a connection's samples are
//! in flight only between its reader thread and its sessions' bounded
//! worker channels, so a slow consumer (or a stalled socket write-back)
//! blocks *its own* reader thread and nothing else — the service and
//! every other connection keep running. Graceful drain on shutdown:
//! when a client half-closes (EOF) its remaining sessions are closed
//! and their reports flushed down the socket before the server closes
//! it; when the configured connection budget is reached the listener
//! stops accepting and [`NetServer::serve`] joins every reader before
//! returning, so the aggregation workers see a quiet service.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Context, Result};

use super::{IngestOutput, IngestStats, Ingestor, TunerService};
use crate::config::experiment::TunaConfig;
use crate::obs::{EventKind, Recorder};

/// Configuration of a [`NetServer`].
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Tuner parameters every connection's sessions share (the same
    /// role the flag-derived config plays in file mode).
    pub cfg: TunaConfig,
    /// Stop accepting once this many connections have been accepted and
    /// drain (0 = serve until the process dies). The CI smoke serves
    /// exactly one client this way and exits cleanly.
    pub max_conns: usize,
    /// Observability: connection open/close journal events, the
    /// `service_net_*` counters, and everything the service itself
    /// records. Disabled by default.
    pub obs: Recorder,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig { cfg: TunaConfig::default(), max_conns: 0, obs: Recorder::default() }
    }
}

/// Whole-server totals across all drained connections.
#[derive(Debug, Default)]
struct NetTotals {
    connections: AtomicU64,
    lines: AtomicU64,
    samples: AtomicU64,
    decisions: AtomicU64,
    failed: AtomicU64,
}

/// What a finished [`NetServer::serve`] drained, summed over every
/// connection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    pub connections: u64,
    pub lines: u64,
    pub samples: u64,
    pub decisions: u64,
    /// Connections that died on a protocol or socket error (their
    /// sessions were still closed server-side).
    pub failed: u64,
}

/// The TCP ingestion server. Bind, then [`NetServer::serve`] blocks the
/// calling thread on the accept loop; reader threads are scoped to the
/// call, so the borrowed [`TunerService`] outlives every connection.
pub struct NetServer {
    listener: TcpListener,
    config: NetServerConfig,
}

impl NetServer {
    /// Bind the listener (use port 0 to let the OS pick — the bound
    /// address is reported by [`Self::local_addr`], and `tuna serve
    /// --listen` prints it for scripts to scrape).
    pub fn bind(addr: &str, config: NetServerConfig) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        Ok(NetServer { listener, config })
    }

    /// The address the listener actually bound.
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound listener address")
    }

    /// Accept and serve connections until the connection budget is
    /// exhausted (forever when `max_conns == 0`), then drain: stop
    /// accepting, join every reader thread, and return the totals.
    /// Each connection's protocol/socket failures are contained to that
    /// connection (counted in [`NetStats::failed`], warned on stderr).
    pub fn serve(&self, service: &TunerService) -> Result<NetStats> {
        let config = &self.config;
        let totals = NetTotals::default();
        std::thread::scope(|scope| -> Result<()> {
            let mut accepted = 0usize;
            for conn in self.listener.incoming() {
                let stream = conn.context("accepting connection")?;
                accepted += 1;
                let totals = &totals;
                scope.spawn(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".to_string());
                    totals.connections.fetch_add(1, Ordering::Relaxed);
                    config.obs.count("service_net_connections_total", 1);
                    config.obs.record_with(|| EventKind::ConnOpen { peer: peer.clone() });
                    match handle_conn(stream, service, config, totals) {
                        Ok(stats) => {
                            config.obs.record_with(|| EventKind::ConnClose {
                                peer: peer.clone(),
                                sessions: stats.sessions_opened,
                                samples: stats.samples,
                                decisions: stats.decisions,
                            });
                        }
                        Err(e) => {
                            totals.failed.fetch_add(1, Ordering::Relaxed);
                            config.obs.count("service_net_conn_errors_total", 1);
                            config
                                .obs
                                .warn("service.net", &format!("connection {peer} failed: {e:#}"));
                        }
                    }
                });
                if config.max_conns > 0 && accepted >= config.max_conns {
                    break; // stop accepting; the scope joins the readers
                }
            }
            Ok(())
        })?;
        Ok(NetStats {
            connections: totals.connections.load(Ordering::Relaxed),
            lines: totals.lines.load(Ordering::Relaxed),
            samples: totals.samples.load(Ordering::Relaxed),
            decisions: totals.decisions.load(Ordering::Relaxed),
            failed: totals.failed.load(Ordering::Relaxed),
        })
    }
}

/// One connection's life: parse lines into the service, write every
/// output back down the socket, and on EOF close whatever the client
/// left open so its reports still flush before the socket does.
fn handle_conn(
    stream: TcpStream,
    service: &TunerService,
    config: &NetServerConfig,
    totals: &NetTotals,
) -> Result<IngestStats> {
    let reader = BufReader::new(stream.try_clone().context("cloning connection stream")?);
    let mut writer = BufWriter::new(stream);
    let mut ingestor = Ingestor::new_with_obs(service, config.cfg.clone(), config.obs.clone());
    // Socket write errors can't surface through the sink closure;
    // capture the first one and fail the connection after the stream
    // is drained (sessions are still closed below either way).
    let mut write_err: Option<std::io::Error> = None;
    let mut sink = |out: IngestOutput| {
        if write_err.is_none() {
            let r = writer
                .write_all(out.render_lines().as_bytes())
                .and_then(|()| writer.flush());
            if let Err(e) = r {
                write_err = Some(e);
            }
        }
    };
    let ingested = ingestor.ingest(reader, &mut sink);
    // Graceful drain: whatever the stream's outcome, close the
    // connection's remaining sessions so the shared service never
    // accumulates orphaned state from failed clients.
    let finished = ingestor.finish_all(&mut sink);
    let stats = ingested?;
    finished?;
    if let Some(e) = write_err {
        return Err(anyhow!(e).context("writing decisions back to client"));
    }
    totals.lines.fetch_add(stats.lines, Ordering::Relaxed);
    totals.samples.fetch_add(stats.samples, Ordering::Relaxed);
    totals.decisions.fetch_add(stats.decisions, Ordering::Relaxed);
    Ok(stats)
}

/// What [`serve_stream`] (the client side) pushed and got back.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetClientReport {
    /// Lines uploaded (comments and blanks included — the server skips
    /// them exactly as the file reader does).
    pub sent_lines: u64,
    /// Reply lines received (`decision …`, `closed …` and their
    /// continuation lines).
    pub reply_lines: u64,
}

/// The client side of the protocol: stream `input`'s lines to a
/// serving `tuna serve --listen` at `addr`, half-close the write side,
/// and hand every reply line to `on_reply` as it arrives. The reply
/// reader runs concurrently with the upload, so a server that answers
/// while the client is still writing back-pressures the upload instead
/// of deadlocking both sides on full socket buffers.
pub fn serve_stream(
    addr: &str,
    input: impl BufRead,
    on_reply: impl FnMut(&str) + Send,
) -> Result<NetClientReport> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to tuner service {addr}"))?;
    let read_half = stream.try_clone().context("cloning client stream")?;
    let mut writer = BufWriter::new(&stream);
    std::thread::scope(|scope| -> Result<NetClientReport> {
        let replies = scope.spawn(move || -> Result<u64> {
            let mut on_reply = on_reply;
            let mut n = 0u64;
            for line in BufReader::new(read_half).lines() {
                let line = line.context("reading service reply")?;
                on_reply(&line);
                n += 1;
            }
            Ok(n)
        });
        let mut sent_lines = 0u64;
        for line in input.lines() {
            let line = line.context("reading input stream")?;
            writer.write_all(line.as_bytes()).context("uploading stream line")?;
            writer.write_all(b"\n").context("uploading stream line")?;
            sent_lines += 1;
        }
        writer.flush().context("flushing upload")?;
        drop(writer);
        // half-close: the server sees EOF, drains, replies, closes
        stream.shutdown(Shutdown::Write).context("half-closing upload side")?;
        let reply_lines = replies
            .join()
            .map_err(|_| anyhow!("reply reader thread panicked"))??;
        Ok(NetClientReport { sent_lines, reply_lines })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::native::NativeNn;
    use crate::perfdb::{normalize, PerfDb, Record};
    use crate::service::ingest::{Event, STREAM_HEADER};
    use std::io::Cursor;
    use std::sync::Arc;

    fn db() -> Arc<PerfDb> {
        let fractions = vec![1.0f32, 0.9, 0.8, 0.7, 0.6, 0.5];
        let tolerant_raw = [10_000.0, 500.0, 20.0, 20.0, 4.0, 8_000.0, 2.0, 16.0];
        let hungry_raw = [200_000.0, 40_000.0, 300.0, 300.0, 0.05, 30_000.0, 2.0, 16.0];
        Arc::new(PerfDb {
            fractions,
            records: vec![
                Record {
                    raw: tolerant_raw,
                    vec: normalize(&tolerant_raw),
                    times_ns: vec![100.0, 100.5, 101.0, 102.0, 104.0, 130.0],
                },
                Record {
                    raw: hungry_raw,
                    vec: normalize(&hungry_raw),
                    times_ns: vec![100.0, 115.0, 140.0, 180.0, 240.0, 320.0],
                },
            ],
        })
    }

    fn sample_line(name: &str, interval: u32) -> String {
        format!(
            "sample {name} {interval} 10000 500 10000 500 1344000 1344000 20 0 20 0 100 \
             0 0 0 0 0 0 0 0 1000000"
        )
    }

    /// A two-session stream; `b` has no trailing close (drain must
    /// report it anyway).
    fn stream_text(intervals: u32) -> String {
        let mut s = format!("{STREAM_HEADER}\n");
        s.push_str("open a 8200 8000 2 16\n");
        s.push_str("open b 8200 8000 2 16\n");
        for i in 1..=intervals {
            s.push_str(&sample_line("a", i));
            s.push('\n');
            s.push_str(&sample_line("b", i));
            s.push('\n');
        }
        s.push_str("close a\n");
        s
    }

    fn cfg() -> TunaConfig {
        TunaConfig { period_s: 0.5, max_step_down: 0.04, ..TunaConfig::default() }
    }

    /// Reference rendering: the same stream through the in-process
    /// ingestor (what `tuna serve FILE` prints).
    fn file_mode_output(text: &str) -> String {
        let db = db();
        let service = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let mut ing = Ingestor::new(&service, cfg());
        let mut out = String::new();
        ing.ingest(Cursor::new(text), |o| out.push_str(&o.render_lines())).unwrap();
        ing.finish_all(|o| out.push_str(&o.render_lines())).unwrap();
        out
    }

    #[test]
    fn tcp_round_trip_is_byte_identical_to_file_mode() {
        let text = stream_text(20);
        let reference = file_mode_output(&text);
        assert!(reference.contains("decision a "));
        assert!(reference.contains("closed b:"), "drained session must report");

        let db = db();
        let service =
            TunerService::spawn_sharded(db.clone(), |_| Box::new(NativeNn::new(&db)), 3);
        let server = NetServer::bind(
            "127.0.0.1:0",
            NetServerConfig { cfg: cfg(), max_conns: 1, ..NetServerConfig::default() },
        )
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let (stats, client, replies) = std::thread::scope(|s| {
            let service = &service;
            let server = &server;
            let srv = s.spawn(move || server.serve(service).unwrap());
            let mut replies = String::new();
            let client = serve_stream(&addr, Cursor::new(text.as_bytes()), |line| {
                replies.push_str(line);
                replies.push('\n');
            })
            .unwrap();
            (srv.join().unwrap(), client, replies)
        });
        assert_eq!(replies, reference, "socket replies must match file-mode bytes");
        assert_eq!(stats.connections, 1);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.samples, 40);
        assert_eq!(client.sent_lines as usize, text.lines().count());
        assert_eq!(client.reply_lines as usize, reference.lines().count());
    }

    #[test]
    fn concurrent_connections_stay_independent() {
        let db = db();
        let service =
            TunerService::spawn_sharded(db.clone(), |_| Box::new(NativeNn::new(&db)), 2);
        let server = NetServer::bind(
            "127.0.0.1:0",
            NetServerConfig { cfg: cfg(), max_conns: 3, ..NetServerConfig::default() },
        )
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stats = std::thread::scope(|s| {
            let service = &service;
            let server = &server;
            let srv = s.spawn(move || server.serve(service).unwrap());
            for c in 0..3u32 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut text = format!("open conn{c} 8200 8000 2 16\n");
                    for i in 1..=10u32 {
                        text.push_str(&sample_line(&format!("conn{c}"), i));
                        text.push('\n');
                    }
                    text.push_str(&format!("close conn{c}\n"));
                    let mut got_close = false;
                    serve_stream(&addr, Cursor::new(text), |line| {
                        got_close |= line.starts_with(&format!("closed conn{c}:"));
                    })
                    .unwrap();
                    assert!(got_close, "conn{c} must get its own close report");
                });
            }
            srv.join().unwrap()
        });
        assert_eq!(stats.connections, 3);
        assert_eq!(stats.samples, 30);
        assert_eq!(stats.failed, 0);
    }

    #[test]
    fn protocol_garbage_fails_only_its_own_connection() {
        let db = db();
        let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        let server = NetServer::bind(
            "127.0.0.1:0",
            NetServerConfig { cfg: cfg(), max_conns: 2, ..NetServerConfig::default() },
        )
        .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stats = std::thread::scope(|s| {
            let service = &service;
            let server = &server;
            let srv = s.spawn(move || server.serve(service).unwrap());
            // bad connection: unknown verb kills it
            serve_stream(&addr, Cursor::new("frobnicate x\n"), |_| {}).unwrap();
            // good connection afterwards still serves
            let mut text = String::from("open ok 8200 8000 2 16\n");
            for i in 1..=5u32 {
                text.push_str(&sample_line("ok", i));
                text.push('\n');
            }
            text.push_str("close ok\n");
            let mut closed = false;
            serve_stream(&addr, Cursor::new(text), |line| {
                closed |= line.starts_with("closed ok:");
            })
            .unwrap();
            assert!(closed);
            srv.join().unwrap()
        });
        assert_eq!(stats.connections, 2);
        assert_eq!(stats.failed, 1, "the garbage connection must be the only failure");
        assert_eq!(stats.samples, 5);
    }

    #[test]
    fn render_lines_matches_event_protocol() {
        // spot-check the Event writer side against the reader used by
        // the server (`sample_line` above must stay a valid 21-field
        // line for the other tests to mean anything)
        let parsed = Event::parse(&sample_line("s", 3)).unwrap();
        assert!(matches!(parsed, Some(Event::Sample { .. })));
    }
}
