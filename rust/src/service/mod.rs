//! Tuner-as-a-service: one long-lived [`TunerService`] owning the
//! performance-database backend and the decision logic, fed by many
//! concurrent sessions.
//!
//! The paper's deployment story (and the ROADMAP north star) is "one
//! tuner service, many workloads": telemetry is cheap and flows in from
//! every run, while the modeling artifacts and the query backend are
//! shared. This module is that split made concrete:
//!
//! * [`TunerService`] owns the [`PerfDb`] and a single shared
//!   [`NnQuery`] backend, and hosts one [`crate::tuner::TunerState`]
//!   per session (telemetry aggregation is keyed by session — sessions
//!   share nothing but the backend).
//! * [`SessionHandle`] is a run's connection: it publishes
//!   [`TelemetrySample`]s and, at tuning-period boundaries, polls its
//!   decision mailbox for the [`Watermarks`] the service sent back.
//! * Two wirings with identical semantics:
//!   [`TunerService::inline`] executes everything synchronously in the
//!   caller (no thread — the reference mode), while
//!   [`TunerService::spawn`] moves aggregation and decisions onto a
//!   background thread behind a **bounded** mpsc channel. Samples are
//!   fire-and-forget; only period-boundary decision requests block the
//!   publisher until the mailbox answers, which is exactly what keeps
//!   the channel path bit-identical to the classic in-loop tuner for
//!   any number of concurrent sessions (proven in the integration
//!   suite's determinism tests).
//! * Fleet scale: [`TunerService::spawn_sharded`] splits aggregation
//!   across N workers, each owning the sessions whose stable name hash
//!   routes to it (FNV-1a mod N), with one bounded channel and one
//!   query backend per worker over the shared [`PerfSource`]. Sessions
//!   share nothing but the database, so sharding is invisible to
//!   decisions: `workers = 1` is exactly [`TunerService::spawn`], and
//!   any worker count is bit-identical to [`TunerService::inline`].
//!   Each worker drains its channel in batches and coalesces the
//!   decision queries that arrived together, amortizing perf-DB
//!   fan-out across same-boundary sessions (safe because a session
//!   blocks on its mailbox after requesting a decision — nothing of
//!   its own can queue behind an unanswered `Decide`).
//!
//! The text ingestion protocol (`tuna serve`) lives in [`ingest`];
//! the TCP ingestion server/client (`tuna serve --listen`) in [`net`].

pub mod ingest;
pub mod net;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::config::experiment::TunaConfig;
use crate::outcome::OutcomeRecord;
use crate::perfdb::native::NnQuery;
use crate::perfdb::PerfSource;
use crate::telemetry::TelemetrySample;
use crate::tpp::Watermarks;
use crate::tuner::{Decision, TunerState};

pub use ingest::{Event, IngestOutput, IngestStats, Ingestor};
pub use net::{serve_stream, NetClientReport, NetServer, NetServerConfig, NetStats};

/// Default bound on the sample channel: deep enough that publishers never
/// stall on aggregation hiccups, small enough that a wedged service
/// exerts back-pressure instead of buffering unboundedly.
pub const DEFAULT_CHANNEL_CAPACITY: usize = 1024;

/// Everything the service needs to open a session: the session-constant
/// query dimensions plus the tuner config governing its decisions.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    /// Free-form session name (reports, `tuna serve` streams). Must not
    /// contain whitespace when used with the text protocol.
    pub name: String,
    /// Fast-tier capacity in pages (fixed; decisions move watermarks).
    pub capacity: u64,
    /// Workload RSS in pages (the 100% reference for fractions).
    pub rss_pages: u64,
    /// Page-management promotion threshold.
    pub hot_thr: u32,
    /// Worker threads of the workload.
    pub threads: u32,
    /// Tuner parameters for this session.
    pub cfg: TunaConfig,
}

/// Final accounting for one closed session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    pub name: String,
    /// Samples the service aggregated for this session.
    pub samples: u64,
    pub decisions: Vec<Decision>,
    pub mean_fraction: f64,
    pub min_fraction: f64,
    /// Cumulative vmstat counters at close.
    pub vmstat: Vec<(&'static str, u64)>,
    /// Total decision-path time (ns) across the session.
    pub decide_ns: u128,
    /// Predicted-vs-realized outcomes (empty unless the session's
    /// `cfg.retune` mode is `observe` or `on`). The trailing decision's
    /// window is settled at close, so every decision with at least one
    /// subsequent sample is accounted for.
    pub outcomes: Vec<OutcomeRecord>,
    /// Drift-forced early re-decides taken (0 unless `retune = on`).
    pub retunes: u64,
}

/// A decision boundary's answer: the watermarks (when a decision was
/// taken) plus how many intervals the session should wait before the
/// next boundary. Computing the wait server-side — in
/// [`TunerState::next_period`], right after the decision — is what
/// keeps drift-forced early re-decides bit-identical between the
/// inline and channel wirings: both learn the shortened period from
/// the same state transition, in the same message order.
struct DecisionReply {
    wm: Option<Watermarks>,
    next_wait: u32,
}

/// Messages on the service channel. Per-sender FIFO ordering of the mpsc
/// channel is what makes the protocol deterministic: a session's
/// `Decide` always arrives after every sample it should cover.
enum Msg {
    Open(u64, SessionSpec, SyncSender<DecisionReply>),
    Sample(u64, TelemetrySample),
    Decide(u64, u32),
    Close(u64, SyncSender<SessionReport>),
}

/// One hosted session: its tuner state plus the decision mailbox
/// (channel mode only).
struct Session {
    name: String,
    state: TunerState,
    mailbox: Option<SyncSender<DecisionReply>>,
    samples: u64,
    /// Interval of the last sample seen (the end marker for settling
    /// the trailing outcome window at close).
    last_interval: u32,
}

/// The service state proper: shared query backend + per-session states.
/// Lives behind a mutex (inline mode) or on the aggregation thread
/// (channel mode); the code paths are the same either way.
///
/// The database is any [`PerfSource`] — flat and in memory, or a lazy
/// sharded DB serving every session from one bounded resident set (the
/// sessions share the source's segment cache *and* its cap; decisions
/// stay bit-identical to a flat-backed service).
struct Core {
    db: Arc<dyn PerfSource>,
    query: Box<dyn NnQuery + Send>,
    sessions: HashMap<u64, Session>,
    /// Observability handle: cloned into every hosted session's tuner
    /// state (so decisions journal through it) and counted for session
    /// lifecycle. Disabled by default — the plain constructors.
    obs: crate::obs::Recorder,
    /// Pre-rendered `worker="N"` gauge label for this core's shard
    /// (worker 0 in inline mode), so the per-worker balance gauges
    /// don't allocate on every update.
    worker_label: String,
}

impl Core {
    fn new(
        db: Arc<dyn PerfSource>,
        query: Box<dyn NnQuery + Send>,
        obs: crate::obs::Recorder,
        worker: usize,
    ) -> Self {
        Core {
            db,
            query,
            sessions: HashMap::new(),
            obs,
            worker_label: format!("worker=\"{worker}\""),
        }
    }

    fn open(&mut self, id: u64, spec: SessionSpec, mailbox: Option<SyncSender<DecisionReply>>) {
        let mut state = TunerState::new(
            self.db.clone(),
            spec.cfg,
            spec.capacity,
            spec.rss_pages,
            spec.hot_thr,
            spec.threads,
        );
        state.set_obs(self.obs.clone());
        state.set_session_label(&spec.name);
        self.obs.count("service_sessions_opened_total", 1);
        self.sessions.insert(
            id,
            Session { name: spec.name, state, mailbox, samples: 0, last_interval: 0 },
        );
        self.obs.gauge_labeled(
            "service_worker_sessions",
            &self.worker_label,
            self.sessions.len() as f64,
        );
    }

    fn sample(&mut self, id: u64, s: &TelemetrySample) {
        if let Some(sess) = self.sessions.get_mut(&id) {
            sess.state.ingest(s);
            sess.samples += 1;
            sess.last_interval = s.interval;
        }
    }

    fn decide(&mut self, id: u64, interval: u32) -> Option<(Option<Watermarks>, u32)> {
        // split borrows: the session state and the shared backend are
        // disjoint fields of the core
        let Core { sessions, query, .. } = self;
        let sess = sessions.get_mut(&id)?;
        let wm = sess.state.decide(interval, query.as_mut());
        Some((wm, sess.state.next_period()))
    }

    fn close(&mut self, id: u64) -> Option<SessionReport> {
        let mut sess = self.sessions.remove(&id)?;
        self.obs.count("service_sessions_closed_total", 1);
        self.obs.gauge_labeled(
            "service_worker_sessions",
            &self.worker_label,
            self.sessions.len() as f64,
        );
        // settle the last decision's outcome window before reporting
        sess.state.finish_outcome(sess.last_interval);
        let mean_fraction = sess.state.mean_fraction();
        let min_fraction = sess.state.min_fraction();
        let vmstat = sess.state.vmstat();
        let outcomes = sess.state.outcomes().to_vec();
        let retunes = sess.state.retunes();
        Some(SessionReport {
            name: sess.name,
            samples: sess.samples,
            mean_fraction,
            min_fraction,
            vmstat,
            decide_ns: sess.state.decide_ns,
            decisions: sess.state.decisions,
            outcomes,
            retunes,
        })
    }

    /// Apply one message, deferring decision queries into `pending`.
    /// Deferral never reorders a session against itself: after sending
    /// `Decide` the session's publisher blocks on its mailbox, so no
    /// later message from that session can be in the queue — only
    /// *other* sessions' traffic slides past, and sessions share
    /// nothing but the (order-insensitive) query backend.
    fn absorb(&mut self, msg: Msg, pending: &mut Vec<(u64, u32)>) {
        match msg {
            Msg::Open(id, spec, mailbox) => self.open(id, spec, Some(mailbox)),
            Msg::Sample(id, s) => self.sample(id, &s),
            Msg::Decide(id, interval) => pending.push((id, interval)),
            Msg::Close(id, reply) => {
                if let Some(report) = self.close(id) {
                    reply.send(report).ok();
                }
                // an unknown id drops `reply`, which surfaces as an error
                // on the handle's recv — no silent hang
            }
        }
    }

    /// Run the deferred decision queries back-to-back, in arrival
    /// order, and answer each session's mailbox. Executing them as one
    /// batch amortizes the perf-DB fan-out (segment touches, query
    /// setup) across every session that hit its boundary in the same
    /// channel drain.
    fn flush_decides(&mut self, pending: &mut Vec<(u64, u32)>) {
        if pending.len() > 1 {
            self.obs
                .count("service_ingest_batched_queries_total", pending.len() as u64);
        }
        for (id, interval) in pending.drain(..) {
            if let Some((wm, next_wait)) = self.decide(id, interval) {
                if let Some(mb) = self.sessions.get(&id).and_then(|s| s.mailbox.as_ref()) {
                    mb.send(DecisionReply { wm, next_wait }).ok();
                }
            }
        }
    }

    /// One aggregation worker's life: block for traffic, drain whatever
    /// else is already queued, then flush the coalesced decisions. The
    /// queue-depth gauge tracks how much each drain absorbed — the
    /// worker-balance signal `tuna obs summary` surfaces.
    fn run(mut self, rx: Receiver<Msg>) {
        let mut pending: Vec<(u64, u32)> = Vec::new();
        while let Ok(first) = rx.recv() {
            let mut drained = 1u64;
            self.absorb(first, &mut pending);
            while let Ok(msg) = rx.try_recv() {
                drained += 1;
                self.absorb(msg, &mut pending);
            }
            self.obs
                .gauge_labeled("service_worker_queue_depth", &self.worker_label, drained as f64);
            self.flush_decides(&mut pending);
        }
    }
}

enum Mode {
    Inline(Mutex<Core>),
    Channel {
        /// One bounded sender per aggregation worker; `None` after
        /// shutdown. A session's sender (picked by stable name hash)
        /// is cloned into its handle at registration.
        txs: Mutex<Option<Vec<SyncSender<Msg>>>>,
        joins: Mutex<Vec<JoinHandle<()>>>,
    },
}

/// The tuner service. Construct with [`Self::inline`] (synchronous, the
/// reference mode), [`Self::spawn`] (one background aggregation worker,
/// bounded channel), or [`Self::spawn_sharded`] (N workers, sessions
/// routed by stable name hash); register any number of concurrent
/// sessions with [`Self::register`]. Decisions are bit-identical across
/// all modes, worker counts and session interleavings because the
/// per-session state and the decision code are exactly the in-loop
/// tuner's — sessions share nothing but the database.
pub struct TunerService {
    mode: Mode,
    next_id: AtomicU64,
    backend: &'static str,
    workers: usize,
}

impl TunerService {
    /// Synchronous service: every publish aggregates under a lock in the
    /// caller's thread. No background thread — the mode the channel path
    /// is proven equivalent to, and the right choice for single-run CLI
    /// commands.
    pub fn inline(db: Arc<dyn PerfSource>, query: Box<dyn NnQuery + Send>) -> Self {
        Self::inline_with_obs(db, query, crate::obs::Recorder::default())
    }

    /// As [`Self::inline`], with an observability recorder cloned into
    /// every hosted session. A disabled recorder makes this identical to
    /// the plain constructor.
    pub fn inline_with_obs(
        db: Arc<dyn PerfSource>,
        query: Box<dyn NnQuery + Send>,
        obs: crate::obs::Recorder,
    ) -> Self {
        let backend = query.backend();
        TunerService {
            mode: Mode::Inline(Mutex::new(Core::new(db, query, obs, 0))),
            next_id: AtomicU64::new(1),
            backend,
            workers: 1,
        }
    }

    /// Channel service with the default channel capacity.
    pub fn spawn(db: Arc<dyn PerfSource>, query: Box<dyn NnQuery + Send>) -> Self {
        Self::spawn_with_capacity(db, query, DEFAULT_CHANNEL_CAPACITY)
    }

    /// As [`Self::spawn`], with an observability recorder for the hosted
    /// sessions.
    pub fn spawn_with_obs(
        db: Arc<dyn PerfSource>,
        query: Box<dyn NnQuery + Send>,
        obs: crate::obs::Recorder,
    ) -> Self {
        Self::spawn_with_capacity_and_obs(db, query, DEFAULT_CHANNEL_CAPACITY, obs)
    }

    /// Channel service: aggregation and decisions run on a dedicated
    /// background thread fed by a bounded mpsc channel of `capacity`
    /// messages.
    pub fn spawn_with_capacity(
        db: Arc<dyn PerfSource>,
        query: Box<dyn NnQuery + Send>,
        capacity: usize,
    ) -> Self {
        Self::spawn_with_capacity_and_obs(db, query, capacity, crate::obs::Recorder::default())
    }

    /// As [`Self::spawn`], with an explicit channel capacity and
    /// observability recorder (the single-worker special case of
    /// [`Self::spawn_workers`]).
    pub fn spawn_with_capacity_and_obs(
        db: Arc<dyn PerfSource>,
        query: Box<dyn NnQuery + Send>,
        capacity: usize,
        obs: crate::obs::Recorder,
    ) -> Self {
        Self::spawn_workers(db, vec![query], capacity, obs)
    }

    /// Sharded channel service: one aggregation worker per entry of
    /// `nn_factory(0..workers)`, each behind its own bounded channel
    /// (default capacity) over the shared database. Sessions route to
    /// workers by stable name hash, so placement — and therefore every
    /// decision — is independent of scheduling: `workers = 1` is
    /// exactly [`Self::spawn`], and any count is bit-identical to
    /// [`Self::inline`].
    pub fn spawn_sharded(
        db: Arc<dyn PerfSource>,
        nn_factory: impl FnMut(usize) -> Box<dyn NnQuery + Send>,
        workers: usize,
    ) -> Self {
        Self::spawn_sharded_with_capacity_and_obs(
            db,
            nn_factory,
            workers,
            DEFAULT_CHANNEL_CAPACITY,
            crate::obs::Recorder::default(),
        )
    }

    /// As [`Self::spawn_sharded`], with an observability recorder for
    /// the hosted sessions and per-worker balance gauges.
    pub fn spawn_sharded_with_obs(
        db: Arc<dyn PerfSource>,
        nn_factory: impl FnMut(usize) -> Box<dyn NnQuery + Send>,
        workers: usize,
        obs: crate::obs::Recorder,
    ) -> Self {
        Self::spawn_sharded_with_capacity_and_obs(
            db,
            nn_factory,
            workers,
            DEFAULT_CHANNEL_CAPACITY,
            obs,
        )
    }

    /// The full-control sharded constructor: explicit worker count,
    /// per-worker channel capacity and observability recorder.
    pub fn spawn_sharded_with_capacity_and_obs(
        db: Arc<dyn PerfSource>,
        mut nn_factory: impl FnMut(usize) -> Box<dyn NnQuery + Send>,
        workers: usize,
        capacity: usize,
        obs: crate::obs::Recorder,
    ) -> Self {
        let queries: Vec<_> = (0..workers.max(1)).map(&mut nn_factory).collect();
        Self::spawn_workers(db, queries, capacity, obs)
    }

    fn spawn_workers(
        db: Arc<dyn PerfSource>,
        queries: Vec<Box<dyn NnQuery + Send>>,
        capacity: usize,
        obs: crate::obs::Recorder,
    ) -> Self {
        let workers = queries.len();
        let backend = queries[0].backend();
        let mut txs = Vec::with_capacity(workers);
        let mut joins = Vec::with_capacity(workers);
        for (w, query) in queries.into_iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::sync_channel::<Msg>(capacity.max(1));
            let core = Core::new(db.clone(), query, obs.clone(), w);
            let join = std::thread::Builder::new()
                .name(format!("tuna-tuner-w{w}"))
                .spawn(move || core.run(rx))
                .expect("spawning tuner-service aggregation worker");
            txs.push(tx);
            joins.push(join);
        }
        TunerService {
            mode: Mode::Channel { txs: Mutex::new(Some(txs)), joins: Mutex::new(joins) },
            next_id: AtomicU64::new(1),
            backend,
            workers,
        }
    }

    /// Query-backend name ("native" / "xla"), for reports.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Whether this service runs the background-channel wiring.
    pub fn is_channel(&self) -> bool {
        matches!(self.mode, Mode::Channel { .. })
    }

    /// Aggregation workers this service runs (1 in inline mode).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The worker a session of this name would route to: FNV-1a of the
    /// name, mod the worker count. Stable across runs and processes —
    /// session placement (and so decision state) never depends on
    /// registration order or scheduling.
    pub fn worker_for(&self, name: &str) -> usize {
        (crate::artifact::fnv1a64(name.as_bytes()) % self.workers.max(1) as u64) as usize
    }

    fn with_core<R>(&self, f: impl FnOnce(&mut Core) -> R) -> Option<R> {
        match &self.mode {
            Mode::Inline(core) => Some(f(&mut core.lock().unwrap())),
            Mode::Channel { .. } => None,
        }
    }

    /// Total sessions ever registered (ids are 1-based).
    pub fn sessions_registered(&self) -> u64 {
        self.next_id.load(Ordering::Relaxed).saturating_sub(1)
    }

    /// Open a session. The returned handle publishes samples and polls
    /// decisions; call [`SessionHandle::finish`] to collect the report
    /// (and, in channel mode, release the sender so the service can shut
    /// down).
    pub fn register(&self, spec: SessionSpec) -> Result<SessionHandle<'_>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let period_intervals = spec.cfg.period_intervals();
        let capacity = spec.capacity;
        let name = spec.name.clone();
        let conn = match &self.mode {
            Mode::Inline(core) => {
                core.lock().unwrap().open(id, spec, None);
                HandleConn::Inline
            }
            Mode::Channel { txs, .. } => {
                let tx = {
                    let guard = txs.lock().unwrap();
                    let txs = guard
                        .as_ref()
                        .ok_or_else(|| anyhow!("tuner service is shut down"))?;
                    txs[self.worker_for(&name)].clone()
                };
                let (mb_tx, mb_rx) = std::sync::mpsc::sync_channel(1);
                tx.send(Msg::Open(id, spec, mb_tx))
                    .map_err(|_| anyhow!("tuner service thread is gone"))?;
                HandleConn::Channel { tx, mailbox: mb_rx }
            }
        };
        Ok(SessionHandle {
            svc: self,
            conn,
            id,
            name,
            capacity,
            next_wait: period_intervals,
            since_decision: 0,
            published: 0,
            dead: false,
        })
    }

    /// Stop accepting new sessions and join every aggregation worker
    /// (channel mode; a no-op inline). Every registered handle must be
    /// finished first — their channel clones keep their worker alive.
    pub fn shutdown(&self) {
        if let Mode::Channel { txs, joins } = &self.mode {
            txs.lock().unwrap().take();
            let joins: Vec<_> = joins.lock().unwrap().drain(..).collect();
            for j in joins {
                j.join().ok();
            }
        }
    }
}

impl Drop for TunerService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

enum HandleConn {
    Inline,
    Channel { tx: SyncSender<Msg>, mailbox: Receiver<DecisionReply> },
}

/// One run's connection to a [`TunerService`]: publish a sample per
/// interval; at tuning-period boundaries the handle requests a decision
/// and blocks on its mailbox until the service answers, so the returned
/// watermarks program the policy at the same interval boundary the
/// in-loop tuner would have programmed them.
pub struct SessionHandle<'s> {
    svc: &'s TunerService,
    conn: HandleConn,
    id: u64,
    name: String,
    capacity: u64,
    /// Intervals until the next decision boundary. Starts at the
    /// configured tuning period; every decision reply refreshes it
    /// (shortened only by an armed drift detector under `retune = on`).
    next_wait: u32,
    since_decision: u32,
    published: u64,
    dead: bool,
}

impl SessionHandle<'_> {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Fast-tier capacity this session was opened with (pages).
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Samples published so far.
    pub fn published(&self) -> u64 {
        self.published
    }

    /// True once the service stopped answering (thread gone); publishes
    /// become no-ops rather than panics — the run continues untuned.
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Publish one interval's sample. Returns new watermarks when this
    /// sample closed a tuning period and the service took a decision.
    pub fn publish(&mut self, sample: TelemetrySample) -> Option<Watermarks> {
        if self.dead {
            return None;
        }
        let interval = sample.interval;
        match &mut self.conn {
            HandleConn::Inline => {
                self.svc.with_core(|core| core.sample(self.id, &sample));
            }
            HandleConn::Channel { tx, .. } => {
                if tx.send(Msg::Sample(self.id, sample)).is_err() {
                    self.dead = true;
                    return None;
                }
            }
        }
        self.published += 1;
        self.since_decision += 1;
        if self.since_decision < self.next_wait {
            return None;
        }
        self.since_decision = 0;
        self.request_decision(interval)
    }

    /// Ask the service for a decision over the current telemetry window
    /// (normally driven by [`Self::publish`]'s period counting). The
    /// reply also refreshes [`Self::next_wait`] — the service, not the
    /// handle, owns the cadence, so a drift-armed session re-decides
    /// early in both wirings identically.
    pub fn request_decision(&mut self, interval: u32) -> Option<Watermarks> {
        if self.dead {
            return None;
        }
        match &mut self.conn {
            HandleConn::Inline => {
                match self.svc.with_core(|core| core.decide(self.id, interval)).flatten() {
                    Some((wm, next_wait)) => {
                        self.next_wait = next_wait.max(1);
                        wm
                    }
                    None => None,
                }
            }
            HandleConn::Channel { tx, mailbox } => {
                if tx.send(Msg::Decide(self.id, interval)).is_err() {
                    self.dead = true;
                    return None;
                }
                match mailbox.recv() {
                    Ok(reply) => {
                        self.next_wait = reply.next_wait.max(1);
                        reply.wm
                    }
                    Err(_) => {
                        self.dead = true;
                        None
                    }
                }
            }
        }
    }

    /// Close the session and collect its report.
    pub fn finish(self) -> Result<SessionReport> {
        match self.conn {
            HandleConn::Inline => self
                .svc
                .with_core(|core| core.close(self.id))
                .flatten()
                .ok_or_else(|| anyhow!("session {} is not open", self.id)),
            HandleConn::Channel { tx, .. } => {
                let (reply_tx, reply_rx) = std::sync::mpsc::sync_channel(1);
                tx.send(Msg::Close(self.id, reply_tx))
                    .map_err(|_| anyhow!("tuner service thread is gone"))?;
                reply_rx
                    .recv()
                    .map_err(|_| anyhow!("tuner service dropped session {}", self.id))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::native::NativeNn;
    use crate::perfdb::{normalize, PerfDb, Record};

    fn db() -> Arc<PerfDb> {
        let fractions = vec![1.0f32, 0.9, 0.8, 0.7, 0.6, 0.5];
        let tolerant_raw = [10_000.0, 500.0, 20.0, 20.0, 4.0, 8_000.0, 2.0, 16.0];
        let hungry_raw = [200_000.0, 40_000.0, 300.0, 300.0, 0.05, 30_000.0, 2.0, 16.0];
        Arc::new(PerfDb {
            fractions,
            records: vec![
                Record {
                    raw: tolerant_raw,
                    vec: normalize(&tolerant_raw),
                    times_ns: vec![100.0, 100.5, 101.0, 102.0, 104.0, 130.0],
                },
                Record {
                    raw: hungry_raw,
                    vec: normalize(&hungry_raw),
                    times_ns: vec![100.0, 115.0, 140.0, 180.0, 240.0, 320.0],
                },
            ],
        })
    }

    fn spec(name: &str) -> SessionSpec {
        SessionSpec {
            name: name.to_string(),
            capacity: 8_200,
            rss_pages: 8_000,
            hot_thr: 2,
            threads: 16,
            cfg: TunaConfig { period_s: 0.5, max_step_down: 0.04, ..TunaConfig::default() },
        }
    }

    fn sample(interval: u32, salt: u64) -> TelemetrySample {
        TelemetrySample {
            interval,
            acc_fast: 10_000 + salt,
            acc_slow: 500,
            sacc_fast: 10_000 + salt,
            sacc_slow: 500,
            flops: 10_500 * 64 * 2,
            iops: 10_500 * 64 * 2,
            promoted: 20,
            promote_failed: 0,
            demoted_kswapd: 20,
            demoted_direct: 0,
            shadow_hits: 0,
            shadow_free_demotions: 0,
            txn_aborts: 0,
            txn_retried_copies: 0,
            admission_accepted: 0,
            admission_rejected_budget: 0,
            admission_rejected_payoff: 0,
            admission_rejected_cooldown: 0,
            fast_free: 100,
            wall_ns: 1_000_000,
        }
    }

    fn drive(service: &TunerService, name: &str, n: u32, salt: u64) -> SessionReport {
        let mut h = service.register(spec(name)).unwrap();
        let mut boundaries = Vec::new();
        for i in 1..=n {
            if let Some(wm) = h.publish(sample(i, salt)) {
                boundaries.push((i, wm.usable(8_200)));
            }
        }
        let report = h.finish().unwrap();
        // every decision the report carries was delivered at its boundary
        assert_eq!(boundaries.len(), report.decisions.len());
        for (d, (i, fm)) in report.decisions.iter().zip(&boundaries) {
            assert_eq!(d.interval, *i);
            assert_eq!(d.new_fm, *fm);
        }
        report
    }

    #[test]
    fn inline_and_channel_modes_agree_bitwise() {
        let db = db();
        let inline = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let channel = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        assert!(!inline.is_channel());
        assert!(channel.is_channel());
        let a = drive(&inline, "a", 20, 0);
        let b = drive(&channel, "b", 20, 0);
        assert_eq!(a.samples, 20);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.decisions.len(), 4, "one decision per 5-interval period");
        assert_eq!(a.decisions.len(), b.decisions.len());
        for (x, y) in a.decisions.iter().zip(&b.decisions) {
            assert_eq!(x.interval, y.interval);
            assert_eq!(x.record, y.record);
            assert_eq!(x.fraction.to_bits(), y.fraction.to_bits());
            assert_eq!(x.new_fm, y.new_fm);
            assert_eq!(x.predicted_loss.to_bits(), y.predicted_loss.to_bits());
        }
        assert_eq!(a.mean_fraction.to_bits(), b.mean_fraction.to_bits());
        assert_eq!(a.vmstat, b.vmstat);
    }

    #[test]
    fn sharded_workers_match_inline_bitwise_at_any_count() {
        let db = db();
        // sequential inline reference, one fresh service per session
        let reference: Vec<SessionReport> = (0..6u64)
            .map(|i| {
                let svc = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
                drive(&svc, &format!("s{i}"), 25, i * 7)
            })
            .collect();
        for workers in [1usize, 3, 8] {
            let service =
                TunerService::spawn_sharded(db.clone(), |_| Box::new(NativeNn::new(&db)), workers);
            assert_eq!(service.workers(), workers);
            let sharded: Vec<SessionReport> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..6u64)
                    .map(|i| {
                        let service = &service;
                        s.spawn(move || drive(service, &format!("s{i}"), 25, i * 7))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (a, b) in reference.iter().zip(&sharded) {
                assert_eq!(a.samples, b.samples, "workers={workers}");
                assert_eq!(a.decisions.len(), b.decisions.len(), "workers={workers}");
                for (x, y) in a.decisions.iter().zip(&b.decisions) {
                    assert_eq!(x.interval, y.interval);
                    assert_eq!(x.record, y.record);
                    assert_eq!(x.fraction.to_bits(), y.fraction.to_bits());
                    assert_eq!(x.new_fm, y.new_fm);
                    assert_eq!(x.predicted_loss.to_bits(), y.predicted_loss.to_bits());
                }
                assert_eq!(a.vmstat, b.vmstat, "workers={workers}");
            }
        }
    }

    #[test]
    fn session_routing_is_a_stable_name_hash() {
        let db = db();
        let service = TunerService::spawn_sharded(db.clone(), |_| Box::new(NativeNn::new(&db)), 4);
        // FNV-1a is a process-independent function of the name alone
        assert_eq!(service.worker_for("alpha"), service.worker_for("alpha"));
        let spread: std::collections::HashSet<usize> =
            (0..64).map(|i| service.worker_for(&format!("sess-{i}"))).collect();
        assert!(spread.len() > 1, "64 names must not all land on one of 4 workers");
        assert!(spread.iter().all(|&w| w < 4));
        // inline services report one worker and route everything to it
        let inline = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        assert_eq!(inline.workers(), 1);
        assert_eq!(inline.worker_for("anything"), 0);
    }

    #[test]
    fn batched_decides_answer_every_mailbox() {
        // Overlapping sessions whose boundaries coincide: decisions for
        // several sessions land in one drain on the same worker, so the
        // deferred-flush path must answer each mailbox exactly once.
        let db = db();
        let service = TunerService::spawn_sharded(db.clone(), |_| Box::new(NativeNn::new(&db)), 1);
        let reports: Vec<SessionReport> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4u64)
                .map(|i| {
                    let service = &service;
                    s.spawn(move || drive(service, &format!("batch{i}"), 20, 0))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &reports {
            assert_eq!(r.samples, 20);
            assert_eq!(r.decisions.len(), 4, "one decision per 5-interval period");
        }
    }

    #[test]
    fn concurrent_sessions_are_independent_and_deterministic() {
        let db = db();
        // sequential reference: one session at a time on a fresh service
        let reference: Vec<SessionReport> = (0..6u64)
            .map(|i| {
                let svc = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
                drive(&svc, &format!("ref{i}"), 25, i * 7)
            })
            .collect();
        // concurrent: all six feed one shared channel service at once
        let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        let concurrent: Vec<SessionReport> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..6u64)
                .map(|i| {
                    let service = &service;
                    s.spawn(move || drive(service, &format!("c{i}"), 25, i * 7))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (a, b) in reference.iter().zip(&concurrent) {
            assert_eq!(a.decisions.len(), b.decisions.len());
            for (x, y) in a.decisions.iter().zip(&b.decisions) {
                assert_eq!(x.fraction.to_bits(), y.fraction.to_bits());
                assert_eq!(x.new_fm, y.new_fm);
                assert_eq!(x.record, y.record);
            }
            assert_eq!(a.samples, b.samples);
        }
    }

    #[test]
    fn finish_reports_vmstat_and_query_budget() {
        let db = db();
        let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        assert_eq!(service.backend(), "native");
        let report = drive(&service, "budget", 10, 0);
        assert_eq!(report.name, "budget");
        assert!(report.decide_ns > 0, "decisions must bill query time");
        assert!(report
            .vmstat
            .iter()
            .any(|&(k, v)| k == "pgpromote_success" && v == 200));
        assert!(report.mean_fraction < 1.0);
        assert!(report.min_fraction <= report.mean_fraction);
    }

    #[test]
    fn shutdown_then_register_errors_instead_of_hanging() {
        let db = db();
        let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        service.shutdown();
        assert!(service.register(spec("late")).is_err());
        // double shutdown is a no-op
        service.shutdown();
    }

    #[test]
    fn observe_mode_reports_outcomes_without_changing_decisions() {
        use crate::outcome::{RetuneConfig, RetuneMode};
        let db = db();
        let off_svc = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let off = drive(&off_svc, "off", 22, 0);
        assert!(off.outcomes.is_empty(), "off mode must report no outcomes");
        assert_eq!(off.retunes, 0);

        let obs_svc = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let mut sp = spec("observing");
        sp.cfg.retune = RetuneConfig { mode: RetuneMode::Observe, ..RetuneConfig::default() };
        let mut h = obs_svc.register(sp).unwrap();
        for i in 1..=22u32 {
            h.publish(sample(i, 0));
        }
        let observed = h.finish().unwrap();
        assert_eq!(off.decisions.len(), observed.decisions.len());
        for (x, y) in off.decisions.iter().zip(&observed.decisions) {
            assert_eq!(x.interval, y.interval);
            assert_eq!(x.fraction.to_bits(), y.fraction.to_bits());
            assert_eq!(x.new_fm, y.new_fm);
        }
        // three settled at boundaries + the trailing window at close
        assert_eq!(observed.outcomes.len(), observed.decisions.len());
        assert_eq!(observed.retunes, 0, "observe mode never acts");
        for o in &observed.outcomes {
            assert_eq!(o.realized, 0.0, "flat wall time realizes zero loss");
        }
    }

    #[test]
    fn retune_on_is_bit_identical_across_inline_and_channel_modes() {
        use crate::outcome::{RetuneConfig, RetuneMode};
        fn drive_retune(service: &TunerService, name: &str) -> SessionReport {
            let mut sp = spec(name);
            sp.cfg.retune = RetuneConfig {
                mode: RetuneMode::On,
                ewma_alpha: 1.0,
                trigger: 0.5,
                early_intervals: 2,
                cooldown_periods: 2,
            };
            let mut h = service.register(sp).unwrap();
            for i in 1..=30u32 {
                let mut s = sample(i, 0);
                // wall time jumps 10× after the first decision period:
                // realized loss drifts far above the prediction
                s.wall_ns = if i <= 5 { 1_000_000 } else { 10_000_000 };
                h.publish(s);
            }
            h.finish().unwrap()
        }
        let db = db();
        let inline = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let channel = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        let a = drive_retune(&inline, "a");
        let b = drive_retune(&channel, "b");
        assert!(a.retunes >= 1, "drifting wall time must force a re-tune");
        assert_eq!(a.retunes, b.retunes);
        assert_eq!(a.decisions.len(), b.decisions.len());
        for (x, y) in a.decisions.iter().zip(&b.decisions) {
            assert_eq!(x.interval, y.interval, "early re-decides must land on the same interval");
            assert_eq!(x.fraction.to_bits(), y.fraction.to_bits());
            assert_eq!(x.new_fm, y.new_fm);
        }
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.decision_interval, y.decision_interval);
            assert_eq!(x.end_interval, y.end_interval);
            assert_eq!(x.realized.to_bits(), y.realized.to_bits());
            assert_eq!(x.predicted.to_bits(), y.predicted.to_bits());
        }
    }

    #[test]
    fn empty_window_decision_request_returns_none() {
        let db = db();
        let service = TunerService::inline(db.clone(), Box::new(NativeNn::new(&db)));
        let mut h = service.register(spec("empty")).unwrap();
        assert!(h.request_decision(1).is_none());
        let report = h.finish().unwrap();
        assert!(report.decisions.is_empty());
        assert!(report.decide_ns > 0, "early returns still bill decide_ns");
    }
}
