//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `tuna <subcommand> [--flag value | --switch] [positional...]`.
//! Flags may use `--flag=value` or `--flag value`. Unknown flags are
//! rejected by [`Args::finish`] so typos fail loudly.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

/// Parsed arguments with typed accessors.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeSet<String>,
    pub positional: Vec<String>,
    consumed: BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `known_switches` lists boolean flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_switches: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&flag) {
                    args.switches.insert(flag.to_string());
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| anyhow!("flag --{flag} expects a value"))?;
                    args.flags.insert(flag.to_string(), v);
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn get(&mut self, key: &str) -> Option<&str> {
        self.consumed.insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("bad value for --{key}: {e}")),
        }
    }

    pub fn switch(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.switches.contains(key)
    }

    /// Error on any flag the command did not consume.
    pub fn finish(&self) -> Result<()> {
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !self.consumed.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_and_positionals() {
        let mut a = Args::parse(argv("run --workload BFS --fraction=0.9 extra"), &[]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("workload"), Some("BFS"));
        assert_eq!(a.get_parse("fraction", 1.0).unwrap(), 0.9);
        assert_eq!(a.positional, vec!["extra"]);
        a.finish().unwrap();
    }

    #[test]
    fn switches_take_no_value() {
        let mut a = Args::parse(argv("tune --xla --target 0.1"), &["xla"]).unwrap();
        assert!(a.switch("xla"));
        assert_eq!(a.get("target"), Some("0.1"));
        a.finish().unwrap();
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(Args::parse(argv("run --workload"), &[]).is_err());
    }

    #[test]
    fn unconsumed_flag_fails_finish() {
        let mut a = Args::parse(argv("run --oops 1"), &[]).unwrap();
        let _ = a.get("other");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults_and_bad_parses() {
        let mut a = Args::parse(argv("run --n abc"), &[]).unwrap();
        assert!(a.get_parse::<u32>("n", 5).is_err());
        let mut b = Args::parse(argv("run"), &[]).unwrap();
        assert_eq!(b.get_parse::<u32>("n", 5).unwrap(), 5);
        assert_eq!(b.get_or("name", "dflt"), "dflt");
    }
}
