//! The Tuna online tuner (§4, §6.2): every tuning period it collapses the
//! telemetry window into a configuration vector, queries the performance
//! database for the nearest execution record, picks the smallest
//! fast-memory size whose *predicted* loss (relative to the record's
//! fast-memory-only baseline) is within the user's target τ, and programs
//! the page-reclaim watermarks accordingly.
//!
//! The decision logic lives in [`TunerState`], which is deliberately
//! query-backend-free: `decide` borrows an [`NnQuery`] for the duration
//! of one decision. That split is what lets [`crate::service`] host many
//! sessions (one `TunerState` each) behind a single shared backend, with
//! decisions bit-identical to the classic in-loop path — both paths run
//! this exact code. [`Tuner`] is the in-loop composition (state + owned
//! backend + period counting) kept as the reference implementation the
//! service is proven against.

use std::sync::Arc;

use crate::config::experiment::TunaConfig;
use crate::outcome::{DriftAction, OutcomeRecord, OutcomeTracker};
use crate::perfdb::native::NnQuery;
use crate::perfdb::{normalize, PerfSource};
use crate::sim::RunTrace;
use crate::telemetry::{TelemetrySample, VmstatCounters, WindowAggregator};
use crate::tpp::Watermarks;

/// Neighbours consulted per decision (curve averaging). The AOT top-k
/// artifact is lowered for 8; we use the nearest 4.
pub const KNN: usize = 4;

/// One tuning decision (kept for traces / Figs. 3–8).
#[derive(Clone, Debug)]
pub struct Decision {
    /// Interval index at which the decision was taken.
    pub interval: u32,
    /// Nearest database record and its squared distance.
    pub record: usize,
    pub dist: f32,
    /// Chosen fast-memory fraction (of the workload RSS).
    pub fraction: f64,
    /// Usable fast-memory pages programmed via the watermarks.
    pub new_fm: u64,
    /// Predicted loss at the chosen fraction.
    pub predicted_loss: f64,
}

/// Per-session tuning state: telemetry aggregation plus the watermark
/// walk. Backend-free — [`Self::decide`] borrows the query for one
/// decision, so many states can share one backend (the service) or each
/// own one ([`Tuner`]).
pub struct TunerState {
    /// The performance database behind this session's loss curves — any
    /// [`PerfSource`]: the flat in-memory DB, or a (lazy) sharded one
    /// serving from a bounded resident set. Decisions are bit-identical
    /// across sources holding the same records.
    db: Arc<dyn PerfSource>,
    cfg: TunaConfig,
    window: WindowAggregator,
    counters: VmstatCounters,
    /// Fast-tier capacity in pages (fixed; Tuna moves watermarks only).
    capacity: u64,
    /// Workload RSS in pages (the 100% reference for fractions).
    rss_pages: u64,
    /// Currently-programmed fast-memory fraction (starts at 100%).
    current_fraction: f64,
    pub decisions: Vec<Decision>,
    /// Total time spent in `decide` (query path), for the §Perf budget.
    pub decide_ns: u128,
    /// Observability handle (disabled by default; see [`Self::set_obs`]).
    /// Records decisions and skip diagnostics — never read back, so
    /// decisions are bit-identical whether or not it is enabled.
    obs: crate::obs::Recorder,
    /// Predicted-vs-realized outcome accounting and drift detection
    /// (inert unless `cfg.retune` enables it). In `observe` mode the
    /// tracker only records; in `on` mode a sustained prediction error
    /// shortens the next tuning period via [`Self::next_period`] —
    /// never the decision itself, so decisions taken at the same
    /// interval stay bit-identical across modes.
    tracker: OutcomeTracker,
    /// Session label stamped on outcome/drift journal events.
    session: String,
}

impl TunerState {
    pub fn new(
        db: Arc<dyn PerfSource>,
        cfg: TunaConfig,
        capacity: u64,
        rss_pages: u64,
        hot_thr: u32,
        threads: u32,
    ) -> Self {
        let tracker = OutcomeTracker::new(cfg.retune);
        TunerState {
            db,
            cfg,
            window: WindowAggregator::new(hot_thr, threads, rss_pages),
            counters: VmstatCounters::new(),
            capacity,
            rss_pages,
            current_fraction: 1.0,
            decisions: Vec::new(),
            decide_ns: 0,
            obs: crate::obs::Recorder::default(),
            tracker,
            session: String::new(),
        }
    }

    /// Attach an observability recorder (constructor signatures stay
    /// unchanged; every existing call site keeps a disabled recorder).
    pub fn set_obs(&mut self, obs: crate::obs::Recorder) {
        self.obs = obs;
    }

    /// Name this session on outcome/drift journal events (constructor
    /// signatures stay unchanged; unset sessions journal as `""`).
    pub fn set_session_label(&mut self, name: &str) {
        self.session = name.to_string();
    }

    /// Profiling intervals per tuning period for this state's config.
    pub fn period_intervals(&self) -> u32 {
        self.cfg.period_intervals()
    }

    /// Accumulate one interval's sample (window + cumulative counters).
    pub fn ingest(&mut self, s: &TelemetrySample) {
        self.window.observe(s);
        self.counters.observe(s);
        self.tracker.observe(s.interval, s.wall_ns);
    }

    pub fn window(&self) -> &WindowAggregator {
        &self.window
    }

    pub fn counters(&self) -> &VmstatCounters {
        &self.counters
    }

    /// vmstat-style cumulative counter dump.
    pub fn vmstat(&self) -> Vec<(&'static str, u64)> {
        self.counters.vmstat()
    }

    /// Take one tuning decision from the current telemetry window.
    ///
    /// The decision-path timer wraps the whole body so early returns
    /// (empty telemetry window, empty neighbour set, no fraction within
    /// the target) still count toward `decide_ns` — the §Perf budget is
    /// "time spent deciding", not "time spent deciding successfully".
    pub fn decide(&mut self, interval: u32, query: &mut dyn NnQuery) -> Option<Watermarks> {
        let t0 = std::time::Instant::now();
        let out = self.decide_inner(interval, query);
        self.decide_ns += t0.elapsed().as_nanos();
        out
    }

    fn decide_inner(&mut self, interval: u32, query: &mut dyn NnQuery) -> Option<Watermarks> {
        let cfg = self.window.take_window_config()?;
        let q = normalize(&cfg.as_array());
        // k-NN: averaging several records' loss-vs-size curves (distance
        // weighted) smooths the knee; individual micro-benchmark records
        // are near-step functions.
        let neighbors = match query.top_k(&q, KNN) {
            Ok(n) if !n.is_empty() => n,
            Ok(_) => return None,
            Err(e) => {
                // A lazy backend surfaces segment I/O or CRC failures
                // here (first touch is at query time). One session's bad
                // segment must not panic or wedge the shared service —
                // skip the decision, name the cause (counted in
                // `obs_warn_total` when observability is on; the stderr
                // line is emitted either way).
                self.obs.warn(
                    "tuner.decide",
                    &format!("tuning decision skipped at interval {interval}: {e:#}"),
                );
                return None;
            }
        };
        let (record, dist) = neighbors[0];
        // Smallest fraction within the loss target; keep the current fast
        // memory size if the records offer none (§3.3). Shrinking is
        // rate-limited per period (the records were matched against
        // telemetry at the *current* size, so walk down and re-measure);
        // growing back is immediate. The weighted curve is computed once
        // and reused for both the target scan and the loss prediction —
        // this is the per-decision hot path.
        let curve = match self.db.weighted_loss_curve_of(&neighbors) {
            Ok(curve) => curve,
            Err(e) => {
                // A lazy source can fail here (I/O or CRC on a segment
                // fault). Skip the decision — the run continues at its
                // current size — but say why, naming the segment: a
                // silently undecided session is undebuggable.
                self.obs.warn(
                    "tuner.decide",
                    &format!("tuning decision skipped at interval {interval}: {e:#}"),
                );
                return None;
            }
        };
        let target = curve
            .iter()
            .rev() // descending grid → iterate ascending fraction
            .find(|&&(_, loss)| loss <= self.cfg.loss_target)
            .map(|&(f, _)| f)?
            .max(self.cfg.min_fm_fraction);
        let fraction = target.max(self.current_fraction - self.cfg.max_step_down);
        self.current_fraction = fraction;
        let predicted_loss = crate::perfdb::interp_desc(&curve, fraction);
        let new_fm =
            ((self.rss_pages as f64 * fraction).ceil() as u64).min(self.capacity);
        self.decisions.push(Decision {
            interval,
            record,
            dist,
            fraction,
            new_fm,
            predicted_loss,
        });
        let wm = Watermarks::for_target_fm(self.capacity, new_fm);
        // Settle the previous decision's outcome *after* this decision
        // is fully formed: the tracker never feeds back into the
        // fraction walk, only (in `on` mode) into the next period
        // length, so decisions stay bit-identical across retune modes.
        let feedback = self.tracker.on_decision(interval, predicted_loss);
        if self.obs.is_enabled() {
            use crate::obs::{EventKind, ERR_BUCKETS, FRACTION_BUCKETS, LOSS_BUCKETS};
            self.obs.count("tuner_decisions_total", 1);
            self.obs
                .observe("tuner_decision_fraction", FRACTION_BUCKETS, fraction);
            self.obs
                .observe("tuner_predicted_loss", LOSS_BUCKETS, predicted_loss);
            self.obs.record(EventKind::Decision {
                interval,
                record: record as u64,
                dist,
                fraction,
                new_fm,
                predicted_loss,
                wm_low: wm.low,
                wm_high: wm.high,
            });
            if let Some(o) = &feedback.outcome {
                self.obs.observe("tuner_realized_loss", LOSS_BUCKETS, o.realized);
                self.obs
                    .observe("tuner_prediction_error", ERR_BUCKETS, o.realized - o.predicted);
                self.obs.record(EventKind::Outcome {
                    session: self.session.clone(),
                    decision_interval: o.decision_interval,
                    predicted: o.predicted,
                    realized: o.realized,
                    abs_err: o.abs_err,
                });
            }
            if self.tracker.active() {
                self.obs.gauge("tuner_drift_state", feedback.action.gauge());
                // A zero delta still registers the family, so a scrape
                // can tell "tracking, 0 retunes" from "tracker off".
                self.obs.count("tuner_retunes_total", feedback.was_retune as u64);
                if matches!(
                    feedback.action,
                    DriftAction::Armed | DriftAction::Retune | DriftAction::Cooldown
                ) {
                    self.obs.record(EventKind::Drift {
                        session: self.session.clone(),
                        interval,
                        ewma_err: self.tracker.ewma_err(),
                        action: feedback.action.name().to_string(),
                    });
                }
            }
        }
        Some(wm)
    }

    /// Intervals until the *next* decision: the configured period,
    /// shortened when `retune = on` and the drift detector is armed.
    /// `off`/`observe` always return the configured period, which is
    /// what makes those modes bit-identical to the legacy cadence.
    pub fn next_period(&self) -> u32 {
        self.tracker.next_period(self.cfg.period_intervals())
    }

    /// Settle the in-flight outcome at end of run (there is no later
    /// decision to close it): journals the final predicted-vs-realized
    /// pair so the last decision of a session is accounted for too.
    pub fn finish_outcome(&mut self, end_interval: u32) -> Option<OutcomeRecord> {
        let o = self.tracker.finish(end_interval)?;
        if self.obs.is_enabled() {
            use crate::obs::{EventKind, ERR_BUCKETS, LOSS_BUCKETS};
            self.obs.observe("tuner_realized_loss", LOSS_BUCKETS, o.realized);
            self.obs
                .observe("tuner_prediction_error", ERR_BUCKETS, o.realized - o.predicted);
            self.obs.record(EventKind::Outcome {
                session: self.session.clone(),
                decision_interval: o.decision_interval,
                predicted: o.predicted,
                realized: o.realized,
                abs_err: o.abs_err,
            });
        }
        Some(o)
    }

    /// Settled predicted-vs-realized outcomes, decision order.
    pub fn outcomes(&self) -> &[OutcomeRecord] {
        &self.tracker.outcomes
    }

    /// Early re-decides forced by the drift detector (0 unless
    /// `retune = on`).
    pub fn retunes(&self) -> u64 {
        self.tracker.retunes
    }

    /// Mean fast-memory fraction across all decisions (the "saving" is
    /// `1 − mean_fraction`).
    pub fn mean_fraction(&self) -> f64 {
        if self.decisions.is_empty() {
            return 1.0;
        }
        self.decisions.iter().map(|d| d.fraction).sum::<f64>() / self.decisions.len() as f64
    }

    /// Smallest fraction ever chosen (peak saving, as Figs. 3–7 report).
    pub fn min_fraction(&self) -> f64 {
        self.decisions
            .iter()
            .map(|d| d.fraction)
            .fold(1.0, f64::min)
    }
}

/// The classic in-loop controller: [`TunerState`] plus an owned query
/// backend and period counting. Attach it to [`crate::sim::Engine::run`]
/// as the observer: `|t| tuner.observe(t)`. Kept as the reference the
/// service path is proven bit-identical against.
pub struct Tuner {
    query: Box<dyn NnQuery>,
    since_decision: u32,
    /// Intervals to wait before the next decision. Equals the
    /// configured period except right after the drift detector arms
    /// under `retune = on`, when the state shortens it.
    next_wait: u32,
    pub state: TunerState,
}

impl Tuner {
    pub fn new(
        db: Arc<dyn PerfSource>,
        query: Box<dyn NnQuery>,
        cfg: TunaConfig,
        capacity: u64,
        rss_pages: u64,
        hot_thr: u32,
        threads: u32,
    ) -> Self {
        let next_wait = cfg.period_intervals();
        Tuner {
            query,
            since_decision: 0,
            next_wait,
            state: TunerState::new(db, cfg, capacity, rss_pages, hot_thr, threads),
        }
    }

    /// Attach an observability recorder to the underlying state.
    pub fn set_obs(&mut self, obs: crate::obs::Recorder) {
        self.state.set_obs(obs);
    }

    /// Engine observer: accumulate telemetry; on period boundaries take a
    /// decision and return the watermarks to program. The boundary is
    /// `next_wait`, not the fixed period: under `retune = on` an armed
    /// drift detector shortens the wait once, forcing an early
    /// re-decide (identical to the configured cadence otherwise).
    pub fn observe(&mut self, t: &RunTrace) -> Option<Watermarks> {
        self.state.ingest(&t.sample());
        self.since_decision += 1;
        if self.since_decision < self.next_wait {
            return None;
        }
        self.since_decision = 0;
        let out = self.decide(t.interval);
        self.next_wait = self.state.next_period();
        out
    }

    /// Take one tuning decision now (see [`TunerState::decide`]).
    pub fn decide(&mut self, interval: u32) -> Option<Watermarks> {
        self.state.decide(interval, self.query.as_mut())
    }

    pub fn decisions(&self) -> &[Decision] {
        &self.state.decisions
    }

    pub fn decide_ns(&self) -> u128 {
        self.state.decide_ns
    }

    pub fn mean_fraction(&self) -> f64 {
        self.state.mean_fraction()
    }

    pub fn min_fraction(&self) -> f64 {
        self.state.min_fraction()
    }

    /// vmstat-style cumulative counter dump.
    pub fn vmstat(&self) -> Vec<(&'static str, u64)> {
        self.state.vmstat()
    }

    /// Settle the in-flight outcome at end of run (see
    /// [`TunerState::finish_outcome`]).
    pub fn finish_outcome(&mut self, end_interval: u32) -> Option<OutcomeRecord> {
        self.state.finish_outcome(end_interval)
    }
}

/// What-if loss prediction: the exact query path of one live decision
/// ([`TunerState::decide`]) — normalize the window vector, k-NN
/// ([`KNN`] neighbours), distance-weighted loss curve, descending-grid
/// interpolation — but evaluated at a caller-chosen `fraction` instead
/// of scanning for the loss target. `tuna whatif` builds on this;
/// keeping it here (not in the CLI) pins it to the decision code so
/// the two can never drift apart.
///
/// Returns `Ok(None)` when the window is empty or the database has no
/// neighbours (the same conditions under which a live decision skips).
pub fn predict_loss_at(
    db: &Arc<dyn PerfSource>,
    query: &mut dyn NnQuery,
    window: &mut WindowAggregator,
    fraction: f64,
) -> anyhow::Result<Option<f64>> {
    let cfg = match window.take_window_config() {
        Some(c) => c,
        None => return Ok(None),
    };
    let q = normalize(&cfg.as_array());
    let neighbors = query.top_k(&q, KNN)?;
    if neighbors.is_empty() {
        return Ok(None);
    }
    let curve = db.weighted_loss_curve_of(&neighbors)?;
    Ok(Some(crate::perfdb::interp_desc(&curve, fraction)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::native::NativeNn;
    use crate::perfdb::{PerfDb, Record};
    use crate::sim::interval::IntervalOutcome;

    /// A hand-built database with two records: one memory-tolerant
    /// (loss stays tiny until 60%), one memory-hungry (loss blows up
    /// immediately).
    fn db() -> Arc<PerfDb> {
        let fractions = vec![1.0f32, 0.9, 0.8, 0.7, 0.6, 0.5];
        let tolerant_raw = [10_000.0, 500.0, 20.0, 20.0, 4.0, 8_000.0, 2.0, 16.0];
        let hungry_raw = [200_000.0, 40_000.0, 300.0, 300.0, 0.05, 30_000.0, 2.0, 16.0];
        let tolerant = Record {
            raw: tolerant_raw,
            vec: normalize(&tolerant_raw),
            times_ns: vec![100.0, 100.5, 101.0, 102.0, 104.0, 130.0],
        };
        let hungry = Record {
            raw: hungry_raw,
            vec: normalize(&hungry_raw),
            times_ns: vec![100.0, 115.0, 140.0, 180.0, 240.0, 320.0],
        };
        Arc::new(PerfDb { fractions, records: vec![tolerant, hungry] })
    }

    fn trace_like(interval: u32, acc_fast: u64, acc_slow: u64, ops: u64) -> RunTrace {
        RunTrace {
            interval,
            clock_ns: 0.0,
            wall_ns: 1.0,
            acc_fast,
            acc_slow,
            sacc_fast: acc_fast,
            sacc_slow: acc_slow,
            flops: ops / 2,
            iops: ops - ops / 2,
            promoted: 20,
            promote_failed: 0,
            demoted_kswapd: 20,
            demoted_direct: 0,
            shadow_hits: 0,
            shadow_free_demotions: 0,
            txn_aborts: 0,
            txn_retried_copies: 0,
            admission_accepted: 0,
            admission_rejected_budget: 0,
            admission_rejected_payoff: 0,
            admission_rejected_cooldown: 0,
            fast_used: 7_000,
            fast_free: 100,
            usable_fm: 7_900,
            outcome: IntervalOutcome::default(),
        }
    }

    fn mk_tuner(db: Arc<PerfDb>, period_s: f64) -> Tuner {
        let query = Box::new(NativeNn::new(&db));
        let cfg = TunaConfig { period_s, max_step_down: 0.04, ..TunaConfig::default() };
        Tuner::new(db, query, cfg, 8_200, 8_000, 2, 16)
    }

    #[test]
    fn decides_every_period_and_shrinks_for_tolerant_workloads() {
        let db = db();
        let mut tuner = mk_tuner(db, 0.5); // 5 intervals per period
        let mut wm_changes = 0;
        for i in 1..=20u32 {
            // telemetry resembling the tolerant record
            let ops = (10_500u64 * 64) * 4;
            if tuner.observe(&trace_like(i, 10_000, 500, ops)).is_some() {
                wm_changes += 1;
            }
        }
        assert_eq!(wm_changes, 4, "one decision per 5-interval period");
        assert_eq!(tuner.decisions().len(), 4);
        // the averaged curve allows shrinking, but the walk is
        // rate-limited to max_step_down per period: 1.0 → 0.96 → … → 0.84
        for (i, d) in tuner.decisions().iter().enumerate() {
            assert_eq!(d.record, 0, "nearest must be the tolerant record");
            let want = 1.0 - 0.04 * (i as f64 + 1.0);
            assert!((d.fraction - want).abs() < 1e-9, "step {i}: {}", d.fraction);
        }
    }

    #[test]
    fn walks_down_to_the_averaged_curve_target_and_not_past_it() {
        let db = db();
        let query = Box::new(NativeNn::new(&db));
        let cfg = TunaConfig { period_s: 0.5, max_step_down: 0.25, ..TunaConfig::default() };
        let mut tuner = Tuner::new(db.clone(), query, cfg, 8_200, 8_000, 2, 16);
        for i in 1..=25u32 {
            tuner.observe(&trace_like(i, 10_000, 500, 10_500 * 64 * 4));
        }
        let fr: Vec<f64> = tuner.decisions().iter().map(|d| d.fraction).collect();
        // the k-NN averaged curve blends the hungry record in, so the
        // equilibrium sits at or above the tolerant record's own 0.6 knee
        let q = normalize(
            &tuner.state.window.take_window_config().map(|c| c.as_array()).unwrap_or([
                10_000.0, 500.0, 20.0, 20.0, 4.0, 8_000.0, 2.0, 16.0,
            ]),
        );
        let mut nn = NativeNn::new(&db);
        let neighbors = crate::perfdb::native::NnQuery::top_k(&mut nn, &q, KNN).unwrap();
        let expect = db
            .min_fraction_within_weighted(&neighbors, 0.05)
            .unwrap()
            .max(0.25);
        let last = *fr.last().unwrap();
        assert!(
            (last - expect).abs() < 1e-6,
            "equilibrium {last} vs averaged-curve target {expect} ({fr:?})"
        );
        // monotone walk: each step down by ≤ max_step_down
        for w in fr.windows(2) {
            assert!(w[0] - w[1] <= 0.25 + 1e-9);
        }
        assert!(last >= 0.6 - 1e-6, "cannot go below the tolerant knee");
    }

    #[test]
    fn memory_hungry_telemetry_keeps_fast_memory() {
        let db = db();
        let mut tuner = mk_tuner(db, 0.5);
        for i in 1..=5u32 {
            let ops = 240_000u64 * 64 / 20; // low AI
            tuner.observe(&trace_like(i, 200_000, 40_000, ops));
        }
        let d = tuner.decisions().last().unwrap();
        assert_eq!(d.record, 1, "must match the hungry record");
        // hungry record never gets under 5% except at 100%
        assert!(d.fraction >= 0.99, "fraction={}", d.fraction);
    }

    #[test]
    fn min_fm_fraction_is_a_floor() {
        let db = db();
        let query = Box::new(NativeNn::new(&db));
        let cfg = TunaConfig {
            period_s: 0.5,
            loss_target: 0.9, // anything goes
            min_fm_fraction: 0.75,
            max_step_down: 1.0, // no rate limit: test the floor itself
            ..TunaConfig::default()
        };
        let mut tuner = Tuner::new(db, query, cfg, 8_200, 8_000, 2, 16);
        for i in 1..=5u32 {
            tuner.observe(&trace_like(i, 10_000, 500, 10_000 * 64 * 4));
        }
        assert!(tuner.decisions().last().unwrap().fraction >= 0.75);
    }

    #[test]
    fn watermarks_map_fraction_to_usable_fm() {
        let db = db();
        let mut tuner = mk_tuner(db, 0.5);
        let mut wm = None;
        for i in 1..=5u32 {
            if let Some(w) = tuner.observe(&trace_like(i, 10_000, 500, 10_500 * 64 * 4)) {
                wm = Some(w);
            }
        }
        let wm = wm.expect("decision expected");
        let d = tuner.decisions().last().unwrap();
        assert_eq!(wm.usable(8_200), d.new_fm);
        wm.check(8_200).unwrap();
    }

    #[test]
    fn decide_bills_time_on_early_returns() {
        let db = db();
        let mut tuner = mk_tuner(db, 0.5);
        // Empty telemetry window: every decide early-returns None, but the
        // decision-path budget must still account for the time spent.
        for i in 0..200u32 {
            assert!(tuner.decide(i).is_none());
        }
        assert!(tuner.decisions().is_empty());
        assert!(tuner.decide_ns() > 0, "early returns must update decide_ns");
    }

    #[test]
    fn stats_track_decisions() {
        let db = db();
        let mut tuner = mk_tuner(db, 0.5);
        for i in 1..=10u32 {
            tuner.observe(&trace_like(i, 10_000, 500, 10_500 * 64 * 4));
        }
        assert!(tuner.mean_fraction() < 1.0);
        assert!(tuner.min_fraction() <= tuner.mean_fraction());
        assert!(tuner.decide_ns() > 0);
    }

    #[test]
    fn obs_records_decisions_without_perturbing_them() {
        let db = db();
        let mut plain = mk_tuner(db.clone(), 0.5);
        let mut observed = mk_tuner(db, 0.5);
        let rec = crate::obs::Recorder::enabled(64);
        observed.set_obs(rec.clone());
        for i in 1..=20u32 {
            let t = trace_like(i, 10_000, 500, 10_500 * 64 * 4);
            plain.observe(&t);
            observed.observe(&t);
        }
        assert_eq!(plain.decisions().len(), observed.decisions().len());
        for (a, b) in plain.decisions().iter().zip(observed.decisions()) {
            assert_eq!(a.fraction.to_bits(), b.fraction.to_bits());
            assert_eq!(a.new_fm, b.new_fm);
            assert_eq!(a.predicted_loss.to_bits(), b.predicted_loss.to_bits());
        }
        let j = rec.journal();
        assert_eq!(j.metrics.counter("tuner_decisions_total"), 4);
        let events: Vec<&crate::obs::Event> = j
            .events
            .iter()
            .filter(|e| matches!(e.kind, crate::obs::EventKind::Decision { .. }))
            .collect();
        assert_eq!(events.len(), 4, "every decision must be journaled");
        if let crate::obs::EventKind::Decision { new_fm, wm_low, wm_high, .. } = events[0].kind {
            let wm = Watermarks::for_target_fm(8_200, new_fm);
            assert_eq!((wm.low, wm.high), (wm_low, wm_high), "event carries chosen watermarks");
        }
    }

    #[test]
    fn shared_backend_state_matches_owned_backend_tuner() {
        // The same sample stream through (a) the in-loop Tuner and (b) a
        // bare TunerState fed through a borrowed backend must produce
        // bit-identical decisions — the invariant the service builds on.
        let db = db();
        let mut tuner = mk_tuner(db.clone(), 0.5);
        let cfg = TunaConfig { period_s: 0.5, max_step_down: 0.04, ..TunaConfig::default() };
        let mut state = TunerState::new(db.clone(), cfg, 8_200, 8_000, 2, 16);
        let mut shared = NativeNn::new(&db);
        let period = state.period_intervals();
        let mut since = 0u32;
        for i in 1..=20u32 {
            let t = trace_like(i, 10_000, 500, 10_500 * 64 * 4);
            let a = tuner.observe(&t);
            state.ingest(&t.sample());
            since += 1;
            let b = if since == period {
                since = 0;
                state.decide(i, &mut shared)
            } else {
                None
            };
            assert_eq!(a.is_some(), b.is_some(), "interval {i}");
            if let (Some(wa), Some(wb)) = (a, b) {
                assert_eq!(wa.usable(8_200), wb.usable(8_200), "interval {i}");
            }
        }
        assert_eq!(tuner.decisions().len(), state.decisions.len());
        for (a, b) in tuner.decisions().iter().zip(&state.decisions) {
            assert_eq!(a.interval, b.interval);
            assert_eq!(a.record, b.record);
            assert_eq!(a.fraction.to_bits(), b.fraction.to_bits());
            assert_eq!(a.new_fm, b.new_fm);
            assert_eq!(a.predicted_loss.to_bits(), b.predicted_loss.to_bits());
        }
    }

    #[test]
    fn observe_mode_is_bit_identical_to_off_and_still_tracks_outcomes() {
        use crate::outcome::{RetuneConfig, RetuneMode};
        let db = db();
        let mut off = mk_tuner(db.clone(), 0.5);
        let cfg = TunaConfig {
            period_s: 0.5,
            max_step_down: 0.04,
            retune: RetuneConfig { mode: RetuneMode::Observe, ..RetuneConfig::default() },
            ..TunaConfig::default()
        };
        let query = Box::new(NativeNn::new(&db));
        let mut observing = Tuner::new(db, query, cfg, 8_200, 8_000, 2, 16);
        // 22 intervals: decisions at 5/10/15/20, then two trailing
        // samples so the last decision's window has content to settle.
        for i in 1..=22u32 {
            let t = trace_like(i, 10_000, 500, 10_500 * 64 * 4);
            let a = off.observe(&t);
            let b = observing.observe(&t);
            assert_eq!(
                a.map(|w| w.usable(8_200)),
                b.map(|w| w.usable(8_200)),
                "interval {i}: observe mode must not change the cadence or the choice"
            );
        }
        assert_eq!(off.decisions().len(), observing.decisions().len());
        for (a, b) in off.decisions().iter().zip(observing.decisions()) {
            assert_eq!(a.interval, b.interval);
            assert_eq!(a.fraction.to_bits(), b.fraction.to_bits());
            assert_eq!(a.predicted_loss.to_bits(), b.predicted_loss.to_bits());
        }
        // ... but only observe mode settles outcomes: one per decision
        // after the first (the last stays pending until finish).
        assert!(off.state.outcomes().is_empty(), "off mode must track nothing");
        assert_eq!(observing.state.outcomes().len(), off.decisions().len() - 1);
        // constant wall time ⇒ realized loss 0 against its own baseline
        for o in observing.state.outcomes() {
            assert_eq!(o.realized, 0.0, "flat wall time must realize zero loss");
        }
        let last = observing.finish_outcome(22).expect("pending outcome at end");
        assert_eq!(last.decision_interval, 20);
        assert_eq!(off.finish_outcome(22), None, "off mode has nothing to settle");
    }

    #[test]
    fn retune_on_forces_an_early_decision_when_realized_loss_drifts() {
        use crate::outcome::{RetuneConfig, RetuneMode};
        let db = db();
        let cfg = TunaConfig {
            period_s: 0.5, // 5 intervals per period
            max_step_down: 0.04,
            retune: RetuneConfig {
                mode: RetuneMode::On,
                ewma_alpha: 1.0,
                trigger: 0.5,
                early_intervals: 2,
                cooldown_periods: 2,
            },
            ..TunaConfig::default()
        };
        let query = Box::new(NativeNn::new(&db));
        let mut tuner = Tuner::new(db, query, cfg, 8_200, 8_000, 2, 16);
        for i in 1..=20u32 {
            let mut t = trace_like(i, 10_000, 500, 10_500 * 64 * 4);
            // wall time jumps 10× after the first decision: realized loss
            // lands far above the prediction, arming the drift detector.
            t.wall_ns = if i <= 5 { 1.0e6 } else { 1.0e7 };
            tuner.observe(&t);
        }
        let intervals: Vec<u32> = tuner.decisions().iter().map(|d| d.interval).collect();
        assert!(
            intervals.windows(2).any(|w| w[1] - w[0] == 2),
            "an armed detector must shorten one wait to early_intervals ({intervals:?})"
        );
        assert!(tuner.state.retunes() >= 1, "the early decision counts as a retune");
        assert!(
            tuner.state.outcomes().iter().any(|o| o.realized > 5.0),
            "the 10× wall-time jump must be realized as a large loss"
        );
    }
}
