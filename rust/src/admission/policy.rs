//! The admission predicate: configuration, per-candidate verdicts, and
//! the [`AdmissionGate`] state machine the TPP promotion pass consults.

use super::budget::BudgetLedger;
use crate::PageId;

/// Copy cost of migrating one page, in access-equivalents: the number
/// of fast-tier line accesses a page copy is worth
/// (`PAGE_BYTES / LINE_BYTES`). A candidate must predict strictly more
/// fast-tier hits than this over its residency horizon to be worth
/// moving.
pub const COPY_COST_ACCESSES: u64 = crate::PAGE_BYTES / crate::LINE_BYTES;

/// Admission-control configuration (the `[admission]` config table and
/// the `--admission/--mig-budget/--cooldown/--horizon` CLI flags).
///
/// All-integer so the config can be hashed into artifact keys and sweep
/// fingerprints exactly, like [`crate::sim::mem::MigrationModel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AdmissionConfig {
    /// Master switch. Disabled (the default) is a true no-op: no gate
    /// is installed and every run is bit-identical to the
    /// pre-admission engine.
    pub enabled: bool,
    /// Per-interval migration budget in pages of copy traffic
    /// (0 = unlimited). Sized against the machine model's migration
    /// throughput knobs (`kswapd_pages_per_interval` 32,
    /// `promote_scan_pages_per_interval` 384): 128 admits a healthy
    /// promotion stream but caps mass re-promotion after a hot-set
    /// shift.
    pub budget_pages: u64,
    /// Intervals a demoted page stays rejected as a ping-pong
    /// candidate.
    pub cooldown_intervals: u32,
    /// Residency horizon (intervals) over which predicted fast-tier
    /// hits are credited against the copy cost (clamped to ≥ 1).
    pub horizon_intervals: u32,
}

impl Default for AdmissionConfig {
    /// Admission control *off* — the configuration every pre-admission
    /// code path implicitly ran with.
    fn default() -> Self {
        AdmissionConfig {
            enabled: false,
            budget_pages: Self::DEFAULT_BUDGET_PAGES,
            cooldown_intervals: Self::DEFAULT_COOLDOWN_INTERVALS,
            horizon_intervals: Self::DEFAULT_HORIZON_INTERVALS,
        }
    }
}

impl AdmissionConfig {
    pub const DEFAULT_BUDGET_PAGES: u64 = 128;
    pub const DEFAULT_COOLDOWN_INTERVALS: u32 = 16;
    pub const DEFAULT_HORIZON_INTERVALS: u32 = 32;

    /// The `tpp-gated` policy's built-in configuration: defaults, on.
    pub fn enabled_default() -> Self {
        AdmissionConfig { enabled: true, ..AdmissionConfig::default() }
    }

    /// Parse a CLI/config mode string; the numeric knobs apply in
    /// either mode (so a later `--admission on` layer can enable a
    /// fully-specified config).
    pub fn parse(
        mode: &str,
        budget_pages: u64,
        cooldown_intervals: u32,
        horizon_intervals: u32,
    ) -> Result<Self, String> {
        let enabled = match mode.trim().to_ascii_lowercase().as_str() {
            "on" | "enabled" | "gated" | "true" => true,
            "off" | "disabled" | "false" => false,
            other => {
                return Err(format!("unknown admission mode `{other}` (valid: on, off)"));
            }
        };
        Ok(AdmissionConfig {
            enabled,
            budget_pages,
            cooldown_intervals,
            horizon_intervals: horizon_intervals.max(1),
        })
    }

    pub fn mode_name(&self) -> &'static str {
        if self.enabled {
            "on"
        } else {
            "off"
        }
    }

    /// Stable (enabled, budget, cooldown, horizon) tuple for artifact
    /// keys and fingerprints (extend, never renumber).
    pub fn key(&self) -> (u8, u64, u32, u32) {
        (
            self.enabled as u8,
            self.budget_pages,
            self.cooldown_intervals,
            self.horizon_intervals,
        )
    }

    /// Inverse of [`Self::key`].
    pub fn from_key(enabled: u8, budget: u64, cooldown: u32, horizon: u32) -> Self {
        AdmissionConfig {
            enabled: enabled != 0,
            budget_pages: budget,
            cooldown_intervals: cooldown,
            horizon_intervals: horizon.max(1),
        }
    }

    /// Predicted fast-tier hits over the residency horizon for a page
    /// with decayed window count `window_count`.
    ///
    /// The window counter halves every interval
    /// ([`crate::sim::mem::TieredMemory::decay_windows`]), so a page
    /// sustaining `r` accesses/interval settles at a decayed count of
    /// `≈ 2r`; `window_count / 2` is therefore the maximum-likelihood
    /// per-interval rate, and hits over the horizon are
    /// `window_count × horizon / 2`.
    pub fn predicted_hits(&self, window_count: u32) -> u64 {
        (window_count as u64).saturating_mul(self.horizon_intervals as u64) / 2
    }
}

/// One candidate's admission verdict. The rejection order is fixed:
/// cool-down first (ping-pong traffic is refused before it can consume
/// payoff analysis or budget), then payoff, then budget — so a budget
/// rejection always means "worth moving, bandwidth exhausted".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Accept,
    RejectBudget,
    RejectPayoff,
    RejectCooldown,
}

/// The per-run admission state: the configured predicate, the budget
/// ledger, and the per-page last-demoted stamps the cool-down filter
/// reads. Owned by the policy ([`crate::tpp::Tpp`]) so sweeps' parallel
/// cells never share gate state.
#[derive(Clone, Debug)]
pub struct AdmissionGate {
    cfg: AdmissionConfig,
    ledger: BudgetLedger,
    /// Per-page stamp: interval of the last demotion **plus one**
    /// (0 = never demoted). Grown lazily to the highest demoted page id.
    last_demoted: Vec<u32>,
}

impl AdmissionGate {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionGate {
            ledger: BudgetLedger::new(cfg.budget_pages),
            cfg,
            last_demoted: Vec::new(),
        }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.cfg
    }

    /// Copy-traffic pages charged so far this interval (incl. debt).
    pub fn spent(&self) -> u64 {
        self.ledger.spent()
    }

    /// Open a new interval: refresh the budget allowance (carrying any
    /// overspend as debt) and charge `carried_copy_pages` of traffic
    /// the gate never saw at admit time — the non-exclusive model's
    /// retried transactional copies.
    pub fn begin_interval(&mut self, carried_copy_pages: u64) {
        self.ledger.begin_interval();
        self.ledger.charge(carried_copy_pages);
    }

    /// Judge one promotion candidate. An [`Verdict::Accept`] charges
    /// one page of copy traffic to the budget; rejections charge
    /// nothing and deliberately leave the page's window history intact
    /// (the benefit signal must survive for the next interval's
    /// attempt).
    pub fn admit(&mut self, id: PageId, window_count: u32, now: u32) -> Verdict {
        let stamp = self.last_demoted.get(id as usize).copied().unwrap_or(0);
        if stamp != 0 {
            let demoted_at = stamp - 1;
            if now.saturating_sub(demoted_at) < self.cfg.cooldown_intervals {
                return Verdict::RejectCooldown;
            }
        }
        if self.cfg.predicted_hits(window_count) <= COPY_COST_ACCESSES {
            return Verdict::RejectPayoff;
        }
        if self.ledger.would_exceed(1) {
            return Verdict::RejectBudget;
        }
        self.ledger.charge(1);
        Verdict::Accept
    }

    /// Record a demotion: stamp the page for the cool-down filter and,
    /// when the demotion actually copied data (`copied` — false for
    /// free shadow unmaps), charge one page of copy traffic.
    pub fn note_demotion(&mut self, id: PageId, now: u32, copied: bool) {
        let idx = id as usize;
        if self.last_demoted.len() <= idx {
            self.last_demoted.resize(idx + 1, 0);
        }
        self.last_demoted[idx] = now + 1;
        if copied {
            self.ledger.charge(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(cfg: AdmissionConfig) -> AdmissionGate {
        let mut g = AdmissionGate::new(cfg);
        g.begin_interval(0);
        g
    }

    #[test]
    fn parse_modes_and_key_roundtrip() {
        for mode in ["on", "enabled", "gated", "true", " ON "] {
            assert!(AdmissionConfig::parse(mode, 1, 2, 3).unwrap().enabled, "{mode}");
        }
        for mode in ["off", "disabled", "false"] {
            assert!(!AdmissionConfig::parse(mode, 1, 2, 3).unwrap().enabled, "{mode}");
        }
        assert!(AdmissionConfig::parse("bogus", 1, 2, 3).is_err());
        assert_eq!(
            AdmissionConfig::parse("on", 1, 2, 0).unwrap().horizon_intervals,
            1,
            "horizon must clamp to >= 1"
        );
        for cfg in [
            AdmissionConfig::default(),
            AdmissionConfig::enabled_default(),
            AdmissionConfig { enabled: true, budget_pages: 0, cooldown_intervals: 7, horizon_intervals: 9 },
        ] {
            let (e, b, c, h) = cfg.key();
            assert_eq!(AdmissionConfig::from_key(e, b, c, h), cfg);
        }
    }

    #[test]
    fn default_is_disabled_and_enabled_default_differs_only_in_the_switch() {
        let off = AdmissionConfig::default();
        let on = AdmissionConfig::enabled_default();
        assert!(!off.enabled && on.enabled);
        assert_eq!(off.budget_pages, on.budget_pages);
        assert_eq!(off.cooldown_intervals, on.cooldown_intervals);
        assert_eq!(off.horizon_intervals, on.horizon_intervals);
    }

    #[test]
    fn payoff_boundary_is_strict() {
        // horizon 32: predicted hits = w * 16; copy cost = 64.
        assert_eq!(COPY_COST_ACCESSES, 64);
        let mut g = gate(AdmissionConfig::enabled_default());
        // w = 4 ⇒ 64 hits = cost exactly: not strictly more, rejected
        assert_eq!(g.admit(0, 4, 100), Verdict::RejectPayoff);
        // w = 5 ⇒ 80 hits > 64: admitted
        assert_eq!(g.admit(0, 5, 100), Verdict::Accept);
        // marginal TPP candidates (hot_thr 2) are exactly what the
        // payoff filter exists to refuse
        assert_eq!(g.admit(1, 2, 100), Verdict::RejectPayoff);
    }

    #[test]
    fn budget_exhaustion_rejects_then_recovers_next_interval() {
        let cfg = AdmissionConfig {
            enabled: true,
            budget_pages: 2,
            cooldown_intervals: 4,
            horizon_intervals: 32,
        };
        let mut g = gate(cfg);
        assert_eq!(g.admit(0, 16, 10), Verdict::Accept);
        assert_eq!(g.admit(1, 16, 10), Verdict::Accept);
        assert_eq!(g.admit(2, 16, 10), Verdict::RejectBudget);
        assert_eq!(g.spent(), 2, "rejections charge nothing");
        g.begin_interval(0);
        assert_eq!(g.admit(2, 16, 11), Verdict::Accept);
    }

    #[test]
    fn carried_copies_and_copying_demotions_consume_the_budget() {
        let cfg = AdmissionConfig {
            enabled: true,
            budget_pages: 3,
            cooldown_intervals: 4,
            horizon_intervals: 32,
        };
        let mut g = AdmissionGate::new(cfg);
        // two retried transactional copies charged up front
        g.begin_interval(2);
        assert_eq!(g.spent(), 2);
        // a copying demotion spends the last page...
        g.note_demotion(9, 5, true);
        assert_eq!(g.admit(0, 16, 5), Verdict::RejectBudget);
        // ...while a free shadow unmap costs nothing
        let mut g2 = AdmissionGate::new(cfg);
        g2.begin_interval(2);
        g2.note_demotion(9, 5, false);
        assert_eq!(g2.admit(0, 16, 5), Verdict::Accept);
    }

    #[test]
    fn cooldown_rejects_until_exactly_the_configured_age() {
        let cfg = AdmissionConfig {
            enabled: true,
            budget_pages: 0,
            cooldown_intervals: 16,
            horizon_intervals: 32,
        };
        let mut g = gate(cfg);
        g.note_demotion(7, 10, true);
        assert_eq!(g.admit(7, 32, 10), Verdict::RejectCooldown, "same interval");
        assert_eq!(g.admit(7, 32, 25), Verdict::RejectCooldown, "15 < 16 intervals");
        assert_eq!(g.admit(7, 32, 26), Verdict::Accept, "cool-down served");
        // pages never demoted are unaffected, including id 0 (the stamp
        // encoding reserves 0 for "never")
        assert_eq!(g.admit(0, 32, 10), Verdict::Accept);
    }

    #[test]
    fn cooldown_outranks_payoff_and_budget() {
        let cfg = AdmissionConfig {
            enabled: true,
            budget_pages: 1,
            cooldown_intervals: 8,
            horizon_intervals: 32,
        };
        let mut g = gate(cfg);
        g.note_demotion(3, 4, true); // also exhausts the 1-page budget
        // cold AND over budget AND cooling down ⇒ the cool-down verdict
        // wins (ping-pong is refused before anything else is consulted)
        assert_eq!(g.admit(3, 1, 5), Verdict::RejectCooldown);
        // payoff outranks budget for non-cooling candidates
        assert_eq!(g.admit(4, 1, 5), Verdict::RejectPayoff);
        assert_eq!(g.admit(5, 32, 5), Verdict::RejectBudget);
    }
}
