//! Migration admission control: budgeted, payoff-gated promotion with
//! thrashing resistance (TierBPF's admission control for page migration
//! and Jenga's responsive-tiering-without-thrashing, both in PAPERS.md).
//!
//! The paper's core claim is that fast-memory sizing is governed by the
//! *overhead* of page migration — yet a stock TPP loop promotes anything
//! that crosses `hot_thr` with no notion of migration bandwidth or
//! payoff, so under drifting hot sets the model overstates achievable
//! savings. This subsystem puts three independent filters in front of
//! every promotion the policy would otherwise issue:
//!
//! 1. **Bandwidth budget** ([`BudgetLedger`]): a per-interval allowance
//!    of migration copy traffic in pages, charged for promotion copies,
//!    copying demotions, and the non-exclusive model's retried
//!    transactional copies. Overspend (traffic the gate could not
//!    refuse, e.g. forced retries) carries over as debt into the next
//!    interval's allowance.
//! 2. **Payoff predicate**: a candidate is admitted only when its
//!    predicted fast-tier hits over a residency horizon — estimated
//!    from the page's decayed window access count — exceed the copy
//!    cost of moving it, measured in access-equivalents
//!    ([`policy::COPY_COST_ACCESSES`]).
//! 3. **Cool-down filter**: a per-page last-demoted stamp; candidates
//!    demoted less than `cooldown_intervals` ago are rejected outright
//!    as ping-pong traffic, before payoff or budget are even consulted.
//!
//! The gate **observes and vetoes, never initiates**: victim selection,
//! watermarks and reclaim order stay exactly TPP's ([`crate::tpp::Tpp`]
//! carries an optional [`AdmissionGate`]; `None` is bit-identical to the
//! pre-admission policy). The `tpp-gated` policy
//! ([`crate::tpp::TppGated`]) is TPP with the gate always installed.
//!
//! Every verdict is counted in
//! [`crate::sim::mem::MigrationCounters`]'s four
//! `admission_{accepted,rejected_budget,rejected_payoff,
//! rejected_cooldown}` counters, which flow end-to-end through
//! telemetry vmstat, service ingest, the obs metric families/journal
//! events and the artifact cell tables.

pub mod budget;
pub mod policy;

pub use budget::BudgetLedger;
pub use policy::{AdmissionConfig, AdmissionGate, Verdict};
