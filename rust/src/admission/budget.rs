//! The per-interval migration bandwidth ledger.
//!
//! Budget is denominated in *pages of copy traffic per interval* — the
//! unit every charge site shares: a promotion copy is one page, a
//! copying (non-shadow) demotion is one page, and each retried
//! transactional copy in the non-exclusive model re-moves one page.
//! Free shadow demotions move no bytes and are never charged.

/// Tracks copy-traffic pages charged against a per-interval budget.
///
/// `budget_pages == 0` means unlimited: nothing is ever refused and the
/// ledger resets every interval. Otherwise spending above the budget —
/// possible because some traffic cannot be refused (kswapd demotions
/// under watermark pressure, forced transactional retries) — carries
/// over as *debt*: the next interval starts with
/// `spent - budget_pages` already consumed, so sustained overspend
/// throttles future admissions instead of being forgotten.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BudgetLedger {
    budget_pages: u64,
    spent: u64,
}

impl BudgetLedger {
    pub fn new(budget_pages: u64) -> Self {
        BudgetLedger { budget_pages, spent: 0 }
    }

    /// Per-interval budget in pages (0 = unlimited).
    pub fn budget_pages(&self) -> u64 {
        self.budget_pages
    }

    /// Copy-traffic pages charged so far this interval (plus any debt
    /// carried from previous intervals).
    pub fn spent(&self) -> u64 {
        self.spent
    }

    /// Pages still admissible this interval (`u64::MAX` when unlimited).
    pub fn remaining(&self) -> u64 {
        if self.budget_pages == 0 {
            return u64::MAX;
        }
        self.budget_pages.saturating_sub(self.spent)
    }

    /// Start a new interval: grant one budget's worth of allowance,
    /// keeping any overspend beyond it as carried debt.
    pub fn begin_interval(&mut self) {
        if self.budget_pages == 0 {
            self.spent = 0;
        } else {
            self.spent = self.spent.saturating_sub(self.budget_pages);
        }
    }

    /// Would charging `pages` more exceed the budget?
    pub fn would_exceed(&self, pages: u64) -> bool {
        self.budget_pages != 0 && self.spent.saturating_add(pages) > self.budget_pages
    }

    /// Charge `pages` of copy traffic (unconditionally — callers that
    /// can refuse the traffic check [`Self::would_exceed`] first;
    /// traffic that cannot be refused is charged regardless and becomes
    /// carried debt).
    pub fn charge(&mut self, pages: u64) {
        self.spent = self.spent.saturating_add(pages);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_budget_is_unlimited() {
        let mut l = BudgetLedger::new(0);
        l.charge(1_000_000);
        assert!(!l.would_exceed(u64::MAX / 2));
        assert_eq!(l.remaining(), u64::MAX);
        l.begin_interval();
        assert_eq!(l.spent(), 0, "unlimited ledger resets each interval");
    }

    #[test]
    fn budget_refuses_at_the_boundary() {
        let mut l = BudgetLedger::new(4);
        assert!(!l.would_exceed(4), "exactly the budget is admissible");
        assert!(l.would_exceed(5));
        l.charge(3);
        assert_eq!(l.remaining(), 1);
        assert!(!l.would_exceed(1));
        assert!(l.would_exceed(2));
        l.charge(1);
        assert!(l.would_exceed(1), "budget exactly exhausted");
        assert_eq!(l.remaining(), 0);
    }

    #[test]
    fn overspend_carries_over_as_debt() {
        let mut l = BudgetLedger::new(4);
        l.charge(10); // unrefusable traffic: 6 pages over budget
        l.begin_interval();
        assert_eq!(l.spent(), 6, "debt carries into the next interval");
        assert_eq!(l.remaining(), 0);
        assert!(l.would_exceed(1));
        l.begin_interval();
        assert_eq!(l.spent(), 2);
        assert_eq!(l.remaining(), 2);
        l.begin_interval();
        assert_eq!(l.spent(), 0, "debt fully amortized");
    }
}
