//! Runtime workload telemetry (§5 "online component").
//!
//! On the paper's testbed this comes from hardware performance counters
//! (AI, access counts) and `/proc/vmstat` (migration counts); here the
//! counters are sourced from per-interval [`TelemetrySample`]s and
//! exported under their vmstat names.
//!
//! The module is split along the service boundary introduced by the
//! tuner-as-a-service redesign:
//!
//! * [`TelemetrySample`] — one interval's counters as a plain,
//!   engine-independent value. The simulator emits these (see
//!   [`crate::sim::RunTrace::sample`]), but any producer can construct
//!   them — `tuna serve` parses them out of a text stream.
//! * [`WindowAggregator`] — pure per-window aggregation: accumulates
//!   samples and collapses a tuning window into the micro-benchmark
//!   configuration vector the tuner queries the database with.
//! * [`VmstatCounters`] — run-lifetime cumulative counters under their
//!   `/proc/vmstat` names, for reports and failure-injection tests.
//!
//! A tuner service hosts one aggregator + counter pair per session; they
//! share nothing, so sessions are independent by construction.

use crate::microbench::MicrobenchConfig;
use crate::sim::RunTrace;
use crate::LINE_BYTES;

/// One interval's telemetry, decoupled from the simulator's trace record:
/// exactly the counters the online component consumes, nothing owned by
/// the engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TelemetrySample {
    /// Interval index this sample was taken at (1-based, as in traces).
    pub interval: u32,
    /// Page accesses served by fast / slow memory.
    pub acc_fast: u64,
    pub acc_slow: u64,
    /// Sampled (hint-fault) accesses per tier: per-page counts saturated
    /// at the policy's `hot_thr` — the units the paper's Eq. (1)–(4) use.
    pub sacc_fast: u64,
    pub sacc_slow: u64,
    pub flops: u64,
    pub iops: u64,
    pub promoted: u64,
    pub promote_failed: u64,
    pub demoted_kswapd: u64,
    pub demoted_direct: u64,
    /// Accesses served by a shadowed page's fast copy while its slow-tier
    /// source frame was still valid (non-exclusive migration only; always
    /// 0 under exclusive semantics, as are the three counters below).
    pub shadow_hits: u64,
    /// Demotions satisfied by unmapping a clean shadow copy — no data
    /// movement.
    pub shadow_free_demotions: u64,
    /// Transactional promotion copies aborted by a write to the page.
    pub txn_aborts: u64,
    /// Aborted copies restarted because the page was still hot.
    pub txn_retried_copies: u64,
    /// Admission-gate verdicts (see [`crate::admission`]); all zero when
    /// the run installs no gate.
    pub admission_accepted: u64,
    pub admission_rejected_budget: u64,
    pub admission_rejected_payoff: u64,
    pub admission_rejected_cooldown: u64,
    /// Free fast-memory pages at the end of the interval (a gauge, not a
    /// counter).
    pub fast_free: u64,
    /// Modeled wall time of the interval in nanoseconds (rounded). The
    /// outcome tracker turns these into realized loss; 0 in telemetry
    /// streams recorded before the field existed (the tracker then
    /// reports zero realized loss rather than inventing one).
    pub wall_ns: u64,
}

impl TelemetrySample {
    /// Extract the sample from a simulator trace record.
    pub fn from_trace(t: &RunTrace) -> Self {
        TelemetrySample {
            interval: t.interval,
            acc_fast: t.acc_fast,
            acc_slow: t.acc_slow,
            sacc_fast: t.sacc_fast,
            sacc_slow: t.sacc_slow,
            flops: t.flops,
            iops: t.iops,
            promoted: t.promoted,
            promote_failed: t.promote_failed,
            demoted_kswapd: t.demoted_kswapd,
            demoted_direct: t.demoted_direct,
            shadow_hits: t.shadow_hits,
            shadow_free_demotions: t.shadow_free_demotions,
            txn_aborts: t.txn_aborts,
            txn_retried_copies: t.txn_retried_copies,
            admission_accepted: t.admission_accepted,
            admission_rejected_budget: t.admission_rejected_budget,
            admission_rejected_payoff: t.admission_rejected_payoff,
            admission_rejected_cooldown: t.admission_rejected_cooldown,
            fast_free: t.fast_free,
            wall_ns: t.wall_ns.round() as u64,
        }
    }
}

impl From<&RunTrace> for TelemetrySample {
    fn from(t: &RunTrace) -> Self {
        TelemetrySample::from_trace(t)
    }
}

/// Raw sums accumulated in the current tuning window (what
/// [`WindowAggregator::take_window_config`] averages). Exposed so tests
/// can check windowing exactly: integer totals across arbitrary window
/// boundaries must sum to the cumulative counters, with no float error.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WindowTotals {
    pub intervals: u32,
    pub acc_fast: u64,
    pub acc_slow: u64,
    pub sacc_fast: u64,
    pub sacc_slow: u64,
    pub promoted: u64,
    pub demoted: u64,
    pub ops: u64,
}

/// Pure per-window aggregation: accumulates [`TelemetrySample`]s and
/// collapses each tuning window into a configuration vector. Holds the
/// session-constant query dimensions (`hot_thr`, threads, RSS) so the
/// service can key one aggregator per session.
#[derive(Clone, Debug)]
pub struct WindowAggregator {
    hot_thr: u32,
    threads: u32,
    rss_pages: u64,
    w: WindowTotals,
}

impl WindowAggregator {
    pub fn new(hot_thr: u32, threads: u32, rss_pages: u64) -> Self {
        WindowAggregator { hot_thr, threads, rss_pages, w: WindowTotals::default() }
    }

    /// Accumulate one interval's sample into the current window.
    pub fn observe(&mut self, s: &TelemetrySample) {
        self.w.intervals += 1;
        self.w.acc_fast += s.acc_fast;
        self.w.acc_slow += s.acc_slow;
        self.w.sacc_fast += s.sacc_fast;
        self.w.sacc_slow += s.sacc_slow;
        self.w.promoted += s.promoted;
        self.w.demoted += s.demoted_kswapd + s.demoted_direct;
        self.w.ops += s.flops + s.iops;
    }

    /// Number of intervals accumulated in the current window.
    pub fn window_len(&self) -> u32 {
        self.w.intervals
    }

    /// Raw sums of the current window (not reset).
    pub fn totals(&self) -> WindowTotals {
        self.w
    }

    /// Collapse the window into a configuration vector (per-interval
    /// means) and reset the window. Returns `None` on an empty window.
    pub fn take_window_config(&mut self) -> Option<MicrobenchConfig> {
        if self.w.intervals == 0 {
            return None;
        }
        let n = self.w.intervals as f64;
        let bytes = (self.w.acc_fast + self.w.acc_slow) * LINE_BYTES;
        let ai = if bytes == 0 { 0.0 } else { self.w.ops as f64 / bytes as f64 };
        // pacc is in *sampled* (hint-fault) units — see TelemetrySample.
        let cfg = MicrobenchConfig {
            pacc_f: self.w.sacc_fast as f64 / n,
            pacc_s: self.w.sacc_slow as f64 / n,
            pm_de: self.w.demoted as f64 / n,
            pm_pr: self.w.promoted as f64 / n,
            ai,
            rss_pages: self.rss_pages as f64,
            hot_thr: self.hot_thr as f64,
            num_threads: self.threads as f64,
        };
        self.w = WindowTotals::default();
        Some(cfg)
    }
}

/// Run-lifetime cumulative counters under their `/proc/vmstat` names —
/// what the testbed exposes; used by reports and the failure-injection
/// tests. Never reset by window boundaries.
#[derive(Clone, Copy, Debug, Default)]
pub struct VmstatCounters {
    pub pgpromote_success: u64,
    pub pgpromote_fail: u64,
    pub pgdemote_kswapd: u64,
    pub pgdemote_direct: u64,
    pub numa_hint_faults: u64,
    pub nr_free_pages_fast: u64,
    /// Non-exclusive (transactional) migration counters; all zero for
    /// exclusive runs. Not standard vmstat names — Nomad-style kernels
    /// would export them similarly.
    pub shadow_hits: u64,
    pub shadow_free_demotions: u64,
    pub txn_aborts: u64,
    pub txn_retried_copies: u64,
    /// Admission-gate verdict counters (see [`crate::admission`]); all
    /// zero for ungated runs. Also not standard vmstat names.
    pub admission_accepted: u64,
    pub admission_rejected_budget: u64,
    pub admission_rejected_payoff: u64,
    pub admission_rejected_cooldown: u64,
}

impl VmstatCounters {
    pub fn new() -> Self {
        VmstatCounters::default()
    }

    /// Fold one interval's sample into the cumulative counters.
    pub fn observe(&mut self, s: &TelemetrySample) {
        self.pgpromote_success += s.promoted;
        self.pgpromote_fail += s.promote_failed;
        self.pgdemote_kswapd += s.demoted_kswapd;
        self.pgdemote_direct += s.demoted_direct;
        self.numa_hint_faults += s.promoted + s.promote_failed;
        self.nr_free_pages_fast = s.fast_free;
        self.shadow_hits += s.shadow_hits;
        self.shadow_free_demotions += s.shadow_free_demotions;
        self.txn_aborts += s.txn_aborts;
        self.txn_retried_copies += s.txn_retried_copies;
        self.admission_accepted += s.admission_accepted;
        self.admission_rejected_budget += s.admission_rejected_budget;
        self.admission_rejected_payoff += s.admission_rejected_payoff;
        self.admission_rejected_cooldown += s.admission_rejected_cooldown;
    }

    /// vmstat-style counter dump (name, value).
    pub fn vmstat(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("pgpromote_success", self.pgpromote_success),
            ("pgpromote_fail", self.pgpromote_fail),
            ("pgdemote_kswapd", self.pgdemote_kswapd),
            ("pgdemote_direct", self.pgdemote_direct),
            ("numa_hint_faults", self.numa_hint_faults),
            ("nr_free_pages_fast", self.nr_free_pages_fast),
            ("shadow_hits", self.shadow_hits),
            ("shadow_free_demotions", self.shadow_free_demotions),
            ("txn_aborts", self.txn_aborts),
            ("txn_retried_copies", self.txn_retried_copies),
            ("admission_accepted", self.admission_accepted),
            ("admission_rejected_budget", self.admission_rejected_budget),
            ("admission_rejected_payoff", self.admission_rejected_payoff),
            ("admission_rejected_cooldown", self.admission_rejected_cooldown),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::interval::IntervalOutcome;
    use crate::util::rng::Rng;

    fn trace(acc_fast: u64, acc_slow: u64, promoted: u64, demoted: u64) -> RunTrace {
        RunTrace {
            interval: 1,
            clock_ns: 0.0,
            wall_ns: 1.0,
            acc_fast,
            acc_slow,
            sacc_fast: acc_fast, // tests use counts ≤ hot_thr per page
            sacc_slow: acc_slow,
            flops: 1000,
            iops: 1000,
            promoted,
            promote_failed: 1,
            demoted_kswapd: demoted,
            demoted_direct: 0,
            shadow_hits: 3,
            shadow_free_demotions: 2,
            txn_aborts: 1,
            txn_retried_copies: 1,
            admission_accepted: 4,
            admission_rejected_budget: 2,
            admission_rejected_payoff: 3,
            admission_rejected_cooldown: 1,
            fast_used: 10,
            fast_free: 5,
            usable_fm: 10,
            outcome: IntervalOutcome::default(),
        }
    }

    fn random_sample(rng: &mut Rng, interval: u32) -> TelemetrySample {
        TelemetrySample {
            interval,
            acc_fast: rng.below(10_000),
            acc_slow: rng.below(2_000),
            sacc_fast: rng.below(5_000),
            sacc_slow: rng.below(1_000),
            flops: rng.below(100_000),
            iops: rng.below(100_000),
            promoted: rng.below(200),
            promote_failed: rng.below(20),
            demoted_kswapd: rng.below(150),
            demoted_direct: rng.below(50),
            shadow_hits: rng.below(400),
            shadow_free_demotions: rng.below(60),
            txn_aborts: rng.below(30),
            txn_retried_copies: rng.below(15),
            admission_accepted: rng.below(100),
            admission_rejected_budget: rng.below(40),
            admission_rejected_payoff: rng.below(40),
            admission_rejected_cooldown: rng.below(40),
            fast_free: rng.below(1_000),
            wall_ns: 1_000_000 + rng.below(1_000_000),
        }
    }

    #[test]
    fn window_means_and_reset() {
        let mut w = WindowAggregator::new(2, 16, 8000);
        w.observe(&trace(1000, 100, 10, 8).sample());
        w.observe(&trace(3000, 300, 20, 12).sample());
        assert_eq!(w.window_len(), 2);
        let cfg = w.take_window_config().unwrap();
        assert!((cfg.pacc_f - 2000.0).abs() < 1e-9);
        assert!((cfg.pacc_s - 200.0).abs() < 1e-9);
        assert!((cfg.pm_pr - 15.0).abs() < 1e-9);
        assert!((cfg.pm_de - 10.0).abs() < 1e-9);
        assert_eq!(cfg.hot_thr, 2.0);
        assert_eq!(cfg.num_threads, 16.0);
        assert_eq!(cfg.rss_pages, 8000.0);
        // AI = 4000 ops / (4400 accesses × 64 B)
        assert!((cfg.ai - 4000.0 / (4400.0 * 64.0)).abs() < 1e-9);
        // window reset
        assert_eq!(w.window_len(), 0);
        assert!(w.take_window_config().is_none());
    }

    #[test]
    fn cumulative_counters_persist_across_windows() {
        let mut w = WindowAggregator::new(2, 16, 8000);
        let mut c = VmstatCounters::new();
        for s in [trace(100, 10, 5, 3).sample(), trace(100, 10, 7, 4).sample()] {
            w.observe(&s);
            c.observe(&s);
            let _ = w.take_window_config();
        }
        assert_eq!(c.pgpromote_success, 12);
        assert_eq!(c.pgdemote_kswapd, 7);
        assert_eq!(c.pgpromote_fail, 2);
        assert_eq!(c.numa_hint_faults, 14);
        assert_eq!(c.shadow_hits, 6);
        assert_eq!(c.shadow_free_demotions, 4);
        assert_eq!(c.txn_aborts, 2);
        assert_eq!(c.txn_retried_copies, 2);
        assert_eq!(c.admission_accepted, 8);
        assert_eq!(c.admission_rejected_budget, 4);
        assert_eq!(c.admission_rejected_payoff, 6);
        assert_eq!(c.admission_rejected_cooldown, 2);
        let vm = c.vmstat();
        assert!(vm.iter().any(|&(k, v)| k == "pgpromote_success" && v == 12));
        assert!(vm.iter().any(|&(k, v)| k == "shadow_free_demotions" && v == 4));
        assert!(vm.iter().any(|&(k, v)| k == "txn_aborts" && v == 2));
        assert!(vm.iter().any(|&(k, v)| k == "admission_accepted" && v == 8));
        assert!(vm.iter().any(|&(k, v)| k == "admission_rejected_cooldown" && v == 2));
    }

    #[test]
    fn sample_extraction_matches_trace_fields() {
        let t = trace(123, 45, 6, 7);
        let s = TelemetrySample::from(&t);
        assert_eq!(s.interval, t.interval);
        assert_eq!(s.acc_fast, 123);
        assert_eq!(s.acc_slow, 45);
        assert_eq!(s.promoted, 6);
        assert_eq!(s.demoted_kswapd, 7);
        assert_eq!(s.promote_failed, 1);
        assert_eq!(s.fast_free, 5);
        assert_eq!(s, t.sample());
    }

    /// Satellite: per-window aggregates must sum to the cumulative
    /// vmstat counters across *arbitrary* window boundaries.
    #[test]
    fn prop_window_totals_sum_to_cumulative_counters() {
        crate::util::proptest::check(
            31,
            64,
            |rng: &mut Rng| {
                let n = 1 + rng.index(60) as u32;
                // random boundary mask: take the window after interval i
                // when bit i is set (the final partial window is flushed
                // unconditionally)
                (n, rng.next_u64(), rng.next_u64())
            },
            |_| vec![],
            |&(n, sample_seed, boundary_mask)| {
                let mut rng = Rng::new(sample_seed);
                let mut agg = WindowAggregator::new(2, 8, 4_000);
                let mut counters = VmstatCounters::new();
                let mut summed = WindowTotals::default();
                let mut direct = WindowTotals::default();
                let mut hint_faults = 0u64;
                for i in 0..n {
                    let s = random_sample(&mut rng, i + 1);
                    agg.observe(&s);
                    counters.observe(&s);
                    direct.intervals += 1;
                    direct.acc_fast += s.acc_fast;
                    direct.acc_slow += s.acc_slow;
                    direct.sacc_fast += s.sacc_fast;
                    direct.sacc_slow += s.sacc_slow;
                    direct.promoted += s.promoted;
                    direct.demoted += s.demoted_kswapd + s.demoted_direct;
                    direct.ops += s.flops + s.iops;
                    hint_faults += s.promoted + s.promote_failed;
                    let take = (boundary_mask >> (i % 64)) & 1 == 1 || i + 1 == n;
                    if take {
                        let t = agg.totals();
                        summed.intervals += t.intervals;
                        summed.acc_fast += t.acc_fast;
                        summed.acc_slow += t.acc_slow;
                        summed.sacc_fast += t.sacc_fast;
                        summed.sacc_slow += t.sacc_slow;
                        summed.promoted += t.promoted;
                        summed.demoted += t.demoted;
                        summed.ops += t.ops;
                        let cfg = agg.take_window_config();
                        if t.intervals > 0 && cfg.is_none() {
                            return Err("non-empty window yielded no config".into());
                        }
                    }
                }
                if summed != direct {
                    return Err(format!("window sums {summed:?} != per-sample sums {direct:?}"));
                }
                if summed.promoted != counters.pgpromote_success {
                    return Err(format!(
                        "window promoted {} != pgpromote_success {}",
                        summed.promoted, counters.pgpromote_success
                    ));
                }
                if summed.demoted != counters.pgdemote_kswapd + counters.pgdemote_direct {
                    return Err(format!(
                        "window demoted {} != pgdemote_kswapd+direct {}",
                        summed.demoted,
                        counters.pgdemote_kswapd + counters.pgdemote_direct
                    ));
                }
                if hint_faults != counters.numa_hint_faults {
                    return Err("numa_hint_faults drifted".into());
                }
                Ok(())
            },
        );
    }

    /// Satellite: rollover when one window spans the whole run
    /// (`window_len == intervals`): the single flush at the end sees
    /// every interval and resets cleanly.
    #[test]
    fn single_window_spanning_whole_run_rolls_over() {
        let intervals = 37u32;
        let mut rng = Rng::new(9);
        let mut agg = WindowAggregator::new(3, 4, 10_000);
        let mut sum_sacc_fast = 0u64;
        for i in 0..intervals {
            let s = random_sample(&mut rng, i + 1);
            sum_sacc_fast += s.sacc_fast;
            agg.observe(&s);
            assert_eq!(agg.window_len(), i + 1, "window grows with every sample");
        }
        assert_eq!(agg.window_len(), intervals);
        let cfg = agg.take_window_config().unwrap();
        assert!((cfg.pacc_f - sum_sacc_fast as f64 / intervals as f64).abs() < 1e-9);
        // rollover: the aggregator is empty again and usable for the next
        // window without carrying anything over
        assert_eq!(agg.window_len(), 0);
        assert_eq!(agg.totals(), WindowTotals::default());
        assert!(agg.take_window_config().is_none());
        let s = random_sample(&mut rng, intervals + 1);
        agg.observe(&s);
        assert_eq!(agg.window_len(), 1);
        let cfg2 = agg.take_window_config().unwrap();
        assert!((cfg2.pacc_f - s.sacc_fast as f64).abs() < 1e-9);
    }
}
