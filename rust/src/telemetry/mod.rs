//! Runtime workload telemetry (§5 "online component").
//!
//! On the paper's testbed this comes from hardware performance counters
//! (AI, access counts) and `/proc/vmstat` (migration counts); here the
//! counters are sourced from the simulator's per-interval trace records
//! and exported under their vmstat names. The tuner consumes the
//! per-tuning-window aggregate as a micro-benchmark configuration vector.

use crate::microbench::MicrobenchConfig;
use crate::sim::RunTrace;
use crate::LINE_BYTES;

/// Accumulates per-interval observations into tuning-window aggregates
/// plus run-lifetime cumulative counters.
#[derive(Clone, Debug)]
pub struct Telemetry {
    hot_thr: u32,
    threads: u32,
    rss_pages: u64,
    // --- window accumulators ---
    w_intervals: u32,
    w_acc_fast: u64,
    w_acc_slow: u64,
    w_sacc_fast: u64,
    w_sacc_slow: u64,
    w_promoted: u64,
    w_demoted: u64,
    w_ops: u64,
    // --- cumulative (vmstat-style) ---
    pub pgpromote_success: u64,
    pub pgpromote_fail: u64,
    pub pgdemote_kswapd: u64,
    pub pgdemote_direct: u64,
    pub numa_hint_faults: u64,
    pub nr_free_pages_fast: u64,
}

impl Telemetry {
    pub fn new(hot_thr: u32, threads: u32, rss_pages: u64) -> Self {
        Telemetry {
            hot_thr,
            threads,
            rss_pages,
            w_intervals: 0,
            w_acc_fast: 0,
            w_acc_slow: 0,
            w_sacc_fast: 0,
            w_sacc_slow: 0,
            w_promoted: 0,
            w_demoted: 0,
            w_ops: 0,
            pgpromote_success: 0,
            pgpromote_fail: 0,
            pgdemote_kswapd: 0,
            pgdemote_direct: 0,
            numa_hint_faults: 0,
            nr_free_pages_fast: 0,
        }
    }

    /// Record one interval.
    pub fn observe(&mut self, t: &RunTrace) {
        self.w_intervals += 1;
        self.w_acc_fast += t.acc_fast;
        self.w_acc_slow += t.acc_slow;
        self.w_sacc_fast += t.sacc_fast;
        self.w_sacc_slow += t.sacc_slow;
        self.w_promoted += t.promoted;
        self.w_demoted += t.demoted_kswapd + t.demoted_direct;
        self.w_ops += t.flops + t.iops;

        self.pgpromote_success += t.promoted;
        self.pgpromote_fail += t.promote_failed;
        self.pgdemote_kswapd += t.demoted_kswapd;
        self.pgdemote_direct += t.demoted_direct;
        self.numa_hint_faults += t.promoted + t.promote_failed;
        self.nr_free_pages_fast = t.fast_free;
    }

    /// Number of intervals accumulated in the current window.
    pub fn window_len(&self) -> u32 {
        self.w_intervals
    }

    /// Collapse the window into a configuration vector (per-interval
    /// means) and reset the window. Returns `None` on an empty window.
    pub fn take_window_config(&mut self) -> Option<MicrobenchConfig> {
        if self.w_intervals == 0 {
            return None;
        }
        let n = self.w_intervals as f64;
        let bytes = (self.w_acc_fast + self.w_acc_slow) * LINE_BYTES;
        let ai = if bytes == 0 { 0.0 } else { self.w_ops as f64 / bytes as f64 };
        // pacc is in *sampled* (hint-fault) units — see RunTrace::sacc_fast.
        let cfg = MicrobenchConfig {
            pacc_f: self.w_sacc_fast as f64 / n,
            pacc_s: self.w_sacc_slow as f64 / n,
            pm_de: self.w_demoted as f64 / n,
            pm_pr: self.w_promoted as f64 / n,
            ai,
            rss_pages: self.rss_pages as f64,
            hot_thr: self.hot_thr as f64,
            num_threads: self.threads as f64,
        };
        self.w_intervals = 0;
        self.w_acc_fast = 0;
        self.w_acc_slow = 0;
        self.w_sacc_fast = 0;
        self.w_sacc_slow = 0;
        self.w_promoted = 0;
        self.w_demoted = 0;
        self.w_ops = 0;
        Some(cfg)
    }

    /// vmstat-style counter dump (name, value) — what `/proc/vmstat`
    /// exposes on the testbed; used by reports and the failure-injection
    /// tests.
    pub fn vmstat(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("pgpromote_success", self.pgpromote_success),
            ("pgpromote_fail", self.pgpromote_fail),
            ("pgdemote_kswapd", self.pgdemote_kswapd),
            ("pgdemote_direct", self.pgdemote_direct),
            ("numa_hint_faults", self.numa_hint_faults),
            ("nr_free_pages_fast", self.nr_free_pages_fast),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::interval::IntervalOutcome;

    fn trace(acc_fast: u64, acc_slow: u64, promoted: u64, demoted: u64) -> RunTrace {
        RunTrace {
            interval: 1,
            clock_ns: 0.0,
            wall_ns: 1.0,
            acc_fast,
            acc_slow,
            sacc_fast: acc_fast, // tests use counts ≤ hot_thr per page
            sacc_slow: acc_slow,
            flops: 1000,
            iops: 1000,
            promoted,
            promote_failed: 1,
            demoted_kswapd: demoted,
            demoted_direct: 0,
            fast_used: 10,
            fast_free: 5,
            usable_fm: 10,
            outcome: IntervalOutcome::default(),
        }
    }

    #[test]
    fn window_means_and_reset() {
        let mut t = Telemetry::new(2, 16, 8000);
        t.observe(&trace(1000, 100, 10, 8));
        t.observe(&trace(3000, 300, 20, 12));
        assert_eq!(t.window_len(), 2);
        let cfg = t.take_window_config().unwrap();
        assert!((cfg.pacc_f - 2000.0).abs() < 1e-9);
        assert!((cfg.pacc_s - 200.0).abs() < 1e-9);
        assert!((cfg.pm_pr - 15.0).abs() < 1e-9);
        assert!((cfg.pm_de - 10.0).abs() < 1e-9);
        assert_eq!(cfg.hot_thr, 2.0);
        assert_eq!(cfg.num_threads, 16.0);
        assert_eq!(cfg.rss_pages, 8000.0);
        // AI = 4000 ops / (4400 accesses × 64 B)
        assert!((cfg.ai - 4000.0 / (4400.0 * 64.0)).abs() < 1e-9);
        // window reset
        assert_eq!(t.window_len(), 0);
        assert!(t.take_window_config().is_none());
    }

    #[test]
    fn cumulative_counters_persist_across_windows() {
        let mut t = Telemetry::new(2, 16, 8000);
        t.observe(&trace(100, 10, 5, 3));
        let _ = t.take_window_config();
        t.observe(&trace(100, 10, 7, 4));
        assert_eq!(t.pgpromote_success, 12);
        assert_eq!(t.pgdemote_kswapd, 7);
        assert_eq!(t.pgpromote_fail, 2);
        assert_eq!(t.numa_hint_faults, 14);
        let vm = t.vmstat();
        assert!(vm.iter().any(|&(k, v)| k == "pgpromote_success" && v == 12));
    }
}
