//! Binary (de)serialization for the performance database.
//!
//! Flat little-endian format (no serde offline):
//!
//! ```text
//! magic    8  b"TUNADB1\0"
//! n_sizes  u32
//! n_recs   u32
//! fractions f32 × n_sizes
//! records:
//!   raw      f64 × 8
//!   vec      f32 × 8
//!   times    f32 × n_sizes
//! crc      u32   (crc32 of everything after the magic)
//! ```

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{PerfDb, Record, DIMS};

const MAGIC: &[u8; 8] = b"TUNADB1\0";

/// CRC-32 (IEEE) lookup table, computed once at compile time — it sits on
/// the hot path of every artifact write/read, so it must not be rebuilt
/// per call.
const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Streaming CRC-32 (IEEE) hasher, for writers that emit artifacts
/// incrementally (e.g. the sharded segment writers) without buffering the
/// whole payload just to checksum it.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.state = CRC_TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// Simple CRC-32 (IEEE) — integrity check for the artifact file.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

/// Serialize to bytes.
pub fn to_bytes(db: &PerfDb) -> Vec<u8> {
    let n_sizes = db.fractions.len() as u32;
    let n_recs = db.records.len() as u32;
    let mut body = Vec::with_capacity(
        8 + (db.records.len() * (DIMS * 12 + db.fractions.len() * 4)) + db.fractions.len() * 4,
    );
    body.extend_from_slice(&n_sizes.to_le_bytes());
    body.extend_from_slice(&n_recs.to_le_bytes());
    for &f in &db.fractions {
        body.extend_from_slice(&f.to_le_bytes());
    }
    for r in &db.records {
        for &x in &r.raw {
            body.extend_from_slice(&x.to_le_bytes());
        }
        for &x in &r.vec {
            body.extend_from_slice(&x.to_le_bytes());
        }
        for &t in &r.times_ns {
            body.extend_from_slice(&t.to_le_bytes());
        }
    }
    let crc = crc32(&body);
    let mut out = Vec::with_capacity(8 + body.len() + 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Deserialize from bytes (validates magic, CRC and structure).
pub fn from_bytes(data: &[u8]) -> Result<PerfDb> {
    if data.len() < 8 + 8 + 4 {
        bail!("perfdb file truncated ({} bytes)", data.len());
    }
    if &data[..8] != MAGIC {
        bail!("bad perfdb magic");
    }
    let body = &data[8..data.len() - 4];
    let stored_crc = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    let crc = crc32(body);
    if crc != stored_crc {
        bail!("perfdb CRC mismatch: stored {stored_crc:#x}, computed {crc:#x}");
    }

    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > body.len() {
            bail!("perfdb body truncated at offset {}", *pos);
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let n_sizes = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let n_recs = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    if n_sizes == 0 || n_sizes > 1_000 || n_recs > 10_000_000 {
        bail!("implausible perfdb header: {n_sizes} sizes, {n_recs} records");
    }
    let mut fractions = Vec::with_capacity(n_sizes);
    for _ in 0..n_sizes {
        fractions.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()));
    }
    let mut records = Vec::with_capacity(n_recs);
    for _ in 0..n_recs {
        let mut raw = [0f64; DIMS];
        for x in &mut raw {
            *x = f64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        }
        let mut vec = [0f32; DIMS];
        for x in &mut vec {
            *x = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        }
        let mut times_ns = Vec::with_capacity(n_sizes);
        for _ in 0..n_sizes {
            times_ns.push(f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()));
        }
        records.push(Record { raw, vec, times_ns });
    }
    if pos != body.len() {
        bail!("perfdb has {} trailing bytes", body.len() - pos);
    }
    Ok(PerfDb { fractions, records })
}

/// Write the database to a file (atomically via a per-process unique temp
/// file in the same directory — see [`crate::artifact::write_atomic`];
/// `path.with_extension("tmp")` would collide when two processes write
/// sibling artifacts).
pub fn save(db: &PerfDb, path: &Path) -> Result<()> {
    crate::artifact::write_atomic(path, &to_bytes(db))
        .with_context(|| format!("saving perfdb {}", path.display()))
}

/// Load a database from a file.
pub fn load(path: &Path) -> Result<PerfDb> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening perfdb {}", path.display()))?
        .read_to_end(&mut data)?;
    from_bytes(&data).with_context(|| format!("parsing perfdb {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::normalize;

    fn sample_db() -> PerfDb {
        let mk = |seed: f64| {
            let raw = [seed * 10.0, seed, seed, seed, 1.0, 4000.0, 2.0, 16.0];
            Record { raw, vec: normalize(&raw), times_ns: vec![100.0 + seed as f32, 120.0, 150.0] }
        };
        PerfDb { fractions: vec![1.0, 0.8, 0.6], records: vec![mk(1.0), mk(2.0), mk(3.0)] }
    }

    #[test]
    fn roundtrip_exact() {
        let db = sample_db();
        let bytes = to_bytes(&db);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back.fractions, db.fractions);
        assert_eq!(back.records.len(), db.records.len());
        for (a, b) in db.records.iter().zip(&back.records) {
            assert_eq!(a.raw, b.raw);
            assert_eq!(a.vec, b.vec);
            assert_eq!(a.times_ns, b.times_ns);
        }
    }

    #[test]
    fn corruption_detected() {
        let db = sample_db();
        let mut bytes = to_bytes(&db);
        // flip a byte in the middle
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(from_bytes(&bytes).is_err());
        // bad magic
        let mut bytes2 = to_bytes(&db);
        bytes2[0] = b'X';
        assert!(from_bytes(&bytes2).is_err());
        // truncation
        let bytes3 = &to_bytes(&db)[..20];
        assert!(from_bytes(bytes3).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let db = sample_db();
        let dir = std::env::temp_dir().join("tuna_store_test");
        let path = dir.join("db.bin");
        save(&db, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.records.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // "123456789" → 0xCBF43926 (IEEE test vector)
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn streaming_crc_matches_one_shot() {
        let data = to_bytes(&sample_db());
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
        assert_eq!(Crc32::new().finish(), crc32(b""));
    }

    #[test]
    fn concurrent_saves_to_sibling_paths_do_not_collide() {
        // `db.bin` and `db.tmp` targets once shared the temp name
        // `db.tmp`; per-process unique temps must keep both writes intact.
        let db = sample_db();
        let dir = std::env::temp_dir().join(format!("tuna_store_tmp_{}", std::process::id()));
        let a = dir.join("db.bin");
        let b = dir.join("db.tmp");
        std::thread::scope(|s| {
            s.spawn(|| save(&db, &a).unwrap());
            s.spawn(|| save(&db, &b).unwrap());
        });
        assert_eq!(load(&a).unwrap().records.len(), 3);
        assert_eq!(load(&b).unwrap().records.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
