//! The performance database (§3.3, §5).
//!
//! Offline, [`builder`] sweeps micro-benchmark configurations × fast-memory
//! sizes through the simulator and collects *execution records*: for each
//! eight-element configuration vector, the micro-benchmark's execution
//! time at every sampled fast-memory fraction. Records are stored in a
//! flat binary file ([`store`]) that both the Rust coordinator and the
//! build-time AOT pipeline read.
//!
//! Online, the runtime queries the database with a telemetry-derived
//! configuration vector; the nearest record (L2 over normalized vectors —
//! exact nearest neighbour, standing in for the paper's Faiss HNSW index)
//! supplies the loss-vs-size curve the tuner needs. Two query paths exist:
//! [`native::NativeNn`] (brute force, the oracle/baseline) and
//! [`crate::runtime::XlaNn`] (the AOT JAX+Pallas executable via PJRT — the
//! production path, compared against native in `benches/perfdb_query.rs`).

pub mod builder;
pub mod native;
pub mod store;

use crate::microbench::MicrobenchConfig;

/// Dimensions of the configuration vector.
pub const DIMS: usize = 8;

/// Per-dimension normalization: `ln(1+x) / scale`, with scales chosen so
/// every dimension lands roughly in `[0, 1]` over its realistic range.
/// MUST stay in sync across the native and XLA query paths — the XLA
/// kernel receives *already-normalized* vectors, so this is the single
/// place normalization is defined.
pub const NORM_SCALES: [f64; DIMS] = [
    14.0, // pacc_f   (ln(1+1.2e6) ≈ 14)
    14.0, // pacc_s
    10.0, // pm_de    (ln(1+2e4) ≈ 10)
    10.0, // pm_pr
    3.0,  // AI       (ln(1+20) ≈ 3)
    11.0, // RSS pages (ln(1+6e4) ≈ 11)
    2.2,  // hot_thr  (ln(1+8) ≈ 2.2)
    3.2,  // threads  (ln(1+24) ≈ 3.2)
];

/// Normalize a raw configuration vector for nearest-neighbour search.
pub fn normalize(raw: &[f64; DIMS]) -> [f32; DIMS] {
    let mut v = [0f32; DIMS];
    for i in 0..DIMS {
        v[i] = ((1.0 + raw[i].max(0.0)).ln() / NORM_SCALES[i]) as f32;
    }
    v
}

/// Linear interpolation of `y(x)` over a curve sampled at strictly
/// *descending* `x` (the grid order of [`PerfDb::fractions`] and every
/// loss curve), clamped outside the range. Allocation-free; used on the
/// tuner's per-decision hot path.
pub fn interp_desc(curve: &[(f64, f64)], x: f64) -> f64 {
    let last = curve.len() - 1;
    if x >= curve[0].0 {
        return curve[0].1;
    }
    if x <= curve[last].0 {
        return curve[last].1;
    }
    let i = curve.partition_point(|&(f, _)| f > x);
    let ((x_hi, y_hi), (x_lo, y_lo)) = (curve[i - 1], curve[i]);
    let t = (x - x_lo) / (x_hi - x_lo);
    y_lo * (1.0 - t) + y_hi * t
}

/// Read-side abstraction over a performance database: everything the
/// tuner's decision path needs, independent of how records are resident.
///
/// Implementations: the flat in-memory [`PerfDb`], the fully-resident
/// [`crate::artifact::shard::ShardedPerfDb`], and the bounded-resident
/// [`crate::artifact::shard::LazyShardedPerfDb`] (segments faulted in on
/// first query and evicted past a residency cap). The methods are
/// fallible because a lazy source performs I/O (and CRC validation) on
/// first touch; in-memory sources never return `Err`.
///
/// Bit-identity contract: for the same underlying records,
/// [`Self::weighted_loss_curve_of`] must return bit-identical curves
/// across implementations — the default method reproduces
/// [`PerfDb::weighted_loss_curve`]'s accumulation order exactly, and
/// implementors of [`Self::loss_curve_of`] delegate to
/// [`PerfDb::loss_curve`] on their resident segment, so tuner decisions
/// do not depend on which source backs the service.
pub trait PerfSource: Send + Sync {
    /// Total records in the database.
    fn n_records(&self) -> usize;

    /// The shared fast-memory fraction grid (descending from 1.0).
    fn fraction_grid(&self) -> &[f32];

    /// Loss-vs-size curve of one record (see [`PerfDb::loss_curve`]).
    fn loss_curve_of(&self, record: usize) -> crate::Result<Vec<(f64, f64)>>;

    /// Distance-weighted average loss curve over several records —
    /// the per-decision hot path ([`PerfDb::weighted_loss_curve`]).
    fn weighted_loss_curve_of(
        &self,
        neighbors: &[(usize, f32)],
    ) -> crate::Result<Vec<(f64, f64)>> {
        assert!(!neighbors.is_empty());
        let fractions = self.fraction_grid();
        let mut acc = vec![0.0f64; fractions.len()];
        let mut wsum = 0.0f64;
        for &(rec, d2) in neighbors {
            let w = 1.0 / (d2 as f64 + 1e-2);
            wsum += w;
            for (i, (_, loss)) in self.loss_curve_of(rec)?.into_iter().enumerate() {
                acc[i] += w * loss;
            }
        }
        Ok(fractions
            .iter()
            .zip(&acc)
            .map(|(&f, &a)| (f as f64, a / wsum))
            .collect())
    }

    /// Short implementation name for logs/reports ("flat", "sharded",
    /// "lazy-sharded").
    fn source_name(&self) -> &'static str;
}

impl PerfSource for PerfDb {
    fn n_records(&self) -> usize {
        self.records.len()
    }

    fn fraction_grid(&self) -> &[f32] {
        &self.fractions
    }

    fn loss_curve_of(&self, record: usize) -> crate::Result<Vec<(f64, f64)>> {
        Ok(self.loss_curve(record))
    }

    fn weighted_loss_curve_of(
        &self,
        neighbors: &[(usize, f32)],
    ) -> crate::Result<Vec<(f64, f64)>> {
        Ok(self.weighted_loss_curve(neighbors))
    }

    fn source_name(&self) -> &'static str {
        "flat"
    }
}

/// One execution record: a configuration and its execution times at each
/// of the database's fast-memory fractions.
#[derive(Clone, Debug)]
pub struct Record {
    /// Raw configuration (pacc_f, pacc_s, pm_de, pm_pr, AI, RSS,
    /// hot_thr, num_threads).
    pub raw: [f64; DIMS],
    /// Normalized vector (what NN search runs on).
    pub vec: [f32; DIMS],
    /// Execution time (ns) at each fraction in [`PerfDb::fractions`].
    pub times_ns: Vec<f32>,
}

impl Record {
    pub fn config(&self) -> MicrobenchConfig {
        MicrobenchConfig::from_array(self.raw)
    }
}

/// The database: a shared fast-memory-fraction grid plus records.
#[derive(Clone, Debug, Default)]
pub struct PerfDb {
    /// Fast-memory fractions, descending from 1.0 (the "fast memory
    /// only" baseline the paper computes `pd'` against).
    pub fractions: Vec<f32>,
    pub records: Vec<Record>,
}

impl PerfDb {
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Predicted execution time of `record` at an arbitrary fraction
    /// (linear interpolation over the sampled grid, clamped outside it).
    ///
    /// This sits on the tuner's per-decision hot path, so it interpolates
    /// over the descending grid in place rather than materializing
    /// ascending copies of the fraction and time vectors on every call.
    pub fn time_at(&self, record: usize, fraction: f64) -> f64 {
        let r = &self.records[record];
        let fr = &self.fractions;
        let last = fr.len() - 1;
        if fraction >= fr[0] as f64 {
            return r.times_ns[0] as f64;
        }
        if fraction <= fr[last] as f64 {
            return r.times_ns[last] as f64;
        }
        // First index whose fraction is <= the query (grid is strictly
        // descending, so the predicate below is monotone true→false).
        let i = fr.partition_point(|&f| (f as f64) > fraction);
        let (x_hi, x_lo) = (fr[i - 1] as f64, fr[i] as f64);
        let (y_hi, y_lo) = (r.times_ns[i - 1] as f64, r.times_ns[i] as f64);
        let t = (fraction - x_lo) / (x_hi - x_lo);
        y_lo * (1.0 - t) + y_hi * t
    }

    /// Predicted relative performance loss `pd' = (y' − x') / x'` at each
    /// fraction, baselined on the record's fast-memory-only time (§3.3).
    pub fn loss_curve(&self, record: usize) -> Vec<(f64, f64)> {
        let r = &self.records[record];
        let base = r.times_ns[0] as f64; // fractions[0] == 1.0
        self.fractions
            .iter()
            .zip(&r.times_ns)
            .map(|(&f, &t)| (f as f64, (t as f64 - base) / base))
            .collect()
    }

    /// Smallest fraction whose predicted loss is within `target`
    /// (scanning the curve from small fractions up). Returns `None` when
    /// even the full size misses the target (can't happen with a sane
    /// record: loss at 1.0 is 0 by construction).
    pub fn min_fraction_within(&self, record: usize, target: f64) -> Option<f64> {
        let curve = self.loss_curve(record);
        // fractions descending → iterate in reverse (ascending fraction)
        for &(f, loss) in curve.iter().rev() {
            if loss <= target {
                return Some(f);
            }
        }
        None
    }

    /// Distance-weighted average loss curve over several records
    /// (weights `1/(d²+ε)`): smooths the step-function character of
    /// individual micro-benchmark records. Returns (fraction, loss)
    /// pairs in the grid order (descending fraction).
    pub fn weighted_loss_curve(&self, neighbors: &[(usize, f32)]) -> Vec<(f64, f64)> {
        assert!(!neighbors.is_empty());
        let mut acc = vec![0.0f64; self.fractions.len()];
        let mut wsum = 0.0f64;
        for &(rec, d2) in neighbors {
            let w = 1.0 / (d2 as f64 + 1e-2);
            wsum += w;
            for (i, (_, loss)) in self.loss_curve(rec).into_iter().enumerate() {
                acc[i] += w * loss;
            }
        }
        self.fractions
            .iter()
            .zip(&acc)
            .map(|(&f, &a)| (f as f64, a / wsum))
            .collect()
    }

    /// Smallest fraction whose *weighted-average* predicted loss over the
    /// `neighbors` records is within `target` (the k-NN variant of
    /// [`Self::min_fraction_within`]).
    pub fn min_fraction_within_weighted(
        &self,
        neighbors: &[(usize, f32)],
        target: f64,
    ) -> Option<f64> {
        let curve = self.weighted_loss_curve(neighbors);
        for &(f, loss) in curve.iter().rev() {
            if loss <= target {
                return Some(f);
            }
        }
        None
    }

    /// Weighted-average predicted loss at an arbitrary fraction
    /// (interpolated in place over the descending curve, clamped).
    /// Callers that also need the curve itself (e.g. the tuner, which
    /// scans it for the loss target) should compute
    /// [`Self::weighted_loss_curve`] once and use [`interp_desc`].
    pub fn weighted_loss_at(&self, neighbors: &[(usize, f32)], fraction: f64) -> f64 {
        interp_desc(&self.weighted_loss_curve(neighbors), fraction)
    }

    /// Basic structural invariants (used by the property-test suite).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.fractions.is_empty() {
            return Err("no fractions".into());
        }
        if (self.fractions[0] - 1.0).abs() > 1e-6 {
            return Err(format!("fractions[0] = {} ≠ 1.0", self.fractions[0]));
        }
        for w in self.fractions.windows(2) {
            if w[1] >= w[0] {
                return Err("fractions not strictly descending".into());
            }
        }
        for (i, r) in self.records.iter().enumerate() {
            if r.times_ns.len() != self.fractions.len() {
                return Err(format!("record {i}: wrong times length"));
            }
            if r.times_ns.iter().any(|t| !t.is_finite() || *t <= 0.0) {
                return Err(format!("record {i}: non-finite/zero time"));
            }
            let want = normalize(&r.raw);
            for d in 0..DIMS {
                if (want[d] - r.vec[d]).abs() > 1e-5 {
                    return Err(format!("record {i}: stale normalized vec dim {d}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_db() -> PerfDb {
        let raw = [1000.0, 100.0, 10.0, 10.0, 1.0, 4000.0, 2.0, 16.0];
        PerfDb {
            fractions: vec![1.0, 0.9, 0.8, 0.7],
            records: vec![Record {
                raw,
                vec: normalize(&raw),
                times_ns: vec![100.0, 103.0, 110.0, 130.0],
            }],
        }
    }

    #[test]
    fn normalization_is_monotone_and_bounded() {
        let lo = normalize(&[0.0; 8]);
        let hi = normalize(&[1.2e6, 1.2e6, 2e4, 2e4, 20.0, 6e4, 8.0, 24.0]);
        for d in 0..DIMS {
            assert!(lo[d] >= 0.0 && lo[d] <= hi[d]);
            assert!(hi[d] < 1.3, "dim {d} = {}", hi[d]);
        }
    }

    #[test]
    fn time_interpolation() {
        let db = tiny_db();
        // (1e-3 tolerance: fractions are stored as f32)
        assert!((db.time_at(0, 1.0) - 100.0).abs() < 1e-3);
        assert!((db.time_at(0, 0.85) - 106.5).abs() < 1e-3);
        assert!((db.time_at(0, 0.5) - 130.0).abs() < 1e-3); // clamped
    }

    #[test]
    fn loss_curve_baselines_on_full_size() {
        let db = tiny_db();
        let curve = db.loss_curve(0);
        assert_eq!(curve[0], (1.0, 0.0));
        assert!((curve[2].1 - 0.10).abs() < 1e-6);
    }

    #[test]
    fn min_fraction_within_target() {
        let db = tiny_db();
        // 5% target: losses are 0 / 3% / 10% / 30% → pick 0.9
        let f = db.min_fraction_within(0, 0.05).unwrap();
        assert!((f - 0.9).abs() < 1e-6);
        // generous target: smallest fraction wins
        let f = db.min_fraction_within(0, 0.5).unwrap();
        assert!((f - 0.7).abs() < 1e-6);
    }

    #[test]
    fn perf_source_default_weighted_curve_is_bit_identical() {
        // A source that only supplies `loss_curve_of` (exercising the
        // trait's default `weighted_loss_curve_of`) must reproduce
        // `PerfDb::weighted_loss_curve` bit-for-bit — the contract that
        // lets lazy sources back the tuner without changing decisions.
        struct DefaultOnly<'a>(&'a PerfDb);
        impl PerfSource for DefaultOnly<'_> {
            fn n_records(&self) -> usize {
                self.0.records.len()
            }
            fn fraction_grid(&self) -> &[f32] {
                &self.0.fractions
            }
            fn loss_curve_of(&self, record: usize) -> crate::Result<Vec<(f64, f64)>> {
                Ok(self.0.loss_curve(record))
            }
            fn source_name(&self) -> &'static str {
                "test"
            }
        }
        let mut db = tiny_db();
        let raw2 = [9000.0, 700.0, 30.0, 20.0, 2.0, 9000.0, 2.0, 16.0];
        db.records.push(Record {
            raw: raw2,
            vec: normalize(&raw2),
            times_ns: vec![100.0, 108.0, 121.0, 160.0],
        });
        let neighbors = [(1usize, 0.3f32), (0usize, 0.01f32)];
        let inherent = db.weighted_loss_curve(&neighbors);
        let via_default = DefaultOnly(&db).weighted_loss_curve_of(&neighbors).unwrap();
        let direct = db.weighted_loss_curve_of(&neighbors).unwrap();
        assert_eq!(inherent.len(), via_default.len());
        for ((xa, ya), ((xb, yb), (xc, yc))) in
            inherent.iter().zip(via_default.iter().zip(&direct))
        {
            assert_eq!(xa.to_bits(), xb.to_bits());
            assert_eq!(ya.to_bits(), yb.to_bits());
            assert_eq!(xa.to_bits(), xc.to_bits());
            assert_eq!(ya.to_bits(), yc.to_bits());
        }
        assert_eq!(DefaultOnly(&db).n_records(), 2);
        assert_eq!(PerfSource::source_name(&db), "flat");
    }

    #[test]
    fn invariants_hold_and_detect_corruption() {
        let mut db = tiny_db();
        db.check_invariants().unwrap();
        db.records[0].times_ns[1] = f32::NAN;
        assert!(db.check_invariants().is_err());
        let mut db2 = tiny_db();
        db2.fractions = vec![0.9, 1.0, 0.8, 0.7];
        assert!(db2.check_invariants().is_err());
    }
}
