//! Offline database construction: sample micro-benchmark configurations,
//! run each one through the simulator at every fast-memory fraction on the
//! grid, and collect the execution records.
//!
//! The paper builds 100 K records × 100 fast-memory sizes and indexes them
//! in under 20 minutes; at our 1024× address-space scale-down the default
//! grid (2 K configs × 39 fractions) builds in well under a minute on a
//! laptop-class CPU, parallelized over std threads (no rayon offline).
//!
//! Parallelism is at *cell* granularity — one (configuration, fraction)
//! measurement per work unit — so short records never straggle behind a
//! few long ones, and each configuration is sampled from its own
//! deterministic RNG stream ([`config_rng`]). Both choices make the built
//! database byte-identical regardless of thread count or scheduling
//! (asserted by `parallel_build_matches_serial_bytes` in the integration
//! suite).

use super::{normalize, PerfDb, Record};
use crate::microbench::{Microbench, MicrobenchConfig};
use crate::sim::{Engine, IntervalModel, MachineModel};
use crate::tpp::{Tpp, Watermarks};
use crate::util::parallel::parallel_map;
use crate::util::rng::{splitmix64, Rng};
use crate::workloads::Workload;

/// Parameters for an offline build.
#[derive(Clone, Debug)]
pub struct BuildParams {
    pub n_configs: usize,
    /// Fast-memory fractions, strictly descending, starting at 1.0.
    pub fractions: Vec<f32>,
    /// Measured intervals per run (after warmup).
    pub intervals: u32,
    /// Warmup intervals discarded (includes the allocation epoch).
    pub warmup: u32,
    pub seed: u64,
    pub machine: MachineModel,
    pub threads: usize,
}

impl Default for BuildParams {
    fn default() -> Self {
        BuildParams {
            n_configs: 2000,
            fractions: default_fractions(),
            intervals: 8,
            warmup: 4,
            seed: 0xDB,
            machine: MachineModel::default(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

/// The default fraction grid: 1.00 down to 0.24 in steps of 0.02
/// (39 sizes; queries interpolate between grid points).
pub fn default_fractions() -> Vec<f32> {
    let mut v = Vec::new();
    let mut f = 100i32;
    while f >= 24 {
        v.push(f as f32 / 100.0);
        f -= 2;
    }
    v
}

/// Draw one configuration from the sampling distribution: log-uniform over
/// each dimension's realistic range (matching what telemetry produces for
/// the Table 1 workloads at our scale).
pub fn sample_config(rng: &mut Rng) -> MicrobenchConfig {
    let log_uniform = |rng: &mut Rng, lo: f64, hi: f64| -> f64 {
        (rng.range_f64(lo.ln(), hi.ln())).exp()
    };
    // pacc is in sampled (hint-fault) units: bounded by hot_thr × pages
    // touched per interval, so tens of thousands at our scale.
    let pacc_total = log_uniform(rng, 500.0, 40_000.0);
    let slow_share = rng.range_f64(0.0, 0.45);
    let pacc_s = pacc_total * slow_share;
    let pacc_f = pacc_total - pacc_s;
    let hot_thr = *[2.0, 2.0, 2.0, 4.0, 8.0].get(rng.index(5)).unwrap();
    // migration rates: up to a few hundred pages/interval, skewed low
    let pm_pr = log_uniform(rng, 1.0, 400.0) - 1.0;
    let pm_de = (pm_pr * rng.range_f64(0.5, 1.5)).min(400.0);
    let ai = log_uniform(rng, 0.02, 20.0);
    let rss_pages = log_uniform(rng, 3_000.0, 40_000.0);
    let num_threads = *[8.0, 16.0, 16.0, 24.0].get(rng.index(4)).unwrap();
    MicrobenchConfig { pacc_f, pacc_s, pm_de, pm_pr, ai, rss_pages, hot_thr, num_threads }
}

/// Execution time (ns) of one micro-benchmark configuration at one
/// fast-memory fraction: run under TPP, discard warmup, sum the rest.
pub fn measure(
    cfg: &MicrobenchConfig,
    fraction: f64,
    machine: &MachineModel,
    intervals: u32,
    warmup: u32,
) -> f64 {
    let mut mb = Microbench::new(*cfg, warmup + intervals);
    let cap = Engine::fm_capacity(mb.rss_pages(), fraction);
    let mut tpp =
        Tpp::with_hot_thr(Watermarks::default_for_capacity(cap), cfg.hot_thr.max(1.0) as u32);
    tpp.scan_budget = machine.promote_scan_pages_per_interval;
    let engine = Engine::new(IntervalModel::new(machine.clone()));
    let res = engine.run(&mut mb, &mut tpp, cap, |_| None);
    res.trace
        .iter()
        .skip(warmup as usize)
        .map(|t| t.wall_ns)
        .sum()
}

/// Build the record for one configuration: sweep every fraction.
pub fn build_record(cfg: &MicrobenchConfig, params: &BuildParams) -> Record {
    let times_ns: Vec<f32> = params
        .fractions
        .iter()
        .map(|&f| {
            measure(cfg, f as f64, &params.machine, params.intervals, params.warmup) as f32
        })
        .collect();
    let raw = cfg.as_array();
    Record { raw, vec: normalize(&raw), times_ns }
}

/// Deterministic per-configuration RNG stream: a function of the build
/// seed and the configuration index only, so sampling is independent of
/// both sampling order and thread scheduling.
pub fn config_rng(seed: u64, index: usize) -> Rng {
    let mut s = seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    Rng::new(splitmix64(&mut s))
}

/// Build the full database. Deterministic per seed; parallel over the
/// `n_configs × fractions` measurement cells, with byte-identical output
/// for any `threads` value (including 1).
pub fn build_database(params: &BuildParams) -> PerfDb {
    assert!(!params.fractions.is_empty() && (params.fractions[0] - 1.0).abs() < 1e-6);
    let n = params.n_configs;
    let m = params.fractions.len();
    let configs: Vec<MicrobenchConfig> =
        (0..n).map(|i| sample_config(&mut config_rng(params.seed, i))).collect();

    // Measure every (config, fraction) cell on the shared worker pool;
    // results come back in cell order, so scheduling cannot reorder the
    // output (see `crate::util::parallel`).
    let times: Vec<f32> = parallel_map(n * m, params.threads, |cell| {
        let (ci, fi) = (cell / m, cell % m);
        measure(
            &configs[ci],
            params.fractions[fi] as f64,
            &params.machine,
            params.intervals,
            params.warmup,
        ) as f32
    });

    let records = configs
        .iter()
        .enumerate()
        .map(|(i, cfg)| {
            let raw = cfg.as_array();
            Record { raw, vec: normalize(&raw), times_ns: times[i * m..(i + 1) * m].to_vec() }
        })
        .collect();
    PerfDb { fractions: params.fractions.clone(), records }
}

/// Build a *sharded* database directly into artifact-store segment files
/// at `dir`: configurations are measured in bounded batches and each
/// completed record streams straight into its segment writer
/// ([`crate::artifact::shard::ShardedWriter`]), so peak memory is one
/// batch of records instead of the whole database — which is also why
/// this returns the validated manifest, not a loaded
/// [`crate::artifact::shard::ShardedPerfDb`] (loading would materialize
/// everything the streaming just avoided; query-time callers load
/// explicitly). Sampling uses the same per-configuration RNG streams as
/// [`build_database`], so the sharded build's flat image is
/// byte-identical to a flat build with the same parameters (asserted in
/// the test suite), for any thread count.
pub fn build_database_sharded(
    params: &BuildParams,
    n_shards: usize,
    dir: &std::path::Path,
) -> crate::Result<crate::artifact::shard::ManifestInfo> {
    use crate::artifact::shard::ShardedWriter;

    assert!(!params.fractions.is_empty() && (params.fractions[0] - 1.0).abs() < 1e-6);
    let n = params.n_configs;
    let m = params.fractions.len();
    let mut writer = ShardedWriter::create(dir, &params.fractions, n_shards)?;
    // Batch size: enough cells to keep every worker busy, small enough
    // that resident records stay bounded.
    let batch = (params.threads.max(1) * 8).max(32);
    let mut start = 0usize;
    while start < n {
        let end = (start + batch).min(n);
        let configs: Vec<MicrobenchConfig> = (start..end)
            .map(|i| sample_config(&mut config_rng(params.seed, i)))
            .collect();
        let times: Vec<f32> = parallel_map((end - start) * m, params.threads, |cell| {
            let (ci, fi) = (cell / m, cell % m);
            measure(
                &configs[ci],
                params.fractions[fi] as f64,
                &params.machine,
                params.intervals,
                params.warmup,
            ) as f32
        });
        for (ci, cfg) in configs.iter().enumerate() {
            let raw = cfg.as_array();
            writer.push(&Record {
                raw,
                vec: normalize(&raw),
                times_ns: times[ci * m..(ci + 1) * m].to_vec(),
            })?;
        }
        start = end;
    }
    writer.finish()?;
    crate::artifact::shard::read_manifest(dir)
}

/// Load the database at `path`, or build it with `params` and cache it
/// there. Benches and examples use this so they are self-contained while
/// sharing one artifact.
pub fn ensure_db(path: &std::path::Path, params: &BuildParams) -> crate::Result<PerfDb> {
    if path.exists() {
        match super::store::load(path) {
            Ok(db) => {
                if db.check_invariants().is_ok() && db.len() >= params.n_configs {
                    return Ok(db);
                }
                eprintln!(
                    "perfdb at {} is stale ({} records < {}); rebuilding",
                    path.display(),
                    db.len(),
                    params.n_configs
                );
            }
            Err(e) => eprintln!("perfdb at {} unreadable ({e:#}); rebuilding", path.display()),
        }
    }
    let t0 = std::time::Instant::now();
    let db = build_database(params);
    eprintln!(
        "built perfdb: {} records x {} sizes in {:.1}s",
        db.len(),
        db.fractions.len(),
        t0.elapsed().as_secs_f64()
    );
    super::store::save(&db, path)?;
    Ok(db)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params(n: usize) -> BuildParams {
        BuildParams {
            n_configs: n,
            fractions: vec![1.0, 0.9, 0.8, 0.6, 0.4],
            intervals: 4,
            warmup: 2,
            seed: 1,
            machine: MachineModel::default(),
            threads: 4,
        }
    }

    #[test]
    fn sampled_configs_are_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let c = sample_config(&mut rng);
            assert!(c.pacc_f >= 0.0 && c.pacc_f < 400_000.0);
            assert!(c.pacc_s >= 0.0);
            assert!(c.ai > 0.0 && c.ai <= 20.0);
            assert!(c.rss_pages >= 3_000.0 && c.rss_pages <= 40_000.0);
            assert!([2.0, 4.0, 8.0].contains(&c.hot_thr));
        }
    }

    #[test]
    fn smaller_fraction_is_slower_in_records() {
        let mut rng = Rng::new(5);
        // pick a memory-hungry config so the effect is clear
        let mut c = sample_config(&mut rng);
        c.pacc_f = 60_000.0;
        c.pacc_s = 10_000.0;
        c.ai = 0.1;
        c.rss_pages = 12_000.0;
        let p = quick_params(1);
        let rec = build_record(&c, &p);
        assert!(
            rec.times_ns.last().unwrap() > &rec.times_ns[0],
            "times {:?}",
            rec.times_ns
        );
    }

    #[test]
    fn build_database_is_deterministic_and_valid() {
        let p = quick_params(6);
        let a = build_database(&p);
        let b = build_database(&p);
        assert_eq!(a.len(), 6);
        a.check_invariants().unwrap();
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.raw, rb.raw);
            assert_eq!(ra.times_ns, rb.times_ns);
        }
    }

    #[test]
    fn parallel_build_is_byte_identical_to_serial() {
        let mut p = quick_params(6);
        p.threads = 1;
        let serial = build_database(&p);
        p.threads = 8;
        let parallel = build_database(&p);
        assert_eq!(
            crate::perfdb::store::to_bytes(&serial),
            crate::perfdb::store::to_bytes(&parallel),
            "thread count must not change the built database"
        );
    }

    #[test]
    fn sharded_streaming_build_matches_flat_build_bytes() {
        let p = quick_params(40);
        let flat = build_database(&p);
        let dir = std::env::temp_dir()
            .join(format!("tuna_sharded_build_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let manifest = build_database_sharded(&p, 4, &dir).unwrap();
        assert_eq!(manifest.segments.len(), 4);
        assert_eq!(manifest.n_records as usize, flat.len());
        let sharded = crate::artifact::shard::ShardedPerfDb::load(&dir).unwrap();
        assert_eq!(
            crate::perfdb::store::to_bytes(&sharded.to_flat()),
            crate::perfdb::store::to_bytes(&flat),
            "streaming sharded build must reproduce the flat build bit-for-bit"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_rng_streams_are_independent_and_stable() {
        let a: Vec<u64> = (0..4).map(|i| config_rng(9, i).next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|i| config_rng(9, i).next_u64()).collect();
        assert_eq!(a, b, "streams are a pure function of (seed, index)");
        let set: std::collections::HashSet<u64> = a.iter().copied().collect();
        assert_eq!(set.len(), a.len(), "streams must differ across indices");
        assert_ne!(config_rng(9, 0).next_u64(), config_rng(10, 0).next_u64());
    }

    #[test]
    fn default_fraction_grid_shape() {
        let f = default_fractions();
        assert_eq!(f[0], 1.0);
        assert!(f.len() == 39, "len={}", f.len());
        assert!(*f.last().unwrap() >= 0.24 - 1e-6);
    }
}
