//! Native brute-force nearest-neighbour query — the correctness oracle and
//! CPU baseline the AOT XLA path is validated and benchmarked against.

use super::{PerfDb, DIMS};

/// Squared L2 distance between two normalized vectors.
#[inline]
pub fn dist2(a: &[f32; DIMS], b: &[f32; DIMS]) -> f32 {
    let mut acc = 0.0f32;
    for d in 0..DIMS {
        let diff = a[d] - b[d];
        acc += diff * diff;
    }
    acc
}

/// The query ordering every backend shares: ascending `(distance, global
/// index)` under [`f32::total_cmp`]. `total_cmp` is a *total* order, so a
/// NaN distance (e.g. a NaN telemetry feature reaching the query vector)
/// sorts deterministically instead of panicking the merge — NaN compares
/// equal to itself bit-for-bit and the index breaks the tie, which is
/// what keeps flat, sharded and lazy backends in exact agreement even on
/// poisoned queries. `dist2` never produces `-0.0` (it sums squares), so
/// `total_cmp`'s `-0.0 < 0.0` refinement cannot reorder finite results.
#[inline]
pub fn dist_then_index(a: &(usize, f32), b: &(usize, f32)) -> std::cmp::Ordering {
    a.1.total_cmp(&b.1).then(a.0.cmp(&b.0))
}

/// Query interface shared by the native and XLA paths.
pub trait NnQuery {
    /// Index of the nearest record and its squared distance.
    fn nearest(&mut self, q: &[f32; DIMS]) -> crate::Result<(usize, f32)>;
    /// `k` nearest records, ascending by distance. Backends without a
    /// top-k path fall back to 1-NN.
    fn top_k(&mut self, q: &[f32; DIMS], k: usize) -> crate::Result<Vec<(usize, f32)>> {
        let _ = k;
        Ok(vec![self.nearest(q)?])
    }
    /// Human-readable backend name for reports.
    fn backend(&self) -> &'static str;
}

/// Brute-force scan over the database's normalized vectors.
pub struct NativeNn {
    vecs: Vec<[f32; DIMS]>,
}

impl NativeNn {
    pub fn new(db: &PerfDb) -> Self {
        NativeNn { vecs: db.records.iter().map(|r| r.vec).collect() }
    }

    /// k nearest records, ascending by (distance, index) under the shared
    /// total order [`dist_then_index`] (used by tests and the ablation
    /// bench comparing 1-NN against k-NN averaging). NaN-safe: a NaN
    /// query degrades to the deterministic index order instead of
    /// panicking.
    pub fn top_k(&self, q: &[f32; DIMS], k: usize) -> Vec<(usize, f32)> {
        let mut all: Vec<(usize, f32)> = self
            .vecs
            .iter()
            .enumerate()
            .map(|(i, v)| (i, dist2(q, v)))
            .collect();
        all.sort_by(dist_then_index);
        all.truncate(k);
        all
    }
}

impl NnQuery for NativeNn {
    fn top_k(&mut self, q: &[f32; DIMS], k: usize) -> crate::Result<Vec<(usize, f32)>> {
        anyhow::ensure!(!self.vecs.is_empty(), "empty database");
        Ok(NativeNn::top_k(self, q, k))
    }

    fn nearest(&mut self, q: &[f32; DIMS]) -> crate::Result<(usize, f32)> {
        anyhow::ensure!(!self.vecs.is_empty(), "empty database");
        // Argmin under the shared total order (not `<`): the first record
        // seeds `best` with its *actual* distance, so even an all-NaN
        // distance set yields the deterministic (index 0) answer every
        // backend agrees on, rather than a sentinel that never updates.
        let mut best: Option<(usize, f32)> = None;
        for (i, v) in self.vecs.iter().enumerate() {
            let cand = (i, dist2(q, v));
            let better = match &best {
                None => true,
                Some(b) => dist_then_index(&cand, b) == std::cmp::Ordering::Less,
            };
            if better {
                best = Some(cand);
            }
        }
        Ok(best.expect("non-empty database"))
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::{normalize, Record};

    fn db_with(vecs: &[[f64; DIMS]]) -> PerfDb {
        PerfDb {
            fractions: vec![1.0, 0.5],
            records: vecs
                .iter()
                .map(|raw| Record { raw: *raw, vec: normalize(raw), times_ns: vec![1.0, 2.0] })
                .collect(),
        }
    }

    #[test]
    fn nearest_finds_exact_match() {
        let raws = [
            [100.0, 0.0, 0.0, 0.0, 1.0, 1000.0, 2.0, 8.0],
            [50_000.0, 9_000.0, 50.0, 60.0, 4.0, 9000.0, 2.0, 16.0],
            [500.0, 400.0, 5.0, 5.0, 0.2, 4000.0, 4.0, 24.0],
        ];
        let db = db_with(&raws);
        let mut nn = NativeNn::new(&db);
        for (i, raw) in raws.iter().enumerate() {
            let (idx, d) = nn.nearest(&normalize(raw)).unwrap();
            assert_eq!(idx, i);
            assert!(d < 1e-9);
        }
    }

    #[test]
    fn nearest_picks_closest_not_first() {
        let raws = [
            [100.0, 0.0, 0.0, 0.0, 1.0, 1000.0, 2.0, 8.0],
            [40_000.0, 8_000.0, 50.0, 60.0, 4.0, 9000.0, 2.0, 16.0],
        ];
        let db = db_with(&raws);
        let mut nn = NativeNn::new(&db);
        let q = [42_000.0, 8_500.0, 55.0, 58.0, 4.2, 9100.0, 2.0, 16.0];
        let (idx, _) = nn.nearest(&normalize(&q)).unwrap();
        assert_eq!(idx, 1);
    }

    #[test]
    fn top_k_is_sorted_and_consistent_with_nearest() {
        let raws: Vec<[f64; DIMS]> = (0..20)
            .map(|i| {
                let x = (i as f64 + 1.0) * 500.0;
                [x, x / 10.0, 5.0, 5.0, 1.0, 4000.0, 2.0, 16.0]
            })
            .collect();
        let db = db_with(&raws);
        let mut nn = NativeNn::new(&db);
        let q = normalize(&[5100.0, 510.0, 5.0, 5.0, 1.0, 4000.0, 2.0, 16.0]);
        let top = nn.top_k(&q, 5);
        assert_eq!(top.len(), 5);
        for w in top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(top[0].0, nn.nearest(&q).unwrap().0);
    }

    #[test]
    fn empty_db_is_an_error() {
        let db = PerfDb { fractions: vec![1.0], records: vec![] };
        let mut nn = NativeNn::new(&db);
        assert!(nn.nearest(&[0.0; DIMS]).is_err());
    }
}
