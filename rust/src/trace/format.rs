//! The durable `TUNATRC1` trace artifact.
//!
//! Flat little-endian layout (built on [`crate::artifact::wire`]),
//! framed so a reader can verify integrity incrementally and a truncated
//! or corrupted file fails parsing instead of replaying garbage:
//!
//! ```text
//! magic        8   b"TUNATRC1"
//! header_len   u32
//! header:          str workload | u64 seed | u32 n_keys | u32 value_bytes
//!                  | u32 ops_per_interval | u32 threads
//!                  | u32 n_intervals | u64 total_ops
//! header_crc   u32 (crc32 of the header payload)
//! frame × n_intervals:
//!   frame_len  u32
//!   payload:       u32 n_ops | (u8 kind, u32 key, u16 len) × n_ops
//!   frame_crc  u32 (crc32 of the payload)
//! ```
//!
//! Encoding is canonical — one trace has exactly one byte representation
//! — so determinism tests can compare whole files, and
//! record → replay → re-record round-trips byte-for-byte. Writes go
//! through [`crate::artifact::write_atomic`] like every other artifact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{KvOp, KvOpKind, KvTrace, TraceHeader};
use crate::artifact::wire::{put_str, put_u32, put_u64, put_u8, Reader};
use crate::perfdb::store::crc32;

pub const MAGIC: &[u8; 8] = b"TUNATRC1";

/// Longest workload name the header accepts (keeps `peek` bounded).
const MAX_NAME: usize = 256;
/// Bytes `peek` reads from the front of the file — enough for the magic,
/// the largest legal header and both length/CRC words.
const PEEK_BYTES: usize = 8 + 4 + 4 + MAX_NAME + 8 + 4 * 5 + 8 + 4;

fn encode_header(h: &TraceHeader, n_intervals: u32, total_ops: u64) -> Vec<u8> {
    let mut out = Vec::new();
    put_str(&mut out, &h.workload);
    put_u64(&mut out, h.seed);
    put_u32(&mut out, h.n_keys);
    put_u32(&mut out, h.value_bytes);
    put_u32(&mut out, h.ops_per_interval);
    put_u32(&mut out, h.threads);
    put_u32(&mut out, n_intervals);
    put_u64(&mut out, total_ops);
    out
}

fn decode_header(payload: &[u8]) -> Result<(TraceHeader, u32, u64)> {
    let mut r = Reader::new(payload);
    let header = TraceHeader {
        workload: r.str()?,
        seed: r.u64()?,
        n_keys: r.u32()?,
        value_bytes: r.u32()?,
        ops_per_interval: r.u32()?,
        threads: r.u32()?,
    };
    let n_intervals = r.u32()?;
    let total_ops = r.u64()?;
    r.done()?;
    Ok((header, n_intervals, total_ops))
}

/// Serialize a trace to its canonical byte representation.
pub fn encode(trace: &KvTrace) -> Result<Vec<u8>> {
    if trace.header.workload.len() > MAX_NAME {
        bail!(
            "trace workload name is {} bytes (max {MAX_NAME})",
            trace.header.workload.len()
        );
    }
    trace.validate()?;
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    let header =
        encode_header(&trace.header, trace.intervals.len() as u32, trace.total_ops());
    put_u32(&mut out, header.len() as u32);
    out.extend_from_slice(&header);
    put_u32(&mut out, crc32(&header));
    for ops in &trace.intervals {
        let mut payload = Vec::with_capacity(4 + ops.len() * 7);
        put_u32(&mut payload, ops.len() as u32);
        for op in ops {
            put_u8(&mut payload, op.kind.code());
            put_u32(&mut payload, op.key);
            payload.extend_from_slice(&op.len.to_le_bytes());
        }
        put_u32(&mut out, payload.len() as u32);
        out.extend_from_slice(&payload);
        put_u32(&mut out, crc32(&payload));
    }
    Ok(out)
}

/// Parse a trace from bytes, verifying the magic and every CRC.
pub fn decode(bytes: &[u8]) -> Result<KvTrace> {
    let mut r = Reader::new(bytes);
    let magic = r.take(8).context("reading trace magic")?;
    if magic != MAGIC {
        bail!("not a TUNATRC1 trace (bad magic {magic:02x?})");
    }
    let header_len = r.u32()? as usize;
    if header_len > 4 + MAX_NAME + 4 * 5 + 16 {
        bail!("implausible trace header length {header_len}");
    }
    let header_payload = r.take(header_len).context("reading trace header")?;
    let want = r.u32()?;
    let got = crc32(header_payload);
    if want != got {
        bail!("trace header CRC mismatch: stored {want:#010x}, computed {got:#010x}");
    }
    let (header, n_intervals, total_ops) = decode_header(header_payload)?;
    // frame count is CRC-protected, but don't let a hostile header
    // pre-allocate gigabytes — growth past this is incremental
    let mut intervals = Vec::with_capacity(n_intervals.min(1 << 16) as usize);
    for i in 0..n_intervals {
        let frame_len = r.u32()? as usize;
        let payload = r
            .take(frame_len)
            .with_context(|| format!("reading trace frame {}/{n_intervals}", i + 1))?;
        let want = r.u32()?;
        let got = crc32(payload);
        if want != got {
            bail!(
                "trace frame {}/{n_intervals} CRC mismatch: stored {want:#010x}, computed {got:#010x}",
                i + 1
            );
        }
        let mut fr = Reader::new(payload);
        let n_ops = fr.u32()? as usize;
        if frame_len != 4 + n_ops * 7 {
            bail!(
                "trace frame {}: {n_ops} ops need {} bytes, frame has {frame_len}",
                i + 1,
                4 + n_ops * 7
            );
        }
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            let kind = KvOpKind::from_code(fr.u8()?)?;
            let key = fr.u32()?;
            let len = u16::from_le_bytes(fr.take(2)?.try_into().unwrap());
            ops.push(KvOp { kind, key, len });
        }
        fr.done()?;
        intervals.push(ops);
    }
    r.done()?;
    let trace = KvTrace { header, intervals };
    if trace.total_ops() != total_ops {
        bail!(
            "trace op count mismatch: header says {total_ops}, frames hold {}",
            trace.total_ops()
        );
    }
    trace.validate()?;
    Ok(trace)
}

/// Write a trace artifact atomically.
pub fn save(path: &Path, trace: &KvTrace) -> Result<()> {
    crate::artifact::write_atomic(path, &encode(trace)?)
        .with_context(|| format!("writing trace {}", path.display()))
}

/// Load and fully verify a trace artifact.
pub fn load(path: &Path) -> Result<KvTrace> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    decode(&bytes).with_context(|| format!("parsing trace {}", path.display()))
}

/// Header-only peek: `(header, op_intervals, total_ops)` from the first
/// few hundred bytes of the file — `tuna store ls` must not read (or
/// CRC) megabytes of frames just to print one line.
pub fn peek(path: &Path) -> Result<(TraceHeader, u32, u64)> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening trace {}", path.display()))?;
    let mut buf = vec![0u8; PEEK_BYTES];
    let mut filled = 0;
    while filled < buf.len() {
        let n = f.read(&mut buf[filled..])?;
        if n == 0 {
            break;
        }
        filled += n;
    }
    buf.truncate(filled);
    let mut r = Reader::new(&buf);
    let magic = r.take(8).context("reading trace magic")?;
    if magic != MAGIC {
        bail!("not a TUNATRC1 trace (bad magic {magic:02x?})");
    }
    let header_len = r.u32()? as usize;
    let header_payload = r.take(header_len).context("reading trace header")?;
    let want = r.u32()?;
    let got = crc32(header_payload);
    if want != got {
        bail!("trace header CRC mismatch: stored {want:#010x}, computed {got:#010x}");
    }
    decode_header(header_payload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{generate, spec_by_name};

    fn sample_trace() -> KvTrace {
        let mut spec = spec_by_name("kv-scan").unwrap();
        spec.n_keys = 2_000;
        spec.ops_per_interval = 500;
        generate(&spec, 77, 4)
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tuna_trc_{tag}_{}.trc", std::process::id()))
    }

    #[test]
    fn encode_decode_roundtrip_is_exact_and_canonical() {
        let t = sample_trace();
        let bytes = encode(&t).unwrap();
        let back = decode(&bytes).unwrap();
        assert_eq!(back, t);
        // canonical: re-encoding the decoded trace is byte-identical
        assert_eq!(encode(&back).unwrap(), bytes);
    }

    #[test]
    fn save_load_and_peek() {
        let t = sample_trace();
        let path = tmp("saveload");
        save(&path, &t).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, t);
        let (h, n_iv, n_ops) = peek(&path).unwrap();
        assert_eq!(h, t.header);
        assert_eq!(n_iv, 4);
        assert_eq!(n_ops, t.total_ops());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let t = sample_trace();
        let bytes = encode(&t).unwrap();
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(decode(&bad).is_err());
        // flip one byte deep inside a frame → parsing must fail (frame
        // CRC, frame length or op decoding, depending on what it hit)
        let mut flipped = bytes.clone();
        let mid = bytes.len() / 2;
        flipped[mid] ^= 0x01;
        assert!(decode(&flipped).is_err());
        // truncation fails instead of panicking
        assert!(decode(&bytes[..bytes.len() - 3]).is_err());
        assert!(decode(&bytes[..20]).is_err());
        // trailing garbage is rejected
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(decode(&trailing).is_err());
    }

    #[test]
    fn header_op_count_must_match_frames() {
        let t = sample_trace();
        let mut bytes = encode(&t).unwrap();
        // the header's total_ops is the last 8 bytes of the header
        // payload; rewrite it (and the header CRC) to lie about counts
        let header_len =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let hdr_start = 12;
        let ops_at = hdr_start + header_len - 8;
        bytes[ops_at..ops_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let crc = crc32(&bytes[hdr_start..hdr_start + header_len]);
        bytes[hdr_start + header_len..hdr_start + header_len + 4]
            .copy_from_slice(&crc.to_le_bytes());
        let err = format!("{:#}", decode(&bytes).unwrap_err());
        assert!(err.contains("op count mismatch"), "{err}");
    }

    #[test]
    fn oversized_name_is_rejected_at_encode() {
        let mut t = sample_trace();
        t.header.workload = "x".repeat(300);
        assert!(encode(&t).is_err());
    }
}
