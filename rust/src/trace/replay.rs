//! Replay engine: turn a KV op stream into per-interval
//! [`AccessProfile`]s over a simulated keyspace → page layout.
//!
//! The replayer is deliberately free of randomness — every page touch is
//! a pure function of the op stream — so a live-generated run and a
//! replay of its recorded trace produce *identical* profiles, and
//! therefore identical engine traces, telemetry and tuner decisions.
//!
//! Layout (`meta | index | values`, page aligned):
//!
//! * one metadata page (superblock / memtable head) touched by every op
//!   — a guaranteed-hot page, like the Btree root;
//! * a hash-index region, 16 B per key (256 entries/page);
//! * a value heap, `value_bytes` per key — consecutive keys share value
//!   pages, so range scans stream contiguous pages while skewed point
//!   ops leave a cold tail the tuner can reclaim.
//!
//! Point ops are latency-exposed *random* touches; scans stream the
//! index and value spans through [`PageHisto::touch_span`]
//! (prefetch-covered, bandwidth-bound) — the random/streamed split the
//! interval model prices differently.

use std::path::Path;

use anyhow::{Context, Result};

use super::gen::{KvGen, KvGenSpec};
use super::{KvOp, KvOpKind, KvTrace};
use crate::workloads::graph::{Layout, PageHisto, Region};
use crate::workloads::{AccessProfile, Workload};

/// Bytes per hash-index entry (key + value pointer).
pub const INDEX_ENTRY_BYTES: u64 = 16;

/// The keyspace → page mapping shared by live and trace-driven replays.
#[derive(Clone, Copy, Debug)]
pub struct KeyspaceLayout {
    pub r_meta: Region,
    pub r_index: Region,
    pub r_values: Region,
    rss: usize,
}

impl KeyspaceLayout {
    pub fn new(n_keys: u32, value_bytes: u32) -> Self {
        let mut l = Layout::new();
        let r_meta = l.region(1, crate::PAGE_BYTES);
        let r_index = l.region(n_keys as u64, INDEX_ENTRY_BYTES);
        let r_values = l.region(n_keys as u64, value_bytes.max(1) as u64);
        KeyspaceLayout { r_meta, r_index, r_values, rss: l.total_pages() }
    }

    pub fn rss_pages(&self) -> usize {
        self.rss
    }
}

/// Where the ops come from: a live generator or a loaded trace.
enum OpSource {
    Gen(KvGen),
    Trace { intervals: std::vec::IntoIter<Vec<KvOp>> },
}

impl OpSource {
    fn next_ops(&mut self) -> Option<Vec<KvOp>> {
        match self {
            OpSource::Gen(g) => Some(g.next_interval_ops()),
            OpSource::Trace { intervals } => intervals.next(),
        }
    }
}

/// A KV workload the engine can drive: live-generated
/// ([`KvReplay::live`]) or replayed from a `TUNATRC1` artifact
/// ([`KvReplay::from_file`], reachable as workload name `trace:FILE`).
pub struct KvReplay {
    name: &'static str,
    layout: KeyspaceLayout,
    n_keys: u32,
    histo: PageHisto,
    source: OpSource,
    threads: u32,
    intervals_left: u32,
    first_interval: bool,
    /// Ops replayed so far (reported by benches / stats).
    pub ops_replayed: u64,
}

/// Map a trace's workload name onto the registry's `&'static str` (the
/// [`Workload`] trait reports static names); externally captured traces
/// fall back to `"kv-trace"`.
fn static_name(name: &str) -> &'static str {
    super::gen::FAMILY
        .iter()
        .find(|f| f.eq_ignore_ascii_case(name))
        .copied()
        .unwrap_or("kv-trace")
}

impl KvReplay {
    /// Live generator run: `intervals` total engine intervals (the first
    /// is the allocation epoch, so the generator supplies
    /// `intervals − 1` op intervals).
    pub fn live(spec: &KvGenSpec, seed: u64, intervals: u32) -> Self {
        let layout = KeyspaceLayout::new(spec.n_keys, spec.value_bytes);
        KvReplay {
            name: static_name(spec.name),
            n_keys: spec.n_keys,
            histo: PageHisto::new(layout.rss_pages()),
            source: OpSource::Gen(KvGen::new(spec.clone(), seed)),
            threads: spec.threads,
            intervals_left: intervals,
            first_interval: true,
            ops_replayed: 0,
            layout,
        }
    }

    /// Replay a loaded trace. `intervals` bounds the run length: the run
    /// ends at `min(intervals, trace frames + 1)` engine intervals, so a
    /// larger default simply replays the whole trace.
    pub fn from_trace(trace: KvTrace, intervals: u32) -> Result<Self> {
        trace.validate()?;
        let h = &trace.header;
        let layout = KeyspaceLayout::new(h.n_keys, h.value_bytes);
        Ok(KvReplay {
            name: static_name(&h.workload),
            n_keys: h.n_keys,
            histo: PageHisto::new(layout.rss_pages()),
            threads: h.threads,
            intervals_left: intervals.min(trace.intervals.len() as u32 + 1),
            first_interval: true,
            ops_replayed: 0,
            layout,
            source: OpSource::Trace { intervals: trace.intervals.into_iter() },
        })
    }

    /// Load a `TUNATRC1` artifact and replay it (the `trace:FILE`
    /// workload-name path).
    pub fn from_file(path: &Path, intervals: u32) -> Result<Self> {
        let trace = super::format::load(path)
            .with_context(|| format!("loading trace workload {}", path.display()))?;
        Self::from_trace(trace, intervals)
    }

    /// Apply one op to the histogram; returns the integer ops it models.
    fn apply(&mut self, op: KvOp) -> u64 {
        // superblock / memtable head: every op consults it
        self.histo.touch(self.layout.r_meta.page_of(0), 1);
        let key = op.key.min(self.n_keys - 1) as u64;
        match op.kind {
            KvOpKind::Read => {
                self.histo.touch(self.layout.r_index.page_of(key), 1);
                self.histo.touch(self.layout.r_values.page_of(key), 1);
                2 + 8 + 4
            }
            KvOpKind::Update => {
                self.histo.touch(self.layout.r_index.page_of(key), 1);
                self.histo.touch(self.layout.r_values.page_of(key), 2);
                2 + 8 + 8
            }
            KvOpKind::Insert => {
                // index entry rewrite + fresh value write
                self.histo.touch(self.layout.r_index.page_of(key), 2);
                self.histo.touch(self.layout.r_values.page_of(key), 2);
                2 + 10 + 8
            }
            KvOpKind::Scan => {
                // seek is random; the range itself streams through the
                // prefetcher in both the index and the value heap
                let end = (key + op.len.max(1) as u64).min(self.n_keys as u64);
                self.histo.touch(self.layout.r_index.page_of(key), 1);
                self.histo.touch_span(&self.layout.r_values, key, end);
                if end - key > 1 {
                    self.histo.touch_span(&self.layout.r_index, key + 1, end);
                }
                2 + 8 + 2 * (end - key)
            }
        }
    }
}

impl Workload for KvReplay {
    fn name(&self) -> &'static str {
        self.name
    }

    fn rss_pages(&self) -> usize {
        self.layout.rss_pages()
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            self.first_interval = false;
            // allocation epoch: fault in the whole address space
            for p in 0..self.rss_pages() as u32 {
                self.histo.touch(p, 1);
            }
            return Some(AccessProfile {
                accesses: self.histo.drain(),
                flops: 0,
                iops: self.rss_pages() as u64 * 16,
            });
        }

        let ops = self.source.next_ops()?;
        let mut iops: u64 = 0;
        for op in ops {
            self.ops_replayed += 1;
            iops += self.apply(op);
        }
        Some(AccessProfile { accesses: self.histo.drain(), flops: 0, iops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{generate, spec_by_name};

    fn small_spec() -> KvGenSpec {
        let mut s = spec_by_name("kv-zipfian").unwrap();
        s.n_keys = 4_000;
        s.ops_per_interval = 2_000;
        s
    }

    fn profiles(w: &mut dyn Workload) -> Vec<AccessProfile> {
        std::iter::from_fn(|| w.next_interval()).collect()
    }

    #[test]
    fn layout_covers_meta_index_values() {
        let l = KeyspaceLayout::new(30_000, 1024);
        assert_eq!(l.r_meta.pages(), 1);
        // 30 000 × 16 B = 118 index pages; 30 000 × 1 KiB = 7 500 value pages
        assert_eq!(l.r_index.pages(), 118);
        assert_eq!(l.r_values.pages(), 7_500);
        assert_eq!(l.rss_pages(), 1 + 118 + 7_500);
    }

    #[test]
    fn live_and_trace_replays_emit_identical_profiles() {
        let spec = small_spec();
        let mut live = KvReplay::live(&spec, 9, 12);
        let trace = generate(&spec, 9, 11);
        let mut replay = KvReplay::from_trace(trace, 12).unwrap();
        let a = profiles(&mut live);
        let b = profiles(&mut replay);
        assert_eq!(a.len(), 12);
        assert_eq!(b.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.accesses, y.accesses);
            assert_eq!((x.flops, x.iops), (y.flops, y.iops));
        }
        assert_eq!(live.ops_replayed, replay.ops_replayed);
        assert_eq!(live.name(), replay.name());
        assert_eq!(live.rss_pages(), replay.rss_pages());
    }

    #[test]
    fn intervals_bound_caps_and_trace_length_caps() {
        let spec = small_spec();
        let trace = generate(&spec, 3, 5);
        // bound below trace length: stops at the bound
        let mut short = KvReplay::from_trace(trace.clone(), 3).unwrap();
        assert_eq!(profiles(&mut short).len(), 3);
        // bound above: stops when the trace runs dry (5 frames + alloc)
        let mut long = KvReplay::from_trace(trace, 400).unwrap();
        assert_eq!(profiles(&mut long).len(), 6);
    }

    #[test]
    fn profiles_have_unique_pages_and_a_hot_meta_page() {
        let spec = small_spec();
        let mut w = KvReplay::live(&spec, 4, 8);
        let all = profiles(&mut w);
        for p in &all {
            assert_eq!(p.duplicate_page(), None, "merge path must dedupe pages");
        }
        // meta page (page 0) is touched every interval after allocation
        for p in &all[1..] {
            assert!(p.accesses.iter().any(|a| a.page == 0 && a.random > 0));
        }
    }

    #[test]
    fn scans_stream_and_point_ops_randomize() {
        let mut scan_spec = spec_by_name("kv-scan").unwrap();
        scan_spec.n_keys = 4_000;
        scan_spec.ops_per_interval = 1_000;
        let mut w = KvReplay::live(&scan_spec, 6, 6);
        let all = profiles(&mut w);
        let streamed: u64 = all[1..]
            .iter()
            .flat_map(|p| &p.accesses)
            .map(|a| a.streamed as u64)
            .sum();
        let random: u64 = all[1..]
            .iter()
            .flat_map(|p| &p.accesses)
            .map(|a| a.random as u64)
            .sum();
        assert!(streamed > random, "scan family must stream: {streamed} vs {random}");

        let mut point = KvReplay::live(&small_spec(), 6, 6);
        let all = profiles(&mut point);
        let streamed: u64 = all[1..]
            .iter()
            .flat_map(|p| &p.accesses)
            .map(|a| a.streamed as u64)
            .sum();
        assert_eq!(streamed, 0, "point families never stream");
    }

    #[test]
    fn zipfian_leaves_a_cold_reclaimable_tail() {
        let spec = small_spec();
        let mut w = KvReplay::live(&spec, 8, 20);
        let rss = w.rss_pages();
        let mut heat = vec![0u64; rss];
        let _ = w.next_interval(); // skip allocation epoch
        while let Some(p) = w.next_interval() {
            for a in p.accesses {
                heat[a.page as usize] += a.total() as u64;
            }
        }
        let mut sorted = heat.clone();
        sorted.sort_unstable();
        let cold_fifth: u64 = sorted[..rss / 5].iter().sum();
        let all: u64 = sorted.iter().sum();
        assert!(
            (cold_fifth as f64) < 0.05 * all as f64,
            "cold 20% holds {cold_fifth}/{all}"
        );
    }

    #[test]
    fn from_file_roundtrips_and_missing_file_errors() {
        let spec = small_spec();
        let trace = generate(&spec, 2, 3);
        let path = std::env::temp_dir()
            .join(format!("tuna_replay_{}.trc", std::process::id()));
        crate::trace::format::save(&path, &trace).unwrap();
        let mut w = KvReplay::from_file(&path, 10).unwrap();
        assert_eq!(profiles(&mut w).len(), 4);
        std::fs::remove_file(&path).ok();
        assert!(KvReplay::from_file(Path::new("/nonexistent.trc"), 10).is_err());
    }
}
