//! YCSB-style synthetic KV op-stream generators.
//!
//! Each family is a named [`KvGenSpec`]: an op mix (read / update /
//! insert / scan fractions) plus a key distribution. Sampling is
//! deterministic per seed — the same spec + seed always produces the
//! same op stream, which is what makes recorded traces bit-reproducible
//! (`tuna trace record` twice → identical `TUNATRC1` files).
//!
//! Skewed distributions sample at *value-page-group* granularity (a
//! zipf rank picks a group of keys sharing one value page, scattered
//! over the keyspace by a fixed multiplicative hash, then a uniform key
//! within the group) — the same trick the Btree workload uses for its
//! leaves, so page-level heat is organic rather than flattened by
//! key-level scatter.

use super::{KvOp, KvOpKind, KvTrace, TraceHeader};
use crate::util::rng::{Rng, Zipf};

/// Key-popularity distribution of a generator family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KeyDist {
    /// Every key equally likely.
    Uniform,
    /// Zipf over value-page groups with exponent `skew` (YCSB default
    /// regime; rank scattered by a fixed hash).
    Zipfian { skew: f64 },
    /// Zipf over *recency*: rank 0 is the most recently inserted key, so
    /// the hot set trails the churn head (YCSB-D).
    Latest { skew: f64 },
    /// A fraction `hot_frac` of the keyspace receives `hot_op_frac` of
    /// the operations; both ranges uniform inside (YCSB hotspot).
    Hotspot { hot_frac: f64, hot_op_frac: f64 },
    /// Zipfian whose scattered hot set shifts by `shift_frac` of the
    /// keyspace every `every` intervals — a migrating hot set, the
    /// access pattern page migration exists for.
    Drift { skew: f64, every: u32, shift_frac: f64 },
}

/// Operation mix as cumulative-able fractions (must sum to ~1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpMix {
    pub read: f64,
    pub update: f64,
    pub insert: f64,
    pub scan: f64,
}

/// Full generator family specification. Defaults are paper-scale-ish:
/// the layout ([`super::replay::KeyspaceLayout`]) lands around 7.6 K
/// pages of RSS (≈ 7.4 paper-GB), between Btree and BFS.
#[derive(Clone, Debug, PartialEq)]
pub struct KvGenSpec {
    /// Family name (what the workload registry and trace headers carry).
    pub name: &'static str,
    pub dist: KeyDist,
    pub mix: OpMix,
    pub n_keys: u32,
    pub ops_per_interval: u32,
    /// Max scan length in keys (scan lengths are uniform in `1..=max`).
    pub scan_max: u16,
    pub value_bytes: u32,
    pub threads: u32,
}

/// Default keyspace size (30 K keys × 1 KiB values ≈ 7.5 K value pages).
pub const DEFAULT_KEYS: u32 = 30_000;
/// Default operations per profiling interval.
pub const DEFAULT_OPS: u32 = 24_000;
/// Default value size in bytes (4 keys per value page).
pub const DEFAULT_VALUE_BYTES: u32 = 1024;
/// Worker threads the KV family models.
pub const KV_THREADS: u32 = 16;

impl KvGenSpec {
    fn family(name: &'static str, dist: KeyDist, mix: OpMix, scan_max: u16) -> Self {
        KvGenSpec {
            name,
            dist,
            mix,
            n_keys: DEFAULT_KEYS,
            ops_per_interval: DEFAULT_OPS,
            scan_max,
            value_bytes: DEFAULT_VALUE_BYTES,
            threads: KV_THREADS,
        }
    }

    /// The [`TraceHeader`] a recording of this spec carries.
    pub fn header(&self, seed: u64) -> TraceHeader {
        TraceHeader {
            workload: self.name.to_string(),
            seed,
            n_keys: self.n_keys,
            value_bytes: self.value_bytes,
            ops_per_interval: self.ops_per_interval,
            threads: self.threads,
        }
    }
}

const READ_MOSTLY: OpMix = OpMix { read: 0.95, update: 0.05, insert: 0.0, scan: 0.0 };

/// Every generator family name, in canonical order — the single source
/// the workload registry (and its error message) derives the KV entries
/// from.
pub const FAMILY: [&str; 6] =
    ["kv-uniform", "kv-zipfian", "kv-latest", "kv-hotspot", "kv-scan", "kv-drift"];

/// Look up a generator family by (case-insensitive) name.
pub fn spec_by_name(name: &str) -> Option<KvGenSpec> {
    let spec = match name.to_ascii_lowercase().as_str() {
        // YCSB-C-like: uniform point reads with light updates.
        "kv-uniform" => KvGenSpec::family("kv-uniform", KeyDist::Uniform, READ_MOSTLY, 0),
        // YCSB-B-like: zipf(0.99) point ops, the classic skewed cache.
        "kv-zipfian" => {
            KvGenSpec::family("kv-zipfian", KeyDist::Zipfian { skew: 0.99 }, READ_MOSTLY, 0)
        }
        // YCSB-D-like: reads chase the insert head (churn + recency).
        "kv-latest" => KvGenSpec::family(
            "kv-latest",
            KeyDist::Latest { skew: 0.9 },
            OpMix { read: 0.85, update: 0.0, insert: 0.15, scan: 0.0 },
            0,
        ),
        // 90% of ops on 10% of the keyspace.
        "kv-hotspot" => KvGenSpec::family(
            "kv-hotspot",
            KeyDist::Hotspot { hot_frac: 0.10, hot_op_frac: 0.90 },
            READ_MOSTLY,
            0,
        ),
        // YCSB-E-like: short range scans dominate, light insert churn.
        "kv-scan" => KvGenSpec::family(
            "kv-scan",
            KeyDist::Zipfian { skew: 0.8 },
            OpMix { read: 0.0, update: 0.0, insert: 0.05, scan: 0.95 },
            128,
        ),
        // Zipfian whose hot set migrates ~29% of the keyspace every 40
        // intervals (4 paper-seconds) — sustained promotion pressure.
        "kv-drift" => KvGenSpec::family(
            "kv-drift",
            KeyDist::Drift { skew: 0.99, every: 40, shift_frac: 0.29 },
            READ_MOSTLY,
            0,
        ),
        _ => return None,
    };
    Some(spec)
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a
}

/// Fixed multiplicative-permutation multiplier for scattering zipf ranks
/// over group ids (hot groups must not be physically adjacent — same
/// idiom as the Btree leaf scatter). The golden-ratio constant is
/// nudged until it is coprime to `n`, so `rank * mul % n` is a true
/// bijection for every group count (0x9E37…15 itself is divisible by 5,
/// which would collapse any keyspace whose group count is too).
fn scatter_multiplier(n: u64) -> u64 {
    let mut mul = 0x9E37_79B9_7F4A_7C15u64 % n.max(1);
    if n <= 1 {
        return 1;
    }
    mul = mul.max(2);
    while gcd(mul, n) != 1 {
        mul += 1;
    }
    mul
}

/// Stateful, deterministic op-stream generator for one spec + seed.
pub struct KvGen {
    spec: KvGenSpec,
    rng: Rng,
    /// Zipf over value-page groups (Zipfian / Drift / scan starts).
    group_zipf: Option<Zipf>,
    /// Zipf over recency ranks (Latest).
    recency_zipf: Option<Zipf>,
    /// Keys per value page (the group size).
    group_keys: u32,
    n_groups: u64,
    /// Multiplier of the rank → group bijection (see [`scatter_multiplier`]).
    scatter_mul: u64,
    /// Churn head: next insert overwrites this ring slot.
    head: u32,
    /// 1-based index of the interval being generated next.
    interval: u32,
}

impl KvGen {
    pub fn new(spec: KvGenSpec, seed: u64) -> Self {
        assert!(spec.n_keys > 0, "empty keyspace");
        // keys per value page: page / value size (≥ 1 key per page)
        let group_keys = (crate::PAGE_BYTES as u32 / spec.value_bytes.max(1)).max(1);
        let n_groups = (spec.n_keys as u64).div_ceil(group_keys as u64);
        let group_zipf = match spec.dist {
            KeyDist::Zipfian { skew } | KeyDist::Drift { skew, .. } => {
                Some(Zipf::new(n_groups as usize, skew))
            }
            _ => None,
        };
        let recency_zipf = match spec.dist {
            KeyDist::Latest { skew } => Some(Zipf::new(spec.n_keys as usize, skew)),
            _ => None,
        };
        KvGen {
            rng: Rng::new(seed ^ 0x6b76_7472_6163_6531), // "kvtrace1"
            spec,
            group_zipf,
            recency_zipf,
            group_keys,
            n_groups,
            scatter_mul: scatter_multiplier(n_groups),
            head: 0,
            interval: 0,
        }
    }

    pub fn spec(&self) -> &KvGenSpec {
        &self.spec
    }

    /// Drift offset (in keys) for the interval being generated.
    fn drift_offset(&self) -> u64 {
        match self.spec.dist {
            KeyDist::Drift { every, shift_frac, .. } => {
                let phase = (self.interval.saturating_sub(1) / every.max(1)) as u64;
                phase.wrapping_mul((shift_frac * self.spec.n_keys as f64) as u64)
                    % self.spec.n_keys as u64
            }
            _ => 0,
        }
    }

    /// Sample one key according to the family distribution.
    fn sample_key(&mut self) -> u32 {
        let n = self.spec.n_keys as u64;
        match self.spec.dist {
            KeyDist::Uniform => self.rng.below(n) as u32,
            KeyDist::Zipfian { .. } | KeyDist::Drift { .. } => {
                let zipf = self.group_zipf.as_ref().expect("zipf built in new()");
                let rank = zipf.sample(&mut self.rng) as u64;
                let group = rank.wrapping_mul(self.scatter_mul) % self.n_groups;
                let key = (group * self.group_keys as u64
                    + self.rng.below(self.group_keys as u64))
                    .min(n - 1);
                ((key + self.drift_offset()) % n) as u32
            }
            KeyDist::Latest { .. } => {
                let zipf = self.recency_zipf.as_ref().expect("zipf built in new()");
                let rank = zipf.sample(&mut self.rng) as u64 % n;
                // rank 0 = most recently inserted slot (head - 1)
                ((self.head as u64 + n - 1 - rank) % n) as u32
            }
            KeyDist::Hotspot { hot_frac, hot_op_frac } => {
                let hot_n = ((n as f64 * hot_frac) as u64).clamp(1, n);
                if self.rng.chance(hot_op_frac) {
                    self.rng.below(hot_n) as u32
                } else if hot_n == n {
                    self.rng.below(n) as u32
                } else {
                    (hot_n + self.rng.below(n - hot_n)) as u32
                }
            }
        }
    }

    /// Generate the next interval's operations.
    pub fn next_interval_ops(&mut self) -> Vec<KvOp> {
        self.interval += 1;
        let mix = self.spec.mix;
        let mut ops = Vec::with_capacity(self.spec.ops_per_interval as usize);
        for _ in 0..self.spec.ops_per_interval {
            let roll = self.rng.f64();
            let op = if roll < mix.scan {
                let start = self.sample_key();
                let len = 1 + self.rng.below(self.spec.scan_max.max(1) as u64) as u16;
                KvOp::scan(start, len)
            } else if roll < mix.scan + mix.insert {
                let key = self.head;
                self.head = (self.head + 1) % self.spec.n_keys;
                KvOp::point(KvOpKind::Insert, key)
            } else if roll < mix.scan + mix.insert + mix.update {
                KvOp::point(KvOpKind::Update, self.sample_key())
            } else {
                KvOp::point(KvOpKind::Read, self.sample_key())
            };
            ops.push(op);
        }
        ops
    }
}

/// Generate a complete trace: `op_intervals` profiling intervals of ops
/// under `spec` + `seed` (the allocation epoch is added by the replayer,
/// so a trace recorded for an `N`-interval run carries `N − 1` frames).
pub fn generate(spec: &KvGenSpec, seed: u64, op_intervals: u32) -> KvTrace {
    let mut g = KvGen::new(spec.clone(), seed);
    let intervals = (0..op_intervals).map(|_| g.next_interval_ops()).collect();
    KvTrace { header: spec.header(seed), intervals }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(name: &str) -> KvGenSpec {
        let mut s = spec_by_name(name).unwrap();
        s.n_keys = 4_000;
        s.ops_per_interval = 2_000;
        s
    }

    #[test]
    fn every_family_resolves_and_unknowns_do_not() {
        for name in FAMILY {
            let s = spec_by_name(name).unwrap();
            assert_eq!(s.name, name);
            let total = s.mix.read + s.mix.update + s.mix.insert + s.mix.scan;
            assert!((total - 1.0).abs() < 1e-9, "{name} mix sums to {total}");
            assert_eq!(s.mix.scan > 0.0, s.scan_max > 0, "{name} scan_max consistency");
        }
        assert!(spec_by_name("kv-nope").is_none());
        assert!(spec_by_name("KV-ZIPFIAN").is_some(), "case-insensitive");
    }

    #[test]
    fn scatter_multiplier_yields_a_bijection() {
        // includes group counts divisible by 5 (the raw golden-ratio
        // constant is too, which is exactly the collapse this guards)
        for n in [1u64, 2, 7, 1000, 7500, 4096] {
            let m = scatter_multiplier(n);
            let mut seen = vec![false; n as usize];
            for r in 0..n {
                seen[(r.wrapping_mul(m) % n) as usize] = true;
            }
            assert!(seen.iter().all(|&b| b), "n={n} mul={m} is not a bijection");
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let spec = small("kv-zipfian");
        let a = generate(&spec, 11, 5);
        let b = generate(&spec, 11, 5);
        assert_eq!(a, b);
        let c = generate(&spec, 12, 5);
        assert_ne!(a, c);
        assert_eq!(a.intervals.len(), 5);
        assert!(a.intervals.iter().all(|i| i.len() == 2_000));
        a.validate().unwrap();
    }

    #[test]
    fn mixes_come_out_at_the_configured_fractions() {
        for name in FAMILY {
            let t = generate(&small(name), 3, 10);
            let s = t.stats();
            let total = s.total_ops() as f64;
            let spec = small(name);
            for (got, want, what) in [
                (s.reads as f64, spec.mix.read, "read"),
                (s.updates as f64, spec.mix.update, "update"),
                (s.inserts as f64, spec.mix.insert, "insert"),
                (s.scans as f64, spec.mix.scan, "scan"),
            ] {
                assert!(
                    (got / total - want).abs() < 0.02,
                    "{name} {what}: {} vs {want}",
                    got / total
                );
            }
        }
    }

    #[test]
    fn zipfian_keys_are_page_skewed() {
        let spec = small("kv-zipfian");
        let t = generate(&spec, 5, 10);
        // heat at value-page-group granularity (4 keys per group)
        let n_groups = spec.n_keys.div_ceil(4) as usize;
        let mut heat = vec![0u64; n_groups];
        for op in t.intervals.iter().flatten() {
            heat[op.key as usize / 4] += 1;
        }
        let mut sorted = heat.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let head: u64 = sorted[..n_groups / 10].iter().sum();
        let all: u64 = sorted.iter().sum();
        assert!(head as f64 > 0.5 * all as f64, "top 10% groups hold {head}/{all}");
        // ... and the cold tail is nearly untouched
        let cold: u64 = sorted[n_groups / 2..].iter().sum();
        assert!((cold as f64) < 0.12 * all as f64, "cold half holds {cold}/{all}");
    }

    #[test]
    fn drift_migrates_the_hot_set() {
        let mut spec = small("kv-drift");
        spec.dist = KeyDist::Drift { skew: 0.99, every: 5, shift_frac: 0.29 };
        let t = generate(&spec, 9, 10);
        let hot_keys = |ivs: &[Vec<KvOp>]| {
            let mut heat = vec![0u64; spec.n_keys as usize];
            for op in ivs.iter().flatten() {
                heat[op.key as usize] += 1;
            }
            let mut idx: Vec<usize> = (0..heat.len()).collect();
            idx.sort_unstable_by_key(|&i| std::cmp::Reverse(heat[i]));
            idx.truncate(spec.n_keys as usize / 20);
            idx.into_iter().collect::<std::collections::HashSet<_>>()
        };
        let phase1 = hot_keys(&t.intervals[..5]);
        let phase2 = hot_keys(&t.intervals[5..]);
        let overlap = phase1.intersection(&phase2).count();
        assert!(
            (overlap as f64) < 0.5 * phase1.len() as f64,
            "hot set barely moved: {overlap}/{}",
            phase1.len()
        );
    }

    #[test]
    fn latest_reads_chase_the_insert_head() {
        let t = generate(&small("kv-latest"), 2, 6);
        // by the last interval the head has advanced well into the ring;
        // reads should cluster just behind it
        let head_after: u64 = (t.stats().inserts) % t.header.n_keys as u64;
        let last = t.intervals.last().unwrap();
        let near = last
            .iter()
            .filter(|op| op.kind == KvOpKind::Read)
            .filter(|op| {
                let dist = (head_after + t.header.n_keys as u64 - op.key as u64)
                    % t.header.n_keys as u64;
                dist < t.header.n_keys as u64 / 4
            })
            .count();
        let reads = last.iter().filter(|op| op.kind == KvOpKind::Read).count();
        assert!(near * 2 > reads, "only {near}/{reads} reads near the head");
    }

    #[test]
    fn hotspot_routes_ops_to_the_hot_range() {
        let spec = small("kv-hotspot");
        let t = generate(&spec, 13, 8);
        let hot_n = spec.n_keys / 10;
        let hot = t
            .intervals
            .iter()
            .flatten()
            .filter(|op| op.key < hot_n)
            .count() as f64;
        let frac = hot / t.total_ops() as f64;
        assert!((frac - 0.9).abs() < 0.02, "hot fraction {frac}");
    }

    #[test]
    fn scan_family_emits_bounded_scans() {
        let spec = small("kv-scan");
        let t = generate(&spec, 21, 4);
        t.validate().unwrap();
        let s = t.stats();
        assert!(s.scans > 0);
        let max = t
            .intervals
            .iter()
            .flatten()
            .filter(|o| o.kind == KvOpKind::Scan)
            .map(|o| o.len)
            .max()
            .unwrap();
        assert!(max >= spec.scan_max / 2 && max <= spec.scan_max, "max len {max}");
    }
}
