//! Trace-driven key-value workload subsystem.
//!
//! The paper evaluates Tuna on five Table-1 applications; related tiering
//! systems (Nomad, ARMS, MEMTIS) lean heavily on key-value-store
//! workloads, whose access patterns — skewed point ops, range scans,
//! insert churn, hot-set drift — the graph/MC workloads never produce.
//! This module supplies that missing workload family in three layers:
//!
//! * [`gen`] — YCSB-style synthetic op-stream generators (uniform,
//!   zipfian, latest, hotspot, scan-heavy, and a time-varying *drift*
//!   mix whose hot set migrates mid-run — the case page migration exists
//!   for). Deterministic per seed: the same spec + seed always yields the
//!   same op stream.
//! * [`format`] — the durable `TUNATRC1` trace artifact: length-prefixed,
//!   CRC'd interval frames behind a CRC'd header (built on
//!   [`crate::artifact::wire`]), written atomically like every other
//!   artifact. `tuna trace record|replay|stats` are the CLI verbs.
//! * [`replay`] — the replay engine: maps a KV op stream onto a simulated
//!   keyspace → page layout and emits per-interval
//!   [`crate::workloads::AccessProfile`]s (point ops are latency-exposed
//!   *random* accesses, scans are prefetch-covered *streamed* spans).
//!   [`replay::KvReplay`] implements [`crate::workloads::Workload`], so
//!   KV workloads — live-generated or replayed from a trace file — flow
//!   unchanged through the engine, the TPP policies, the tuner service,
//!   sweeps and perf-DB experiments.
//!
//! Because the trace is the *op stream* (not the page stream), replaying
//! a recorded trace reproduces the live run exactly: the replayer is
//! deterministic given the ops, so `tuna tune --workload trace:FILE`
//! reaches decisions bit-identical to the run that recorded FILE.

pub mod format;
pub mod gen;
pub mod replay;

use anyhow::{bail, Result};

/// One key-value operation kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvOpKind {
    /// Point read of one key.
    Read,
    /// In-place overwrite of one key's value.
    Update,
    /// Insert at the churn head (the keyspace is a fixed-size ring: an
    /// insert overwrites the oldest slot, so RSS stays constant while
    /// the *hot set* follows the head — YCSB's "latest" regime).
    Insert,
    /// Range scan of `len` consecutive keys starting at `key`.
    Scan,
}

impl KvOpKind {
    /// Stable on-disk code (never renumber, only extend).
    pub fn code(&self) -> u8 {
        match self {
            KvOpKind::Read => 0,
            KvOpKind::Update => 1,
            KvOpKind::Insert => 2,
            KvOpKind::Scan => 3,
        }
    }

    /// Inverse of [`Self::code`].
    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => KvOpKind::Read,
            1 => KvOpKind::Update,
            2 => KvOpKind::Insert,
            3 => KvOpKind::Scan,
            other => bail!("unknown KV op code {other} in trace"),
        })
    }
}

/// One operation of the stream. `len` is the scan length in keys and 0
/// for point ops; `key` indexes the fixed-size keyspace ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvOp {
    pub kind: KvOpKind,
    pub key: u32,
    pub len: u16,
}

impl KvOp {
    pub fn point(kind: KvOpKind, key: u32) -> Self {
        KvOp { kind, key, len: 0 }
    }

    pub fn scan(key: u32, len: u16) -> Self {
        KvOp { kind: KvOpKind::Scan, key, len }
    }
}

/// Everything the replayer needs to rebuild the keyspace → page layout,
/// persisted verbatim in the trace header so a loaded trace reproduces
/// the live run's address space exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceHeader {
    /// Generator family name (`kv-zipfian`, ...) — free-form for
    /// externally captured traces.
    pub workload: String,
    /// Seed the op stream was generated with (informational for captured
    /// traces; replay consumes the ops, never the seed).
    pub seed: u64,
    /// Keys in the keyspace ring.
    pub n_keys: u32,
    /// Value size in bytes (sets keys-per-value-page in the layout).
    pub value_bytes: u32,
    /// Nominal operations per profiling interval.
    pub ops_per_interval: u32,
    /// Worker threads the workload models.
    pub threads: u32,
}

/// Largest replay address space a trace header may imply (16 M pages =
/// 64 GiB of simulated RSS, ~16 paper-TB after scale-down — far beyond
/// any real experiment, small enough that the replayer's histograms
/// allocate instead of aborting on a crafted header).
pub const MAX_REPLAY_RSS_PAGES: u64 = 1 << 24;

/// Bound-check a keyspace before anything sizes itself from it — shared
/// by [`KvTrace::validate`] (hostile/foreign trace headers) and the CLI
/// (oversized `--keys`/generator overrides).
pub fn check_layout_bounds(n_keys: u32, value_bytes: u32) -> Result<()> {
    if n_keys == 0 {
        bail!("empty keyspace (n_keys = 0)");
    }
    if value_bytes == 0 {
        bail!("value_bytes = 0");
    }
    // u32 × u32 fits u64, so the products cannot overflow
    let value_pages =
        (n_keys as u64 * value_bytes as u64).div_ceil(crate::PAGE_BYTES);
    let index_pages = (n_keys as u64 * replay::INDEX_ENTRY_BYTES)
        .div_ceil(crate::PAGE_BYTES);
    let rss = 1 + value_pages + index_pages;
    if rss > MAX_REPLAY_RSS_PAGES {
        bail!(
            "keyspace implies {rss} pages of replay RSS (max {MAX_REPLAY_RSS_PAGES}): \
             n_keys {n_keys} x value_bytes {value_bytes} is not a simulable working set"
        );
    }
    Ok(())
}

/// A complete in-memory trace: header + one op vector per profiling
/// interval (the allocation epoch is a replayer artifact, not part of
/// the trace).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KvTrace {
    pub header: TraceHeader,
    pub intervals: Vec<Vec<KvOp>>,
}

/// Per-kind op counts plus scan-volume summary (for `tuna trace stats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    pub reads: u64,
    pub updates: u64,
    pub inserts: u64,
    pub scans: u64,
    /// Total keys covered by scans.
    pub scanned_keys: u64,
}

impl TraceStats {
    pub fn total_ops(&self) -> u64 {
        self.reads + self.updates + self.inserts + self.scans
    }

    pub fn mean_scan_len(&self) -> f64 {
        if self.scans == 0 {
            0.0
        } else {
            self.scanned_keys as f64 / self.scans as f64
        }
    }
}

impl KvTrace {
    pub fn total_ops(&self) -> u64 {
        self.intervals.iter().map(|i| i.len() as u64).sum()
    }

    /// Tally the op mix across the whole trace.
    pub fn stats(&self) -> TraceStats {
        let mut s = TraceStats::default();
        for op in self.intervals.iter().flatten() {
            match op.kind {
                KvOpKind::Read => s.reads += 1,
                KvOpKind::Update => s.updates += 1,
                KvOpKind::Insert => s.inserts += 1,
                KvOpKind::Scan => {
                    s.scans += 1;
                    s.scanned_keys += op.len as u64;
                }
            }
        }
        s
    }

    /// Validate internal consistency (key bounds, layout size); loaders
    /// call this so a corrupt or foreign trace fails before it reaches
    /// the replayer.
    pub fn validate(&self) -> Result<()> {
        // A hostile header must not size the replayer into an abort:
        // bound the implied address space before anything allocates.
        check_layout_bounds(self.header.n_keys, self.header.value_bytes)?;
        for (i, ops) in self.intervals.iter().enumerate() {
            for op in ops {
                if op.key >= self.header.n_keys {
                    bail!(
                        "interval {}: key {} out of keyspace (n_keys {})",
                        i + 1,
                        op.key,
                        self.header.n_keys
                    );
                }
                if (op.kind == KvOpKind::Scan) != (op.len > 0) {
                    bail!(
                        "interval {}: {:?} op with scan length {} (scans need len > 0, point ops len = 0)",
                        i + 1,
                        op.kind,
                        op.len
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> KvTrace {
        KvTrace {
            header: TraceHeader {
                workload: "kv-test".into(),
                seed: 7,
                n_keys: 100,
                value_bytes: 1024,
                ops_per_interval: 4,
                threads: 4,
            },
            intervals: vec![
                vec![
                    KvOp::point(KvOpKind::Read, 1),
                    KvOp::point(KvOpKind::Update, 2),
                    KvOp::scan(10, 5),
                ],
                vec![KvOp::point(KvOpKind::Insert, 3)],
            ],
        }
    }

    #[test]
    fn op_codes_roundtrip_and_reject_unknown() {
        for k in [KvOpKind::Read, KvOpKind::Update, KvOpKind::Insert, KvOpKind::Scan] {
            assert_eq!(KvOpKind::from_code(k.code()).unwrap(), k);
        }
        assert!(KvOpKind::from_code(9).is_err());
    }

    #[test]
    fn stats_tally_the_mix() {
        let t = tiny_trace();
        let s = t.stats();
        assert_eq!((s.reads, s.updates, s.inserts, s.scans), (1, 1, 1, 1));
        assert_eq!(s.scanned_keys, 5);
        assert_eq!(s.total_ops(), 4);
        assert_eq!(t.total_ops(), 4);
        assert!((s.mean_scan_len() - 5.0).abs() < 1e-12);
        assert_eq!(TraceStats::default().mean_scan_len(), 0.0);
    }

    #[test]
    fn validate_catches_bad_traces() {
        let ok = tiny_trace();
        ok.validate().unwrap();
        let mut out_of_range = ok.clone();
        out_of_range.intervals[0][0].key = 100;
        assert!(out_of_range.validate().is_err());
        let mut zero_len_scan = ok.clone();
        zero_len_scan.intervals[0][2].len = 0;
        assert!(zero_len_scan.validate().is_err());
        let mut point_with_len = ok.clone();
        point_with_len.intervals[0][0].len = 3;
        assert!(point_with_len.validate().is_err());
        let mut empty_keys = ok.clone();
        empty_keys.header.n_keys = 0;
        assert!(empty_keys.validate().is_err());
        // a crafted header must not size the replayer into an abort
        let mut huge = ok;
        huge.header.n_keys = u32::MAX;
        huge.header.value_bytes = u32::MAX;
        let err = format!("{:#}", huge.validate().unwrap_err());
        assert!(err.contains("replay RSS"), "{err}");
    }
}
