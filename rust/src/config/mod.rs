//! Configuration system.
//!
//! A TOML-subset parser (`[section]`, `key = value` with integers, floats,
//! booleans, strings and flat arrays; `#` comments) plus the typed
//! experiment configuration [`ExperimentConfig`] assembled from it. The
//! full TOML spec (and `serde`) is unavailable offline; this subset covers
//! every config file the project ships.

pub mod experiment;
pub mod parse;

pub use experiment::ExperimentConfig;
pub use parse::{parse_file, parse_str, ConfigDoc, Value};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn end_to_end_parse_and_typed_read() {
        let doc = parse_str(
            r#"
            # comment
            title = "demo"
            [machine]
            fast_lat_ns = 100
            slow_bw_gbps = 12.5
            numa = true
            sizes = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "title").unwrap(), "demo");
        assert_eq!(doc.get_i64("machine", "fast_lat_ns").unwrap(), 100);
        assert!((doc.get_f64("machine", "slow_bw_gbps").unwrap() - 12.5).abs() < 1e-12);
        assert!(doc.get_bool("machine", "numa").unwrap());
        assert_eq!(
            doc.get_array("machine", "sizes")
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap())
                .collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
    }
}
