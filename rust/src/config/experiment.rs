//! Typed experiment configuration, assembled from a parsed config document
//! (or defaults). This is the launcher-facing config system: every CLI
//! subcommand and bench reads one of these.

use anyhow::Result;

use super::parse::ConfigDoc;
use crate::admission::AdmissionConfig;
use crate::outcome::RetuneConfig;
use crate::sim::machine::MachineModel;
use crate::sim::mem::MigrationModel;

/// Tuna's online-tuner parameters (§4, §6.2).
#[derive(Clone, Debug)]
pub struct TunaConfig {
    /// Performance-loss target τ (paper default 5%).
    pub loss_target: f64,
    /// Tuning period in paper-equivalent seconds (default 2.5 s;
    /// §6.3 sweeps 0.5/1/2.5/5 s). One profiling interval = 0.1 s.
    pub period_s: f64,
    /// Smallest fast-memory fraction the tuner will ever choose.
    pub min_fm_fraction: f64,
    /// Largest per-period *shrink* step (fraction of RSS). The database
    /// record is queried from telemetry measured at the *current* size,
    /// so its prediction is only locally valid; shrinking incrementally
    /// and re-measuring each period is the paper's runtime feedback loop
    /// (growth is unrestricted — backing off must be fast).
    pub max_step_down: f64,
    /// Use the AOT XLA (PJRT) query path; falls back to the native
    /// brute-force oracle when artifacts are unavailable.
    pub use_xla: bool,
    /// Decision-outcome accountability and online re-tuning
    /// (`[retune]` table: `mode`, `ewma_alpha`, `trigger`,
    /// `early_intervals`, `cooldown_periods`). Default off — the
    /// tracker is inert and the legacy decision cadence is untouched.
    pub retune: RetuneConfig,
}

impl Default for TunaConfig {
    fn default() -> Self {
        TunaConfig {
            loss_target: 0.05,
            period_s: 2.5,
            min_fm_fraction: 0.25,
            max_step_down: 0.02,
            use_xla: false,
            retune: RetuneConfig::default(),
        }
    }
}

impl TunaConfig {
    /// Profiling intervals per tuning period (one interval ≡ 0.1 s).
    pub fn period_intervals(&self) -> u32 {
        (self.period_s / 0.1).round().max(1.0) as u32
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub machine: MachineModel,
    /// Workload name (Table 1) — "BFS", "SSSP", "PageRank", "XSBench",
    /// "Btree", or "microbench".
    pub workload: String,
    /// Run length in profiling intervals.
    pub intervals: u32,
    /// Initial fast-memory fraction of the workload RSS.
    pub fm_fraction: f64,
    /// TPP promotion threshold.
    pub hot_thr: u32,
    pub seed: u64,
    /// Page-migration semantics (`[migration]` table: `mode`,
    /// `abort_on_write`, `copy_intervals`). Default exclusive — defers
    /// to each policy's own model.
    pub migration: MigrationModel,
    /// Migration admission control (`[admission]` table: `mode`,
    /// `budget_pages`, `cooldown_intervals`, `horizon_intervals`).
    /// Default disabled — no gate, pre-admission behaviour bit-for-bit.
    pub admission: AdmissionConfig,
    pub tuna: TunaConfig,
    /// Path to the performance database (binary, built offline).
    pub perfdb_path: String,
    /// Path to the AOT query artifact (HLO text).
    pub hlo_path: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            machine: MachineModel::default(),
            workload: "BFS".to_string(),
            intervals: 400,
            fm_fraction: 1.0,
            hot_thr: 2,
            seed: 42,
            migration: MigrationModel::Exclusive,
            admission: AdmissionConfig::default(),
            tuna: TunaConfig::default(),
            perfdb_path: "artifacts/perfdb.bin".to_string(),
            hlo_path: "artifacts/perfdb_query.hlo.txt".to_string(),
        }
    }
}

impl ExperimentConfig {
    /// Read from a parsed document; every key optional (paper defaults).
    pub fn from_doc(doc: &ConfigDoc) -> Result<Self> {
        let d = ExperimentConfig::default();
        let mut machine = MachineModel::default();
        machine.cores = doc.i64_or("machine", "cores", machine.cores as i64) as u32;
        machine.freq_ghz = doc.f64_or("machine", "freq_ghz", machine.freq_ghz);
        machine.ipc = doc.f64_or("machine", "ipc", machine.ipc);
        machine.fast_lat_ns = doc.f64_or("machine", "fast_lat_ns", machine.fast_lat_ns);
        machine.slow_lat_ns = doc.f64_or("machine", "slow_lat_ns", machine.slow_lat_ns);
        machine.fast_bw = doc.f64_or("machine", "fast_bw_gbps", machine.fast_bw);
        machine.slow_read_bw = doc.f64_or("machine", "slow_read_bw_gbps", machine.slow_read_bw);
        machine.slow_write_bw =
            doc.f64_or("machine", "slow_write_bw_gbps", machine.slow_write_bw);
        machine.mlp_per_core = doc.f64_or("machine", "mlp_per_core", machine.mlp_per_core);
        machine.mlp_per_page = doc.f64_or("machine", "mlp_per_page", machine.mlp_per_page);
        machine.kswapd_pages_per_interval = doc.i64_or(
            "machine",
            "kswapd_pages_per_interval",
            machine.kswapd_pages_per_interval as i64,
        ) as u64;
        machine.validate()?;

        let retune = RetuneConfig::parse(
            doc.str_or("retune", "mode", d.tuna.retune.mode_name()),
            doc.f64_or("retune", "ewma_alpha", d.tuna.retune.ewma_alpha),
            doc.f64_or("retune", "trigger", d.tuna.retune.trigger),
            doc.i64_or("retune", "early_intervals", d.tuna.retune.early_intervals as i64) as u32,
            doc.i64_or("retune", "cooldown_periods", d.tuna.retune.cooldown_periods as i64)
                as u32,
        )
        .map_err(|e| anyhow::anyhow!("[retune] {e}"))?;

        let tuna = TunaConfig {
            loss_target: doc.f64_or("tuna", "loss_target", d.tuna.loss_target),
            period_s: doc.f64_or("tuna", "period_s", d.tuna.period_s),
            min_fm_fraction: doc.f64_or("tuna", "min_fm_fraction", d.tuna.min_fm_fraction),
            max_step_down: doc.f64_or("tuna", "max_step_down", d.tuna.max_step_down),
            use_xla: doc.bool_or("tuna", "use_xla", d.tuna.use_xla),
            retune,
        };
        anyhow::ensure!(
            tuna.loss_target > 0.0 && tuna.loss_target < 1.0,
            "loss_target must be in (0,1)"
        );
        anyhow::ensure!(tuna.period_s > 0.0, "period_s must be positive");

        let migration = MigrationModel::parse(
            doc.str_or("migration", "mode", "exclusive"),
            doc.bool_or("migration", "abort_on_write", true),
            doc.i64_or(
                "migration",
                "copy_intervals",
                MigrationModel::DEFAULT_COPY_INTERVALS as i64,
            ) as u32,
        )
        .map_err(|e| anyhow::anyhow!("[migration] {e}"))?;

        let admission = AdmissionConfig::parse(
            doc.str_or("admission", "mode", "off"),
            doc.i64_or(
                "admission",
                "budget_pages",
                AdmissionConfig::DEFAULT_BUDGET_PAGES as i64,
            ) as u64,
            doc.i64_or(
                "admission",
                "cooldown_intervals",
                AdmissionConfig::DEFAULT_COOLDOWN_INTERVALS as i64,
            ) as u32,
            doc.i64_or(
                "admission",
                "horizon_intervals",
                AdmissionConfig::DEFAULT_HORIZON_INTERVALS as i64,
            ) as u32,
        )
        .map_err(|e| anyhow::anyhow!("[admission] {e}"))?;

        Ok(ExperimentConfig {
            machine,
            workload: doc.str_or("workload", "name", &d.workload).to_string(),
            intervals: doc.i64_or("workload", "intervals", d.intervals as i64) as u32,
            fm_fraction: doc.f64_or("workload", "fm_fraction", d.fm_fraction),
            hot_thr: doc.i64_or("tpp", "hot_thr", d.hot_thr as i64) as u32,
            seed: doc.i64_or("", "seed", d.seed as i64) as u64,
            migration,
            admission,
            tuna,
            perfdb_path: doc.str_or("paths", "perfdb", &d.perfdb_path).to_string(),
            hlo_path: doc.str_or("paths", "hlo", &d.hlo_path).to_string(),
        })
    }

    /// Parse from a config-file string.
    pub fn from_str(text: &str) -> Result<Self> {
        Self::from_doc(&super::parse::parse_str(text)?)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_doc(&super::parse::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_defaults() {
        let c = ExperimentConfig::default();
        assert_eq!(c.tuna.loss_target, 0.05);
        assert_eq!(c.tuna.period_s, 2.5);
        assert_eq!(c.tuna.period_intervals(), 25);
        assert_eq!(c.hot_thr, 2);
    }

    #[test]
    fn from_doc_overrides_selected_keys() {
        let c = ExperimentConfig::from_str(
            r#"
            seed = 7
            [workload]
            name = "SSSP"
            intervals = 100
            fm_fraction = 0.9
            [tuna]
            loss_target = 0.10
            period_s = 0.5
            [machine]
            cores = 8
            "#,
        )
        .unwrap();
        assert_eq!(c.workload, "SSSP");
        assert_eq!(c.intervals, 100);
        assert_eq!(c.seed, 7);
        assert!((c.tuna.loss_target - 0.10).abs() < 1e-12);
        assert_eq!(c.tuna.period_intervals(), 5);
        assert_eq!(c.machine.cores, 8);
        // untouched keys keep defaults
        assert_eq!(c.hot_thr, 2);
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ExperimentConfig::from_str("[tuna]\nloss_target = 2.0\n").is_err());
        assert!(ExperimentConfig::from_str("[tuna]\nperiod_s = -1.0\n").is_err());
        assert!(ExperimentConfig::from_str("[machine]\ncores = 0\n").is_err());
        assert!(ExperimentConfig::from_str("[migration]\nmode = \"bogus\"\n").is_err());
        assert!(ExperimentConfig::from_str("[admission]\nmode = \"bogus\"\n").is_err());
        assert!(ExperimentConfig::from_str("[retune]\nmode = \"sideways\"\n").is_err());
        assert!(ExperimentConfig::from_str("[retune]\newma_alpha = 2.0\n").is_err());
    }

    #[test]
    fn migration_table_parses_and_defaults_to_exclusive() {
        let c = ExperimentConfig::from_str("").unwrap();
        assert!(c.migration.is_exclusive());

        let c = ExperimentConfig::from_str(
            r#"
            [migration]
            mode = "non-exclusive"
            "#,
        )
        .unwrap();
        assert_eq!(c.migration, MigrationModel::non_exclusive_default());

        let c = ExperimentConfig::from_str(
            r#"
            [migration]
            mode = "nomad"
            abort_on_write = false
            copy_intervals = 3
            "#,
        )
        .unwrap();
        assert_eq!(
            c.migration,
            MigrationModel::NonExclusive { abort_on_write: false, copy_intervals: 3 }
        );
    }

    #[test]
    fn admission_table_parses_and_defaults_to_disabled() {
        let c = ExperimentConfig::from_str("").unwrap();
        assert_eq!(c.admission, AdmissionConfig::default());
        assert!(!c.admission.enabled);

        let c = ExperimentConfig::from_str(
            r#"
            [admission]
            mode = "on"
            "#,
        )
        .unwrap();
        assert_eq!(c.admission, AdmissionConfig::enabled_default());

        let c = ExperimentConfig::from_str(
            r#"
            [admission]
            mode = "gated"
            budget_pages = 64
            cooldown_intervals = 8
            horizon_intervals = 16
            "#,
        )
        .unwrap();
        assert_eq!(
            c.admission,
            AdmissionConfig {
                enabled: true,
                budget_pages: 64,
                cooldown_intervals: 8,
                horizon_intervals: 16,
            }
        );

        // numeric knobs survive even in off mode, ready for a CLI
        // `--admission on` layered on top of the config file
        let c = ExperimentConfig::from_str("[admission]\nbudget_pages = 9\n").unwrap();
        assert!(!c.admission.enabled);
        assert_eq!(c.admission.budget_pages, 9);
    }

    #[test]
    fn retune_table_parses_and_defaults_to_off() {
        use crate::outcome::RetuneMode;
        let c = ExperimentConfig::from_str("").unwrap();
        assert_eq!(c.tuna.retune, RetuneConfig::default());
        assert!(!c.tuna.retune.enabled());

        let c = ExperimentConfig::from_str(
            r#"
            [retune]
            mode = "observe"
            "#,
        )
        .unwrap();
        assert_eq!(c.tuna.retune.mode, RetuneMode::Observe);

        let c = ExperimentConfig::from_str(
            r#"
            [retune]
            mode = "on"
            ewma_alpha = 0.5
            trigger = 0.08
            early_intervals = 3
            cooldown_periods = 4
            "#,
        )
        .unwrap();
        assert_eq!(
            c.tuna.retune,
            RetuneConfig {
                mode: RetuneMode::On,
                ewma_alpha: 0.5,
                trigger: 0.08,
                early_intervals: 3,
                cooldown_periods: 4,
            }
        );

        // numeric knobs survive even in off mode, ready for a CLI
        // `--retune on` layered on top of the config file
        let c = ExperimentConfig::from_str("[retune]\ntrigger = 0.2\n").unwrap();
        assert!(!c.tuna.retune.enabled());
        assert_eq!(c.tuna.retune.trigger, 0.2);
    }
}
