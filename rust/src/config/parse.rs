//! TOML-subset parser.
//!
//! Supported grammar (one statement per line):
//!
//! ```toml
//! # comment
//! [section.name]
//! key = 42            # integer
//! key = 3.5           # float
//! key = true          # boolean
//! key = "string"      # string (no escapes beyond \" \\ \n \t)
//! key = [1, 2, 3]     # flat array of the scalar types above
//! ```
//!
//! Keys before any `[section]` land in the root section `""`.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar or flat-array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`x = 3` readable as 3.0).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: `section -> key -> value`.
#[derive(Clone, Debug, Default)]
pub struct ConfigDoc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl ConfigDoc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Result<i64> {
        self.get(section, key)
            .and_then(Value::as_i64)
            .ok_or_else(|| anyhow!("missing integer [{section}] {key}"))
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<f64> {
        self.get(section, key)
            .and_then(Value::as_f64)
            .ok_or_else(|| anyhow!("missing float [{section}] {key}"))
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<bool> {
        self.get(section, key)
            .and_then(Value::as_bool)
            .ok_or_else(|| anyhow!("missing bool [{section}] {key}"))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<&str> {
        self.get(section, key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("missing string [{section}] {key}"))
    }

    pub fn get_array(&self, section: &str, key: &str) -> Result<&[Value]> {
        self.get(section, key)
            .and_then(Value::as_array)
            .ok_or_else(|| anyhow!("missing array [{section}] {key}"))
    }

    /// Typed getters with defaults — the common pattern for experiment
    /// configs where most knobs stay at their paper values.
    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = (&String, &BTreeMap<String, Value>)> {
        self.sections.iter()
    }
}

/// Parse a config document from a string.
pub fn parse_str(input: &str) -> Result<ConfigDoc> {
    let mut doc = ConfigDoc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let value = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: bad value for `{key}`", lineno + 1))?;
        doc.sections
            .get_mut(&section)
            .unwrap()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

/// Parse a config file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<ConfigDoc> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading config {}", path.display()))?;
    parse_str(&text).with_context(|| format!("parsing config {}", path.display()))
}

fn strip_comment(line: &str) -> &str {
    // Honour `#` only outside string literals.
    let mut in_str = false;
    let mut prev_escape = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !prev_escape => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        prev_escape = c == '\\' && !prev_escape;
    }
    line
}

fn parse_value(tok: &str) -> Result<Value> {
    if tok.is_empty() {
        bail!("empty value");
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = tok.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_array_items(body) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let v = parse_value(part)?;
            if matches!(v, Value::Array(_)) {
                bail!("nested arrays unsupported");
            }
            items.push(v);
        }
        return Ok(Value::Array(items));
    }
    if let Some(body) = tok.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(unescape(body)?));
    }
    // number: int first, then float
    if let Ok(v) = tok.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = tok.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    bail!("unrecognized value `{tok}`")
}

fn split_array_items(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut prev_escape = false;
    for c in body.chars() {
        match c {
            '"' if !prev_escape => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
        prev_escape = c == '\\' && !prev_escape;
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            other => bail!("bad escape \\{other:?}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse_value("42").unwrap(), Value::Int(42));
        assert_eq!(parse_value("-7").unwrap(), Value::Int(-7));
        assert_eq!(parse_value("1_000").unwrap(), Value::Int(1000));
        assert_eq!(parse_value("2.5").unwrap(), Value::Float(2.5));
        assert_eq!(parse_value("true").unwrap(), Value::Bool(true));
        assert_eq!(parse_value("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn string_escapes() {
        assert_eq!(
            parse_value(r#""a\"b\n""#).unwrap(),
            Value::Str("a\"b\n".into())
        );
        assert!(parse_value(r#""bad\q""#).is_err());
    }

    #[test]
    fn arrays() {
        let v = parse_value("[1, 2.5, \"x,y\", true]").unwrap();
        let arr = v.as_array().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0], Value::Int(1));
        assert_eq!(arr[1], Value::Float(2.5));
        assert_eq!(arr[2], Value::Str("x,y".into()));
        assert_eq!(arr[3], Value::Bool(true));
        assert!(parse_value("[[1]]").is_err());
    }

    #[test]
    fn comments_and_sections() {
        let doc = parse_str("a = 1 # trailing\n[s] # section comment\nb = \"has # inside\"\n")
            .unwrap();
        assert_eq!(doc.get_i64("", "a").unwrap(), 1);
        assert_eq!(doc.get_str("s", "b").unwrap(), "has # inside");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let err = parse_str("x ==").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(parse_str("[open").is_err());
        assert!(parse_str("k = ").is_err());
        assert!(parse_str("justtext").is_err());
    }

    #[test]
    fn defaults() {
        let doc = parse_str("[m]\nx = 5\n").unwrap();
        assert_eq!(doc.i64_or("m", "x", 9), 5);
        assert_eq!(doc.i64_or("m", "y", 9), 9);
        assert_eq!(doc.f64_or("m", "x", 0.0), 5.0);
        assert_eq!(doc.str_or("m", "z", "d"), "d");
        assert!(!doc.bool_or("m", "w", false));
    }
}
