//! `tuna` — the L3 coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!
//! ```text
//! tuna info                             Table 1 + machine model
//! tuna build-db [--configs N] [--out artifacts/perfdb.bin] [--seed S]
//! tuna run  --workload BFS [--fraction 0.9] [--policy tpp|first-touch]
//!           [--intervals N] [--seed S] [--config FILE]
//!           [--migration exclusive|non-exclusive [--abort-on-write BOOL]
//!            [--copy-intervals N]]
//!           [--admission on|off [--mig-budget PAGES] [--cooldown N]
//!            [--horizon N]]
//!                               --migration non-exclusive runs the
//!                               Nomad-style transactional model (shadow
//!                               copies, abort-on-write) and reports the
//!                               shadow/txn counters; --admission on gates
//!                               promotions behind the migration admission
//!                               control (per-interval page budget,
//!                               benefit-vs-copy-cost payoff test,
//!                               post-demotion cool-down) and reports the
//!                               admission verdict counters
//! tuna tune --workload BFS [--target 0.05] [--period 2.5] [--xla]
//!           [--db artifacts/perfdb.bin | --store DIR [--name perfdb]
//!            [--resident-segments N]] [--artifacts artifacts]
//!           [--intervals N] [--config FILE] [--record FILE]
//!                               --record writes the run's telemetry
//!                               stream (tuna-telemetry v1) for replay
//!                               through `tuna serve`; --store serves the
//!                               store's sharded perf DB lazily from a
//!                               bounded resident set (--resident-segments
//!                               caps it; decisions are bit-identical to
//!                               the fully-resident backend)
//! tuna serve [--db artifacts/perfdb.bin | --store DIR [--name perfdb]
//!            [--resident-segments N]]
//!           [--artifacts artifacts] [--target 0.05] [--period 2.5]
//!           [--workers N] [--listen ADDR [--max-conns N] | --connect ADDR]
//!           [FILE...]
//!                               tuner-as-a-service ingestion: tail
//!                               telemetry sample streams from FILEs (or
//!                               stdin) and print watermark decisions as
//!                               sessions hit their tuning periods;
//!                               --workers N shards sessions across N
//!                               aggregation workers (decisions stay
//!                               bit-identical for any N); --listen ADDR
//!                               accepts tuna-telemetry v1 connections
//!                               over TCP and writes decisions back on
//!                               each client's socket (--max-conns N
//!                               drains after N connections); --connect
//!                               ADDR streams FILEs (or stdin) to such a
//!                               server and prints the reply lines
//! tuna sweep [--workloads BFS,SSSP] [--fractions 1.0,0.9,0.8,...]
//!           [--policy tpp,first-touch,memtis,tuna,tpp-nomad,tpp-gated]
//!           [--seeds 1,2,3]
//!           [--hot-thrs 2,4] [--threads N] [--intervals N]
//!           [--migrations exclusive,non-exclusive
//!            [--abort-on-write BOOL] [--copy-intervals N]]
//!           [--admission on|off [--mig-budget PAGES] [--cooldown N]
//!            [--horizon N]]
//!           [--memtis | --first-touch] [--db artifacts/perfdb.bin]
//!           [--store DIR] [--name NAME] [--append]
//!           [--resident-segments N [--db-name perfdb]]
//!                               parallel grid sweep (Fig. 1 and beyond);
//!                               with --store, baselines are served from /
//!                               persisted to the artifact store and the
//!                               cells are saved as a diffable table; with
//!                               --resident-segments, Tuna cells query the
//!                               store's sharded perf DB from a bounded
//!                               resident set
//! tuna build-db --store DIR [--shards N] [--name perfdb]
//!              [--resident-segments N]
//!                               sharded build streaming into store
//!                               segments; --resident-segments additionally
//!                               opens the result lazily and reports the
//!                               serving-memory budget at that cap
//! tuna store ls   [--store DIR] list artifacts (perfdbs, sweeps, baselines,
//!                               traces; foreign files show as `(?)`)
//! tuna store diff A B [--store DIR] [--tol T] [--strict]
//!                               cell-by-cell sweep comparison (regressions)
//! tuna trace record --workload kv-zipfian [--seed S] [--intervals N]
//!                  [--keys N] [--ops N] [--out FILE | --store DIR [--name N]]
//!                               generate + persist a TUNATRC1 op-stream
//!                               artifact (with --from FILE: re-encode an
//!                               existing trace, byte-identically)
//! tuna trace replay FILE [--fraction F]
//!                  [--policy tpp|first-touch|memtis|tpp-nomad|tpp-gated]
//!                  [--intervals N] [--hot-thr T] [--store DIR]
//!                  [--admission on|off [--mig-budget PAGES] [--cooldown N]
//!                   [--horizon N]]
//!                               drive the recorded op stream through a
//!                               policy run (Tuna: `tuna tune --workload
//!                               trace:FILE`)
//! tuna trace stats FILE [--store DIR]
//!                               header + op-mix summary (full CRC check)
//! tuna obs dump FILE            every journal event + the metric snapshot
//! tuna obs summary FILE         per-phase breakdown, decision timeline,
//!                               histograms, warnings
//! tuna obs diff A B             metric deltas between two journals
//! tuna obs outcomes FILE        per-session predicted-vs-realized decision
//!                               timeline, prediction-error quantiles,
//!                               worst decisions ranked, drift transitions
//! tuna whatif --workload BFS --fraction 0.8 [run flags] [--config FILE]
//!                               measured what-if: the loss the offline
//!                               sweep would report for that exact
//!                               (workload, fraction) cell, bit-for-bit
//! tuna whatif --stream FILE --fraction F [--sessions N]
//!            [--db artifacts/perfdb.bin] [--configs N]
//!                               predicted what-if: the tuner's own query
//!                               path (kNN + weighted loss curve) over a
//!                               recorded tuna-telemetry v1 stream,
//!                               evaluated at fraction F; with N more
//!                               co-located sessions the fast memory is
//!                               split, so F becomes F/(1+N)
//! ```
//!
//! `run`, `tune`, `serve` and `sweep` additionally accept
//! `--obs-journal FILE` (persist a `TUNAOBS1` event journal),
//! `--metrics FILE` (Prometheus-style exposition) and `--obs-ring N`
//! (journal ring capacity). Either sink flag enables the recorder;
//! results are bit-identical with it on or off.
//!
//! `run`, `tune`, `serve` and `sweep` also accept the decision-outcome
//! accountability knobs `--retune on|observe|off`, `--retune-alpha A`,
//! `--retune-trigger T`, `--retune-early N` and `--retune-cooldown N`
//! (layered over the `[retune]` config table). `observe` tracks
//! predicted-vs-realized loss per decision — journal `Outcome`/`Drift`
//! events, `tuner_realized_loss` / `tuner_prediction_error` /
//! `tuner_drift_state` / `tuner_retunes_total` metric families —
//! without altering any decision (bit-identical to `off`); `on`
//! additionally re-decides early when the EWMA prediction error drifts
//! past the trigger, with a cool-down so adaptation cannot thrash.
//! `tuna run` drives fixed watermarks (no tuner in the loop), so there
//! the knobs are validated and reported but change nothing.
//!
//! Workload names everywhere: the five Table 1 applications, the KV
//! family (`kv-uniform`, `kv-zipfian`, `kv-latest`, `kv-hotspot`,
//! `kv-scan`, `kv-drift`), or `trace:FILE` to replay a recorded trace.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use tuna::artifact::cells::{diff, SweepTable};
use tuna::artifact::shard::{
    LazyShardedNn, LazyShardedPerfDb, ResidencyLimit, DEFAULT_SHARDS,
};
use tuna::artifact::{fnv1a64, ArtifactStore};
use tuna::cli::Args;
use tuna::config::ExperimentConfig;
use tuna::coordinator::sweep::{run_sweep_with_cache, BaselineCache, TunaDb};
use tuna::coordinator::{self, RunSpec, SweepPolicy, SweepSpec};
use tuna::perfdb::builder::{build_database_sharded, ensure_db, BuildParams};
use tuna::perfdb::native::{NativeNn, NnQuery};
use tuna::perfdb::PerfSource;
use tuna::outcome::RetuneConfig;
use tuna::report::{pct, Table};
use tuna::runtime::XlaNn;
use tuna::admission::AdmissionConfig;
use tuna::service::{IngestOutput, Ingestor, TunerService};
use tuna::sim::{MachineModel, MigrationModel};
use tuna::trace::{format as trace_format, gen as trace_gen};
use tuna::util::human_bytes;
use tuna::workloads::{PAGES_PER_PAPER_GB, TABLE1};
use tuna::PAGE_BYTES;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::parse(
        std::env::args().skip(1),
        &["xla", "first-touch", "memtis", "strict", "append"],
    )?;
    match args.subcommand.clone().as_deref() {
        Some("info") => cmd_info(&mut args),
        Some("build-db") => cmd_build_db(&mut args),
        Some("run") => cmd_run(&mut args),
        Some("tune") => cmd_tune(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("sweep") => cmd_sweep(&mut args),
        Some("store") => cmd_store(&mut args),
        Some("trace") => cmd_trace(&mut args),
        Some("obs") => cmd_obs(&mut args),
        Some("whatif") => cmd_whatif(&mut args),
        Some(other) => {
            bail!(
                "unknown subcommand `{other}` (try: info, build-db, run, tune, serve, sweep, store, trace, obs, whatif)"
            )
        }
        None => {
            println!(
                "usage: tuna <info|build-db|run|tune|serve|sweep|store|trace|obs|whatif> [flags]  (see README)"
            );
            Ok(())
        }
    }
}

/// Observability sinks resolved from `--obs-journal FILE`,
/// `--metrics FILE` and `--obs-ring N`. Either sink flag enables the
/// recorder; with neither, every command keeps its zero-cost disabled
/// path.
struct ObsSinks {
    obs: tuna::obs::Recorder,
    journal: Option<PathBuf>,
    metrics: Option<PathBuf>,
}

impl ObsSinks {
    fn from_args(args: &mut Args) -> Result<ObsSinks> {
        let journal = args.get("obs-journal").map(PathBuf::from);
        let metrics = args.get("metrics").map(PathBuf::from);
        let ring: usize = args.get_parse("obs-ring", tuna::obs::DEFAULT_RING_CAPACITY)?;
        let obs = if journal.is_some() || metrics.is_some() {
            tuna::obs::Recorder::enabled(ring)
        } else {
            tuna::obs::Recorder::disabled()
        };
        Ok(ObsSinks { obs, journal, metrics })
    }

    /// Persist whichever sinks were requested, after the command's work.
    fn flush(&self) -> Result<()> {
        if let Some(path) = &self.journal {
            self.obs.write_journal(path)?;
            println!("obs journal written to {}", path.display());
        }
        if let Some(path) = &self.metrics {
            self.obs.write_metrics(path)?;
            println!("metrics written to {}", path.display());
        }
        Ok(())
    }
}

fn load_exp(args: &mut Args) -> Result<ExperimentConfig> {
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(&path.to_string())),
        None => Ok(ExperimentConfig::default()),
    }
}

fn spec_from(args: &mut Args, exp: &ExperimentConfig) -> Result<RunSpec> {
    let mut spec = RunSpec::new(&args.get_or("workload", &exp.workload));
    spec.seed = args.get_parse("seed", exp.seed)?;
    spec.intervals = args.get_parse("intervals", exp.intervals)?;
    spec.fm_fraction = args.get_parse("fraction", exp.fm_fraction)?;
    spec.hot_thr = args.get_parse("hot-thr", exp.hot_thr)?;
    spec.migration = migration_from(args, exp.migration)?;
    spec.admission = admission_from(args, exp.admission)?;
    spec.machine = exp.machine.clone();
    Ok(spec)
}

/// Resolve the migration model from `--migration MODE`,
/// `--abort-on-write BOOL` and `--copy-intervals N`, layered over the
/// `[migration]` table of `--config` (flags win; with neither, the
/// policy's own default applies).
fn migration_from(args: &mut Args, default: MigrationModel) -> Result<MigrationModel> {
    let (dmode, dabort, dcopy) = match default {
        MigrationModel::Exclusive => {
            ("exclusive", true, MigrationModel::DEFAULT_COPY_INTERVALS)
        }
        MigrationModel::NonExclusive { abort_on_write, copy_intervals } => {
            ("non-exclusive", abort_on_write, copy_intervals)
        }
    };
    let mode = args.get_or("migration", dmode);
    let abort: bool = args.get_parse("abort-on-write", dabort)?;
    let copy: u32 = args.get_parse("copy-intervals", dcopy)?;
    MigrationModel::parse(&mode, abort, copy).map_err(anyhow::Error::msg)
}

/// Resolve the admission-control config from `--admission MODE`,
/// `--mig-budget PAGES`, `--cooldown N` and `--horizon N`, layered over
/// the `[admission]` table of `--config` (flags win; with neither, no
/// gate is installed).
fn admission_from(args: &mut Args, default: AdmissionConfig) -> Result<AdmissionConfig> {
    let mode = args.get_or("admission", default.mode_name());
    let budget: u64 = args.get_parse("mig-budget", default.budget_pages)?;
    let cooldown: u32 = args.get_parse("cooldown", default.cooldown_intervals)?;
    let horizon: u32 = args.get_parse("horizon", default.horizon_intervals)?;
    AdmissionConfig::parse(&mode, budget, cooldown, horizon).map_err(anyhow::Error::msg)
}

/// Resolve the decision-outcome accountability config from `--retune
/// MODE`, `--retune-alpha A`, `--retune-trigger T`, `--retune-early N`
/// and `--retune-cooldown N`, layered over the `[retune]` table of
/// `--config` (flags win; with neither, the tracker stays off and the
/// legacy decision path is bit-identical).
fn retune_from(args: &mut Args, default: RetuneConfig) -> Result<RetuneConfig> {
    let mode = args.get_or("retune", default.mode_name());
    let alpha: f64 = args.get_parse("retune-alpha", default.ewma_alpha)?;
    let trigger: f64 = args.get_parse("retune-trigger", default.trigger)?;
    let early: u32 = args.get_parse("retune-early", default.early_intervals)?;
    let cooldown: u32 = args.get_parse("retune-cooldown", default.cooldown_periods)?;
    RetuneConfig::parse(&mode, alpha, trigger, early, cooldown).map_err(anyhow::Error::msg)
}

fn cmd_info(args: &mut Args) -> Result<()> {
    args.finish()?;
    let mut t = Table::new(
        "Table 1: workloads (paper RSS, scaled pages)",
        &["Workload", "paper RSS", "pages here", "bytes here", "description"],
    );
    for w in TABLE1 {
        let pages = (w.paper_rss_gb * PAGES_PER_PAPER_GB) as u64;
        t.row(vec![
            w.name.to_string(),
            format!("{:.1} G", w.paper_rss_gb),
            pages.to_string(),
            human_bytes(pages * PAGE_BYTES),
            w.description.to_string(),
        ]);
    }
    t.print();
    let m = MachineModel::default();
    println!("\nmachine model (one socket of the paper's testbed):\n{m:#?}");
    Ok(())
}

fn cmd_build_db(args: &mut Args) -> Result<()> {
    let out_given = args.get("out").map(|s| s.to_string());
    let out = PathBuf::from(out_given.clone().unwrap_or_else(|| "artifacts/perfdb.bin".into()));
    let mut params = BuildParams::default();
    params.n_configs = args.get_parse("configs", params.n_configs)?;
    params.seed = args.get_parse("seed", params.seed)?;
    let store_dir = args.get("store").map(PathBuf::from);
    let shards_given = args.get("shards").is_some();
    let shards: usize = args.get_parse("shards", DEFAULT_SHARDS)?;
    let named = args.get("name").map(|s| s.to_string());
    let resident_given = args.get("resident-segments").is_some();
    let resident: usize = args.get_parse("resident-segments", 0usize)?;
    args.finish()?;

    if let Some(dir) = store_dir {
        if out_given.is_some() {
            bail!("--out conflicts with --store (sharded builds land in the store; use --name)");
        }
        // Sharded build: completed records stream straight into the
        // store's segment files instead of accumulating in memory.
        let store = ArtifactStore::open(&dir)?;
        let name = named.unwrap_or_else(|| "perfdb".to_string());
        let target = store.perfdb_dir().join(&name);
        let t0 = std::time::Instant::now();
        let manifest = build_database_sharded(&params, shards, &target)?;
        println!(
            "sharded perfdb ready at {}: {} records x {} fm sizes in {} segments ({:.1}s)",
            target.display(),
            manifest.n_records,
            manifest.fractions.len(),
            manifest.segments.len(),
            t0.elapsed().as_secs_f64()
        );
        if resident_given {
            // Open the result lazily at the requested cap and report the
            // serving-memory budget: the cap's worst-case resident bytes
            // (the largest `resident` segments) vs the whole database.
            let lazydb =
                LazyShardedPerfDb::open(&target, ResidencyLimit::segments(resident))?;
            let mut sizes = tuna::artifact::shard::segment_sizes(&target, &manifest);
            let total: u64 = sizes.iter().sum();
            sizes.sort_unstable_by(|a, b| b.cmp(a));
            let keep = if resident == 0 { sizes.len() } else { resident.min(sizes.len()) };
            let budget: u64 = sizes[..keep].iter().sum();
            println!(
                "lazy residency budget at cap {}: ≤ {} resident of {} on disk \
                 ({} of {} segments); manifest validated, segments untouched",
                if resident == 0 { "unbounded".to_string() } else { resident.to_string() },
                human_bytes(budget),
                human_bytes(total),
                keep,
                lazydb.n_shards()
            );
        }
        return Ok(());
    }
    if shards_given || named.is_some() || resident_given {
        bail!(
            "--shards/--name/--resident-segments require --store DIR (sharded builds live \
             in the artifact store)"
        );
    }

    let db = ensure_db(&out, &params)?;
    println!(
        "perfdb ready at {}: {} records x {} fm sizes",
        out.display(),
        db.len(),
        db.fractions.len()
    );
    Ok(())
}

fn cmd_run(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let mut spec = spec_from(args, &exp)?;
    let first_touch = args.switch("first-touch");
    let memtis = args.switch("memtis");
    let retune = retune_from(args, exp.tuna.retune)?;
    let sinks = ObsSinks::from_args(args)?;
    args.finish()?;
    spec.obs = sinks.obs.clone();
    // Fixed-watermark runs have no tuner, so there is nothing for the
    // accountability layer to hold accountable; the knobs are still
    // validated (shared config files parse everywhere) and announced so
    // a stray `--retune on` is never silently swallowed.
    if retune.enabled() {
        println!(
            "note: `tuna run` has no tuner in the loop; --retune {} is validated but drives nothing here",
            retune.mode_name()
        );
    }

    let baseline = coordinator::run_fm_only(&spec)?;
    let run = if first_touch {
        coordinator::run_first_touch(&spec)?
    } else if memtis {
        coordinator::run_memtis(&spec)?
    } else {
        coordinator::run_tpp(&spec)?
    };
    let loss = coordinator::overall_loss(&run, &baseline);

    let mut t = Table::new(
        &format!("{} under {} at {} fast memory", spec.workload, run.policy, pct(spec.fm_fraction)),
        &["metric", "value"],
    );
    t.row(vec!["intervals".into(), run.trace.len().to_string()]);
    t.row(vec!["total time".into(), tuna::util::human_ns(run.total_ns as u64)]);
    t.row(vec!["perf loss vs fast-only".into(), pct(loss)]);
    t.row(vec!["promotions".into(), run.total_promoted().to_string()]);
    t.row(vec!["promotion failures".into(), run.total_promote_failed().to_string()]);
    t.row(vec!["demotions".into(), run.total_demoted().to_string()]);
    // The transactional-migration counters appear whenever the run used
    // the non-exclusive model (even if all zero, so scripts can grep for
    // the rows); exclusive runs keep the pre-migration-axis output.
    let txn_total = run.total_shadow_hits()
        + run.total_shadow_free_demotions()
        + run.total_txn_aborts()
        + run.total_txn_retried_copies();
    if !spec.migration.is_exclusive() || txn_total > 0 {
        t.row(vec!["migration mode".into(), spec.migration.mode_name().to_string()]);
        t.row(vec!["shadow_hits".into(), run.total_shadow_hits().to_string()]);
        t.row(vec![
            "shadow_free_demotions".into(),
            run.total_shadow_free_demotions().to_string(),
        ]);
        t.row(vec!["txn_aborts".into(), run.total_txn_aborts().to_string()]);
        t.row(vec![
            "txn_retried_copies".into(),
            run.total_txn_retried_copies().to_string(),
        ]);
    }
    // Same contract for the admission-verdict counters: the rows appear
    // whenever the run was gated (even if some are zero, so scripts can
    // grep for them); ungated runs keep the pre-admission output.
    if spec.admission.enabled || run.total_admission_verdicts() > 0 {
        t.row(vec!["admission".into(), spec.admission.mode_name().to_string()]);
        t.row(vec![
            "admission_accepted".into(),
            run.total_admission_accepted().to_string(),
        ]);
        t.row(vec![
            "admission_rejected_budget".into(),
            run.total_admission_rejected_budget().to_string(),
        ]);
        t.row(vec![
            "admission_rejected_payoff".into(),
            run.total_admission_rejected_payoff().to_string(),
        ]);
        t.row(vec![
            "admission_rejected_cooldown".into(),
            run.total_admission_rejected_cooldown().to_string(),
        ]);
    }
    t.print();
    sinks.flush()?;
    Ok(())
}

fn cmd_tune(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let mut spec = spec_from(args, &exp)?;
    let db_given = args.get("db").map(|s| s.to_string());
    let db_path = PathBuf::from(db_given.clone().unwrap_or_else(|| exp.perfdb_path.clone()));
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let use_xla = args.switch("xla") || exp.tuna.use_xla;
    let record = args.get("record").map(PathBuf::from);
    let store_dir = args.get("store").map(PathBuf::from);
    let named = args.get("name").map(|s| s.to_string());
    let resident_given = args.get("resident-segments").is_some();
    let resident: usize = args.get_parse("resident-segments", 0usize)?;
    let mut tuna_cfg = exp.tuna.clone();
    tuna_cfg.loss_target = args.get_parse("target", tuna_cfg.loss_target)?;
    tuna_cfg.period_s = args.get_parse("period", tuna_cfg.period_s)?;
    tuna_cfg.retune = retune_from(args, tuna_cfg.retune)?;
    let mut params = BuildParams::default();
    params.n_configs = args.get_parse("configs", params.n_configs)?;
    let sinks = ObsSinks::from_args(args)?;
    args.finish()?;
    spec.obs = sinks.obs.clone();
    if named.is_some() && store_dir.is_none() {
        bail!("--name requires --store DIR (it names the sharded perf DB inside the store)");
    }
    if resident_given && store_dir.is_none() {
        bail!("--resident-segments requires --store DIR (it caps the store's sharded perf DB)");
    }
    if store_dir.is_some() && db_given.is_some() {
        bail!("--db conflicts with --store (the store's sharded perf DB is the backend)");
    }
    if store_dir.is_some() && use_xla {
        bail!("--xla needs the flat perf DB (--db); the store backend queries its shards directly");
    }

    // The database: the store's sharded perf DB served lazily from a
    // bounded resident set, or the flat artifact (built on first use).
    let mut lazy: Option<Arc<LazyShardedPerfDb>> = None;
    let (source, query): (Arc<dyn PerfSource>, Box<dyn NnQuery + Send>) = match &store_dir {
        Some(dir) => {
            let store = ArtifactStore::open_existing(dir)?;
            let name = named.unwrap_or_else(|| "perfdb".to_string());
            let mut db = LazyShardedPerfDb::open(
                &store.perfdb_dir().join(&name),
                ResidencyLimit::segments(resident),
            )?;
            db.set_obs(sinks.obs.clone());
            let db = Arc::new(db);
            lazy = Some(db.clone());
            (db.clone() as Arc<dyn PerfSource>, Box::new(LazyShardedNn::new(db, 0)))
        }
        None => {
            let db = Arc::new(ensure_db(&db_path, &params)?);
            let query: Box<dyn NnQuery + Send> = if use_xla {
                Box::new(XlaNn::from_manifest(&artifacts, &db)?)
            } else {
                Box::new(NativeNn::new(&db))
            };
            (db as Arc<dyn PerfSource>, query)
        }
    };

    let baseline = coordinator::run_fm_only(&spec)?;
    let service = TunerService::inline_with_obs(source, query, sinks.obs.clone());
    let run = match &record {
        Some(path) => {
            // Tap the session's stream events into a tuna-telemetry v1
            // file that `tuna serve` replays to the same decisions.
            let mut stream = format!("{}\n", tuna::service::ingest::STREAM_HEADER);
            let run =
                coordinator::run_tuna_service_tapped(&spec, &service, &tuna_cfg, |ev| {
                    stream.push_str(&ev.to_line());
                    stream.push('\n');
                })?;
            tuna::artifact::write_atomic(path, stream.as_bytes())?;
            println!("telemetry stream recorded to {}", path.display());
            run
        }
        None => coordinator::run_tuna_service(&spec, &service, &tuna_cfg)?,
    };
    let loss = coordinator::overall_loss(&run.result, &baseline);

    let mut t = Table::new(
        &format!(
            "Tuna on {} (target {}, period {}s, backend {})",
            spec.workload,
            pct(tuna_cfg.loss_target),
            tuna_cfg.period_s,
            run.backend
        ),
        &["metric", "value"],
    );
    t.row(vec!["decisions".into(), run.decisions.len().to_string()]);
    t.row(vec!["mean FM saving".into(), pct(run.mean_saving())]);
    t.row(vec!["max FM saving".into(), pct(run.max_saving())]);
    t.row(vec!["overall perf loss".into(), pct(loss)]);
    t.row(vec![
        "query path total".into(),
        tuna::util::human_ns(run.decide_ns as u64),
    ]);
    if !run.decisions.is_empty() {
        t.row(vec![
            "query path / decision".into(),
            tuna::util::human_ns((run.decide_ns / run.decisions.len() as u128) as u64),
        ]);
    }
    // Accountability rows appear whenever the tracker was active (even
    // if all zero, so scripts can grep for them); `--retune off` runs
    // keep the pre-outcome output byte-for-byte.
    if tuna_cfg.retune.enabled() {
        t.row(vec!["retune mode".into(), tuna_cfg.retune.mode_name().to_string()]);
        t.row(vec!["outcomes tracked".into(), run.outcomes.len().to_string()]);
        if !run.outcomes.is_empty() {
            let mean_abs: f64 = run.outcomes.iter().map(|o| o.abs_err).sum::<f64>()
                / run.outcomes.len() as f64;
            t.row(vec!["mean |prediction error|".into(), pct(mean_abs)]);
        }
        t.row(vec!["retunes".into(), run.retunes.to_string()]);
    }
    for (name, v) in &run.vmstat {
        t.row(vec![format!("vmstat {name}"), v.to_string()]);
    }
    t.print();
    if let Some(db) = &lazy {
        print_residency(db);
    }
    sinks.flush()?;
    Ok(())
}

/// Residency accounting after a run over a [`LazyShardedPerfDb`] — the
/// proof the `--resident-segments` cap was honored (CI greps the
/// `peak N resident` phrase).
fn print_residency(db: &LazyShardedPerfDb) {
    let s = db.stats();
    let cap = db.limit();
    let cap_str = match (cap.max_segments, cap.max_bytes) {
        (0, 0) => "unbounded".to_string(),
        (n, 0) => format!("{n} segment(s)"),
        (0, b) => human_bytes(b),
        (n, b) => format!("{n} segment(s) / {}", human_bytes(b)),
    };
    println!(
        "lazy perfdb residency: cap {cap_str}, peak {} resident of {} segments ({}), \
         {} loads, {} evictions, {} CRC checks",
        s.peak_resident_segments,
        db.n_shards(),
        human_bytes(s.peak_resident_bytes),
        s.loads,
        s.evictions,
        s.crc_verifies
    );
}

/// `tuna serve`: the tuner as a standalone service. Telemetry arrives
/// from *outside* the process as tuna-telemetry v1 lines — files or
/// stdin (any number of interleaved sessions), or, with `--listen
/// ADDR`, over TCP from any number of concurrent client connections.
/// Decisions print (or write back down each client's socket) as the
/// sessions hit their tuning-period boundaries, and each `close` line
/// prints the session's final report. `--workers N` shards aggregation
/// across N workers (decisions are bit-identical for any N); `--connect
/// ADDR` is the client side, streaming FILEs (or stdin) to a listening
/// server and printing its replies.
fn cmd_serve(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let listen = args.get("listen").map(|s| s.to_string());
    let connect = args.get("connect").map(|s| s.to_string());
    let workers: usize = args.get_parse("workers", 1usize)?;
    let max_conns: usize = args.get_parse("max-conns", 0usize)?;
    if workers == 0 {
        bail!("--workers must be at least 1");
    }
    if listen.is_some() && connect.is_some() {
        bail!("--listen (server) conflicts with --connect (client)");
    }
    let store_dir = args.get("store").map(PathBuf::from);
    let named = args.get("name").map(|s| s.to_string());
    if store_dir.is_none() && named.is_some() {
        bail!("--name requires --store DIR (it names the sharded perf DB inside the store)");
    }
    let db_given = args.get("db").map(|s| s.to_string());
    if store_dir.is_some() && db_given.is_some() {
        bail!(
            "--db conflicts with --store (the store's sharded perf DB is the backend; \
             pick it with --name)"
        );
    }
    let db_name = named.unwrap_or_else(|| "perfdb".to_string());
    let db_path = PathBuf::from(db_given.unwrap_or_else(|| exp.perfdb_path.clone()));
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let resident_given = args.get("resident-segments").is_some();
    let resident: usize = args.get_parse("resident-segments", 0usize)?;
    let mut tuna_cfg = exp.tuna.clone();
    tuna_cfg.loss_target = args.get_parse("target", tuna_cfg.loss_target)?;
    tuna_cfg.period_s = args.get_parse("period", tuna_cfg.period_s)?;
    tuna_cfg.retune = retune_from(args, tuna_cfg.retune)?;
    let mut params = BuildParams::default();
    params.n_configs = args.get_parse("configs", params.n_configs)?;
    let files = args.positional.clone();
    let sinks = ObsSinks::from_args(args)?;
    args.finish()?;
    if resident_given && store_dir.is_none() {
        bail!("--resident-segments requires --store DIR (it caps the store's sharded perf DB)");
    }

    // Client mode: no database, no service — stream the files (or
    // stdin) to a listening server, one connection per stream, and
    // print every reply line as it arrives.
    if let Some(addr) = &connect {
        let mut sent = 0u64;
        let mut replies = 0u64;
        if files.is_empty() {
            let stdin = std::io::stdin();
            let rep = tuna::service::serve_stream(addr, stdin.lock(), |line| println!("{line}"))?;
            sent += rep.sent_lines;
            replies += rep.reply_lines;
        } else {
            for file in &files {
                let f = std::fs::File::open(file)
                    .map_err(|e| anyhow::anyhow!("opening stream {file}: {e}"))?;
                let rep = tuna::service::serve_stream(
                    addr,
                    std::io::BufReader::new(f),
                    |line| println!("{line}"),
                )?;
                sent += rep.sent_lines;
                replies += rep.reply_lines;
            }
        }
        println!("streamed {sent} lines to {addr}: {replies} reply lines");
        return Ok(());
    }

    // The database backend: the store's sharded perf DB — served lazily
    // from a bounded resident set, never materialized whole — when
    // --store is given, else the flat artifact (built on first use).
    // Each aggregation worker gets its own query backend over the one
    // shared source, so sharded decision paths never contend on a lock.
    let mut lazy: Option<Arc<LazyShardedPerfDb>> = None;
    type NnFactory = Box<dyn FnMut(usize) -> Box<dyn NnQuery + Send>>;
    let (source, nn_factory, backend): (Arc<dyn PerfSource>, NnFactory, &str) =
        match &store_dir {
            Some(dir) => {
                let store = ArtifactStore::open_existing(dir)?;
                let mut db = LazyShardedPerfDb::open(
                    &store.perfdb_dir().join(&db_name),
                    ResidencyLimit::segments(resident),
                )?;
                db.set_obs(sinks.obs.clone());
                let db = Arc::new(db);
                lazy = Some(db.clone());
                let ldb = db.clone();
                let factory: NnFactory =
                    Box::new(move |_| Box::new(LazyShardedNn::new(ldb.clone(), 0)));
                (db as Arc<dyn PerfSource>, factory, "lazy-sharded")
            }
            None => {
                let db = Arc::new(ensure_db(&db_path, &params)?);
                let (query, backend) = tuna::runtime::service_backend(&artifacts, &db);
                // worker 0 reuses the probe query; further workers get
                // a fresh backend of the same flavor
                let mut first = Some(query);
                let fdb = db.clone();
                let artifacts = artifacts.clone();
                let factory: NnFactory = Box::new(move |_| {
                    if let Some(q) = first.take() {
                        return q;
                    }
                    if backend == "xla" {
                        if let Ok(x) = XlaNn::from_manifest(&artifacts, &fdb) {
                            return Box::new(x);
                        }
                    }
                    Box::new(NativeNn::new(&fdb))
                });
                (db as Arc<dyn PerfSource>, factory, backend)
            }
        };
    println!(
        "tuner service up: {} records x {} fm sizes, backend {backend}, target {}, period {}s, {} worker(s)",
        source.n_records(),
        source.fraction_grid().len(),
        pct(tuna_cfg.loss_target),
        tuna_cfg.period_s,
        workers
    );

    let service =
        TunerService::spawn_sharded_with_obs(source, nn_factory, workers, sinks.obs.clone());

    // Server mode: accept tuna-telemetry v1 connections and write
    // decisions back on each client's socket.
    if let Some(addr) = &listen {
        if !files.is_empty() {
            bail!("--listen takes no FILE arguments (stream them from a client via --connect)");
        }
        let server = tuna::service::NetServer::bind(
            addr,
            tuna::service::NetServerConfig {
                cfg: tuna_cfg.clone(),
                max_conns,
                obs: sinks.obs.clone(),
            },
        )?;
        // scripts scrape the bound address (--listen 127.0.0.1:0)
        println!("listening on {}", server.local_addr()?);
        let stats = server.serve(&service)?;
        println!(
            "served {} connection(s), {} lines: {} samples -> {} decisions ({} failed)",
            stats.connections, stats.lines, stats.samples, stats.decisions, stats.failed
        );
        if let Some(db) = &lazy {
            print_residency(db);
        }
        sinks.flush()?;
        return Ok(());
    }

    let mut ingestor = Ingestor::new_with_obs(&service, tuna_cfg, sinks.obs.clone());
    // one rendering shared with the network server's socket write-back
    let print = |out: IngestOutput| print!("{}", out.render_lines());
    let mut totals = (0u64, 0u64, 0u64); // lines, samples, decisions
    if files.is_empty() {
        let stdin = std::io::stdin();
        let stats = ingestor.ingest(stdin.lock(), print)?;
        totals = (stats.lines, stats.samples, stats.decisions);
    } else {
        for file in &files {
            let f = std::fs::File::open(file)
                .map_err(|e| anyhow::anyhow!("opening stream {file}: {e}"))?;
            let stats = ingestor.ingest(std::io::BufReader::new(f), print)?;
            totals.0 += stats.lines;
            totals.1 += stats.samples;
            totals.2 += stats.decisions;
        }
    }
    // streams without trailing `close` lines still get their reports
    ingestor.finish_all(print)?;
    println!(
        "served {} lines: {} samples -> {} decisions",
        totals.0, totals.1, totals.2
    );
    if let Some(db) = &lazy {
        print_residency(db);
    }
    sinks.flush()?;
    Ok(())
}

/// Parse a comma-separated list of values.
fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .map(|x| x.trim())
        .filter(|x| !x.is_empty())
        .map(|x| x.parse::<T>().map_err(|e| anyhow::anyhow!("bad list item `{x}`: {e}")))
        .collect()
}

fn cmd_sweep(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let default_workload = args.get_or("workload", &exp.workload);
    let workloads: Vec<String> = args
        .get_or("workloads", &default_workload)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    // Singular flags stay accepted as aliases (pre-executor invocations
    // like `tuna sweep --workload BFS --seed 7 --fraction 0.9` keep working).
    let single_fraction =
        args.get_or("fraction", "1.0,0.95,0.895,0.8,0.7,0.5,0.3,0.266");
    let fractions: Vec<f64> = parse_list(&args.get_or("fractions", &single_fraction))?;
    let single_seed = args.get_or("seed", &exp.seed.to_string());
    let seeds: Vec<u64> = parse_list(&args.get_or("seeds", &single_seed))?;
    let single_hot_thr = args.get_or("hot-thr", &exp.hot_thr.to_string());
    let hot_thrs: Vec<u32> = parse_list(&args.get_or("hot-thrs", &single_hot_thr))?;
    let intervals: u32 = args.get_parse("intervals", exp.intervals)?;
    let threads: usize = args.get_parse("threads", 0usize)?;
    // `--memtis` / `--first-touch` are kept as shorthands for `--policy`.
    let memtis = args.switch("memtis");
    let first_touch = args.switch("first-touch");
    let default_policy =
        if memtis { "memtis" } else if first_touch { "first-touch" } else { "tpp" };
    let policies: Vec<SweepPolicy> = args
        .get_or("policy", default_policy)
        .split(',')
        .map(|s| SweepPolicy::parse(s.trim()))
        .collect::<Result<_>>()?;
    // Migration-mode axis. `--migrations exclusive,non-exclusive` crosses
    // the grid with both models; the shared --abort-on-write and
    // --copy-intervals knobs apply to every non-exclusive mode listed,
    // and the singular --migration stays accepted as an alias.
    let (dmode, cabort, ccopy) = match exp.migration {
        MigrationModel::Exclusive => {
            ("exclusive", true, MigrationModel::DEFAULT_COPY_INTERVALS)
        }
        MigrationModel::NonExclusive { abort_on_write, copy_intervals } => {
            ("non-exclusive", abort_on_write, copy_intervals)
        }
    };
    let abort: bool = args.get_parse("abort-on-write", cabort)?;
    let copy: u32 = args.get_parse("copy-intervals", ccopy)?;
    let single_migration = args.get_or("migration", dmode);
    let migrations: Vec<MigrationModel> = args
        .get_or("migrations", &single_migration)
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| MigrationModel::parse(s, abort, copy).map_err(anyhow::Error::msg))
        .collect::<Result<_>>()?;
    // Admission knob: shared by every cell; tpp-gated cells force the
    // enabled default when left off (see SweepSpec::expand).
    let admission = admission_from(args, exp.admission)?;
    // Retune knob: shared by every Tuna cell (the only policy with a
    // tuner in the loop; other cells ignore it).
    let retune = retune_from(args, exp.tuna.retune)?;
    let db_given = args.get("db").map(|s| s.to_string());
    let db_path = PathBuf::from(db_given.clone().unwrap_or_else(|| exp.perfdb_path.clone()));
    let store_dir = args.get("store").map(PathBuf::from);
    let sweep_name = args.get("name").map(|s| s.to_string());
    let append = args.switch("append");
    let resident_given = args.get("resident-segments").is_some();
    let resident: usize = args.get_parse("resident-segments", 0usize)?;
    let tuna_db_name = args.get("db-name").map(|s| s.to_string());
    let sinks = ObsSinks::from_args(args)?;
    args.finish()?;
    if store_dir.is_none() && sweep_name.is_some() {
        bail!("--name requires --store DIR (it names the persisted cell table)");
    }
    if append && (store_dir.is_none() || sweep_name.is_none()) {
        bail!("--append requires --store DIR and --name NAME (the table to accumulate into)");
    }
    if (resident_given || tuna_db_name.is_some()) && store_dir.is_none() {
        bail!(
            "--resident-segments/--db-name require --store DIR (they select the store's \
             sharded perf DB for Tuna cells)"
        );
    }
    if tuna_db_name.is_some() && !resident_given {
        bail!(
            "--db-name requires --resident-segments (it names the store perf DB the lazy \
             Tuna backend serves; without the knob, Tuna cells use the flat --db path)"
        );
    }
    if resident_given && db_given.is_some() {
        bail!(
            "--db conflicts with --resident-segments (Tuna cells then query the store's \
             sharded perf DB; pick it with --db-name)"
        );
    }

    let mut spec = SweepSpec::new(&workloads)
        .with_fractions(fractions)
        .with_seeds(seeds)
        .with_hot_thrs(hot_thrs)
        .with_policies(policies.clone())
        .with_migrations(migrations)
        .with_admission(admission)
        .with_intervals(intervals)
        .with_threads(threads)
        .with_machine(exp.machine.clone())
        .with_obs(sinks.obs.clone());
    let mut lazy: Option<Arc<LazyShardedPerfDb>> = None;
    if policies.contains(&SweepPolicy::Tuna) {
        // With --resident-segments, Tuna cells query the store's sharded
        // perf DB from a bounded resident set (all cells share one
        // segment cache through the sweep's single tuner service).
        let tuna_db = match (&store_dir, resident_given) {
            (Some(dir), true) => {
                let name = tuna_db_name.unwrap_or_else(|| "perfdb".to_string());
                let store = ArtifactStore::open_existing(dir)?;
                let mut db = LazyShardedPerfDb::open(
                    &store.perfdb_dir().join(&name),
                    ResidencyLimit::segments(resident),
                )?;
                db.set_obs(sinks.obs.clone());
                let db = Arc::new(db);
                lazy = Some(db.clone());
                TunaDb::Lazy(db)
            }
            _ => TunaDb::Flat(Arc::new(ensure_db(&db_path, &BuildParams::default())?)),
        };
        let mut tuna_cfg = exp.tuna.clone();
        tuna_cfg.retune = retune;
        spec = spec.with_tuna_db(tuna_db, tuna_cfg);
    }

    // With --store, fast-memory-only baselines are served from (and
    // written through to) the artifact store, so a repeated invocation
    // re-simulates zero baselines.
    let (store, cache) = match &store_dir {
        Some(dir) => {
            let store = ArtifactStore::open(dir)?;
            let cache = BaselineCache::persistent(&store.baselines_dir())?
                .with_obs(sinks.obs.clone());
            (Some(store), cache)
        }
        None => (None, BaselineCache::new()),
    };
    let res = run_sweep_with_cache(&spec, &cache)?;

    let mut t = Table::new(
        &format!(
            "parallel sweep: {} workloads × {} fractions × {} seeds × {} hot-thrs × {} policies × {} migration modes = {} cells",
            spec.workloads.len(),
            spec.fractions.len(),
            spec.seeds.len(),
            spec.hot_thrs.len(),
            spec.policies.len(),
            spec.migrations.len(),
            res.len()
        ),
        &[
            "workload", "policy", "migration", "seed", "FM size", "perf loss", "saving",
            "migrations", "failures",
        ],
    );
    for c in &res.cells {
        t.row(vec![
            c.spec.workload.clone(),
            c.spec.policy.name().to_string(),
            c.spec.migration.mode_name().to_string(),
            c.spec.seed.to_string(),
            pct(c.spec.fm_fraction),
            pct(c.loss),
            pct(c.saving),
            c.result.total_migrations().to_string(),
            c.result.total_promote_failed().to_string(),
        ]);
    }
    t.print();
    println!(
        "\n{} cells in {}; baselines: {} computed, {} cache hits, {} loaded from disk",
        res.len(),
        tuna::util::human_ns(res.wall_ns as u64),
        res.baselines_computed,
        res.baseline_hits,
        res.baseline_disk_hits
    );
    if let Some(db) = &lazy {
        print_residency(db);
    }

    if let Some(store) = &store {
        let table = SweepTable::from_sweep(&res);
        // Default artifact name: a fingerprint of the grid axes, so
        // rerunning the same sweep overwrites its own table rather than
        // piling up near-duplicates.
        let name = sweep_name.unwrap_or_else(|| {
            let mut fp = Vec::new();
            for w in &spec.workloads {
                fp.extend_from_slice(w.as_bytes());
                fp.push(0);
            }
            for &f in &spec.fractions {
                fp.extend_from_slice(&f.to_le_bytes());
            }
            for &s in &spec.seeds {
                fp.extend_from_slice(&s.to_le_bytes());
            }
            for &h in &spec.hot_thrs {
                fp.extend_from_slice(&h.to_le_bytes());
            }
            for p in &spec.policies {
                fp.push(p.code());
            }
            // The migration axis only contributes when it departs from
            // the default [exclusive], so pre-axis sweeps keep their
            // auto-names (reruns overwrite the same table).
            if spec.migrations != [MigrationModel::Exclusive] {
                for m in &spec.migrations {
                    let (mode, abort, copy) = m.key();
                    fp.push(mode);
                    fp.push(abort);
                    fp.extend_from_slice(&copy.to_le_bytes());
                }
            }
            // Same guard for the admission knob: it only contributes when
            // it departs from the disabled default, so pre-admission
            // sweeps keep their auto-names.
            if spec.admission != AdmissionConfig::default() {
                let (enabled, budget, cooldown, horizon) = spec.admission.key();
                fp.push(enabled);
                fp.extend_from_slice(&budget.to_le_bytes());
                fp.extend_from_slice(&cooldown.to_le_bytes());
                fp.extend_from_slice(&horizon.to_le_bytes());
            }
            // And for the retune knob, so pre-outcome sweeps keep their
            // auto-names too.
            if retune != RetuneConfig::default() {
                fp.extend_from_slice(retune.mode_name().as_bytes());
                fp.extend_from_slice(&retune.ewma_alpha.to_le_bytes());
                fp.extend_from_slice(&retune.trigger.to_le_bytes());
                fp.extend_from_slice(&retune.early_intervals.to_le_bytes());
                fp.extend_from_slice(&retune.cooldown_periods.to_le_bytes());
            }
            fp.extend_from_slice(&spec.intervals.to_le_bytes());
            fp.extend_from_slice(format!("{:?}", spec.machine).as_bytes());
            format!("sweep-{:012x}", fnv1a64(&fp) & 0xFFFF_FFFF_FFFF)
        });
        let path = store.sweep_path(&name);
        if append {
            SweepTable::append(&path, &table.rows)?;
            println!(
                "sweep cells appended to {} (+{} rows, {} total)",
                path.display(),
                table.len(),
                SweepTable::peek_rows(&path)?
            );
        } else {
            table.save(&path)?;
            println!("sweep cells persisted to {} ({} rows)", path.display(), table.len());
        }
    }
    sinks.flush()?;
    Ok(())
}

fn cmd_store(args: &mut Args) -> Result<()> {
    let action = args.positional.first().cloned();
    let store_dir = PathBuf::from(args.get_or("store", "artifacts/store"));
    match action.as_deref() {
        Some("ls") => {
            args.finish()?;
            let store = ArtifactStore::open_existing(&store_dir)?;
            let items = store.ls()?;
            let mut t = Table::new(
                &format!("artifact store at {}", store_dir.display()),
                &["kind", "name", "size", "detail"],
            );
            let n = items.len();
            for a in items {
                t.row(vec![a.kind.to_string(), a.name, human_bytes(a.bytes), a.detail]);
            }
            t.print();
            println!("\n{n} artifact(s)");
            Ok(())
        }
        Some("diff") => {
            let tol: f64 = args.get_parse("tol", 1e-9)?;
            let strict = args.switch("strict");
            args.finish()?;
            let (a_name, b_name) = match (args.positional.get(1), args.positional.get(2)) {
                (Some(a), Some(b)) => (a.clone(), b.clone()),
                _ => bail!("usage: tuna store diff <a> <b> [--store DIR] [--tol T] [--strict]"),
            };
            let store = ArtifactStore::open_existing(&store_dir)?;
            let path_a = store.resolve_sweep(&a_name);
            let path_b = store.resolve_sweep(&b_name);
            let table_a = SweepTable::load(&path_a)?;
            let table_b = SweepTable::load(&path_b)?;
            let d = diff(&table_a, &table_b, tol);

            let mut t = Table::new(
                &format!("store diff: {a_name} -> {b_name} ({} matched cells)", d.matched),
                &["cell", "loss a", "loss b", "Δloss", "Δsaving", "Δmigrations"],
            );
            for delta in d.regressions.iter().chain(d.improvements.iter()) {
                t.row(vec![
                    format!(
                        "{} {} seed {} thr {} @{}",
                        delta.a.workload,
                        delta.a.policy.name(),
                        delta.a.seed,
                        delta.a.hot_thr,
                        pct(delta.a.fm_fraction)
                    ),
                    pct(delta.a.loss),
                    pct(delta.b.loss),
                    format!("{:+.4}", delta.d_loss),
                    format!("{:+.4}", delta.d_saving),
                    format!("{:+}", delta.d_migrations),
                ]);
            }
            t.print();
            println!(
                "\n{} regression(s), {} improvement(s), {} cell(s) only in {a_name}, {} only in {b_name}",
                d.regressions.len(),
                d.improvements.len(),
                d.only_in_a.len(),
                d.only_in_b.len()
            );
            if strict && !d.regressions.is_empty() {
                bail!("{} cell(s) regressed beyond tolerance {tol}", d.regressions.len());
            }
            Ok(())
        }
        _ => bail!("usage: tuna store <ls|diff a b> [--store DIR]"),
    }
}

/// `tuna trace`: record, replay and inspect durable KV op-stream
/// artifacts (`TUNATRC1`). Traces are first-class store artifacts — a
/// recorded stream replays through any policy run or `tuna tune
/// --workload trace:FILE` with decisions bit-identical to the live
/// generator run that produced it.
fn cmd_trace(args: &mut Args) -> Result<()> {
    let action = args.positional.first().cloned();
    match action.as_deref() {
        Some("record") => cmd_trace_record(args),
        Some("replay") => cmd_trace_replay(args),
        Some("stats") => cmd_trace_stats(args),
        _ => bail!("usage: tuna trace <record|replay FILE|stats FILE> [flags]"),
    }
}

fn cmd_trace_record(args: &mut Args) -> Result<()> {
    let from = args.get("from").map(PathBuf::from);
    let workload = args.get("workload").map(|s| s.to_string());
    let seed_flag = args.get("seed").map(|s| s.to_string());
    let intervals_flag = args.get("intervals").map(|s| s.to_string());
    let seed: u64 = match &seed_flag {
        Some(s) => s.parse().map_err(|e| anyhow::anyhow!("bad value for --seed: {e}"))?,
        None => 42,
    };
    let intervals: u32 = match &intervals_flag {
        Some(s) => {
            s.parse().map_err(|e| anyhow::anyhow!("bad value for --intervals: {e}"))?
        }
        None => 120,
    };
    let keys = args.get("keys").map(|s| s.to_string());
    let ops = args.get("ops").map(|s| s.to_string());
    let out_given = args.get("out").map(PathBuf::from);
    let store_dir = args.get("store").map(PathBuf::from);
    let named = args.get("name").map(|s| s.to_string());
    args.finish()?;
    if out_given.is_some() && store_dir.is_some() {
        bail!("--out conflicts with --store (store traces are named with --name)");
    }
    if named.is_some() && store_dir.is_none() {
        bail!("--name requires --store DIR (it names the trace inside the store)");
    }

    let trace = match (&from, &workload) {
        (Some(_), Some(_)) => bail!("--from conflicts with --workload"),
        (Some(path), None) => {
            // Re-encode an existing trace: the canonical encoding makes
            // record → replay → re-record byte-for-byte stable. Generator
            // flags would be silently meaningless here, so reject them.
            if keys.is_some()
                || ops.is_some()
                || seed_flag.is_some()
                || intervals_flag.is_some()
            {
                bail!(
                    "--seed/--intervals/--keys/--ops apply to generated traces, not \
                     --from re-records (a re-record copies the stream verbatim)"
                );
            }
            trace_format::load(path)?
        }
        (None, Some(name)) => {
            let mut spec = trace_gen::spec_by_name(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "`{name}` is not a KV generator family; valid: {}",
                    trace_gen::FAMILY.join(", ")
                )
            })?;
            if let Some(k) = &keys {
                spec.n_keys = k.parse().map_err(|e| anyhow::anyhow!("bad --keys: {e}"))?;
            }
            if let Some(o) = &ops {
                spec.ops_per_interval =
                    o.parse().map_err(|e| anyhow::anyhow!("bad --ops: {e}"))?;
            }
            // reject degenerate/absurd keyspaces here, not as a panic or
            // abort inside the generator
            tuna::trace::check_layout_bounds(spec.n_keys, spec.value_bytes)?;
            // A trace recorded at N intervals replays to N engine
            // intervals: the first is the allocation epoch, so the
            // generator supplies N − 1 op frames.
            trace_gen::generate(&spec, seed, intervals.saturating_sub(1))
        }
        (None, None) => bail!("trace record needs --workload FAMILY or --from FILE"),
    };

    let out = match (&out_given, &store_dir) {
        (Some(path), None) => path.clone(),
        (None, Some(dir)) => {
            let store = ArtifactStore::open(dir)?;
            let name = named
                .unwrap_or_else(|| format!("{}-{}", trace.header.workload, trace.header.seed));
            store.trace_path(&name)
        }
        (None, None) => PathBuf::from(format!(
            "artifacts/traces/{}-{}.trc",
            trace.header.workload, trace.header.seed
        )),
        (Some(_), Some(_)) => unreachable!("checked above"),
    };
    trace_format::save(&out, &trace)?;
    let s = trace.stats();
    println!(
        "trace recorded to {}: {} seed {}, {} ops in {} intervals ({} keys, {})",
        out.display(),
        trace.header.workload,
        trace.header.seed,
        s.total_ops(),
        trace.intervals.len(),
        trace.header.n_keys,
        human_bytes(std::fs::metadata(&out)?.len()),
    );
    Ok(())
}

fn cmd_trace_replay(args: &mut Args) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: tuna trace replay FILE [flags]"))?;
    let store_dir = args.get("store").map(PathBuf::from);
    let path = match &store_dir {
        Some(dir) => ArtifactStore::open_existing(dir)?.resolve_trace(&file),
        None => PathBuf::from(&file),
    };
    // default run length: the whole trace (frames + allocation epoch);
    // saturate — a crafted header can declare u32::MAX frames and peek,
    // unlike the full load, does not bound the count
    let (_, frames, _) = trace_format::peek(&path)?;
    let mut spec = RunSpec::new(&format!("trace:{}", path.display()));
    spec.intervals = args.get_parse("intervals", frames.saturating_add(1))?;
    spec.fm_fraction = args.get_parse("fraction", 0.9)?;
    spec.hot_thr = args.get_parse("hot-thr", spec.hot_thr)?;
    spec.migration = migration_from(args, MigrationModel::Exclusive)?;
    spec.admission = admission_from(args, AdmissionConfig::default())?;
    let policy = SweepPolicy::parse(&args.get_or("policy", "tpp"))?;
    args.finish()?;

    let baseline = coordinator::run_fm_only(&spec)?;
    let run = match policy {
        SweepPolicy::Tpp => coordinator::run_tpp(&spec)?,
        SweepPolicy::FirstTouch => coordinator::run_first_touch(&spec)?,
        SweepPolicy::Memtis => coordinator::run_memtis(&spec)?,
        SweepPolicy::TppNomad => coordinator::run_tpp_nomad(&spec)?,
        SweepPolicy::TppGated => coordinator::run_tpp_gated(&spec)?,
        SweepPolicy::Tuna => bail!(
            "trace replay under Tuna needs the perf DB: use `tuna tune --workload trace:{}`",
            path.display()
        ),
    };
    let loss = coordinator::overall_loss(&run, &baseline);
    let mut t = Table::new(
        &format!(
            "replay of {} ({}) under {} at {} fast memory",
            path.display(),
            run.workload,
            run.policy,
            pct(spec.fm_fraction)
        ),
        &["metric", "value"],
    );
    t.row(vec!["intervals".into(), run.trace.len().to_string()]);
    t.row(vec!["total time".into(), tuna::util::human_ns(run.total_ns as u64)]);
    t.row(vec!["perf loss vs fast-only".into(), pct(loss)]);
    t.row(vec!["promotions".into(), run.total_promoted().to_string()]);
    t.row(vec!["demotions".into(), run.total_demoted().to_string()]);
    if run.total_admission_verdicts() > 0 {
        t.row(vec![
            "admission_accepted".into(),
            run.total_admission_accepted().to_string(),
        ]);
        t.row(vec![
            "admission_rejected_budget".into(),
            run.total_admission_rejected_budget().to_string(),
        ]);
        t.row(vec![
            "admission_rejected_payoff".into(),
            run.total_admission_rejected_payoff().to_string(),
        ]);
        t.row(vec![
            "admission_rejected_cooldown".into(),
            run.total_admission_rejected_cooldown().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_trace_stats(args: &mut Args) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: tuna trace stats FILE [--store DIR]"))?;
    let store_dir = args.get("store").map(PathBuf::from);
    args.finish()?;
    let path = match &store_dir {
        Some(dir) => ArtifactStore::open_existing(dir)?.resolve_trace(&file),
        None => PathBuf::from(&file),
    };
    // full load: stats double as an integrity check of every frame CRC
    let trace = trace_format::load(&path)?;
    let s = trace.stats();
    let h = &trace.header;
    let layout = tuna::trace::replay::KeyspaceLayout::new(h.n_keys, h.value_bytes);
    let mut t = Table::new(&format!("trace {}", path.display()), &["field", "value"]);
    t.row(vec!["workload".into(), h.workload.clone()]);
    t.row(vec!["seed".into(), h.seed.to_string()]);
    t.row(vec!["keys".into(), h.n_keys.to_string()]);
    t.row(vec!["value bytes".into(), h.value_bytes.to_string()]);
    t.row(vec!["threads".into(), h.threads.to_string()]);
    t.row(vec!["intervals".into(), trace.intervals.len().to_string()]);
    t.row(vec!["ops".into(), s.total_ops().to_string()]);
    t.row(vec![
        "mix r/u/i/s".into(),
        format!("{}/{}/{}/{}", s.reads, s.updates, s.inserts, s.scans),
    ]);
    t.row(vec!["mean scan len".into(), format!("{:.1}", s.mean_scan_len())]);
    t.row(vec![
        "replay RSS".into(),
        format!(
            "{} pages ({})",
            layout.rss_pages(),
            human_bytes(layout.rss_pages() as u64 * PAGE_BYTES)
        ),
    ]);
    t.print();
    Ok(())
}

/// `tuna obs`: introspect persisted `TUNAOBS1` observability journals —
/// the artifacts `--obs-journal` writes.
fn cmd_obs(args: &mut Args) -> Result<()> {
    let action = args.positional.first().cloned();
    let file_at = |args: &Args, i: usize, usage: &str| -> Result<PathBuf> {
        args.positional
            .get(i)
            .map(PathBuf::from)
            .ok_or_else(|| anyhow::anyhow!("usage: {usage}"))
    };
    match action.as_deref() {
        Some("dump") => {
            args.finish()?;
            let path = file_at(args, 1, "tuna obs dump FILE")?;
            let j = tuna::obs::Journal::load(&path)?;
            print!("{}", tuna::obs::render::render_dump(&j));
            Ok(())
        }
        Some("summary") => {
            args.finish()?;
            let path = file_at(args, 1, "tuna obs summary FILE")?;
            let j = tuna::obs::Journal::load(&path)?;
            print!("{}", tuna::obs::render::render_summary(&j));
            Ok(())
        }
        Some("diff") => {
            args.finish()?;
            let a = file_at(args, 1, "tuna obs diff A B")?;
            let b = file_at(args, 2, "tuna obs diff A B")?;
            let ja = tuna::obs::Journal::load(&a)?;
            let jb = tuna::obs::Journal::load(&b)?;
            print!(
                "{}",
                tuna::obs::render::render_diff(
                    &a.display().to_string(),
                    &ja,
                    &b.display().to_string(),
                    &jb,
                )
            );
            Ok(())
        }
        Some("outcomes") => {
            args.finish()?;
            let path = file_at(args, 1, "tuna obs outcomes FILE")?;
            let j = tuna::obs::Journal::load(&path)?;
            print!("{}", tuna::obs::render::render_outcomes(&j));
            Ok(())
        }
        _ => bail!("usage: tuna obs <dump FILE|summary FILE|diff A B|outcomes FILE>"),
    }
}

/// `tuna whatif`: the capacity-planning question — "what would the
/// loss be at fraction f / with N more sessions?" — as a first-class
/// verb instead of an offline sweep.
///
/// Two modes:
///
/// * **measured** (`--workload W --fraction F`): actually runs the
///   cell — TPP policy against the fast-memory-only baseline, the
///   exact composition of one sweep cell — so the answer agrees
///   bit-for-bit with the offline sweep's loss for the same
///   (workload, fraction) cell.
/// * **predicted** (`--stream FILE --fraction F [--sessions N]`): no
///   simulation at all — replays a recorded tuna-telemetry v1 stream
///   into per-session aggregation windows and evaluates the tuner's
///   own decision query path (`tuner::predict_loss_at`: kNN +
///   distance-weighted loss curve + grid interpolation) at the
///   requested fraction. With `--sessions N`, fast memory would be
///   split across N more co-located sessions, so each session is
///   evaluated at F/(1+N).
fn cmd_whatif(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let stream = args.get("stream").map(PathBuf::from);
    let sessions: u32 = args.get_parse("sessions", 0u32)?;
    match stream {
        Some(path) => {
            let fraction: f64 = args.get_parse("fraction", exp.fm_fraction)?;
            let db_path = PathBuf::from(args.get_or("db", &exp.perfdb_path));
            let mut params = BuildParams::default();
            params.n_configs = args.get_parse("configs", params.n_configs)?;
            args.finish()?;
            if !(fraction > 0.0 && fraction <= 1.0) {
                bail!("--fraction must be in (0, 1], got {fraction}");
            }

            let db = Arc::new(ensure_db(&db_path, &params)?);
            let mut query = NativeNn::new(&db);
            let source: Arc<dyn PerfSource> = db.clone();

            use tuna::service::ingest::Event;
            use tuna::telemetry::WindowAggregator;
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading stream {}: {e}", path.display()))?;
            // Per-session aggregation windows, exactly as the live
            // ingest path would build them (open lines size the
            // window; samples accumulate into it).
            let mut windows: std::collections::BTreeMap<String, (WindowAggregator, u64)> =
                std::collections::BTreeMap::new();
            for line in text.lines() {
                match Event::parse(line)? {
                    Some(Event::Open { name, rss_pages, hot_thr, threads, .. }) => {
                        windows.insert(
                            name,
                            (WindowAggregator::new(hot_thr, threads, rss_pages), 0),
                        );
                    }
                    Some(Event::Sample { name, sample }) => match windows.get_mut(&name) {
                        Some((w, n)) => {
                            w.observe(&sample);
                            *n += 1;
                        }
                        None => bail!("sample for session `{name}` before its open line"),
                    },
                    Some(Event::Close { .. }) | None => {}
                }
            }
            if windows.is_empty() {
                bail!("stream {} holds no sessions (no open lines)", path.display());
            }

            let eff = fraction / (1.0 + sessions as f64);
            let mut t = Table::new(
                &format!(
                    "what-if (predicted): loss at {} fast memory{}",
                    pct(fraction),
                    if sessions > 0 {
                        format!(
                            ", split with {sessions} more session(s) -> {} each",
                            pct(eff)
                        )
                    } else {
                        String::new()
                    }
                ),
                &["session", "samples", "predicted loss"],
            );
            for (name, (mut w, n)) in windows {
                let predicted =
                    tuna::tuner::predict_loss_at(&source, &mut query, &mut w, eff)?;
                t.row(vec![
                    name,
                    n.to_string(),
                    match predicted {
                        Some(loss) => pct(loss),
                        None => "(empty window)".into(),
                    },
                ]);
            }
            t.print();
            Ok(())
        }
        None => {
            let spec = spec_from(args, &exp)?;
            args.finish()?;
            if sessions > 0 {
                bail!(
                    "--sessions needs --stream FILE (the predicted mode); the measured \
                     mode runs exactly one (workload, fraction) cell"
                );
            }
            let loss = coordinator::whatif_measured(&spec)?;
            let mut t = Table::new(
                &format!(
                    "what-if (measured): {} at {} fast memory",
                    spec.workload,
                    pct(spec.fm_fraction)
                ),
                &["metric", "value"],
            );
            t.row(vec!["policy".into(), "tpp".into()]);
            t.row(vec!["seed".into(), spec.seed.to_string()]);
            t.row(vec!["intervals".into(), spec.intervals.to_string()]);
            t.row(vec!["perf loss vs fast-only".into(), pct(loss)]);
            t.print();
            Ok(())
        }
    }
}
