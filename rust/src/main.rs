//! `tuna` — the L3 coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!
//! ```text
//! tuna info                             Table 1 + machine model
//! tuna build-db [--configs N] [--out artifacts/perfdb.bin] [--seed S]
//! tuna run  --workload BFS [--fraction 0.9] [--policy tpp|first-touch]
//!           [--intervals N] [--seed S] [--config FILE]
//! tuna tune --workload BFS [--target 0.05] [--period 2.5] [--xla]
//!           [--db artifacts/perfdb.bin] [--artifacts artifacts]
//!           [--intervals N] [--config FILE]
//! tuna sweep [--workloads BFS,SSSP] [--fractions 1.0,0.9,0.8,...]
//!           [--policy tpp,first-touch,memtis,tuna] [--seeds 1,2,3]
//!           [--hot-thrs 2,4] [--threads N] [--intervals N]
//!           [--memtis | --first-touch] [--db artifacts/perfdb.bin]
//!                               parallel grid sweep (Fig. 1 and beyond)
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use tuna::cli::Args;
use tuna::config::ExperimentConfig;
use tuna::coordinator::{self, RunSpec, SweepPolicy, SweepSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::perfdb::native::{NativeNn, NnQuery};
use tuna::report::{pct, Table};
use tuna::runtime::XlaNn;
use tuna::sim::MachineModel;
use tuna::util::human_bytes;
use tuna::workloads::{self, PAGES_PER_PAPER_GB, TABLE1};
use tuna::PAGE_BYTES;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::parse(std::env::args().skip(1), &["xla", "first-touch", "memtis"])?;
    match args.subcommand.clone().as_deref() {
        Some("info") => cmd_info(&mut args),
        Some("build-db") => cmd_build_db(&mut args),
        Some("run") => cmd_run(&mut args),
        Some("tune") => cmd_tune(&mut args),
        Some("sweep") => cmd_sweep(&mut args),
        Some(other) => {
            bail!("unknown subcommand `{other}` (try: info, build-db, run, tune, sweep)")
        }
        None => {
            println!(
                "usage: tuna <info|build-db|run|tune|sweep> [flags]  (see README)"
            );
            Ok(())
        }
    }
}

fn load_exp(args: &mut Args) -> Result<ExperimentConfig> {
    match args.get("config") {
        Some(path) => ExperimentConfig::from_file(std::path::Path::new(&path.to_string())),
        None => Ok(ExperimentConfig::default()),
    }
}

fn spec_from(args: &mut Args, exp: &ExperimentConfig) -> Result<RunSpec> {
    let mut spec = RunSpec::new(&args.get_or("workload", &exp.workload));
    spec.seed = args.get_parse("seed", exp.seed)?;
    spec.intervals = args.get_parse("intervals", exp.intervals)?;
    spec.fm_fraction = args.get_parse("fraction", exp.fm_fraction)?;
    spec.hot_thr = args.get_parse("hot-thr", exp.hot_thr)?;
    spec.machine = exp.machine.clone();
    Ok(spec)
}

fn cmd_info(args: &mut Args) -> Result<()> {
    args.finish()?;
    let mut t = Table::new(
        "Table 1: workloads (paper RSS, scaled pages)",
        &["Workload", "paper RSS", "pages here", "bytes here", "description"],
    );
    for w in TABLE1 {
        let pages = (w.paper_rss_gb * PAGES_PER_PAPER_GB) as u64;
        t.row(vec![
            w.name.to_string(),
            format!("{:.1} G", w.paper_rss_gb),
            pages.to_string(),
            human_bytes(pages * PAGE_BYTES),
            w.description.to_string(),
        ]);
    }
    t.print();
    let m = MachineModel::default();
    println!("\nmachine model (one socket of the paper's testbed):\n{m:#?}");
    Ok(())
}

fn cmd_build_db(args: &mut Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "artifacts/perfdb.bin"));
    let mut params = BuildParams::default();
    params.n_configs = args.get_parse("configs", params.n_configs)?;
    params.seed = args.get_parse("seed", params.seed)?;
    args.finish()?;
    let db = ensure_db(&out, &params)?;
    println!(
        "perfdb ready at {}: {} records x {} fm sizes",
        out.display(),
        db.len(),
        db.fractions.len()
    );
    Ok(())
}

fn cmd_run(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let spec = spec_from(args, &exp)?;
    let first_touch = args.switch("first-touch");
    let memtis = args.switch("memtis");
    args.finish()?;

    let baseline = coordinator::run_fm_only(&spec)?;
    let run = if first_touch {
        coordinator::run_first_touch(&spec)?
    } else if memtis {
        coordinator::run_memtis(&spec)?
    } else {
        coordinator::run_tpp(&spec)?
    };
    let loss = coordinator::overall_loss(&run, &baseline);

    let mut t = Table::new(
        &format!("{} under {} at {} fast memory", spec.workload, run.policy, pct(spec.fm_fraction)),
        &["metric", "value"],
    );
    t.row(vec!["intervals".into(), run.trace.len().to_string()]);
    t.row(vec!["total time".into(), tuna::util::human_ns(run.total_ns as u64)]);
    t.row(vec!["perf loss vs fast-only".into(), pct(loss)]);
    t.row(vec!["promotions".into(), run.total_promoted().to_string()]);
    t.row(vec!["promotion failures".into(), run.total_promote_failed().to_string()]);
    t.row(vec!["demotions".into(), run.total_demoted().to_string()]);
    t.print();
    Ok(())
}

fn cmd_tune(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let spec = spec_from(args, &exp)?;
    let db_path = PathBuf::from(args.get_or("db", &exp.perfdb_path));
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let use_xla = args.switch("xla") || exp.tuna.use_xla;
    let mut tuna_cfg = exp.tuna.clone();
    tuna_cfg.loss_target = args.get_parse("target", tuna_cfg.loss_target)?;
    tuna_cfg.period_s = args.get_parse("period", tuna_cfg.period_s)?;
    args.finish()?;

    let db = Arc::new(ensure_db(&db_path, &BuildParams::default())?);
    let query: Box<dyn NnQuery> = if use_xla {
        Box::new(XlaNn::from_manifest(&artifacts, &db)?)
    } else {
        Box::new(NativeNn::new(&db))
    };

    let baseline = coordinator::run_fm_only(&spec)?;
    let run = coordinator::run_tuna(&spec, db, query, &tuna_cfg)?;
    let loss = coordinator::overall_loss(&run.result, &baseline);

    let mut t = Table::new(
        &format!(
            "Tuna on {} (target {}, period {}s, backend {})",
            spec.workload,
            pct(tuna_cfg.loss_target),
            tuna_cfg.period_s,
            run.backend
        ),
        &["metric", "value"],
    );
    t.row(vec!["decisions".into(), run.decisions.len().to_string()]);
    t.row(vec!["mean FM saving".into(), pct(run.mean_saving())]);
    t.row(vec!["max FM saving".into(), pct(run.max_saving())]);
    t.row(vec!["overall perf loss".into(), pct(loss)]);
    t.row(vec![
        "query path total".into(),
        tuna::util::human_ns(run.decide_ns as u64),
    ]);
    if !run.decisions.is_empty() {
        t.row(vec![
            "query path / decision".into(),
            tuna::util::human_ns((run.decide_ns / run.decisions.len() as u128) as u64),
        ]);
    }
    for (name, v) in &run.vmstat {
        t.row(vec![format!("vmstat {name}"), v.to_string()]);
    }
    t.print();

    // workloads sanity: make sure the chosen workload exists in Table 1
    let known = workloads::ALL_NAMES;
    if !known.iter().any(|n| n.eq_ignore_ascii_case(&spec.workload)) {
        eprintln!("note: `{}` is not a Table 1 workload", spec.workload);
    }
    Ok(())
}

/// Parse a comma-separated list of values.
fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .map(|x| x.trim())
        .filter(|x| !x.is_empty())
        .map(|x| x.parse::<T>().map_err(|e| anyhow::anyhow!("bad list item `{x}`: {e}")))
        .collect()
}

fn cmd_sweep(args: &mut Args) -> Result<()> {
    let exp = load_exp(args)?;
    let default_workload = args.get_or("workload", &exp.workload);
    let workloads: Vec<String> = args
        .get_or("workloads", &default_workload)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    // Singular flags stay accepted as aliases (pre-executor invocations
    // like `tuna sweep --workload BFS --seed 7 --fraction 0.9` keep working).
    let single_fraction =
        args.get_or("fraction", "1.0,0.95,0.895,0.8,0.7,0.5,0.3,0.266");
    let fractions: Vec<f64> = parse_list(&args.get_or("fractions", &single_fraction))?;
    let single_seed = args.get_or("seed", &exp.seed.to_string());
    let seeds: Vec<u64> = parse_list(&args.get_or("seeds", &single_seed))?;
    let single_hot_thr = args.get_or("hot-thr", &exp.hot_thr.to_string());
    let hot_thrs: Vec<u32> = parse_list(&args.get_or("hot-thrs", &single_hot_thr))?;
    let intervals: u32 = args.get_parse("intervals", exp.intervals)?;
    let threads: usize = args.get_parse("threads", 0usize)?;
    // `--memtis` / `--first-touch` are kept as shorthands for `--policy`.
    let memtis = args.switch("memtis");
    let first_touch = args.switch("first-touch");
    let default_policy =
        if memtis { "memtis" } else if first_touch { "first-touch" } else { "tpp" };
    let policies: Vec<SweepPolicy> = args
        .get_or("policy", default_policy)
        .split(',')
        .map(|s| SweepPolicy::parse(s.trim()))
        .collect::<Result<_>>()?;
    let db_path = PathBuf::from(args.get_or("db", &exp.perfdb_path));
    args.finish()?;

    let mut spec = SweepSpec::new(&workloads)
        .with_fractions(fractions)
        .with_seeds(seeds)
        .with_hot_thrs(hot_thrs)
        .with_policies(policies.clone())
        .with_intervals(intervals)
        .with_threads(threads)
        .with_machine(exp.machine.clone());
    if policies.contains(&SweepPolicy::Tuna) {
        let db = Arc::new(ensure_db(&db_path, &BuildParams::default())?);
        spec = spec.with_tuna(db, exp.tuna.clone());
    }

    let res = coordinator::run_sweep(&spec)?;

    let mut t = Table::new(
        &format!(
            "parallel sweep: {} workloads × {} fractions × {} seeds × {} hot-thrs × {} policies = {} cells",
            spec.workloads.len(),
            spec.fractions.len(),
            spec.seeds.len(),
            spec.hot_thrs.len(),
            spec.policies.len(),
            res.len()
        ),
        &["workload", "policy", "seed", "FM size", "perf loss", "saving", "migrations", "failures"],
    );
    for c in &res.cells {
        t.row(vec![
            c.spec.workload.clone(),
            c.spec.policy.name().to_string(),
            c.spec.seed.to_string(),
            pct(c.spec.fm_fraction),
            pct(c.loss),
            pct(c.saving),
            c.result.total_migrations().to_string(),
            c.result.total_promote_failed().to_string(),
        ]);
    }
    t.print();
    println!(
        "\n{} cells in {}; {} baselines computed, {} baseline-cache hits",
        res.len(),
        tuna::util::human_ns(res.wall_ns as u64),
        res.baselines_computed,
        res.baseline_hits
    );
    Ok(())
}
