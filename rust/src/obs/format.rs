//! The durable `TUNAOBS1` journal artifact.
//!
//! Same framing discipline as the store's segments, cell tables and
//! traces: an 8-byte magic, a little-endian wire body, and a trailing
//! CRC32 of the body. The encoding is canonical — metric families are
//! written in `BTreeMap` (sorted-name) order and events in ring order
//! — so `load` → `save` of an existing journal is byte-identical.
//!
//! Body layout (all via [`crate::artifact::wire`]):
//!
//! ```text
//! u64  dropped                     ring drops at capture time
//! u32  n_counters  { str name, u64 value } ...
//! u32  n_gauges    { str name, f64 value } ...
//! u32  n_hists     { str name, u32 n_bounds, f64 bounds...,
//!                    u64 counts[n_bounds+1]..., f64 sum, u64 count } ...
//! u32  n_events    { u64 t_ns, u8 tag, payload } ...
//! ```

use std::path::Path;

use anyhow::{bail, Context};

use super::{Event, EventKind, HistSnapshot, Journal, MetricsSnapshot};
use crate::artifact::wire::{put_f32, put_f64, put_str, put_u32, put_u64, put_u8, Reader};
use crate::perfdb::store::crc32;
use crate::Result;

/// Magic prefix of a journal artifact.
pub const MAGIC: &[u8; 8] = b"TUNAOBS1";

const TAG_WARN: u8 = 0;
const TAG_INTERVAL: u8 = 1;
const TAG_DECISION: u8 = 2;
const TAG_INGEST: u8 = 3;
const TAG_SEG_LOAD: u8 = 4;
const TAG_SEG_EVICT: u8 = 5;
const TAG_SWEEP_CELL: u8 = 6;
/// Interval event carrying the four admission-gate verdict counters.
/// Written only when at least one of them is nonzero; an all-zero
/// interval still encodes as the legacy [`TAG_INTERVAL`], so journals
/// from ungated runs are byte-identical to the pre-admission format
/// (and old journals decode unchanged, with the counters zeroed).
const TAG_INTERVAL_V2: u8 = 7;
/// Decision-outcome record (PR 9): predicted vs realized loss for one
/// decision. Fresh tag — journals written before it exist never carry
/// it, so pre-PR9 artifacts decode unchanged (golden-fixture tested).
const TAG_OUTCOME: u8 = 8;
/// Drift-detector transition (PR 9): armed / retune / cooldown.
const TAG_DRIFT: u8 = 9;
/// Network-ingestion connection accepted (PR 10, `tuna serve --listen`).
/// Fresh tags again: journals written before fleet serving never carry
/// them, so older artifacts decode unchanged.
const TAG_CONN_OPEN: u8 = 10;
/// Network-ingestion connection drained and closed, with totals.
const TAG_CONN_CLOSE: u8 = 11;

fn encode_kind(out: &mut Vec<u8>, kind: &EventKind) {
    match kind {
        EventKind::Warn { site, message } => {
            put_u8(out, TAG_WARN);
            put_str(out, site);
            put_str(out, message);
        }
        EventKind::Interval {
            workload,
            policy,
            interval,
            wall_ns,
            fast_used,
            promoted,
            demoted,
            txn_aborts,
            shadow_free_demotions,
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
        } => {
            let gated = admission_accepted
                + admission_rejected_budget
                + admission_rejected_payoff
                + admission_rejected_cooldown
                > 0;
            put_u8(out, if gated { TAG_INTERVAL_V2 } else { TAG_INTERVAL });
            put_str(out, workload);
            put_str(out, policy);
            put_u32(out, *interval);
            put_f64(out, *wall_ns);
            put_u64(out, *fast_used);
            put_u64(out, *promoted);
            put_u64(out, *demoted);
            put_u64(out, *txn_aborts);
            put_u64(out, *shadow_free_demotions);
            if gated {
                put_u64(out, *admission_accepted);
                put_u64(out, *admission_rejected_budget);
                put_u64(out, *admission_rejected_payoff);
                put_u64(out, *admission_rejected_cooldown);
            }
        }
        EventKind::Decision {
            interval,
            record,
            dist,
            fraction,
            new_fm,
            predicted_loss,
            wm_low,
            wm_high,
        } => {
            put_u8(out, TAG_DECISION);
            put_u32(out, *interval);
            put_u64(out, *record);
            put_f32(out, *dist);
            put_f64(out, *fraction);
            put_u64(out, *new_fm);
            put_f64(out, *predicted_loss);
            put_u64(out, *wm_low);
            put_u64(out, *wm_high);
        }
        EventKind::IngestBatch {
            lines,
            samples,
            decisions,
            sessions_opened,
            sessions_closed,
        } => {
            put_u8(out, TAG_INGEST);
            put_u64(out, *lines);
            put_u64(out, *samples);
            put_u64(out, *decisions);
            put_u64(out, *sessions_opened);
            put_u64(out, *sessions_closed);
        }
        EventKind::SegmentLoad {
            segment,
            records,
            crc_checked,
            wall_ns,
        } => {
            put_u8(out, TAG_SEG_LOAD);
            put_u32(out, *segment);
            put_u64(out, *records);
            put_u8(out, u8::from(*crc_checked));
            put_u64(out, *wall_ns);
        }
        EventKind::SegmentEvict { segment } => {
            put_u8(out, TAG_SEG_EVICT);
            put_u32(out, *segment);
        }
        EventKind::SweepCell {
            workload,
            policy,
            fraction,
            seed,
            wall_ns,
        } => {
            put_u8(out, TAG_SWEEP_CELL);
            put_str(out, workload);
            put_str(out, policy);
            put_f64(out, *fraction);
            put_u64(out, *seed);
            put_u64(out, *wall_ns);
        }
        EventKind::Outcome {
            session,
            decision_interval,
            predicted,
            realized,
            abs_err,
        } => {
            put_u8(out, TAG_OUTCOME);
            put_str(out, session);
            put_u32(out, *decision_interval);
            put_f64(out, *predicted);
            put_f64(out, *realized);
            put_f64(out, *abs_err);
        }
        EventKind::Drift {
            session,
            interval,
            ewma_err,
            action,
        } => {
            put_u8(out, TAG_DRIFT);
            put_str(out, session);
            put_u32(out, *interval);
            put_f64(out, *ewma_err);
            put_str(out, action);
        }
        EventKind::ConnOpen { peer } => {
            put_u8(out, TAG_CONN_OPEN);
            put_str(out, peer);
        }
        EventKind::ConnClose {
            peer,
            sessions,
            samples,
            decisions,
        } => {
            put_u8(out, TAG_CONN_CLOSE);
            put_str(out, peer);
            put_u64(out, *sessions);
            put_u64(out, *samples);
            put_u64(out, *decisions);
        }
    }
}

fn decode_kind(r: &mut Reader<'_>) -> Result<EventKind> {
    let tag = r.u8()?;
    Ok(match tag {
        TAG_WARN => EventKind::Warn {
            site: r.str()?,
            message: r.str()?,
        },
        TAG_INTERVAL | TAG_INTERVAL_V2 => EventKind::Interval {
            workload: r.str()?,
            policy: r.str()?,
            interval: r.u32()?,
            wall_ns: r.f64()?,
            fast_used: r.u64()?,
            promoted: r.u64()?,
            demoted: r.u64()?,
            txn_aborts: r.u64()?,
            shadow_free_demotions: r.u64()?,
            admission_accepted: if tag == TAG_INTERVAL_V2 { r.u64()? } else { 0 },
            admission_rejected_budget: if tag == TAG_INTERVAL_V2 { r.u64()? } else { 0 },
            admission_rejected_payoff: if tag == TAG_INTERVAL_V2 { r.u64()? } else { 0 },
            admission_rejected_cooldown: if tag == TAG_INTERVAL_V2 { r.u64()? } else { 0 },
        },
        TAG_DECISION => EventKind::Decision {
            interval: r.u32()?,
            record: r.u64()?,
            dist: r.f32()?,
            fraction: r.f64()?,
            new_fm: r.u64()?,
            predicted_loss: r.f64()?,
            wm_low: r.u64()?,
            wm_high: r.u64()?,
        },
        TAG_INGEST => EventKind::IngestBatch {
            lines: r.u64()?,
            samples: r.u64()?,
            decisions: r.u64()?,
            sessions_opened: r.u64()?,
            sessions_closed: r.u64()?,
        },
        TAG_SEG_LOAD => EventKind::SegmentLoad {
            segment: r.u32()?,
            records: r.u64()?,
            crc_checked: r.u8()? != 0,
            wall_ns: r.u64()?,
        },
        TAG_SEG_EVICT => EventKind::SegmentEvict { segment: r.u32()? },
        TAG_SWEEP_CELL => EventKind::SweepCell {
            workload: r.str()?,
            policy: r.str()?,
            fraction: r.f64()?,
            seed: r.u64()?,
            wall_ns: r.u64()?,
        },
        TAG_OUTCOME => EventKind::Outcome {
            session: r.str()?,
            decision_interval: r.u32()?,
            predicted: r.f64()?,
            realized: r.f64()?,
            abs_err: r.f64()?,
        },
        TAG_DRIFT => EventKind::Drift {
            session: r.str()?,
            interval: r.u32()?,
            ewma_err: r.f64()?,
            action: r.str()?,
        },
        TAG_CONN_OPEN => EventKind::ConnOpen { peer: r.str()? },
        TAG_CONN_CLOSE => EventKind::ConnClose {
            peer: r.str()?,
            sessions: r.u64()?,
            samples: r.u64()?,
            decisions: r.u64()?,
        },
        other => bail!("unknown obs event tag {other} in journal"),
    })
}

impl Journal {
    /// Canonical `TUNAOBS1` byte encoding.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        put_u64(&mut body, self.dropped);
        put_u32(&mut body, self.metrics.counters.len() as u32);
        for (name, &v) in &self.metrics.counters {
            put_str(&mut body, name);
            put_u64(&mut body, v);
        }
        put_u32(&mut body, self.metrics.gauges.len() as u32);
        for (name, &v) in &self.metrics.gauges {
            put_str(&mut body, name);
            put_f64(&mut body, v);
        }
        put_u32(&mut body, self.metrics.hists.len() as u32);
        for (name, h) in &self.metrics.hists {
            put_str(&mut body, name);
            put_u32(&mut body, h.bounds.len() as u32);
            for &b in &h.bounds {
                put_f64(&mut body, b);
            }
            for &c in &h.counts {
                put_u64(&mut body, c);
            }
            put_f64(&mut body, h.sum);
            put_u64(&mut body, h.count);
        }
        put_u32(&mut body, self.events.len() as u32);
        for ev in &self.events {
            put_u64(&mut body, ev.t_ns);
            encode_kind(&mut body, &ev.kind);
        }
        let mut out = Vec::with_capacity(MAGIC.len() + body.len() + 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out
    }

    /// Parse a `TUNAOBS1` byte stream (magic + CRC validated).
    pub fn decode(data: &[u8]) -> Result<Journal> {
        if data.len() < MAGIC.len() + 4 || &data[..MAGIC.len()] != MAGIC {
            bail!("not a TUNAOBS1 journal (bad magic or truncated)");
        }
        let body = &data[MAGIC.len()..data.len() - 4];
        let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
        let actual = crc32(body);
        if stored != actual {
            bail!("obs journal CRC mismatch: stored {stored:#010x}, computed {actual:#010x}");
        }
        let mut r = Reader::new(body);
        let dropped = r.u64()?;
        let mut metrics = MetricsSnapshot::default();
        for _ in 0..r.u32()? {
            let name = r.str()?;
            metrics.counters.insert(name, r.u64()?);
        }
        for _ in 0..r.u32()? {
            let name = r.str()?;
            metrics.gauges.insert(name, r.f64()?);
        }
        for _ in 0..r.u32()? {
            let name = r.str()?;
            let n_bounds = r.u32()? as usize;
            if n_bounds > 1 << 16 {
                bail!("implausible histogram bound count {n_bounds} in journal");
            }
            let mut h = HistSnapshot::default();
            for _ in 0..n_bounds {
                h.bounds.push(r.f64()?);
            }
            for _ in 0..n_bounds + 1 {
                h.counts.push(r.u64()?);
            }
            h.sum = r.f64()?;
            h.count = r.u64()?;
            metrics.hists.insert(name, h);
        }
        let n_events = r.u32()? as usize;
        let mut events = Vec::with_capacity(n_events.min(1 << 20));
        for _ in 0..n_events {
            let t_ns = r.u64()?;
            events.push(Event {
                t_ns,
                kind: decode_kind(&mut r)?,
            });
        }
        r.done()?;
        Ok(Journal {
            dropped,
            metrics,
            events,
        })
    }

    /// Atomically persist the journal at `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::artifact::write_atomic(path, &self.encode())
            .with_context(|| format!("writing obs journal {}", path.display()))
    }

    /// Load a journal artifact from `path`.
    pub fn load(path: &Path) -> Result<Journal> {
        let data = std::fs::read(path)
            .with_context(|| format!("reading obs journal {}", path.display()))?;
        Self::decode(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Recorder;

    fn sample_journal() -> Journal {
        let r = Recorder::enabled(16);
        r.count("engine_intervals_total", 4);
        r.gauge("perfdb_resident_segments", 2.0);
        r.observe("tuner_decision_fraction", super::super::FRACTION_BUCKETS, 0.8);
        r.record(EventKind::Interval {
            workload: "BFS".into(),
            policy: "tpp".into(),
            interval: 1,
            wall_ns: 1.5e6,
            fast_used: 1000,
            promoted: 12,
            demoted: 3,
            txn_aborts: 1,
            shadow_free_demotions: 2,
            // all-zero verdicts: this event must take the legacy
            // TAG_INTERVAL encoding (byte-stability below depends on it)
            admission_accepted: 0,
            admission_rejected_budget: 0,
            admission_rejected_payoff: 0,
            admission_rejected_cooldown: 0,
        });
        r.record(EventKind::Decision {
            interval: 2,
            record: 17,
            dist: 0.25,
            fraction: 0.8,
            new_fm: 4096,
            predicted_loss: 0.031,
            wm_low: 64,
            wm_high: 96,
        });
        r.record(EventKind::IngestBatch {
            lines: 10,
            samples: 8,
            decisions: 1,
            sessions_opened: 1,
            sessions_closed: 1,
        });
        r.record(EventKind::SegmentLoad {
            segment: 3,
            records: 256,
            crc_checked: true,
            wall_ns: 42_000,
        });
        r.record(EventKind::SegmentEvict { segment: 3 });
        r.record(EventKind::SweepCell {
            workload: "kv-drift".into(),
            policy: "tpp-nomad".into(),
            fraction: 0.6,
            seed: 7,
            wall_ns: 9_000_000,
        });
        r.record(EventKind::Outcome {
            session: "kv-drift@7".into(),
            decision_interval: 25,
            predicted: 0.031,
            realized: 0.044,
            abs_err: 0.013,
        });
        r.record(EventKind::Drift {
            session: "kv-drift@7".into(),
            interval: 50,
            ewma_err: 0.013,
            action: "armed".into(),
        });
        r.record(EventKind::ConnOpen { peer: "127.0.0.1:40412".into() });
        r.record(EventKind::ConnClose {
            peer: "127.0.0.1:40412".into(),
            sessions: 2,
            samples: 120,
            decisions: 8,
        });
        r.warn("fmt.test", "synthetic warning");
        r.journal()
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let j = sample_journal();
        let decoded = Journal::decode(&j.encode()).unwrap();
        assert_eq!(decoded, j);
    }

    #[test]
    fn reencode_is_byte_stable() {
        let bytes = sample_journal().encode();
        let reencoded = Journal::decode(&bytes).unwrap().encode();
        assert_eq!(reencoded, bytes);
    }

    /// A gated interval (nonzero admission verdicts) takes the V2 tag
    /// and round-trips every counter; the legacy-tag event in the sample
    /// journal proves all-zero intervals stay on the old encoding.
    #[test]
    fn gated_intervals_roundtrip_via_the_v2_tag() {
        let r = Recorder::enabled(4);
        let ev = EventKind::Interval {
            workload: "kv-drift".into(),
            policy: "tpp-gated".into(),
            interval: 7,
            wall_ns: 2.5e6,
            fast_used: 512,
            promoted: 9,
            demoted: 4,
            txn_aborts: 0,
            shadow_free_demotions: 0,
            admission_accepted: 9,
            admission_rejected_budget: 3,
            admission_rejected_payoff: 11,
            admission_rejected_cooldown: 5,
        };
        r.record(ev.clone());
        let j = r.journal();
        let decoded = Journal::decode(&j.encode()).unwrap();
        assert_eq!(decoded.events.len(), 1);
        assert_eq!(decoded.events[0].kind, ev);
        // and re-encoding the decoded journal is still byte-stable
        assert_eq!(decoded.encode(), j.encode());
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = sample_journal().encode();
        assert!(Journal::decode(&bytes[..bytes.len() - 2]).is_err(), "truncation");
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = Journal::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("CRC mismatch"), "got: {err}");
        assert!(
            Journal::decode(b"NOTOBS00xxxxxxxx").is_err(),
            "bad magic must fail"
        );
    }
}
