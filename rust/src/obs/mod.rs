//! First-class observability: a metrics registry, a structured event
//! journal, and the `obs::Recorder` handle threaded through the engine,
//! tuner, service, sweep executor and lazy perf-DB.
//!
//! Design constraints, in order:
//!
//! 1. **The recorder observes, never perturbs.** With observability
//!    enabled at any ring size, decisions, sweep cells and RunResult
//!    digests are bit-identical to a run with it disabled. Nothing in
//!    this module feeds back into simulation or tuning state.
//! 2. **Zero cost when disabled.** A disabled [`Recorder`] is a `None`;
//!    every hot-path hook is one pointer check. Event payloads that
//!    would allocate are built behind [`Recorder::record_with`] so the
//!    closure never runs when disabled.
//! 3. **No cross-thread contention.** Counters and histograms live in
//!    per-thread shards (registered once per thread, merged only at
//!    snapshot time), so the sweep pool and the service aggregation
//!    thread never serialize on a metrics lock.
//!
//! The journal is a bounded ring ([`Recorder::enabled`] picks the
//! capacity): when full, the oldest event is dropped and the drop is
//! counted, surfaced as the `obs_journal_dropped_total` metric and the
//! `dropped` field of the persisted artifact. [`Journal`] round-trips
//! through the durable CRC'd `TUNAOBS1` format (see [`format`]) with a
//! canonical encoding, so dump → load → re-dump is byte-stable.

pub mod format;
pub mod render;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::Result;

/// Default journal ring capacity used by the CLI flags.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

/// Histogram bounds for wall-clock / modeled durations in nanoseconds.
pub const NS_BUCKETS: &[f64] = &[1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10];

/// Histogram bounds for per-interval page-migration volumes.
pub const PAGES_BUCKETS: &[f64] = &[
    0.0, 1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0,
];

/// Histogram bounds for fast-memory fractions and residency ratios.
pub const FRACTION_BUCKETS: &[f64] = &[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0];

/// Histogram bounds for predicted performance loss.
pub const LOSS_BUCKETS: &[f64] = &[0.0, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0];

/// Histogram bounds for *signed* prediction error
/// (realized − predicted loss): negative buckets catch
/// over-predictions, positive ones under-predictions.
pub const ERR_BUCKETS: &[f64] = &[
    -0.5, -0.2, -0.1, -0.05, -0.02, 0.0, 0.02, 0.05, 0.1, 0.2, 0.5,
];

/// One journal entry: a monotonic timestamp (ns since the recorder was
/// created) plus the structured payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub t_ns: u64,
    pub kind: EventKind,
}

/// Structured event payloads, one variant per instrumented site.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A diagnostic that was also emitted on stderr.
    Warn { site: String, message: String },
    /// One engine interval boundary, with the interval's migration
    /// transaction outcomes (promotions, demotions, shadow-free
    /// demotions and aborts from the non-exclusive model) and the
    /// admission-gate verdicts (all zero for ungated runs).
    Interval {
        workload: String,
        policy: String,
        interval: u32,
        wall_ns: f64,
        fast_used: u64,
        promoted: u64,
        demoted: u64,
        txn_aborts: u64,
        shadow_free_demotions: u64,
        admission_accepted: u64,
        admission_rejected_budget: u64,
        admission_rejected_payoff: u64,
        admission_rejected_cooldown: u64,
    },
    /// One tuner decision: the kNN inputs and the chosen watermarks.
    Decision {
        interval: u32,
        record: u64,
        dist: f32,
        fraction: f64,
        new_fm: u64,
        predicted_loss: f64,
        wm_low: u64,
        wm_high: u64,
    },
    /// One `Ingestor::ingest` batch (a file or stdin stream).
    IngestBatch {
        lines: u64,
        samples: u64,
        decisions: u64,
        sessions_opened: u64,
        sessions_closed: u64,
    },
    /// A lazy perf-DB segment faulted in (CRC-checked on first touch).
    SegmentLoad {
        segment: u32,
        records: u64,
        crc_checked: bool,
        wall_ns: u64,
    },
    /// A lazy perf-DB segment evicted to honor the residency limit.
    SegmentEvict { segment: u32 },
    /// One sweep cell finished (wall time measured around the cell run).
    SweepCell {
        workload: String,
        policy: String,
        fraction: f64,
        seed: u64,
        wall_ns: u64,
    },
    /// One decision's outcome closed: the predicted loss joined to the
    /// loss the session then actually realized over the decision
    /// period (see `outcome::OutcomeTracker`).
    Outcome {
        session: String,
        decision_interval: u32,
        predicted: f64,
        realized: f64,
        abs_err: f64,
    },
    /// The drift detector left the stable state at a decision boundary
    /// (`action` is `armed`, `retune` or `cooldown`).
    Drift {
        session: String,
        interval: u32,
        ewma_err: f64,
        action: String,
    },
    /// A `tuna serve --listen` client connected (network ingestion).
    ConnOpen { peer: String },
    /// A network ingestion connection drained and closed, with its
    /// lifetime totals (mirrors the per-connection `IngestBatch`).
    ConnClose {
        peer: String,
        sessions: u64,
        samples: u64,
        decisions: u64,
    },
}

impl EventKind {
    /// Short stable name used by `tuna obs dump|summary`.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Warn { .. } => "warn",
            EventKind::Interval { .. } => "interval",
            EventKind::Decision { .. } => "decision",
            EventKind::IngestBatch { .. } => "ingest-batch",
            EventKind::SegmentLoad { .. } => "segment-load",
            EventKind::SegmentEvict { .. } => "segment-evict",
            EventKind::SweepCell { .. } => "sweep-cell",
            EventKind::Outcome { .. } => "outcome",
            EventKind::Drift { .. } => "drift",
            EventKind::ConnOpen { .. } => "conn-open",
            EventKind::ConnClose { .. } => "conn-close",
        }
    }

    /// The subsystem ("phase") the event belongs to.
    pub fn phase(&self) -> &'static str {
        match self {
            EventKind::Warn { .. } => "warn",
            EventKind::Interval { .. } => "engine",
            EventKind::Decision { .. } => "tuner",
            EventKind::IngestBatch { .. }
            | EventKind::ConnOpen { .. }
            | EventKind::ConnClose { .. } => "service",
            EventKind::SegmentLoad { .. } | EventKind::SegmentEvict { .. } => "perfdb",
            EventKind::SweepCell { .. } => "sweep",
            EventKind::Outcome { .. } | EventKind::Drift { .. } => "outcome",
        }
    }

    /// Busy time the event accounts for, where it carries one. Interval
    /// events report *modeled* nanoseconds; segment loads and sweep
    /// cells report measured wall time.
    pub fn busy_ns(&self) -> u64 {
        match self {
            EventKind::Interval { wall_ns, .. } => *wall_ns as u64,
            EventKind::SegmentLoad { wall_ns, .. } => *wall_ns,
            EventKind::SweepCell { wall_ns, .. } => *wall_ns,
            _ => 0,
        }
    }
}

/// A merged point-in-time view of the metrics registry. `BTreeMap`
/// keys give the canonical (sorted) order that both the Prometheus
/// exposition and the `TUNAOBS1` encoding rely on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

/// A merged fixed-bucket histogram; `counts` has one slot per bound
/// plus a final `+Inf` overflow slot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl MetricsSnapshot {
    /// Counter value, zero when absent.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Prometheus text exposition. Deterministic: families sorted by
    /// name, histogram buckets in bound order.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        let mut typed_gauges: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
        for (name, v) in &self.gauges {
            // a labeled gauge key is `family{label="…"}`: emit one TYPE
            // line per family (unlabeled keys are their own family, so
            // label-free expositions are byte-identical to before)
            let family = name.split('{').next().unwrap_or(name);
            if typed_gauges.insert(family) {
                out.push_str(&format!("# TYPE {family} gauge\n"));
            }
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, h) in &self.hists {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, c) in h.counts.iter().enumerate() {
                cum += c;
                let le = match h.bounds.get(i) {
                    Some(b) => format!("{b}"),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

/// The loadable/persistable journal: the ring contents at capture
/// time, the drop count, and a metrics snapshot taken alongside.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Journal {
    pub dropped: u64,
    pub metrics: MetricsSnapshot,
    pub events: Vec<Event>,
}

/// Per-thread metrics shard. Each thread that touches a registry gets
/// its own shard; the mutexes below are uncontended in steady state
/// (only the owning thread locks them, except during a snapshot merge).
#[derive(Default)]
struct Shard {
    counters: Mutex<HashMap<&'static str, u64>>,
    hists: Mutex<HashMap<&'static str, Hist>>,
}

#[derive(Clone)]
struct Hist {
    bounds: &'static [f64],
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

struct Ring {
    cap: usize,
    events: VecDeque<Event>,
    dropped: u64,
}

struct Inner {
    id: u64,
    epoch: Instant,
    shards: Mutex<Vec<Arc<Shard>>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    ring: Mutex<Ring>,
}

static NEXT_REGISTRY_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// This thread's shard per registry id. Entries outlive dropped
    /// registries (bounded by recorders created on this thread), but
    /// the registry holds the authoritative `Arc` list for merging.
    static LOCAL_SHARDS: RefCell<HashMap<u64, Arc<Shard>>> = RefCell::new(HashMap::new());
}

/// The observability handle. Cheap to clone (an `Option<Arc>`); the
/// default / [`Recorder::disabled`] form is a no-op on every hook.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<Inner>>,
}

impl fmt::Debug for Recorder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Recorder")
            .field("enabled", &self.is_enabled())
            .finish()
    }
}

impl Recorder {
    /// A recorder on which every hook is a no-op (same as `default()`).
    pub fn disabled() -> Self {
        Recorder { inner: None }
    }

    /// An active recorder with a journal ring of `ring_capacity`
    /// events (clamped to at least 1).
    pub fn enabled(ring_capacity: usize) -> Self {
        let cap = ring_capacity.max(1);
        Recorder {
            inner: Some(Arc::new(Inner {
                id: NEXT_REGISTRY_ID.fetch_add(1, Ordering::Relaxed),
                epoch: Instant::now(),
                shards: Mutex::new(Vec::new()),
                gauges: Mutex::new(BTreeMap::new()),
                ring: Mutex::new(Ring {
                    cap,
                    events: VecDeque::with_capacity(cap.min(1024)),
                    dropped: 0,
                }),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn with_shard<R>(&self, f: impl FnOnce(&Shard) -> R) -> Option<R> {
        let inner = self.inner.as_ref()?;
        let shard = LOCAL_SHARDS.with(|m| {
            m.borrow_mut()
                .entry(inner.id)
                .or_insert_with(|| {
                    let s = Arc::new(Shard::default());
                    inner.shards.lock().unwrap().push(s.clone());
                    s
                })
                .clone()
        });
        Some(f(&shard))
    }

    /// Add `delta` to the named counter.
    pub fn count(&self, name: &'static str, delta: u64) {
        self.with_shard(|s| {
            *s.counters.lock().unwrap().entry(name).or_insert(0) += delta;
        });
    }

    /// Set the named gauge to `value` (gauges are registry-central:
    /// last writer wins, which is what "current resident segments"
    /// style values want).
    pub fn gauge(&self, name: &'static str, value: f64) {
        if let Some(inner) = &self.inner {
            inner.gauges.lock().unwrap().insert(name.to_string(), value);
        }
    }

    /// Set one time series of a labeled gauge family: the stored key is
    /// `name{labels}` (e.g. `service_worker_sessions{worker="3"}`).
    /// Labels must be a well-formed `key="value"` list — the exposition
    /// and the `TUNAOBS1` encoding store the key verbatim, and
    /// [`MetricsSnapshot::render_prometheus`] groups every series of a
    /// family under one `# TYPE` line.
    pub fn gauge_labeled(&self, name: &'static str, labels: &str, value: f64) {
        if let Some(inner) = &self.inner {
            inner
                .gauges
                .lock()
                .unwrap()
                .insert(format!("{name}{{{labels}}}"), value);
        }
    }

    /// Record `value` into the named fixed-bucket histogram. The first
    /// observation on a thread fixes the bounds; all sites for one
    /// name must pass the same `bounds` slice.
    pub fn observe(&self, name: &'static str, bounds: &'static [f64], value: f64) {
        self.with_shard(|s| {
            let mut hists = s.hists.lock().unwrap();
            let h = hists.entry(name).or_insert_with(|| Hist {
                bounds,
                counts: vec![0; bounds.len() + 1],
                sum: 0.0,
                count: 0,
            });
            let slot = h
                .bounds
                .iter()
                .position(|&b| value <= b)
                .unwrap_or(h.bounds.len());
            h.counts[slot] += 1;
            h.sum += value;
            h.count += 1;
        });
    }

    /// Append an event to the journal ring (oldest dropped when full).
    pub fn record(&self, kind: EventKind) {
        if let Some(inner) = &self.inner {
            let t_ns = inner.epoch.elapsed().as_nanos() as u64;
            let mut ring = inner.ring.lock().unwrap();
            if ring.events.len() == ring.cap {
                ring.events.pop_front();
                ring.dropped += 1;
            }
            ring.events.push_back(Event { t_ns, kind });
        }
    }

    /// Like [`Recorder::record`], but the payload closure only runs
    /// when the recorder is enabled — use for events whose payload
    /// allocates.
    pub fn record_with(&self, kind: impl FnOnce() -> EventKind) {
        if self.is_enabled() {
            self.record(kind());
        }
    }

    /// Structured warning: always emitted on stderr as
    /// `warning: <message>` (so CLI diagnostics are unchanged whether
    /// or not observability is on); when enabled, additionally counted
    /// in `obs_warn_total` and journaled as a [`EventKind::Warn`].
    pub fn warn(&self, site: &str, message: &str) {
        eprintln!("warning: {message}");
        self.warn_event(site, message);
    }

    /// The structured half of [`Recorder::warn`], without the stderr
    /// line — for call sites that print their own diagnostic verbatim
    /// (the runtime tests' `skipping: …` lines keep their historical
    /// format) but still want the counter + journal event.
    pub fn warn_event(&self, site: &str, message: &str) {
        if self.is_enabled() {
            self.count("obs_warn_total", 1);
            self.record(EventKind::Warn {
                site: site.to_string(),
                message: message.to_string(),
            });
        }
    }

    /// Merge all per-thread shards plus gauges into one snapshot.
    /// Empty when disabled. The journal drop counter is surfaced here
    /// as `obs_journal_dropped_total`.
    ///
    /// Deterministic by construction: counters and bucket counts are
    /// integer sums (commutative in any order), and each histogram's
    /// floating-point `sum` is folded over its per-shard partial sums
    /// in `total_cmp` order — so the rendered exposition is
    /// byte-identical no matter which order threads registered their
    /// shards in.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        let Some(inner) = &self.inner else {
            return snap;
        };
        let mut hist_sums: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for shard in inner.shards.lock().unwrap().iter() {
            for (&name, &v) in shard.counters.lock().unwrap().iter() {
                *snap.counters.entry(name.to_string()).or_insert(0) += v;
            }
            for (&name, h) in shard.hists.lock().unwrap().iter() {
                let e = snap.hists.entry(name.to_string()).or_insert_with(|| HistSnapshot {
                    bounds: h.bounds.to_vec(),
                    counts: vec![0; h.bounds.len() + 1],
                    sum: 0.0,
                    count: 0,
                });
                for (acc, &c) in e.counts.iter_mut().zip(&h.counts) {
                    *acc += c;
                }
                hist_sums.entry(name.to_string()).or_default().push(h.sum);
                e.count += h.count;
            }
        }
        for (name, mut sums) in hist_sums {
            sums.sort_by(|a, b| a.total_cmp(b));
            if let Some(e) = snap.hists.get_mut(&name) {
                e.sum = sums.iter().sum();
            }
        }
        for (name, &v) in inner.gauges.lock().unwrap().iter() {
            snap.gauges.insert(name.clone(), v);
        }
        let dropped = inner.ring.lock().unwrap().dropped;
        *snap
            .counters
            .entry("obs_journal_dropped_total".to_string())
            .or_insert(0) += dropped;
        snap
    }

    /// Capture the journal: current ring contents (oldest first), the
    /// drop count, and a metrics snapshot. Empty when disabled.
    pub fn journal(&self) -> Journal {
        let metrics = self.snapshot();
        let Some(inner) = &self.inner else {
            return Journal::default();
        };
        let ring = inner.ring.lock().unwrap();
        Journal {
            dropped: ring.dropped,
            metrics,
            events: ring.events.iter().cloned().collect(),
        }
    }

    /// Write the Prometheus exposition of [`Recorder::snapshot`] to
    /// `path` (atomically). No-op files are still written when the
    /// recorder is disabled so callers don't have to special-case.
    pub fn write_metrics(&self, path: &Path) -> Result<()> {
        crate::artifact::write_atomic(path, self.snapshot().render_prometheus().as_bytes())
    }

    /// Persist the journal as a durable `TUNAOBS1` artifact at `path`.
    pub fn write_journal(&self, path: &Path) -> Result<()> {
        self.journal().save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let r = Recorder::disabled();
        r.count("x_total", 3);
        r.gauge("g", 1.0);
        r.observe("h", NS_BUCKETS, 5.0);
        r.record(EventKind::SegmentEvict { segment: 1 });
        let mut ran = false;
        r.record_with(|| {
            ran = true;
            EventKind::SegmentEvict { segment: 2 }
        });
        assert!(!ran, "record_with closure must not run when disabled");
        assert_eq!(r.snapshot(), MetricsSnapshot::default());
        assert_eq!(r.journal(), Journal::default());
    }

    #[test]
    fn counters_merge_across_threads() {
        let r = Recorder::enabled(16);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let r = r.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        r.count("t_total", 1);
                        r.observe("t_hist", PAGES_BUCKETS, 3.0);
                    }
                });
            }
        });
        r.count("t_total", 5);
        let snap = r.snapshot();
        assert_eq!(snap.counter("t_total"), 405);
        let h = &snap.hists["t_hist"];
        assert_eq!(h.count, 400);
        assert_eq!(h.sum, 1200.0);
        // value 3.0 lands in the `le 4` bucket (index 2 of PAGES_BUCKETS)
        assert_eq!(h.counts[2], 400);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let r = Recorder::enabled(3);
        for i in 0..8u32 {
            r.record(EventKind::SegmentEvict { segment: i });
        }
        let j = r.journal();
        assert_eq!(j.dropped, 5);
        let kept: Vec<u32> = j
            .events
            .iter()
            .map(|e| match e.kind {
                EventKind::SegmentEvict { segment } => segment,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(kept, vec![5, 6, 7], "oldest events must be dropped first");
        assert_eq!(j.metrics.counter("obs_journal_dropped_total"), 5);
    }

    #[test]
    fn warn_counts_and_journals() {
        let r = Recorder::enabled(8);
        r.warn("test.site", "something odd");
        let j = r.journal();
        assert_eq!(j.metrics.counter("obs_warn_total"), 1);
        assert!(matches!(
            &j.events[0].kind,
            EventKind::Warn { site, message }
                if site == "test.site" && message == "something odd"
        ));
    }

    #[test]
    fn snapshot_bytes_are_identical_across_shard_registration_order() {
        // Three shards whose histogram partial sums are chosen so a
        // naive registration-order fold gives different f64 results:
        // (1e16 + 1) + (-1e16) == 0 but (1e16 + (-1e16)) + 1 == 1.
        // Threads are joined one at a time so each ordering's shard
        // registration sequence is exactly the value sequence.
        let build = |values: &[f64]| {
            let r = Recorder::enabled(4);
            for &v in values {
                let r2 = r.clone();
                std::thread::spawn(move || {
                    r2.observe("order_hist", NS_BUCKETS, v);
                    r2.count("order_total", 1);
                })
                .join()
                .unwrap();
            }
            r
        };
        let a = build(&[1e16, 1.0, -1e16]);
        let b = build(&[1e16, -1e16, 1.0]);
        assert_eq!(a.snapshot(), b.snapshot());
        assert_eq!(
            a.snapshot().render_prometheus(),
            b.snapshot().render_prometheus(),
            "exposition must be byte-identical regardless of shard registration order"
        );
        // And two snapshots of one unchanged registry render the same
        // bytes (determinism within a process, not just across runs).
        assert_eq!(
            a.snapshot().render_prometheus(),
            a.snapshot().render_prometheus()
        );
    }

    #[test]
    fn warn_event_counts_without_duplicating_stderr_state() {
        let r = Recorder::enabled(8);
        r.warn_event("runtime.artifacts", "skipping: run `make artifacts` first");
        let j = r.journal();
        assert_eq!(j.metrics.counter("obs_warn_total"), 1);
        assert!(matches!(
            &j.events[0].kind,
            EventKind::Warn { site, .. } if site == "runtime.artifacts"
        ));
    }

    #[test]
    fn labeled_gauges_share_one_type_line_per_family() {
        let r = Recorder::enabled(4);
        r.gauge_labeled("service_worker_sessions", "worker=\"0\"", 5.0);
        r.gauge_labeled("service_worker_sessions", "worker=\"1\"", 3.0);
        r.gauge("service_total", 8.0);
        let text = r.snapshot().render_prometheus();
        assert_eq!(
            text.matches("# TYPE service_worker_sessions gauge").count(),
            1,
            "one TYPE line per family, not per series: {text}"
        );
        assert!(text.contains("service_worker_sessions{worker=\"0\"} 5\n"));
        assert!(text.contains("service_worker_sessions{worker=\"1\"} 3\n"));
        assert!(text.contains("# TYPE service_total gauge\nservice_total 8\n"));
        // last-writer-wins per series, independently per label set
        r.gauge_labeled("service_worker_sessions", "worker=\"1\"", 4.0);
        assert!(r
            .snapshot()
            .render_prometheus()
            .contains("service_worker_sessions{worker=\"1\"} 4\n"));
    }

    #[test]
    fn prometheus_exposition_is_sorted_and_cumulative() {
        let r = Recorder::enabled(4);
        r.count("b_total", 2);
        r.count("a_total", 1);
        r.gauge("g_now", 1.5);
        r.observe("h_ns", &[1.0, 10.0], 0.5);
        r.observe("h_ns", &[1.0, 10.0], 5.0);
        r.observe("h_ns", &[1.0, 10.0], 50.0);
        let text = r.snapshot().render_prometheus();
        let a = text.find("a_total 1").unwrap();
        let b = text.find("b_total 2").unwrap();
        assert!(a < b, "families must be name-sorted");
        assert!(text.contains("# TYPE g_now gauge\ng_now 1.5\n"));
        assert!(text.contains("h_ns_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("h_ns_bucket{le=\"10\"} 2\n"));
        assert!(text.contains("h_ns_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("h_ns_sum 55.5\n"));
        assert!(text.contains("h_ns_count 3\n"));
        assert!(text.contains("obs_journal_dropped_total 0\n"));
    }
}
