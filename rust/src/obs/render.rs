//! Human renderings of [`Journal`] artifacts for the `tuna obs`
//! verbs: `dump` (every event + the full exposition), `summary`
//! (per-phase time breakdown, decision timeline, histograms), and
//! `diff` (metric families of two journals side by side).

use std::collections::BTreeSet;

use super::{Event, EventKind, HistSnapshot, Journal};
use crate::report::{ascii_series, pct, Table};
use crate::util::human_ns;

fn event_line(ev: &Event) -> String {
    let body = match &ev.kind {
        EventKind::Warn { site, message } => format!("site={site} {message}"),
        EventKind::Interval {
            workload,
            policy,
            interval,
            wall_ns,
            fast_used,
            promoted,
            demoted,
            txn_aborts,
            shadow_free_demotions,
            admission_accepted,
            admission_rejected_budget,
            admission_rejected_payoff,
            admission_rejected_cooldown,
        } => {
            let mut line = format!(
                "{workload}/{policy} interval={interval} wall={} fast_used={fast_used} \
                 promoted={promoted} demoted={demoted} aborts={txn_aborts} \
                 shadow_free={shadow_free_demotions}",
                human_ns(*wall_ns as u64)
            );
            // ungated intervals keep their pre-admission rendering
            if admission_accepted
                + admission_rejected_budget
                + admission_rejected_payoff
                + admission_rejected_cooldown
                > 0
            {
                line.push_str(&format!(
                    " adm_ok={admission_accepted} adm_budget={admission_rejected_budget} \
                     adm_payoff={admission_rejected_payoff} \
                     adm_cooldown={admission_rejected_cooldown}"
                ));
            }
            line
        }
        EventKind::Decision {
            interval,
            record,
            dist,
            fraction,
            new_fm,
            predicted_loss,
            wm_low,
            wm_high,
        } => format!(
            "interval={interval} record={record} dist={dist:.4} fraction={fraction:.3} \
             new_fm={new_fm} predicted_loss={} wm_low={wm_low} wm_high={wm_high}",
            pct(*predicted_loss)
        ),
        EventKind::IngestBatch {
            lines,
            samples,
            decisions,
            sessions_opened,
            sessions_closed,
        } => format!(
            "lines={lines} samples={samples} decisions={decisions} \
             opened={sessions_opened} closed={sessions_closed}"
        ),
        EventKind::SegmentLoad {
            segment,
            records,
            crc_checked,
            wall_ns,
        } => format!(
            "segment={segment} records={records} crc_checked={crc_checked} wall={}",
            human_ns(*wall_ns)
        ),
        EventKind::SegmentEvict { segment } => format!("segment={segment}"),
        EventKind::SweepCell {
            workload,
            policy,
            fraction,
            seed,
            wall_ns,
        } => format!(
            "{workload}/{policy} fraction={fraction:.3} seed={seed} wall={}",
            human_ns(*wall_ns)
        ),
        EventKind::Outcome {
            session,
            decision_interval,
            predicted,
            realized,
            abs_err,
        } => format!(
            "session={session} decision_interval={decision_interval} predicted={} \
             realized={} abs_err={}",
            pct(*predicted),
            pct(*realized),
            pct(*abs_err)
        ),
        EventKind::Drift {
            session,
            interval,
            ewma_err,
            action,
        } => format!(
            "session={session} interval={interval} ewma_err={ewma_err:+.4} action={action}"
        ),
        EventKind::ConnOpen { peer } => format!("peer={peer}"),
        EventKind::ConnClose {
            peer,
            sessions,
            samples,
            decisions,
        } => format!(
            "peer={peer} sessions={sessions} samples={samples} decisions={decisions}"
        ),
    };
    format!("[{:>10}] {:<13} {body}", human_ns(ev.t_ns), ev.kind.name())
}

fn span_line(j: &Journal) -> String {
    let span = match (j.events.first(), j.events.last()) {
        (Some(a), Some(b)) => human_ns(b.t_ns.saturating_sub(a.t_ns)),
        _ => "0ns".to_string(),
    };
    format!(
        "{} events ({} dropped from ring), span {span}",
        j.events.len(),
        j.dropped
    )
}

/// Every event in ring order, followed by the metric exposition.
pub fn render_dump(j: &Journal) -> String {
    let mut out = String::new();
    out.push_str(&span_line(j));
    out.push('\n');
    for ev in &j.events {
        out.push_str(&event_line(ev));
        out.push('\n');
    }
    out.push_str("\n== metrics ==\n");
    out.push_str(&j.metrics.render_prometheus());
    out
}

fn render_hist(name: &str, h: &HistSnapshot) -> String {
    let max = h.counts.iter().copied().max().unwrap_or(0).max(1);
    let mut out = format!("{name}  (count {}, sum {})\n", h.count, h.sum);
    for (i, &c) in h.counts.iter().enumerate() {
        let le = match h.bounds.get(i) {
            Some(b) => format!("{b}"),
            None => "+Inf".to_string(),
        };
        let bar = "#".repeat((c * 40 / max) as usize);
        out.push_str(&format!("  le {le:>12}  {c:>10}  {bar}\n"));
    }
    out
}

/// Per-phase breakdown, decision timeline with predicted loss, and
/// the journal's histograms.
pub fn render_summary(j: &Journal) -> String {
    let mut out = String::new();
    out.push_str(&span_line(j));
    out.push('\n');

    let phases = ["engine", "tuner", "service", "perfdb", "sweep", "outcome", "warn"];
    let mut t = Table::new("per-phase breakdown", &["phase", "events", "busy time"]);
    for phase in phases {
        let evs: Vec<&Event> = j.events.iter().filter(|e| e.kind.phase() == phase).collect();
        if evs.is_empty() {
            continue;
        }
        let busy: u64 = evs.iter().map(|e| e.kind.busy_ns()).sum();
        t.row(vec![
            phase.to_string(),
            evs.len().to_string(),
            human_ns(busy),
        ]);
    }
    out.push_str(&t.render());

    let decisions: Vec<&Event> = j
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Decision { .. }))
        .collect();
    if !decisions.is_empty() {
        let mut t = Table::new(
            "decision timeline",
            &["interval", "fraction", "new_fm", "predicted loss", "wm low", "wm high"],
        );
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for ev in &decisions {
            if let EventKind::Decision {
                interval,
                fraction,
                new_fm,
                predicted_loss,
                wm_low,
                wm_high,
                ..
            } = &ev.kind
            {
                t.row(vec![
                    interval.to_string(),
                    format!("{fraction:.3}"),
                    new_fm.to_string(),
                    pct(*predicted_loss),
                    wm_low.to_string(),
                    wm_high.to_string(),
                ]);
                xs.push(*interval as f64);
                ys.push(*predicted_loss);
            }
        }
        out.push_str(&t.render());
        if xs.len() >= 2 {
            out.push_str(&ascii_series("predicted loss", &xs, &ys, 6));
        }
    }

    if !j.metrics.hists.is_empty() {
        out.push_str("\n== histograms ==\n");
        for (name, h) in &j.metrics.hists {
            out.push_str(&render_hist(name, h));
        }
    }

    let warns: Vec<&Event> = j
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Warn { .. }))
        .collect();
    if !warns.is_empty() {
        out.push_str("\n== warnings ==\n");
        for ev in warns {
            out.push_str(&event_line(ev));
            out.push('\n');
        }
    }
    out
}

/// The `tuna obs outcomes` view: per-session predicted-vs-realized
/// decision timelines, absolute-error quantiles, the worst decisions
/// ranked by |error|, and the drift/re-tune transitions.
pub fn render_outcomes(j: &Journal) -> String {
    use std::collections::BTreeMap;

    let mut out = String::new();
    out.push_str(&span_line(j));
    out.push('\n');

    // (session, decision_interval, predicted, realized, abs_err),
    // grouped per session in ring (= decision) order.
    let mut by_session: BTreeMap<&str, Vec<(u32, f64, f64, f64)>> = BTreeMap::new();
    for ev in &j.events {
        if let EventKind::Outcome {
            session,
            decision_interval,
            predicted,
            realized,
            abs_err,
        } = &ev.kind
        {
            by_session
                .entry(session.as_str())
                .or_default()
                .push((*decision_interval, *predicted, *realized, *abs_err));
        }
    }
    if by_session.is_empty() {
        out.push_str(
            "no outcome events in this journal (record one with --retune observe|on)\n",
        );
        return out;
    }

    for (session, rows) in &by_session {
        let mut t = Table::new(
            &format!("session {session}: predicted vs realized"),
            &["decision interval", "predicted", "realized", "error"],
        );
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for &(di, p, r, _) in rows {
            t.row(vec![
                di.to_string(),
                pct(p),
                pct(r),
                format!("{:+.4}", r - p),
            ]);
            xs.push(di as f64);
            ys.push(r);
        }
        out.push_str(&t.render());
        if xs.len() >= 2 {
            out.push_str(&ascii_series("realized loss", &xs, &ys, 6));
        }
    }

    let mut errs: Vec<f64> = by_session
        .values()
        .flat_map(|rows| rows.iter().map(|&(_, _, _, e)| e))
        .collect();
    errs.sort_by(|a, b| a.total_cmp(b));
    let quantile = |f: f64| errs[((errs.len() - 1) as f64 * f).round() as usize];
    let mut t = Table::new("absolute prediction error quantiles", &["quantile", "abs err"]);
    for (name, f) in [("p50", 0.5), ("p90", 0.9), ("p99", 0.99), ("max", 1.0)] {
        t.row(vec![name.to_string(), format!("{:.4}", quantile(f))]);
    }
    out.push_str(&t.render());

    let mut worst: Vec<(&str, u32, f64, f64, f64)> = by_session
        .iter()
        .flat_map(|(s, rows)| rows.iter().map(move |&(di, p, r, e)| (*s, di, p, r, e)))
        .collect();
    worst.sort_by(|a, b| b.4.total_cmp(&a.4).then(a.1.cmp(&b.1)));
    worst.truncate(10);
    let mut t = Table::new(
        "worst decisions (by |realized - predicted|)",
        &["session", "decision interval", "predicted", "realized", "abs err"],
    );
    for (s, di, p, r, e) in &worst {
        t.row(vec![
            s.to_string(),
            di.to_string(),
            pct(*p),
            pct(*r),
            format!("{e:.4}"),
        ]);
    }
    out.push_str(&t.render());

    let drifts: Vec<&Event> = j
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Drift { .. }))
        .collect();
    if !drifts.is_empty() {
        out.push_str("\n== drift transitions ==\n");
        for ev in &drifts {
            out.push_str(&event_line(ev));
            out.push('\n');
        }
    }
    out.push_str(&format!(
        "{} outcome(s) across {} session(s), {} drift transition(s), {} retune(s)\n",
        errs.len(),
        by_session.len(),
        drifts.len(),
        j.metrics.counter("tuner_retunes_total")
    ));
    out
}

/// Metric families of two journals side by side with deltas (b - a).
pub fn render_diff(label_a: &str, a: &Journal, label_b: &str, b: &Journal) -> String {
    let mut out = String::new();
    out.push_str(&format!("a: {label_a} — {}\n", span_line(a)));
    out.push_str(&format!("b: {label_b} — {}\n", span_line(b)));

    let mut t = Table::new("metric diff (b - a)", &["metric", "a", "b", "delta"]);
    let mut changed = 0usize;
    let mut total = 0usize;

    let counter_names: BTreeSet<&String> = a
        .metrics
        .counters
        .keys()
        .chain(b.metrics.counters.keys())
        .collect();
    for name in counter_names {
        let va = a.metrics.counter(name);
        let vb = b.metrics.counter(name);
        let delta = vb as i128 - va as i128;
        total += 1;
        if delta != 0 {
            changed += 1;
        }
        t.row(vec![
            name.clone(),
            va.to_string(),
            vb.to_string(),
            format!("{delta:+}"),
        ]);
    }

    let gauge_names: BTreeSet<&String> = a
        .metrics
        .gauges
        .keys()
        .chain(b.metrics.gauges.keys())
        .collect();
    for name in gauge_names {
        let va = a.metrics.gauges.get(name).copied();
        let vb = b.metrics.gauges.get(name).copied();
        let cell = |v: Option<f64>| v.map(|v| format!("{v}")).unwrap_or_else(|| "-".to_string());
        let delta = vb.unwrap_or(0.0) - va.unwrap_or(0.0);
        total += 1;
        if delta != 0.0 || va.is_some() != vb.is_some() {
            changed += 1;
        }
        t.row(vec![name.clone(), cell(va), cell(vb), format!("{delta:+}")]);
    }

    let hist_names: BTreeSet<&String> = a
        .metrics
        .hists
        .keys()
        .chain(b.metrics.hists.keys())
        .collect();
    for name in hist_names {
        let ca = a.metrics.hists.get(name).map(|h| h.count).unwrap_or(0);
        let cb = b.metrics.hists.get(name).map(|h| h.count).unwrap_or(0);
        let delta = cb as i128 - ca as i128;
        total += 1;
        if delta != 0 {
            changed += 1;
        }
        t.row(vec![
            format!("{name}_count"),
            ca.to_string(),
            cb.to_string(),
            format!("{delta:+}"),
        ]);
    }

    out.push_str(&t.render());
    out.push_str(&format!("{changed} of {total} metric families differ\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Recorder;

    fn journal_with_decisions() -> Journal {
        let r = Recorder::enabled(32);
        r.count("tuner_decisions_total", 2);
        r.observe("engine_promoted_per_interval", crate::obs::PAGES_BUCKETS, 12.0);
        for i in 0..2u32 {
            r.record(EventKind::Decision {
                interval: 5 + i,
                record: 3,
                dist: 0.1,
                fraction: 0.8 - 0.1 * i as f64,
                new_fm: 1000 - 10 * i as u64,
                predicted_loss: 0.02 + 0.01 * i as f64,
                wm_low: 30,
                wm_high: 45,
            });
        }
        r.warn("render.test", "one warning");
        r.journal()
    }

    #[test]
    fn dump_and_summary_mention_key_content() {
        let j = journal_with_decisions();
        let dump = render_dump(&j);
        assert!(dump.contains("decision"));
        assert!(dump.contains("tuner_decisions_total 2"));
        let summary = render_summary(&j);
        assert!(summary.contains("per-phase breakdown"));
        assert!(summary.contains("decision timeline"));
        assert!(summary.contains("predicted loss"));
        assert!(summary.contains("engine_promoted_per_interval"));
        assert!(summary.contains("one warning"));
    }

    #[test]
    fn interval_lines_mention_admission_only_when_gated() {
        let interval = |adm: u64| EventKind::Interval {
            workload: "kv-drift".into(),
            policy: "tpp-gated".into(),
            interval: 1,
            wall_ns: 1.0e6,
            fast_used: 10,
            promoted: 2,
            demoted: 1,
            txn_aborts: 0,
            shadow_free_demotions: 0,
            admission_accepted: adm,
            admission_rejected_budget: 0,
            admission_rejected_payoff: 0,
            admission_rejected_cooldown: adm,
        };
        let r = Recorder::enabled(4);
        r.record(interval(0));
        let dump = render_dump(&r.journal());
        assert!(!dump.contains("adm_ok"), "ungated line must keep the old rendering");
        let r = Recorder::enabled(4);
        r.record(interval(3));
        let dump = render_dump(&r.journal());
        assert!(dump.contains("adm_ok=3"));
        assert!(dump.contains("adm_cooldown=3"));
    }

    #[test]
    fn outcomes_view_ranks_sessions_quantiles_and_drift() {
        let r = Recorder::enabled(32);
        r.count("tuner_retunes_total", 1);
        for (i, err) in [0.01, 0.08, 0.02].iter().enumerate() {
            r.record(EventKind::Outcome {
                session: "kv-drift@7".into(),
                decision_interval: 25 * (i as u32 + 1),
                predicted: 0.05,
                realized: 0.05 + err,
                abs_err: *err,
            });
        }
        r.record(EventKind::Drift {
            session: "kv-drift@7".into(),
            interval: 50,
            ewma_err: 0.05,
            action: "retune".into(),
        });
        let text = render_outcomes(&r.journal());
        assert!(text.contains("session kv-drift@7: predicted vs realized"));
        assert!(text.contains("absolute prediction error quantiles"));
        assert!(text.contains("worst decisions"));
        assert!(text.contains("action=retune"));
        assert!(text.contains("3 outcome(s) across 1 session(s), 1 drift transition(s), 1 retune(s)"));
        // the worst decision (abs_err 0.08, interval 50) ranks first
        let worst_at = text.find("worst decisions").unwrap();
        let after = &text[worst_at..];
        let i50 = after.find("50").unwrap();
        let i25 = after.find("25").unwrap();
        assert!(i50 < i25, "worst decision must rank first");

        let empty = render_outcomes(&Recorder::enabled(4).journal());
        assert!(empty.contains("no outcome events"));
    }

    #[test]
    fn diff_flags_changed_families() {
        let ra = Recorder::enabled(4);
        ra.count("x_total", 1);
        let rb = Recorder::enabled(4);
        rb.count("x_total", 3);
        rb.count("y_total", 1);
        let text = render_diff("a", &ra.journal(), "b", &rb.journal());
        assert!(text.contains("x_total"));
        assert!(text.contains("+2"));
        assert!(text.contains("y_total"));
        assert!(text.contains("metric families differ"));
    }
}
